"""Batch fast-path unit tests: vectorized water-filling, queue feeding,
and single-scenario equivalence with the event-driven simulator."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import MB, GB, netmodel, testbeds
from repro.core.types import FileSpec, TransferParams
from repro.core.baselines import _StaticOneChunkScheduler
from repro.core.chunking import partition_files
from repro.core.simulator import Simulation
from repro.data.filesets import uniform_files
from repro.eval.fabric import FabricSimulation as BatchSimulation
from repro.eval.scenarios import Scenario, build_simulation


def test_batchsim_shim_is_gone():
    """The `repro.eval.batchsim` deprecation shim was removed: importing
    it raises ModuleNotFoundError, and the package no longer exports the
    alias — `repro.eval.fabric.FabricSimulation` is the one NumPy driver."""
    import importlib
    import sys

    import repro.eval

    sys.modules.pop("repro.eval.batchsim", None)
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.eval.batchsim")
    with pytest.raises(AttributeError):
        repro.eval.BatchSimulation

# ------------------------------------------------------------------ #
# waterfill_batch == waterfill (the scalar reference)
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    caps=st.lists(
        st.floats(min_value=0.0, max_value=1e10), min_size=1, max_size=12
    ),
    pool=st.floats(min_value=0.0, max_value=5e10),
)
def test_waterfill_batch_matches_scalar(caps, pool):
    batch = netmodel.waterfill_batch(np.array([caps]), np.array([pool]))[0]
    scalar = netmodel.waterfill(caps, pool)
    assert batch.shape == (len(caps),)
    np.testing.assert_allclose(batch, scalar, rtol=1e-9, atol=1e-3)


def test_waterfill_batch_many_rows():
    rng = np.random.RandomState(0)
    caps = rng.uniform(0, 1e9, size=(64, 8))
    caps[rng.uniform(size=caps.shape) < 0.3] = 0.0  # idle channels
    pool = rng.uniform(0, 4e9, size=64)
    out = netmodel.waterfill_batch(caps, pool)
    for i in range(64):
        np.testing.assert_allclose(
            out[i], netmodel.waterfill(list(caps[i]), pool[i]),
            rtol=1e-9, atol=1e-3,
        )
    # conservation: never allocate more than pool nor more than caps
    assert (out <= caps + 1e-6).all()
    assert (out.sum(axis=1) <= pool + 1e-3).all()


# ------------------------------------------------------------------ #
# single-scenario equivalence with the event simulator
# ------------------------------------------------------------------ #


def _event_and_batch(files, net, pp, p, cc):
    def mk():
        chunks = partition_files(files, net, 1)
        sched = _StaticOneChunkScheduler(
            chunks, net, cc,
            TransferParams(pipelining=pp, parallelism=p, concurrency=cc),
        )
        return Simulation(sched.chunks, net, sched, tick_period=5.0)

    event = mk().run()
    batch = BatchSimulation([mk()]).run()[0]
    return event, batch


@pytest.mark.parametrize(
    "n,size,pp,p,cc",
    [
        (40, 4 * MB, 4, 1, 4),
        (6, 2 * GB, 0, 4, 2),
        (25, 64 * MB, 2, 2, 8),
        (1, 512 * MB, 0, 1, 1),
    ],
)
def test_batch_matches_event_static(n, size, pp, p, cc):
    ev, ba = _event_and_batch(uniform_files(n, size), testbeds.XSEDE, pp, p, cc)
    assert ba.total_bytes == ev.total_bytes
    assert ba.total_time == pytest.approx(ev.total_time, rel=1e-9)
    assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=20),
    size=st.integers(min_value=1, max_value=int(1 * GB)),
    cc=st.integers(min_value=1, max_value=10),
)
def test_batch_matches_event_property(n, size, cc):
    ev, ba = _event_and_batch(
        uniform_files(n, size), testbeds.STAMPEDE_COMET, 2, 2, cc
    )
    assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9)
    assert sum(ba.per_chunk_bytes.values()) == pytest.approx(
        sum(ev.per_chunk_bytes.values()), rel=1e-9
    )


def test_batch_adaptive_schedulers_match_event():
    for algo in ("sc", "mc", "promc"):
        sc = Scenario(
            network=testbeds.BLUEWATERS_STAMPEDE.name,
            dataset="mixed",
            algorithm=algo,
        )
        ev = build_simulation(sc).run()
        ba = BatchSimulation([build_simulation(sc)], names=[sc.name]).run()[0]
        assert ba.throughput == pytest.approx(ev.throughput, rel=1e-6), algo
        assert ba.n_moves == ev.n_moves, algo


def test_batch_runs_disjoint_scenarios_together():
    """Scenarios of different sizes/chunk counts coexist in one batch and
    each matches its solo event run."""
    scs = [
        Scenario(network=testbeds.LAN.name, dataset="uniform_small",
                 algorithm="untuned"),
        Scenario(network=testbeds.XSEDE.name, dataset="uniform_huge",
                 algorithm="promc", max_cc=4),
        Scenario(network=testbeds.LONI.name, dataset="des",
                 algorithm="globus"),
    ]
    batch = BatchSimulation(
        [build_simulation(s) for s in scs], names=[s.name for s in scs]
    ).run()
    for s, ba in zip(scs, batch):
        ev = build_simulation(s).run()
        assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9), s.name
