"""Simulator behaviour + the paper's qualitative claims as assertions."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import GB, MB, FileSpec, TransferParams, run_transfer
from repro.core import testbeds
from repro.core.baselines import _StaticOneChunkScheduler
from repro.core.chunking import partition_files
from repro.core.simulator import Simulation
from repro.data.filesets import (
    dark_energy_survey,
    genome_sequencing,
    mixed_dataset,
    uniform_files,
)


def fixed_run(net, files, pp, p, cc, **kw):
    chunks = partition_files(files, net, 1)
    sched = _StaticOneChunkScheduler(
        chunks, net, cc, TransferParams(pipelining=pp, parallelism=p, concurrency=cc)
    )
    return Simulation(sched.chunks, net, sched, tick_period=5.0, **kw).run()


SMALL = uniform_files(200, 1 * MB)
HUGE = uniform_files(8, 10 * GB)


# ------------------------------------------------------------------ #
# conservation / sanity
# ------------------------------------------------------------------ #


def test_all_bytes_delivered():
    r = fixed_run(testbeds.XSEDE, SMALL, 4, 1, 4)
    assert r.total_bytes == 200 * MB
    assert r.throughput > 0
    assert r.total_time > 0


def test_throughput_never_exceeds_link():
    for net in (testbeds.XSEDE, testbeds.LONI, testbeds.LAN):
        r = fixed_run(net, HUGE, 0, 4, 8)
        assert r.throughput <= net.bandwidth * (1 + 1e-9)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=30),
    size=st.integers(min_value=1, max_value=int(2 * GB)),
    pp=st.integers(min_value=0, max_value=16),
    p=st.integers(min_value=1, max_value=8),
    cc=st.integers(min_value=1, max_value=12),
)
def test_simulation_terminates_and_conserves(n, size, pp, p, cc):
    files = uniform_files(n, size)
    r = fixed_run(testbeds.STAMPEDE_COMET, files, pp, p, cc)
    assert r.total_bytes == n * size
    assert r.throughput <= testbeds.STAMPEDE_COMET.bandwidth * (1 + 1e-9)


@settings(max_examples=20, deadline=None)
@given(algo=st.sampled_from(["sc", "mc", "promc", "globus", "untuned"]))
def test_algorithms_complete_mixed_dataset(algo):
    files = mixed_dataset(scale=0.01)
    r = run_transfer(files, testbeds.STAMPEDE_COMET, algo, max_cc=6)
    assert r.total_bytes == sum(f.size for f in files)


# ------------------------------------------------------------------ #
# paper claims (Figs. 1-2): individual parameter effects
# ------------------------------------------------------------------ #


def test_pipelining_helps_small_files_up_to_2x():
    """Fig. 1a: up to ~2x on small files on XSEDE."""
    base = fixed_run(testbeds.XSEDE, SMALL, 0, 1, 1).throughput
    deep = fixed_run(testbeds.XSEDE, SMALL, 16, 1, 1).throughput
    assert 1.5 <= deep / base <= 2.3


def test_pipelining_negligible_for_large_files():
    """Fig. 1a: impact becomes negligible for large files."""
    base = fixed_run(testbeds.XSEDE, HUGE, 0, 1, 1).throughput
    deep = fixed_run(testbeds.XSEDE, HUGE, 16, 1, 1).throughput
    assert deep / base < 1.05


def test_parallelism_helps_large_files_on_buffer_limited_path():
    """Fig. 1b: XSEDE buffer (32 MB) < BDP (75 MB) => parallel streams win."""
    base = fixed_run(testbeds.XSEDE, HUGE, 0, 1, 1).throughput
    par = fixed_run(testbeds.XSEDE, HUGE, 0, 4, 1).throughput
    assert par / base > 1.3


def test_parallelism_useless_for_small_files():
    """Fig. 1b: no impact (if not negative) on small files."""
    base = fixed_run(testbeds.XSEDE, SMALL, 0, 1, 1).throughput
    par = fixed_run(testbeds.XSEDE, SMALL, 0, 8, 1).throughput
    assert par / base < 1.05


def test_parallelism_unneeded_when_buffer_exceeds_bdp():
    """LONI: BDP ~12.5 MB < 16 MB buffer => no window limitation."""
    base = fixed_run(testbeds.LONI, HUGE, 0, 1, 1).throughput
    par = fixed_run(testbeds.LONI, HUGE, 0, 4, 1).throughput
    assert par / base < 1.1


def test_concurrency_helps_both_small_and_large():
    """Fig. 1c: concurrency is the most broadly effective parameter."""
    for files in (SMALL, HUGE):
        one = fixed_run(testbeds.XSEDE, files, 0, 1, 1).throughput
        eight = fixed_run(testbeds.XSEDE, files, 0, 1, 8).throughput
        assert eight / one > 3.0


def test_concurrency_declines_past_disk_saturation():
    """Fig. 9a: throughput decreases after CC 8 (disk overload)."""
    des = dark_energy_survey(scale=0.1)
    at8 = run_transfer(des, testbeds.BLUEWATERS_STAMPEDE, "mc", max_cc=8)
    at16 = run_transfer(des, testbeds.BLUEWATERS_STAMPEDE, "mc", max_cc=16)
    assert at16.throughput < at8.throughput


# ------------------------------------------------------------------ #
# paper claims (Sec. 4): algorithm comparisons
# ------------------------------------------------------------------ #


def test_mc_promc_beat_sc_and_globus_on_des():
    """Fig. 9a ordering: MC/ProMC > Globus > SC-ish > untuned."""
    des = dark_energy_survey(scale=0.1)
    net = testbeds.BLUEWATERS_STAMPEDE
    r = {
        a: run_transfer(des, net, a, max_cc=8).throughput
        for a in ("untuned", "globus", "sc", "mc", "promc")
    }
    assert r["mc"] > r["globus"] > r["untuned"]
    assert r["promc"] > r["globus"]
    assert r["mc"] > r["sc"]
    # ~22 Gbps at CC=8 in the paper; we land in the same regime
    assert r["mc"] * 8 / 1e9 > 15


def test_sc_self_limits_concurrency_on_wan():
    """Sec. 4.1: SC's concurrency eq. returns 2 for Medium+ chunks when
    RTT < 100 ms, so SC plateaus regardless of maxCC."""
    des = dark_energy_survey(scale=0.1)
    net = testbeds.BLUEWATERS_STAMPEDE
    at4 = run_transfer(des, net, "sc", max_cc=4).throughput
    at16 = run_transfer(des, net, "sc", max_cc=16).throughput
    assert at16 / at4 < 1.1


def test_genome_sc_competitive():
    """Fig. 10: on the small-file genome dataset SC performs closer to
    MC/ProMC (concurrency calc returns high values for small avg size)."""
    gen = genome_sequencing(scale=0.005)
    net = testbeds.STAMPEDE_COMET
    sc = run_transfer(gen, net, "sc", max_cc=16).throughput
    mc = run_transfer(gen, net, "mc", max_cc=16).throughput
    assert sc / mc > 0.6


def test_order_of_magnitude_win_over_untuned():
    """Abstract: up to 10x over baseline — realized on small-file datasets."""
    gen = genome_sequencing(scale=0.005)
    net = testbeds.STAMPEDE_COMET
    untuned = run_transfer(gen, net, "untuned", max_cc=16).throughput
    mc = run_transfer(gen, net, "mc", max_cc=16).throughput
    assert mc / untuned > 8.0


def test_globus_connect_personal_lan_degradation():
    """Fig. 13: GCP ~500 Mbps while ours exceed 2 Gbps."""
    mx = mixed_dataset(scale=0.02)
    gcp = run_transfer(mx, testbeds.LAN, "globus", max_cc=4, connect_personal=True)
    ours = run_transfer(mx, testbeds.LAN, "mc", max_cc=4)
    assert gcp.throughput * 8 / 1e9 < 1.0
    assert ours.throughput * 8 / 1e9 > 2.0
    assert ours.throughput / gcp.throughput > 3.0


def test_chunked_beats_one_chunk_for_sc():
    """Sec. 4.1: 1-chunk SC is worse than 2-chunk SC on mixed data."""
    mx = mixed_dataset(scale=0.02)
    net = testbeds.STAMPEDE_COMET
    one = run_transfer(mx, net, "sc", max_cc=8, num_chunks=1).throughput
    two = run_transfer(mx, net, "sc", max_cc=8, num_chunks=2).throughput
    assert two >= one * 0.98  # never meaningfully worse
    # and for small maxCC the gap is visible for MC (paper: up to 20%)
    one_mc = run_transfer(mx, net, "mc", max_cc=2, num_chunks=1).throughput
    two_mc = run_transfer(mx, net, "mc", max_cc=4, num_chunks=2).throughput
    assert two_mc > one_mc


def test_scheduler_never_strands_work():
    """Regression: ProMC once left a chunk with residual bytes forever."""
    files = mixed_dataset(scale=0.03)
    r = run_transfer(files, testbeds.STAMPEDE_COMET, "promc", max_cc=16)
    assert r.total_bytes == sum(f.size for f in files)
