"""Distributed runtime tests: sharding rules, grad-sync plans, compression,
fault tolerance. Multi-device tests run on 8 host-platform devices via a
subprocess (so the main test process keeps 1 device)."""
import math
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import testbeds
from repro.core.types import ChunkType
from repro.distributed import compression, grad_sync
from repro.distributed.fault import (
    MeshPlan,
    RestartPolicy,
    StragglerDetector,
    elastic_mesh_plans,
    reallocate_channels_for_straggler,
)
from repro.distributed.sharding import DEFAULT_RULES, ShardingCtx, abstract_mesh
from repro.launch.mesh import set_mesh

# ------------------------------------------------------------------ #
# sharding rules
# ------------------------------------------------------------------ #


def _ctx(shape=(2, 2, 2), axes=("pod", "data", "model"), manual=frozenset()):
    # AbstractMesh: shape-only (rule resolution never touches devices);
    # abstract_mesh() papers over the 0.4.x vs newer constructor signatures
    mesh = abstract_mesh(shape, axes)
    return ShardingCtx(mesh=mesh, rules=dict(DEFAULT_RULES), manual_axes=manual)


def test_resolve_divisibility_fallback():
    ctx = _ctx((1, 2, 16), ("pod", "data", "model"))
    # 4 heads cannot shard over 16-way model axis -> replicated
    spec = ctx.resolve(("batch", "seq", "heads", None), (8, 128, 4, 256))
    assert spec[2] is None
    # 6912 mlp shards fine
    spec = ctx.resolve(("batch", "seq", "mlp"), (8, 128, 6912))
    assert spec[2] == "model"


def test_resolve_axis_used_once():
    ctx = _ctx((1, 2, 2), ("pod", "data", "model"))
    spec = ctx.resolve(("heads", "kv"), (4, 4))  # both want "model"
    assert spec[0] == "model" and spec[1] is None


def test_resolve_strips_manual_axes():
    ctx = _ctx((2, 2, 2), manual=frozenset({"pod"}))
    spec = ctx.resolve(("batch",), (8,))
    assert spec[0] == "data"  # ("pod","data") with pod stripped


def test_resolve_missing_axis():
    ctx = _ctx((2, 2), ("data", "model"))
    spec = ctx.resolve(("batch",), (8,))
    assert spec[0] == "data"


# ------------------------------------------------------------------ #
# grad-sync plans
# ------------------------------------------------------------------ #


def _fake_grads():
    return {
        "layers": {
            "w_big": jax.ShapeDtypeStruct((64, 4096, 4096), jnp.float32),  # 4.3GB
            "w_mid": jax.ShapeDtypeStruct((64, 512, 512), jnp.float32),  # 67MB
            "norm": jax.ShapeDtypeStruct((64, 4096), jnp.float32),  # 1MB
        },
        "embed": {"tok": jax.ShapeDtypeStruct((32000, 4096), jnp.float32)},
    }


def test_plan_chunks_and_params():
    plan = grad_sync.build_sync_plan(_fake_grads(), max_cc=8, num_chunks=4)
    assert len(plan.chunks) >= 2
    total = sum(c.total_bytes for c in plan.chunks)
    want = sum(
        int(np.prod(l.shape)) * 4
        for l in jax.tree.leaves(_fake_grads())
    )
    assert total == want
    # every chunk got Algorithm-1 params
    for c in plan.chunks:
        assert c.params is not None
        assert c.params.concurrency >= 1


def test_plan_slices_large_tensors():
    plan = grad_sync.build_sync_plan(_fake_grads(), max_cc=8, num_chunks=4)
    # the 4.3 GB tensor belongs to a chunk with parallelism > 1 on the DCN
    # (BDP 12.5MB / window 4MB => 4 streams) and divides on axis 0
    assert plan.slicing["layers/w_big"] > 1
    assert plan.slicing["layers/norm"] == 1


def test_plan_order_covers_everything_once():
    plan = grad_sync.build_sync_plan(_fake_grads(), max_cc=8)
    seen = {}
    for item in plan.order:
        key = (item.path, item.slice_idx)
        assert key not in seen
        seen[key] = True
    paths = {p for p, _ in seen}
    assert paths == {
        "layers/w_big", "layers/w_mid", "layers/norm", "embed/tok"
    }


def test_plan_compression_classes():
    plan = grad_sync.build_sync_plan(_fake_grads(), max_cc=8, num_chunks=4)
    for item in plan.order:
        if item.chunk_type == ChunkType.SMALL:
            assert item.compress == "none"  # latency-bound: keep fp32


def test_sc_plan_is_sequential():
    plan = grad_sync.build_sync_plan(_fake_grads(), algorithm="sc")
    types = [i.chunk_type for i in plan.order]
    # all items of one chunk type appear contiguously
    seen = []
    for t in types:
        if not seen or seen[-1] != t:
            seen.append(t)
    assert len(seen) == len(set(seen))


def test_simulate_sync_schedules():
    shapes = _fake_grads()
    naive = grad_sync.simulate_sync(
        shapes, algorithm="sc", max_cc=1, num_chunks=1,
        compress_by_class=grad_sync.NO_COMPRESSION,
    )
    tuned = grad_sync.simulate_sync(shapes, algorithm="promc", max_cc=8)
    assert tuned.total_time < naive.total_time
    # compression halves the big-bucket bytes => visibly faster sync
    uncompressed = grad_sync.simulate_sync(
        shapes, algorithm="promc", max_cc=8,
        compress_by_class=grad_sync.NO_COMPRESSION,
    )
    assert tuned.total_time < uncompressed.total_time


# ------------------------------------------------------------------ #
# numerical equivalence on 8 devices (subprocess: isolated device count)
# ------------------------------------------------------------------ #

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.config import reduce_for_smoke
    from repro.models.model import build_model
    from repro.train.train_step import StepConfig, init_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig
    from repro.data.synthetic import SyntheticLM, DataConfig
    from repro.launch.mesh import set_mesh

    cfg = reduce_for_smoke(get_config("llama3.2-3b"))
    model = build_model(cfg)
    batch = {k: jnp.asarray(v) for k, v in
             next(SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=32)).batches(1)).items()}
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=50)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with set_mesh(mesh):
        outs = {}
        for name, scfg in {
            "naive": StepConfig(optimizer=opt, sync_algorithm="naive"),
            "promc": StepConfig(optimizer=opt, sync_algorithm="promc", compress=False),
            "mc": StepConfig(optimizer=opt, sync_algorithm="mc", compress=False),
            "promc_comp": StepConfig(optimizer=opt, sync_algorithm="promc", compress=True),
        }.items():
            step = jax.jit(make_train_step(model, scfg, mesh=mesh, multi_pod=True))
            st = init_train_state(model, jax.random.PRNGKey(0))
            st, m = step(st, batch)
            outs[name] = (st["params"], float(m["loss"]))
    ref_p, ref_l = outs["naive"]
    for name in ("promc", "mc"):
        p, l = outs[name]
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            ref_p, p)))
        assert diff == 0.0, f"{name} diverged from naive: {diff}"
        assert abs(l - ref_l) < 1e-6
    # compressed path close but not identical
    p, l = outs["promc_comp"]
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        ref_p, p)))
    assert diff < 5e-3, f"compressed sync too far from exact: {diff}"
    print("SUBPROCESS_OK")
    """
)


@pytest.mark.slow
def test_multipod_sync_matches_naive_8dev():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr


# ------------------------------------------------------------------ #
# compression
# ------------------------------------------------------------------ #


def test_int8_error_feedback_reduces_bias():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1024,)) * 1e-3
    # without EF, repeated quantization of the same gradient keeps the same
    # error; with EF the accumulated average converges to the true value.
    ef = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for i in range(20):
        q, s, ef = compression.int8_encode(g, ef)
        acc = acc + compression.int8_decode(q.astype(jnp.int32), s)
    err_ef = float(jnp.linalg.norm(acc / 20 - g) / jnp.linalg.norm(g))
    q, s, _ = compression.int8_encode(g)
    one = compression.int8_decode(q.astype(jnp.int32), s)
    err_once = float(jnp.linalg.norm(one - g) / jnp.linalg.norm(g))
    assert err_ef < err_once * 0.5


def test_bf16_roundtrip_error_small():
    g = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    rel = float(compression.compression_error(g, "bf16"))
    assert rel < 5e-3


# ------------------------------------------------------------------ #
# fault tolerance
# ------------------------------------------------------------------ #


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(tau=1.5, patience=3)
    for w in range(5):
        for h in range(4):
            det.record(f"h{h}", 1.0)
        det.record("h4", 3.0)
        flagged = det.update_flags()
    assert "h4" in flagged
    assert all(f == "h4" for f in flagged)


def test_straggler_needs_patience():
    det = StragglerDetector(tau=1.5, patience=3)
    for h in range(4):
        det.record(f"h{h}", 1.0)
    det.record("h4", 5.0)
    assert det.update_flags() == []  # only one window


def test_channel_reallocation_conserves():
    alloc = {"pod0": 4, "pod1": 4}
    out = reallocate_channels_for_straggler(alloc, "pod0")
    assert sum(out.values()) == 8
    assert out["pod0"] == 3 and out["pod1"] == 5


def test_restart_policy_backoff_and_exhaustion():
    p = RestartPolicy(max_failures=3, backoff_base=1.0, backoff_cap=10.0)
    delays = [p.next_delay() for _ in range(4)]
    assert delays[:3] == [1.0, 2.0, 4.0]
    assert delays[3] is None


def test_elastic_mesh_plans():
    plans = elastic_mesh_plans(2, 256, lost_pods=1)
    assert plans and plans[0].axes == ("data", "model")
    assert plans[0].chips <= 256
    plans = elastic_mesh_plans(2, 256, lost_chips_in_pod=16)
    assert plans[0].shape[0] == 2  # pod axis preserved
    assert plans[0].chips <= 2 * 240
