"""Strategies for the offline hypothesis shim (see package docstring).

Each strategy is a tiny object with ``example(rng) -> value``. Draws bias
toward boundary values (the endpoints of integer/float ranges, empty/full
lists) because that is where the real library finds most bugs; the bias
keeps the shim useful as a regression net, not just a smoke loop.
"""
from __future__ import annotations

import math
from random import Random
from typing import Callable, List, Optional, Sequence

__all__ = [
    "SearchStrategy",
    "integers",
    "floats",
    "booleans",
    "just",
    "none",
    "sampled_from",
    "one_of",
    "lists",
    "tuples",
    "Random",
]

#: probability that a bounded scalar draw returns a range endpoint
_EDGE_P = 0.15


class SearchStrategy:
    def __init__(self, draw: Callable[[Random], object], label: str = "strategy"):
        self._draw = draw
        self._label = label

    def example(self, rng: Optional[Random] = None):
        return self._draw(rng if rng is not None else Random())

    def map(self, fn: Callable) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)), f"{self._label}.map")

    def filter(self, pred: Callable) -> "SearchStrategy":
        def draw(rng: Random):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError(f"{self._label}.filter found no passing example")

        return SearchStrategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return f"<shim {self._label}>"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    if min_value > max_value:
        raise ValueError("min_value > max_value")

    def draw(rng: Random) -> int:
        r = rng.random()
        if r < _EDGE_P / 2:
            return min_value
        if r < _EDGE_P:
            return max_value
        return rng.randint(min_value, max_value)

    return SearchStrategy(draw, f"integers({min_value}, {max_value})")


def floats(
    min_value: float,
    max_value: float,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> SearchStrategy:
    if not (min_value <= max_value):
        raise ValueError("min_value > max_value")

    log_spread = min_value > 0 and max_value / min_value > 1e3

    def draw(rng: Random) -> float:
        r = rng.random()
        if r < _EDGE_P / 2:
            return float(min_value)
        if r < _EDGE_P:
            return float(max_value)
        if log_spread and rng.random() < 0.5:
            # wide positive ranges: half the draws log-uniform so tiny
            # magnitudes are actually exercised
            return float(
                math.exp(rng.uniform(math.log(min_value), math.log(max_value)))
            )
        return rng.uniform(min_value, max_value)

    return SearchStrategy(draw, f"floats({min_value}, {max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def none() -> SearchStrategy:
    return just(None)


def sampled_from(elements: Sequence) -> SearchStrategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))], "sampled_from")


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    if not strategies:
        raise ValueError("one_of requires at least one strategy")
    return SearchStrategy(
        lambda rng: strategies[rng.randrange(len(strategies))].example(rng),
        "one_of",
    )


def lists(
    elements: SearchStrategy,
    min_size: int = 0,
    max_size: Optional[int] = None,
    unique_by: Optional[Callable] = None,
) -> SearchStrategy:
    cap = max_size if max_size is not None else min_size + 10

    def draw(rng: Random) -> List:
        r = rng.random()
        if r < _EDGE_P / 2:
            n = min_size
        elif r < _EDGE_P:
            n = cap
        else:
            n = rng.randint(min_size, cap)
        out: List = []
        seen = set()
        attempts = 0
        while len(out) < n and attempts < 200 + 50 * n:
            attempts += 1
            v = elements.example(rng)
            if unique_by is not None:
                k = unique_by(v)
                if k in seen:
                    continue
                seen.add(k)
            out.append(v)
        if len(out) < min_size:
            raise ValueError("lists(): could not satisfy uniqueness constraint")
        return out

    return SearchStrategy(draw, f"lists(min={min_size}, max={max_size})")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies), "tuples"
    )
