"""Minimal offline stand-in for the ``hypothesis`` property-testing library.

This package is only importable when the real ``hypothesis`` distribution is
absent: ``tests/conftest.py`` appends ``tests/_compat`` to ``sys.path`` iff
``importlib.util.find_spec("hypothesis")`` fails, so an installed hypothesis
always wins.

Scope: exactly the surface the repo's property tests use —
``@given(**strategies)``, ``@settings(max_examples=..., deadline=...)``,
``assume``, and the strategies in :mod:`hypothesis.strategies`
(integers/floats/lists/tuples/sampled_from/booleans/just/one_of).

Semantics: each test runs ``max_examples`` times with values drawn from a
PRNG seeded from the test's qualified name, so runs are deterministic across
processes and machines. There is no shrinking and no example database; a
failing example's kwargs are attached to the assertion message instead.
"""
from __future__ import annotations

import functools
import zlib

from . import strategies
from .strategies import Random

__all__ = ["given", "settings", "assume", "example", "HealthCheck", "strategies"]

#: real hypothesis exposes a version; some tooling sniffs it
__version__ = "0.0-repro-compat-shim"

_DEFAULT_MAX_EXAMPLES = 100


class _Unsatisfied(Exception):
    """Raised by assume(False); the current example is silently skipped."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:
    """No-op placeholders (the shim has no health checks to suppress)."""

    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much, cls.data_too_large]


class settings:
    """Decorator recording run parameters for a ``@given`` wrapper.

    Usable above or below ``@given`` (both orders appear in the wild); only
    ``max_examples`` matters to the shim, the rest is accepted and ignored.
    """

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def example(*_args, **_kwargs):
    """Accepted for API compatibility; explicit examples are not replayed."""

    def deco(fn):
        return fn

    return deco


class _HypothesisHandle:
    """Mimics hypothesis's per-test handle (plugins read .inner_test)."""

    def __init__(self, inner_test):
        self.inner_test = inner_test


def given(*arg_strategies, **kw_strategies):
    if arg_strategies and kw_strategies:
        raise TypeError("shim @given supports all-positional or all-keyword")

    def deco(fn):
        inner = getattr(fn, "_shim_inner", fn)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", None) or getattr(
                inner, "_shim_settings", None
            )
            max_examples = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = Random(zlib.crc32(fn.__qualname__.encode("utf-8")))
            ran = 0
            attempts = 0
            while ran < max_examples and attempts < max_examples * 20:
                attempts += 1
                if arg_strategies:
                    drawn_args = [s.example(rng) for s in arg_strategies]
                    drawn_kw = {}
                else:
                    drawn_args = []
                    drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    inner(*args, *drawn_args, **kwargs, **drawn_kw)
                except _Unsatisfied:
                    continue
                except AssertionError as e:
                    shown = drawn_kw if drawn_kw else tuple(drawn_args)
                    raise AssertionError(
                        f"falsifying example (shim, try #{attempts}): {shown!r}"
                    ) from e
                ran += 1
            return None

        # pytest must not see the strategy parameters as fixtures: drop the
        # __wrapped__ link functools.wraps installed so inspect.signature
        # reports the wrapper's own (*args, **kwargs).
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper._shim_inner = inner
        # pytest plugins (anyio, hypothesis's own) sniff fn.hypothesis.inner_test
        wrapper.hypothesis = _HypothesisHandle(inner)
        return wrapper

    return deco
