"""Autotuner subsystem tests: grid-vs-scalar-loop equivalence, the
successive-halving budget/monotonicity contract, history round-trips,
the bounded-regret property, and the zero-host-round invariant for the
static controller kind."""
import json
import os

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import testbeds
from repro.eval.runner import run_matrix, run_scenario
from repro.eval.scenarios import Scenario, expand_candidates, smoke_matrix
from repro.eval.tune import (
    HistoryStore,
    hill_climb,
    oracle_search,
    regret_report,
    successive_halving,
)
from repro.eval.tune.oracle import candidate_lists, context_key
from repro.eval.tune.space import param_space, scenario_space

#: adaptivity bonus bound (see test_oracle_dominates_heuristics_bounded):
#: multi-chunk adaptive schedulers may legitimately beat EVERY static
#: setting — per-chunk parameters and online re-allocation are exactly
#: what one static triple cannot express — but only by a bounded margin.
#: Measured maximum over the property pool: ~1.25 (MC/ProMC on mixed
#: datasets under a tight maxCC=4 budget, where the per-size-class split
#: is worth the most); densifying the grid does not close it, so it is a
#: real adaptivity edge, not search error.
ADAPTIVITY_BONUS = 1.30


def _smoke_slice(n):
    return smoke_matrix()[:n]


# ------------------------------------------------------------------ #
# search space
# ------------------------------------------------------------------ #


def test_param_space_meets_candidate_budget_on_every_smoke_scenario():
    for sc in smoke_matrix():
        sp = scenario_space(sc, n_candidates=64)
        assert sp.size >= 64, (sc.name, sp)
        # axes stay inside the admissible range and are strictly sorted
        assert sp.pp_axis[0] == 0
        assert sp.par_axis[0] == 1 and sp.cc_axis[0] == 1
        assert sp.cc_axis[-1] <= sc.max_cc
        for axis in (sp.pp_axis, sp.par_axis, sp.cc_axis):
            assert list(axis) == sorted(set(axis))


def test_param_space_pins_disk_saturation_cc():
    net = testbeds.TESTBEDS[testbeds.BLUEWATERS_STAMPEDE.name]
    sp = param_space(net, max_cc=32, avg_file_size=64 * 1024**2)
    if 1 < net.disk.saturation_cc < 32:
        assert net.disk.saturation_cc in sp.cc_axis


def test_untuned_baseline_is_always_a_candidate():
    """(0, 1, 1) — the 10x-claim baseline — is in every default grid
    (axis endpoints are kept), so the oracle dominates it by
    construction."""
    for sc in _smoke_slice(6):
        sp = scenario_space(sc, n_candidates=64)
        assert 0 in sp.pp_axis and 1 in sp.par_axis and 1 in sp.cc_axis


def test_space_neighbors_stay_in_bounds():
    sp = scenario_space(smoke_matrix()[0], n_candidates=64)
    for idx in [(0, 0, 0), tuple(s - 1 for s in sp.shape)]:
        for nb in sp.neighbors(idx):
            assert all(0 <= nb[a] < sp.shape[a] for a in range(3))
            assert sum(abs(nb[a] - idx[a]) for a in range(3)) == 1


# ------------------------------------------------------------------ #
# oracle: grid == scalar loop
# ------------------------------------------------------------------ #


def test_oracle_argmax_matches_scalar_candidate_loop():
    """The batched candidate-expanded sweep must pick exactly the argmax
    a plain per-scenario Python loop over candidates picks (and report
    exactly its throughputs)."""
    scenarios = _smoke_slice(4)
    oracle = oracle_search(scenarios, backend="numpy", n_candidates=16)
    _, _, cands = candidate_lists(scenarios, n_candidates=16)
    for sc in scenarios:
        key = context_key(sc)
        table = oracle.tables[key]
        assert list(table.candidates) == cands[key]
        loop_thr = [
            run_scenario(row, backend="numpy").throughput
            for row in expand_candidates([sc], cands[key])
        ]
        assert list(table.throughputs) == pytest.approx(loop_thr, rel=1e-12)
        assert table.best_index == int(np.argmax(loop_thr))


def test_oracle_dedups_shared_contexts():
    """Scenarios differing only in scheduler / num_chunks share one
    candidate table (the static objective ignores both fields)."""
    base = smoke_matrix()[0]
    import dataclasses

    variants = [
        base,
        dataclasses.replace(base, algorithm="mc"),
        dataclasses.replace(base, algorithm="promc", num_chunks=2),
    ]
    oracle = oracle_search(variants, backend="numpy", n_candidates=16)
    assert len(oracle.tables) == 1
    assert len({e.best_params for e in oracle.entries}) == 1
    # evals == one context's candidate count, not 3x
    assert oracle.evals == len(next(iter(oracle.tables.values())).candidates)


# ------------------------------------------------------------------ #
# successive halving: monotonicity + the budget/quality acceptance bar
# ------------------------------------------------------------------ #


def test_successive_halving_monotone_and_within_bar():
    """On the smoke matrix: every rung shrinks a *nested* survivor set
    that keeps the rung argmax, fidelity fractions are non-decreasing
    with a full-fidelity final rung — and the result lands within 5% of
    the oracle's throughput on every context for less than 1/4 of the
    oracle's (full-fidelity-equivalent) candidate evaluations."""
    scenarios = smoke_matrix()
    oracle = oracle_search(scenarios, backend="numpy", n_candidates=64)
    sha = successive_halving(scenarios, backend="numpy", n_candidates=64)

    for key, rungs in sha.trace.items():
        prev_kept = None
        prev_frac = 0.0
        for rung in rungs:
            evaluated = set(rung["evaluated"])
            kept = rung["kept"]
            assert set(kept) <= evaluated
            assert 0 < len(kept) <= len(evaluated)
            if prev_kept is not None:
                # survivors only ever shrink (nested selection)
                assert evaluated <= prev_kept
                assert len(kept) < len(prev_kept)
            assert rung["fraction"] >= prev_frac
            prev_kept, prev_frac = set(kept), rung["fraction"]
        assert rungs[-1]["fraction"] == 1.0

    by_ctx = {e.context: e for e in oracle.entries}
    for entry in sha.entries:
        ratio = entry.best_throughput / by_ctx[entry.context].best_throughput
        assert ratio >= 0.95, (entry.scenario, ratio)
    assert sha.equivalent_evals <= oracle.evals / 4.0, (
        sha.equivalent_evals, oracle.evals,
    )


def test_hill_climb_reaches_oracle_on_slice():
    scenarios = _smoke_slice(6)
    oracle = oracle_search(scenarios, backend="numpy", n_candidates=64)
    hill = hill_climb(scenarios, backend="numpy", n_candidates=64)
    by_ctx = {e.context: e for e in oracle.entries}
    for entry in hill.entries:
        ratio = entry.best_throughput / by_ctx[entry.context].best_throughput
        assert ratio >= 0.95, (entry.scenario, ratio)
    assert hill.evals < oracle.evals


# ------------------------------------------------------------------ #
# history store
# ------------------------------------------------------------------ #


def test_history_store_round_trip(tmp_path):
    path = os.path.join(tmp_path, "winners.json")
    store = HistoryStore(path)
    sc = smoke_matrix()[0]
    assert store.seed(sc) is None
    assert store.record(sc, (8, 2, 4), 1.5e9, method="oracle")
    # a worse result must not clobber the winner
    assert not store.record(sc, (0, 1, 1), 1.0e9, method="sha")
    store.save()

    reloaded = HistoryStore(path)
    seed = reloaded.seed(sc)
    assert (seed.pipelining, seed.parallelism, seed.concurrency) == (8, 2, 4)
    assert reloaded.best_throughput(sc) == 1.5e9
    # the JSON on disk is the documented stable format
    with open(path) as f:
        data = json.load(f)
    assert data["version"] == 1
    key = f"{sc.network}/{sc.dataset}/cc{sc.max_cc}"
    assert data["winners"][key]["method"] == "oracle"
    # a strictly better result replaces it
    assert reloaded.record(sc, (16, 4, 8), 2.0e9, method="hill")
    seed2 = reloaded.seed(sc)
    assert seed2.concurrency == 8


def test_history_warm_start_reduces_hill_evals(tmp_path):
    scenarios = _smoke_slice(4)
    cold = hill_climb(scenarios, backend="numpy", n_candidates=16)
    store = HistoryStore(os.path.join(tmp_path, "w.json"))
    for key, table in cold.tables.items():
        # seed the store with each context's winner
        rep = next(sc for sc in scenarios if context_key(sc) == key)
        store.record(rep, table.best_params, table.best_throughput, "hill")
    warm = hill_climb(
        scenarios, backend="numpy", n_candidates=16, history=store
    )
    assert warm.evals <= cold.evals
    for e_cold, e_warm in zip(cold.entries, warm.entries):
        assert e_warm.best_throughput >= e_cold.best_throughput * (1 - 1e-12)


def test_history_rejects_unknown_version(tmp_path):
    path = os.path.join(tmp_path, "bad.json")
    with open(path, "w") as f:
        json.dump({"version": 99, "winners": {}}, f)
    with pytest.raises(ValueError, match="version"):
        HistoryStore(path)


# ------------------------------------------------------------------ #
# regret properties
# ------------------------------------------------------------------ #


@settings(max_examples=6, deadline=None, derandomize=True)
@given(
    network=st.sampled_from(
        [
            testbeds.XSEDE.name,
            testbeds.STAMPEDE_COMET.name,
            testbeds.LAN.name,
        ]
    ),
    dataset=st.sampled_from(
        ["mixed", "uniform_small", "uniform_huge", "small_dominated"]
    ),
    algorithm=st.sampled_from(["sc", "mc", "promc", "globus", "untuned"]),
    max_cc=st.sampled_from([4, 8]),
)
def test_oracle_dominates_heuristics_bounded(
    network, dataset, algorithm, max_cc
):
    """Oracle throughput >= every heuristic's throughput per scenario —
    up to the bounded adaptivity bonus.

    Strict domination as literally stated is FALSE in the model (found
    while building this suite): multi-chunk adaptive schedulers give
    each size class its own parameters and re-allocate channels online,
    which no single static (pp, p, cc) can express, and on
    small-dominated datasets that legitimately beats the best static
    setting by a few percent. What must hold: (a) the oracle strictly
    dominates the *static* baselines whose settings live inside the
    grid — untuned's (0, 1, 1) is always a grid point — and (b) the
    adaptive edge is bounded (measured max ~1.25, asserted <= 1.30):
    anything larger would mean the oracle missed a static optimum, not
    that adaptivity won."""
    sc = Scenario(
        network=network, dataset=dataset, algorithm=algorithm,
        max_cc=max_cc,
    )
    heur = run_matrix([sc], backend="numpy")[0]
    oracle = oracle_search([sc], backend="numpy", n_candidates=16)
    best = oracle.entries[0].best_throughput
    assert best * ADAPTIVITY_BONUS >= heur.throughput, (
        sc.name, best, heur.throughput,
    )
    if algorithm == "untuned":
        # (0,1,1) is in the grid: domination is exact, not approximate
        assert best >= heur.throughput * (1 - 1e-9)


def test_regret_report_shape_and_static_rows_excluded():
    scenarios = _smoke_slice(5)
    heur = run_matrix(scenarios, backend="numpy")
    oracle = oracle_search(scenarios, backend="numpy", n_candidates=16)
    # static candidate rows must not be scored as contestants
    extra = expand_candidates(scenarios[:1], [(0, 1, 1)])
    rep = regret_report(
        scenarios + extra,
        heur + run_matrix(extra, backend="numpy"),
        oracle,
    )
    assert all(r["algorithm"] != "static" for r in rep.per_scenario)
    assert len(rep.per_scenario) == len(scenarios)
    for agg in rep.per_algorithm.values():
        assert agg["min"] <= agg["median"] <= agg["max"]
        assert 0 < agg["median"] <= ADAPTIVITY_BONUS
    table = rep.format_table()
    assert "median" in table and "beats-oracle" in table


# ------------------------------------------------------------------ #
# static rows: scenario plumbing + zero-host-round on the JAX backend
# ------------------------------------------------------------------ #


def test_scenario_name_reserves_separator_and_static_coupling():
    with pytest.raises(ValueError, match="reserved name separator"):
        Scenario(network="a|tl", dataset="mixed", algorithm="mc")
    with pytest.raises(ValueError, match="static_params"):
        Scenario(network="lan", dataset="mixed", algorithm="static")
    with pytest.raises(ValueError, match="static_params"):
        Scenario(
            network="lan", dataset="mixed", algorithm="mc",
            static_params=(0, 1, 1),
        )
    with pytest.raises(ValueError, match="invalid static_params"):
        Scenario(
            network="lan", dataset="mixed", algorithm="static",
            static_params=(-1, 1, 1),
        )
    sc = Scenario(
        network="lan", dataset="mixed", algorithm="static",
        static_params=(4, 2, 8),
    )
    assert "|pp4.p2.cc8" in sc.name


def test_static_rows_zero_host_round_on_jax():
    """Candidate rows run the fused device loop without a single parked-
    row replay — the invariant `difftest --expect-zero-replays` gates."""
    from repro.eval.fabric import jax_backend

    scenarios = expand_candidates(
        _smoke_slice(3), [(0, 1, 1), (16, 4, 8), (4, 2, 16)]
    )
    jax_backend.reset_sync_stats()
    jax_res = run_matrix(scenarios, backend="jax")
    stats = dict(jax_backend.SYNC_STATS)
    assert stats["post_row_replays"] == 0
    assert stats["replay_rounds"] == 0
    ev_res = run_matrix(scenarios, backend="event")
    for sc, jr, er in zip(scenarios, jax_res, ev_res):
        assert jr.throughput == pytest.approx(er.throughput, rel=1e-9), sc.name


def test_static_scheduler_kind_is_distinct():
    from repro.eval.fabric.driver import (
        KIND_SC,
        KIND_STATIC,
        _scheduler_kind,
    )
    from repro.eval.scenarios import build_simulation

    sim = build_simulation(
        Scenario(
            network=testbeds.LAN.name, dataset="mixed",
            algorithm="static", static_params=(8, 2, 4),
        )
    )
    assert _scheduler_kind(sim.scheduler) == KIND_STATIC
    assert KIND_STATIC < KIND_SC  # every >= KIND_SC dispatch excludes it
    assert sim.scheduler.name == "Static(pp=8,p=2,cc=4)"
