"""Controller-kernel equivalence: the array-native decision layer
(`repro.eval.fabric.controllers`) must reproduce the paper's scalar
control algorithms bit-for-bit, and its NumPy and JAX instantiations must
agree with each other.

`core.schedulers` / `core.params` are now *facades* over these kernels,
so the reference implementations here are standalone re-statements of the
original pure-Python logic (Algorithms 1-3 as PR 1 shipped them) — not
calls back into the facade, which would be circular.
"""
import math

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.types import MC_ROUND_ROBIN_ORDER, PROMC_DELTA, ChunkType
from repro.eval.fabric import controllers
from repro.eval.fabric.shim import jax_ops, numpy_ops

_NP = numpy_ops()
_CTYPES = list(ChunkType)[:4]
_RR_RANK = {ct: i for i, ct in enumerate(MC_ROUND_ROBIN_ORDER)}


# ------------------------------------------------------------------ #
# scalar references (the pre-facade implementations, verbatim logic)
# ------------------------------------------------------------------ #


def _ref_optimal_params(avg, bdp, buf, max_cc, num_files, max_pp):
    pp = max(0, min(int(math.ceil(bdp / avg)), max_pp))
    par = max(1, min(int(math.ceil(bdp / buf)), int(math.ceil(avg / buf))))
    cc = max(1, int(min(max(bdp / avg, 2.0), float(max_cc))))
    if num_files is not None and num_files > 0:
        pp = min(pp, max(0, num_files - 1))
        cc = min(cc, num_files)
    return pp, par, cc


def _ref_round_robin(ctypes, nonempty, max_cc):
    order = [
        i
        for ct in MC_ROUND_ROBIN_ORDER
        for i, (c, ne) in enumerate(zip(ctypes, nonempty))
        if c == ct and ne
    ]
    alloc = {i: 0 for i in order}
    if not order:
        return alloc
    k = 0
    for _ in range(max_cc):
        alloc[order[k % len(order)]] += 1
        k += 1
    return alloc


def _ref_weighted(ctypes, total_bytes, nonempty, max_cc):
    live = [i for i, ne in enumerate(nonempty) if ne]
    if not live:
        return {}
    weights = {i: PROMC_DELTA[ctypes[i]] * total_bytes[i] for i in live}
    total = sum(weights.values()) or 1.0
    shares = {i: weights[i] / total * max_cc for i in live}
    alloc = {i: int(math.floor(shares[i])) for i in live}
    for i in live:
        if alloc[i] == 0:
            alloc[i] = 1
    budget = max(max_cc, len(live))
    while sum(alloc.values()) > budget:
        i = max(alloc, key=lambda j: (alloc[j], -shares[j]))
        if alloc[i] <= 1:
            break
        alloc[i] -= 1
    frac = sorted(live, key=lambda i: shares[i] - math.floor(shares[i]), reverse=True)
    k = 0
    while sum(alloc.values()) < budget and frac:
        alloc[frac[k % len(frac)]] += 1
        k += 1
    return alloc


def _ref_eta(bytes_rem, thr, pred, done):
    if done or bytes_rem <= 0:
        return 0.0
    rate = thr if thr > 0 else pred
    if rate <= 0:
        return math.inf
    return bytes_rem / rate


def _ref_laggards(etas0, owners0, live, n_channels):
    """distribute_to_laggards' grant loop: dict of grants + emit order."""
    etas = {i: etas0[i] for i in live}
    owners = {i: owners0[i] for i in live}
    moves = {}
    if not live:
        return moves
    for _ in range(n_channels):
        dst = max(etas, key=lambda i: etas[i])
        moves[dst] = moves.get(dst, 0) + 1
        n = owners[dst] + moves[dst]
        if math.isfinite(etas[dst]) and n > 0:
            etas[dst] *= (n - 1) / n if n > 1 else 0.5
    return moves


class _RefPromcStreak:
    """The scalar ProMC on_tick state machine (pre-facade, verbatim)."""

    def __init__(self, ratio=2.0, patience=3):
        self.ratio, self.patience = ratio, patience
        self.streak, self.pair = 0, None

    def tick(self, etas, thrs, n_chs, live):
        lv = [i for i in live if n_chs[i] > 0]
        if len(lv) < 2:
            self.streak, self.pair = 0, None
            return None
        fast = min(lv, key=lambda i: etas[i])
        slow = max(lv, key=lambda i: etas[i])
        if not math.isfinite(etas[slow]) and thrs[slow] == 0:
            return None
        imb = (
            etas[slow] >= self.ratio * etas[fast]
            and fast != slow
            and n_chs[fast] > 1
        )
        pair = (fast, slow)
        if imb and pair == self.pair:
            self.streak += 1
        elif imb:
            self.streak, self.pair = 1, pair
        else:
            self.streak, self.pair = 0, None
            return None
        if self.streak >= self.patience:
            self.streak, self.pair = 0, None
            return (fast, slow)
        return None


# ------------------------------------------------------------------ #
# Algorithm 1 (tuning)
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    avg=st.floats(min_value=1.0, max_value=1e12),
    bdp=st.floats(min_value=0.0, max_value=1e10),
    buf=st.floats(min_value=1024.0, max_value=1e9),
    max_cc=st.integers(min_value=1, max_value=64),
    nf=st.integers(min_value=0, max_value=500),
)
def test_optimal_params_matches_scalar_algorithm1(avg, bdp, buf, max_cc, nf):
    pp, par, cc = controllers.optimal_params(
        _NP, np.float64(avg), np.float64(bdp), np.float64(buf),
        np.float64(max_cc), np.int64(nf), 4096,
    )
    ref = _ref_optimal_params(avg, bdp, buf, max_cc, nf or None, 4096)
    assert (int(pp), int(par), int(cc)) == ref


def test_sc_chunk_order_is_stable_largest_first():
    ct = np.array([0, 3, 1, 3, 2], dtype=np.int64)
    order = controllers.sc_chunk_order(_NP, ct)
    assert list(order) == sorted(range(5), key=lambda i: -int(ct[i]))


# ------------------------------------------------------------------ #
# channel distributions (Alg. 2 round-robin, Alg. 3 weighted)
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    spec=st.lists(
        st.tuples(
            st.sampled_from(_CTYPES),
            st.integers(min_value=0, max_value=1),  # nonempty
        ),
        min_size=1,
        max_size=4,
        unique_by=lambda t: t[0],
    ),
    max_cc=st.integers(min_value=1, max_value=32),
)
def test_round_robin_kernel_matches_scalar(spec, max_cc):
    ctypes = [ct for ct, _ in spec]
    nonempty = [bool(ne) for _, ne in spec]
    rank = np.array([_RR_RANK[ct] for ct in ctypes], dtype=np.int64)
    alloc = controllers.round_robin_alloc(
        _NP, rank, np.array(nonempty), max_cc
    )
    ref = _ref_round_robin(ctypes, nonempty, max_cc)
    for i in range(len(spec)):
        assert int(alloc[i]) == ref.get(i, 0)


@settings(max_examples=200, deadline=None)
@given(
    spec=st.lists(
        st.tuples(
            st.sampled_from(_CTYPES),
            st.integers(min_value=0, max_value=int(5e12)),  # bytes (0=empty)
        ),
        min_size=1,
        max_size=4,
        unique_by=lambda t: t[0],
    ),
    max_cc=st.integers(min_value=1, max_value=32),
)
def test_weighted_kernel_matches_scalar(spec, max_cc):
    ctypes = [ct for ct, _ in spec]
    sizes = [b for _, b in spec]
    nonempty = np.array([b > 0 for b in sizes])
    weights = np.array(
        [PROMC_DELTA[ct] * b for ct, b in spec], dtype=np.float64
    )
    alloc = controllers.weighted_alloc(
        _NP, weights, nonempty, max_cc, trim_iters=len(spec)
    )
    ref = _ref_weighted(ctypes, sizes, list(nonempty), max_cc)
    for i in range(len(spec)):
        assert int(alloc[i]) == ref.get(i, 0)


# ------------------------------------------------------------------ #
# laggard-ETA discounting (Sec. 3.3 re-allocation)
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    chunks=st.lists(
        st.tuples(
            st.floats(min_value=0.0, max_value=1e12),  # bytes_remaining
            st.floats(min_value=0.0, max_value=1e9),   # throughput
            st.integers(min_value=0, max_value=8),     # n_channels
            st.integers(min_value=0, max_value=1),     # done
        ),
        min_size=1,
        max_size=5,
    ),
    src=st.integers(min_value=0, max_value=4),
    freed=st.integers(min_value=0, max_value=10),
)
def test_laggard_grants_match_scalar_discount_loop(chunks, src, freed):
    src = src % len(chunks)
    bytes_rem = np.array([c[0] for c in chunks])
    thr = np.array([c[1] for c in chunks])
    owners = np.array([c[2] for c in chunks], dtype=np.int64)
    done = np.array([bool(c[3]) for c in chunks])
    pred = np.zeros(len(chunks))
    etas = [
        _ref_eta(bytes_rem[i], thr[i], pred[i], done[i])
        for i in range(len(chunks))
    ]
    live_idx = [
        i for i in range(len(chunks))
        if not done[i] and i != src and bytes_rem[i] > 0
    ]
    ref = _ref_laggards(etas, owners, live_idx, freed)

    eta_arr = controllers.chunk_eta(_NP, bytes_rem, thr, pred, done)
    live = ~done & (np.arange(len(chunks)) != src) & (bytes_rem > 0)
    grants, first = controllers.laggard_grants(
        _NP, eta_arr, owners, live, np.int64(freed if live_idx else 0),
        max(freed, 1),
    )
    for i in range(len(chunks)):
        assert int(grants[i]) == ref.get(i, 0)
    # emission order == dict insertion order (first grant)
    order = sorted(np.flatnonzero(grants > 0), key=lambda d: first[d])
    assert [int(d) for d in order] == list(ref)


# ------------------------------------------------------------------ #
# ProMC streak state machine (Sec. 3.4) incl. reset semantics
# ------------------------------------------------------------------ #


@settings(max_examples=150, deadline=None)
@given(
    ticks=st.lists(
        st.lists(
            st.tuples(
                st.floats(min_value=0.0, max_value=1e10),  # bytes
                st.sampled_from([0.0, 10.0, 100.0, 1e6]),  # throughput
                st.integers(min_value=0, max_value=6),     # n_channels
            ),
            min_size=3,
            max_size=3,
        ),
        min_size=1,
        max_size=8,
    ),
    patience=st.integers(min_value=1, max_value=4),
)
def test_promc_streak_machine_matches_scalar(ticks, patience):
    """Drive the same tick sequence through the scalar reference machine
    and the kernel; streak state and fired moves must match at every
    step (including resets on balance and the patience threshold)."""
    ref = _RefPromcStreak(ratio=2.0, patience=patience)
    streak, pf, ps = np.int64(0), np.int64(-1), np.int64(-1)
    for views in ticks:
        bytes_rem = np.array([v[0] for v in views])
        thr = np.array([v[1] for v in views])
        n_ch = np.array([v[2] for v in views], dtype=np.int64)
        done = np.zeros(3, dtype=bool)
        pred = np.zeros(3)
        etas = [
            _ref_eta(bytes_rem[i], thr[i], pred[i], done[i])
            for i in range(3)
        ]
        live_idx = [i for i in range(3) if bytes_rem[i] > 0]
        ref_move = ref.tick(etas, thr, n_ch, live_idx)

        eta_arr = controllers.chunk_eta(_NP, bytes_rem, thr, pred, done)
        live = ~done & (bytes_rem > 0)
        streak, pf, ps, move, src, dst = controllers.promc_tick(
            _NP, eta_arr, thr, n_ch, live, streak, pf, ps, 2.0,
            np.int64(patience),
        )
        if ref_move is None:
            assert not bool(move)
        else:
            assert bool(move) and (int(src), int(dst)) == ref_move
        assert int(streak) == ref.streak
        ref_pair = ref.pair or (-1, -1)
        assert (int(pf), int(ps)) == ref_pair


def test_tick_ctrl_grows_full_resume_stack_even_without_a_move():
    """A row parked by the device's resume-stack-overflow guard replays
    its tick on the host; the replay must leave stack headroom even when
    no move fires, or the row would re-park at every subsequent tick
    (degrading the O(1)-syncs property to O(ticks))."""
    from repro.eval.fabric.driver import FabricSimulation
    from repro.eval.scenarios import Scenario, build_simulation

    sc = Scenario(
        network="stampede-comet", dataset="mixed", algorithm="promc"
    )
    drv = FabricSimulation([build_simulation(sc)], names=[sc.name])
    drv.start()
    p0 = drv.P
    drv.prepend_n[0, 0] = p0  # stack full
    rows = np.ones(1, dtype=bool)
    drv._tick_ctrl(rows)  # fresh streak: patience not reached => no move
    assert drv.P > p0
    assert (drv.prepend_n < drv.P).all()


def test_promc_completion_reset_is_wired_in_driver():
    """The batched driver drops the accumulated streak on any chunk
    completion, mirroring the scalar on_chunk_complete reset."""
    from repro.eval.fabric.driver import FabricSimulation
    from repro.eval.scenarios import Scenario, build_simulation

    sc = Scenario(
        network="stampede-comet", dataset="mixed", algorithm="promc"
    )
    drv = FabricSimulation([build_simulation(sc)], names=[sc.name])
    drv.start()
    drv.streak[0] = 2  # pretend accumulated imbalance evidence
    drv.pair_fast[0], drv.pair_slow[0] = 0, 1
    m = np.zeros((1, drv.K), dtype=bool)
    m[0, 0] = True
    drv._complete_ctrl(m)
    assert drv.streak[0] == 0
    assert drv.pair_fast[0] == -1 and drv.pair_slow[0] == -1


# ------------------------------------------------------------------ #
# SC cursor walk over empty size classes
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    nfiles=st.lists(
        st.integers(min_value=0, max_value=5), min_size=1, max_size=5
    ),
    ctypes=st.lists(
        st.integers(min_value=0, max_value=3), min_size=5, max_size=5
    ),
    cursor=st.integers(min_value=0, max_value=5),
)
def test_sc_cursor_advance_skips_empty_chunks(nfiles, ctypes, cursor):
    K = len(nfiles)
    order = sorted(range(K), key=lambda i: -ctypes[i])
    # scalar: cursor += 1 then walk while the pointed chunk is empty
    ref = min(cursor, K) + 1
    while ref < K and nfiles[order[ref]] == 0:
        ref += 1
    out = controllers.sc_advance_cursor(
        _NP,
        np.array(True),
        np.int64(min(cursor, K)),
        np.array(order, dtype=np.int64),
        np.array(nfiles, dtype=np.int64),
        np.int64(K),
    )
    assert int(out) == ref


# ------------------------------------------------------------------ #
# NumPy / JAX instantiations agree
# ------------------------------------------------------------------ #


def test_controller_kernels_numpy_and_jax_agree():
    from jax.experimental import enable_x64

    rng = np.random.RandomState(7)
    S, K = 32, 4
    eta = np.where(
        rng.uniform(size=(S, K)) < 0.15, np.inf, rng.uniform(1.0, 1e4, (S, K))
    )
    thr = np.where(rng.uniform(size=(S, K)) < 0.3, 0.0, rng.uniform(1, 1e9, (S, K)))
    n_ch = rng.randint(0, 6, size=(S, K)).astype(np.int64)
    live = rng.uniform(size=(S, K)) < 0.8
    streak = rng.randint(0, 3, size=S).astype(np.int64)
    pf = rng.randint(-1, K, size=S).astype(np.int64)
    ps = rng.randint(-1, K, size=S).astype(np.int64)
    n_grants = rng.randint(0, 6, size=S).astype(np.int64)
    weights = rng.uniform(0, 1e12, size=(S, K))
    nonempty = rng.uniform(size=(S, K)) < 0.8
    max_cc = rng.randint(1, 17, size=S).astype(np.int64)

    ref_tick = controllers.promc_tick(
        _NP, eta, thr, n_ch, live, streak, pf, ps, 2.0, 3
    )
    ref_lag = controllers.laggard_grants(_NP, eta, n_ch, live, n_grants, 6)
    ref_w = controllers.weighted_alloc(_NP, weights, nonempty, max_cc, K)
    with enable_x64():
        import jax.numpy as jnp

        J = jax_ops()
        out_tick = controllers.promc_tick(
            J, jnp.asarray(eta), jnp.asarray(thr), jnp.asarray(n_ch),
            jnp.asarray(live), jnp.asarray(streak), jnp.asarray(pf),
            jnp.asarray(ps), 2.0, 3,
        )
        out_lag = controllers.laggard_grants(
            J, jnp.asarray(eta), jnp.asarray(n_ch), jnp.asarray(live),
            jnp.asarray(n_grants), 6,
        )
        out_w = controllers.weighted_alloc(
            J, jnp.asarray(weights), jnp.asarray(nonempty),
            jnp.asarray(max_cc), K,
        )
    for r, o in zip(ref_tick, out_tick):
        np.testing.assert_array_equal(np.asarray(o), r)
    for r, o in zip(ref_lag, out_lag):
        np.testing.assert_array_equal(np.asarray(o), r)
    np.testing.assert_array_equal(np.asarray(out_w), ref_w)


def test_facade_and_kernels_share_decisions_end_to_end():
    """Spot check that the facade (event sim) and the fused JAX loop make
    identical move decisions on a ProMC scenario (n_moves match)."""
    from repro.eval.runner import run_matrix
    from repro.eval.scenarios import Scenario

    sc = Scenario(
        network="bluewaters-stampede", dataset="mixed", algorithm="promc"
    )
    ev = run_matrix([sc], backend="event")[0]
    jx = run_matrix([sc], backend="jax")[0]
    assert jx.n_moves == ev.n_moves
    assert jx.throughput == pytest.approx(ev.throughput, rel=1e-9)
