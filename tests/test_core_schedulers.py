"""Scheduler unit tests: Alg. 2 round-robin, Alg. 3 weighting, re-allocation."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (
    GB,
    MB,
    Chunk,
    ChunkType,
    FileSpec,
    make_scheduler,
    prepare_chunks,
    round_robin_distribution,
    weighted_distribution,
)
from repro.core import testbeds
from repro.core.schedulers import (
    ChunkView,
    Move,
    Open,
    ProActiveMultiChunkScheduler,
)


def _chunk(ctype, n, size):
    return Chunk(ctype=ctype, files=[FileSpec(f"{ctype.name}{i}", size) for i in range(n)])


def test_mc_round_robin_paper_example():
    """Sec. 3.3 worked example: maxCC=8, chunks {Small, Medium, Large}
    -> (3, 2, 3) because the RR order is {Huge, Small, Large, Medium}."""
    chunks = [
        _chunk(ChunkType.SMALL, 10, 1 * MB),
        _chunk(ChunkType.MEDIUM, 10, 100 * MB),
        _chunk(ChunkType.LARGE, 10, 500 * MB),
    ]
    alloc = round_robin_distribution(chunks, 8)
    assert alloc[0] == 3  # Small
    assert alloc[1] == 2  # Medium
    assert alloc[2] == 3  # Large
    assert sum(alloc.values()) == 8


def test_mc_round_robin_fewer_channels_than_chunks():
    """Ordering {Huge, Small, Large, Medium} decides who gets scarce channels."""
    chunks = [
        _chunk(ChunkType.SMALL, 5, 1 * MB),
        _chunk(ChunkType.MEDIUM, 5, 100 * MB),
        _chunk(ChunkType.LARGE, 5, 500 * MB),
        _chunk(ChunkType.HUGE, 5, 4 * GB),
    ]
    alloc = round_robin_distribution(chunks, 2)
    assert alloc[3] == 1  # Huge first
    assert alloc[0] == 1  # Small second
    assert alloc[1] == 0 and alloc[2] == 0


def test_promc_weighted_distribution():
    """Alg. 3: weight = delta * size, delta = {6,3,2,1} for {S,M,L,H}."""
    chunks = [
        _chunk(ChunkType.SMALL, 100, 10 * MB),  # 1000 MB * 6 = 6000
        _chunk(ChunkType.HUGE, 1, 2000 * MB),  # 2000 MB * 1 = 2000
    ]
    alloc = weighted_distribution(chunks, 8)
    # shares: small 6000/8000*8 = 6, huge 2000/8000*8 = 2
    assert alloc[0] == 6
    assert alloc[1] == 2


def test_promc_every_live_chunk_gets_a_channel():
    chunks = [
        _chunk(ChunkType.SMALL, 1, 1 * MB),  # negligible weight
        _chunk(ChunkType.HUGE, 10, 10 * GB),
    ]
    alloc = weighted_distribution(chunks, 4)
    assert alloc[0] >= 1
    assert alloc[1] >= 1
    assert sum(alloc.values()) == 4


@settings(max_examples=200, deadline=None)
@given(
    sizes=st.lists(
        st.tuples(
            st.sampled_from(list(ChunkType)[:4]),
            st.integers(min_value=1, max_value=50),
            st.integers(min_value=1 * MB, max_value=int(5 * GB)),
        ),
        min_size=1,
        max_size=4,
        unique_by=lambda t: t[0],
    ),
    max_cc=st.integers(min_value=1, max_value=64),
)
def test_channel_conservation_property(sizes, max_cc):
    """Property: both distributions hand out exactly the channel budget
    (ProMC may exceed maxCC only to guarantee one channel per live chunk)."""
    chunks = [_chunk(ct, n, s) for ct, n, s in sizes]
    rr = round_robin_distribution(chunks, max_cc)
    assert sum(rr.values()) == max_cc
    wd = weighted_distribution(chunks, max_cc)
    assert sum(wd.values()) == max(max_cc, len(chunks))
    assert all(v >= 1 for v in wd.values())


def _views(specs):
    """specs: list of (bytes_remaining, throughput, n_channels)."""
    return [
        ChunkView(
            index=i,
            ctype=ChunkType.SMALL,
            bytes_remaining=b,
            files_remaining=1 if b else 0,
            throughput=thr,
            n_channels=n,
            done=b == 0,
            predicted_rate=thr or 1.0,
        )
        for i, (b, thr, n) in enumerate(specs)
    ]


def _mk_promc(max_cc=8):
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec(f"s{i}", 1 * MB) for i in range(50)] + [
        FileSpec(f"h{i}", 4 * GB) for i in range(10)
    ]
    chunks = prepare_chunks(files, net, 2, max_cc)
    return make_scheduler("promc", chunks, net, max_cc)


def test_promc_reallocation_needs_three_consecutive_periods():
    """Sec. 3.4: 'waits three periods to avoid incorrect estimations'."""
    sched = _mk_promc()
    # chunk 0 is fast (eta=10s), chunk 1 slow (eta=100s): ratio 10 >= 2
    v = _views([(10 * GB, 1e9, 4), (100 * GB, 1e9, 4)])
    assert sched.on_tick(v) == []  # period 1
    assert sched.on_tick(v) == []  # period 2
    moves = sched.on_tick(v)  # period 3 -> move
    assert moves == [Move(src=0, dst=1, n=1)]
    # streak resets after the move
    assert sched.on_tick(v) == []


def test_promc_streak_resets_when_balanced():
    sched = _mk_promc()
    imbalanced = _views([(10 * GB, 1e9, 4), (100 * GB, 1e9, 4)])
    balanced = _views([(10 * GB, 1e9, 4), (11 * GB, 1e9, 4)])
    assert sched.on_tick(imbalanced) == []
    assert sched.on_tick(imbalanced) == []
    assert sched.on_tick(balanced) == []  # streak broken
    assert sched.on_tick(imbalanced) == []
    assert sched.on_tick(imbalanced) == []
    assert sched.on_tick(imbalanced) != []  # three fresh periods again


def test_promc_never_strands_fast_chunk():
    """The fast chunk keeps its last channel."""
    sched = _mk_promc()
    v = _views([(10 * GB, 1e9, 1), (100 * GB, 1e9, 7)])
    for _ in range(5):
        assert sched.on_tick(v) == []


def test_promc_threshold_is_two_x():
    """Slow chunk must be expected to run >= 2x longer (Sec. 3.4)."""
    sched = _mk_promc()
    v = _views([(10 * GB, 1e9, 4), (19 * GB, 1e9, 4)])  # ratio 1.9 < 2
    for _ in range(5):
        assert sched.on_tick(v) == []


def test_distribute_to_laggards_conserves_channels():
    sched = _mk_promc()
    view = _views([(0, 1e9, 3), (50 * GB, 1e9, 2), (100 * GB, 2e8, 3)])
    moves = sched.on_chunk_complete(view, 0)
    assert sum(m.n for m in moves) == 3
    assert all(isinstance(m, Move) and m.src == 0 for m in moves)
    # the slowest chunk (index 2: eta 500s vs 50s) receives at least as many
    got = {m.dst: m.n for m in moves}
    assert got.get(2, 0) >= got.get(1, 0)


def test_sc_opens_only_first_chunk_then_advances():
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec(f"s{i}", 1 * MB) for i in range(10)] + [
        FileSpec(f"h{i}", 4 * GB) for i in range(4)
    ]
    chunks = prepare_chunks(files, net, 2, 8)
    sched = make_scheduler("sc", chunks, net, 8)
    first = sched.initial_actions(_views([(1, 0, 0), (1, 0, 0)]))
    assert len(first) == 1 and isinstance(first[0], Open)
    opened = first[0]
    # largest class first: LARGE chunk (index 1) before SMALL
    assert opened.chunk == 1
    assert opened.n == chunks[1].params.concurrency
    nxt = sched.on_chunk_complete(
        _views([(1 * GB, 0, 0), (0, 1e9, opened.n)]), 1
    )
    kinds = [type(a).__name__ for a in nxt]
    assert kinds == ["Close", "Open"]
    assert nxt[1].chunk == 0
    assert nxt[1].n == chunks[0].params.concurrency


def test_unknown_scheduler_raises():
    net = testbeds.STAMPEDE_COMET
    chunks = prepare_chunks([FileSpec("a", MB)], net, 1, 2)
    with pytest.raises(ValueError):
        make_scheduler("nope", chunks, net, 2)
