"""Real threaded TransferEngine: correctness of actual file movement."""
import hashlib
import os

import pytest

from repro.core import MB, FileSpec, prepare_chunks
from repro.core import testbeds
from repro.core.engine import TransferEngine, bytes_task, file_task
from repro.core.schedulers import make_scheduler


def _make_files(tmp_path, sizes):
    src_dir = tmp_path / "src"
    dst_dir = tmp_path / "dst"
    src_dir.mkdir()
    dst_dir.mkdir()
    specs, tasks = [], {}
    rng_state = 1234
    for i, size in enumerate(sizes):
        name = f"f{i:03d}"
        src = src_dir / name
        # deterministic pseudo-random contents
        blocks = []
        remaining = size
        while remaining > 0:
            rng_state = (rng_state * 6364136223846793005 + 1442695040888963407) % (
                1 << 64
            )
            blk = rng_state.to_bytes(8, "little") * 1024  # 8 KB
            blocks.append(blk[: min(len(blk), remaining)])
            remaining -= len(blocks[-1])
        data = b"".join(blocks)
        src.write_bytes(data)
        spec = FileSpec(name=name, size=size, path=str(src))
        specs.append(spec)
        tasks[name] = file_task(spec, str(src), str(dst_dir / name))
    return specs, tasks, src_dir, dst_dir


def _digest(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(1 << 20)
            if not b:
                return h.hexdigest()
            h.update(b)


@pytest.mark.parametrize("algo", ["sc", "mc", "promc"])
def test_engine_copies_everything_bit_exact(tmp_path, algo):
    net = testbeds.LAN
    sizes = [256 * 1024] * 6 + [8 * MB] * 2  # small + stripeable files
    specs, tasks, src_dir, dst_dir = _make_files(tmp_path, sizes)
    chunks = prepare_chunks(specs, net, 2, max_cc=4)
    sched = make_scheduler(algo, chunks, net, 4)
    eng = TransferEngine(net, tick_period=0.05)
    report = eng.run(chunks, sched, tasks)
    assert report.files_done == len(specs)
    assert report.total_bytes == sum(sizes)
    for s in specs:
        assert _digest(dst_dir / s.name) == _digest(src_dir / s.name)


def test_engine_striped_write_is_correct(tmp_path):
    """parallelism > 1 stripes one big file across sub-threads."""
    net = testbeds.XSEDE  # BDP 75MB > buf 32MB -> Alg. 1 picks parallelism 3
    sizes = [96 * MB]  # > buffer so Alg. 1 assigns multiple streams
    specs, tasks, src_dir, dst_dir = _make_files(tmp_path, sizes)
    chunks = prepare_chunks(specs, net, 1, max_cc=2)
    assert chunks[0].params.parallelism >= 2
    sched = make_scheduler("mc", chunks, net, 2)
    eng = TransferEngine(net, tick_period=0.05)
    eng.run(chunks, sched, tasks)
    assert _digest(dst_dir / "f000") == _digest(src_dir / "f000")


def test_engine_bytes_task(tmp_path):
    payload = os.urandom(3 * MB)
    spec = FileSpec(name="shard0", size=len(payload))
    dst = tmp_path / "shard0.bin"
    task = bytes_task(spec, payload, str(dst))
    net = testbeds.CKPT_STORE
    chunks = prepare_chunks([spec], net, 1, max_cc=2)
    sched = make_scheduler("mc", chunks, net, 2)
    TransferEngine(net, tick_period=0.02).run(chunks, sched, {"shard0": task})
    assert dst.read_bytes() == payload


def test_task_holds_one_destination_fd_for_lifetime(tmp_path, monkeypatch):
    """The destination is opened once per task (not per pwrite) and the fd
    is released by finalize — the engine calls finalize after the last
    stripe completes."""
    opens = []
    real_open = os.open

    def counting_open(path, *a, **kw):
        fd = real_open(path, *a, **kw)
        opens.append(str(path))
        return fd

    monkeypatch.setattr(os, "open", counting_open)
    payload = os.urandom(5 * MB)
    dst = tmp_path / "out.bin"
    task = bytes_task(FileSpec(name="x", size=len(payload)), payload, str(dst))
    # many writes (the engine writes 1 MB blocks, striped across threads)
    block = MB
    for off in range(0, len(payload), block):
        task.write(off, payload[off : off + block])
    assert opens.count(str(dst)) == 1
    task.finalize()
    assert dst.read_bytes() == payload
    # after finalize the fd is closed; a fresh write reopens exactly once
    task.write(0, b"y")
    task.finalize()
    assert opens.count(str(dst)) == 2


def test_engine_latency_injection_pipelining_speedup(tmp_path):
    """With injected control latency, pipelining visibly reduces wall time —
    the paper's mechanism, demonstrated on the real engine."""
    import dataclasses

    net = dataclasses.replace(testbeds.LAN, rtt=0.03, unhidden_overhead=0.0)
    sizes = [64 * 1024] * 20
    specs, tasks, _, _ = _make_files(tmp_path, sizes)

    def run_with(pp):
        from repro.core.types import Chunk, ChunkType, TransferParams

        chunk = Chunk(
            ctype=ChunkType.ALL,
            files=list(specs),
            params=TransferParams(pipelining=pp, parallelism=1, concurrency=1),
        )
        sched = make_scheduler("mc", [chunk], net, 1)
        sched.chunks[0].params = chunk.params  # keep fixed params
        eng = TransferEngine(net, tick_period=0.05, inject_latency=True)
        return eng.run([chunk], sched, tasks).total_time

    slow = run_with(0)
    fast = run_with(9)
    assert fast < slow  # 30ms/file gap vs 3ms/file gap
