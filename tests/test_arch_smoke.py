"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-gradient step + one prefill/decode step on CPU. Asserts shapes and
finiteness. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.config import reduce_for_smoke
from repro.models.model import build_model, count_active_params, count_params

ARCH_IDS = sorted(ARCHS)

#: cheap representatives exercised in tier-1; every other architecture's
#: smoke runs with -m slow (they are all still covered there)
FAST_ARCHS = {"llama3.2-3b", "phi4-mini-3.8b"}
ARCH_PARAMS = [
    pytest.param(a, marks=() if a in FAST_ARCHS else (pytest.mark.slow,))
    for a in ARCH_IDS
]


def _batch_for(cfg, rng, batch=2, seq=16):
    tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    b = {"tokens": tokens, "targets": targets}
    if cfg.frontend == "vision_stub":
        b["prefix_embed"] = (
            jax.random.normal(rng, (batch, cfg.num_prefix_tokens, cfg.d_model))
            * 0.02
        )
    if cfg.frontend == "audio_stub":
        b["frames"] = (
            jax.random.normal(rng, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
        )
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_loss(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch_for(cfg, rng)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_gradient_step(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch_for(cfg, rng)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # at least the embedding gradient must be nonzero
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_then_decode(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch_for(cfg, rng, batch=2, seq=8)
    prefix = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    max_len = prefix + 12  # cache covers prefix + prompt + decoded tokens
    cache = model.init_cache(2, max_len)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for pos in range(prefix + 8, prefix + 11):
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode logits must match the train forward logits
    (same params, same tokens) — validates cache correctness."""
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.frontend == "vision_stub":
        pytest.skip("prefix handling differs between train/serve paths")
    model = build_model(cfg, remat="none")
    params = model.init(rng)
    batch = _batch_for(cfg, rng, batch=1, seq=8)
    full_logits, _ = jax.jit(model.forward)(params, batch)

    prefill_batch = dict(batch)
    prefill_batch["tokens"] = batch["tokens"][:, :4]
    cache = model.init_cache(1, 8)
    logits, cache = jax.jit(model.prefill)(params, prefill_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1, :]),
        np.asarray(full_logits[:, 3, :]),
        rtol=2e-2, atol=2e-2,
    )
    step = jax.jit(model.decode_step)
    for pos in range(4, 8):
        tok = batch["tokens"][:, pos]
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full_logits[:, pos, :]),
            rtol=2e-2, atol=2e-2,
        )


@pytest.mark.slow
def test_param_counts_sane():
    """Full configs: parameter totals in the right ballpark for their names."""
    expect = {
        "deepseek-moe-16b": (14e9, 20e9),
        "phi3.5-moe-42b": (38e9, 46e9),
        "paligemma-3b": (2e9, 3.5e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "gemma3-1b": (0.7e9, 1.5e9),
        "yi-9b": (8e9, 10e9),
        "phi4-mini-3.8b": (3.3e9, 4.6e9),
        "llama3.2-3b": (2.8e9, 4e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "whisper-base": (0.05e9, 0.12e9),
    }
    for arch, (lo, hi) in expect.items():
        model = build_model(get_config(arch))
        n = count_params(model)
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"
        n_active = count_active_params(model)
        assert n_active <= n


def test_moe_active_params():
    model = build_model(get_config("deepseek-moe-16b"))
    total, active = count_params(model), count_active_params(model)
    # 64 routed experts, top-6: active well under half of total
    assert active < 0.45 * total
