"""Randomized cross-backend differential fuzz: the seeded counterpart of
the fixed golden matrix.

Each example draws a random ``NetworkSpec`` (bandwidth / RTT / buffers /
disk contention, optional control-RTT asymmetry and a time-varying
bandwidth profile), a random fileset (degenerate cases included: 1-file
datasets, zero-size files, single-class swarms), and a random scheduler
configuration, then runs the *same* simulation through the event
reference, the batched NumPy fabric driver, and the JAX device loop and
holds all three to the matrix difftest's 2% bar (agreement is bit-level
in practice).

Seeding is fixed either way: the vendored offline hypothesis shim seeds
draws from the test's qualified name, and the real library runs with
``derandomize=True`` — CI replays the identical example set on every
push. This is the harness that caught the channel-ordering divergence
(recycled columns vs. the event simulator's list order) now pinned by
``tests/test_zero_host_rounds.py::test_channel_order_tie_regression``.
"""
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.core.types import GB, KB, MB, DiskSpec, FileSpec, NetworkSpec, gbps
from repro.eval.runner import run_simulations

RTOL = 0.02

#: file-size pool: spans all four size classes on every generated network,
#: plus the degenerate zero-size file (metadata-only transfer)
SIZE_POOL = (
    0, 64 * KB, 1 * MB, 4 * MB, 48 * MB, 200 * MB, 900 * MB, 2 * GB,
    8 * GB,
)

#: piecewise-constant capacity profiles (None = static path)
PROFILES = (
    None,
    ((0.0, 1.0), (10.0, 0.5)),
    ((0.0, 1.0), (5.0, 0.4), (30.0, 0.9)),
    ((0.0, 0.7), (20.0, 1.0)),
)


def _network(bw_gbps, rtt_ms, buf_mb, disk_frac, sat_cc, contention,
             unhidden_ms, ctrl_mult, profile):
    bw = gbps(bw_gbps)
    return NetworkSpec(
        name="fuzz-net",
        bandwidth=bw,
        rtt=rtt_ms * 1e-3,
        buffer_size=buf_mb * MB,
        disk=DiskSpec(
            streaming_rate=bw * disk_frac,
            per_file_overhead=0.004,
            saturation_cc=sat_cc,
            contention=contention,
            per_channel_rate=bw * 0.35,
        ),
        unhidden_overhead=unhidden_ms * 1e-3,
        control_rtt=None if ctrl_mult is None else rtt_ms * 1e-3 * ctrl_mult,
        bandwidth_profile=profile,
    )


#: fixed (pp, p, cc) draws for the static controller kind (the
#: autotuner's candidate rows): the same event/numpy/jax 2% bar as the
#: adaptive schedulers, including a deliberately oversubscribed cc=16
STATIC_PARAMS = ((0, 1, 1), (8, 2, 2), (32, 4, 8), (4, 8, 4), (128, 1, 16))


def _run(backend, files, net, algo, max_cc, num_chunks, tick,
         static_params=None):
    # fresh scheduler per backend: controllers are stateful
    kw = {"static_params": static_params} if algo == "static" else {}
    sched = build_scheduler(
        algo, files, net, max_cc=max_cc, num_chunks=num_chunks, **kw
    )
    sim = Simulation(
        sched.chunks, sched.network, sched, tick_period=tick
    )
    return run_simulations([sim], names=["fuzz"], backend=backend)[0]


@settings(max_examples=8, deadline=None, derandomize=True)
@given(
    bw_gbps=st.sampled_from([0.5, 2.0, 10.0, 30.0]),
    rtt_ms=st.sampled_from([0.2, 10.0, 60.0, 150.0]),
    buf_mb=st.sampled_from([1, 4, 32]),
    disk_frac=st.sampled_from([0.3, 0.9, 1.5]),
    sat_cc=st.sampled_from([2, 8, 12]),
    contention=st.sampled_from([0.0, 0.02, 0.08]),
    unhidden_ms=st.sampled_from([0.0, 12.0, 55.0]),
    ctrl_mult=st.sampled_from([None, 1.0, 4.0, 15.0]),
    profile=st.sampled_from(PROFILES),
    sizes=st.lists(
        st.sampled_from(SIZE_POOL), min_size=1, max_size=14
    ),
    algo=st.sampled_from(
        ["sc", "mc", "promc", "globus", "untuned", "static"]
    ),
    static_params=st.sampled_from(STATIC_PARAMS),
    max_cc=st.sampled_from([1, 2, 8, 16]),
    num_chunks=st.sampled_from([1, 2, 3, 4]),
    tick=st.sampled_from([1.0, 2.5, 5.0]),
)
def test_fuzz_event_numpy_jax_agree(
    bw_gbps, rtt_ms, buf_mb, disk_frac, sat_cc, contention, unhidden_ms,
    ctrl_mult, profile, sizes, algo, static_params, max_cc, num_chunks,
    tick,
):
    net = _network(
        bw_gbps, rtt_ms, buf_mb, disk_frac, sat_cc, contention,
        unhidden_ms, ctrl_mult, profile,
    )
    files = [FileSpec(f"f{i}", s) for i, s in enumerate(sizes)]
    results = {
        backend: _run(
            backend, files, net, algo, max_cc, num_chunks, tick,
            static_params=static_params,
        )
        for backend in ("event", "numpy", "jax")
    }
    ev = results["event"]
    for backend in ("numpy", "jax"):
        r = results[backend]
        assert r.total_bytes == ev.total_bytes
        denom = max(abs(ev.throughput), 1e-12)
        rel = abs(r.throughput - ev.throughput) / denom
        assert rel <= RTOL, (
            f"{backend} diverged: event={ev.throughput:.6g} "
            f"{backend}={r.throughput:.6g} rel={rel:.3%} "
            f"(net bw={bw_gbps}g rtt={rtt_ms}ms ctrl={ctrl_mult} "
            f"prof={profile is not None} algo={algo} cc={max_cc} "
            f"k={num_chunks} tick={tick} files={len(sizes)})"
        )
    # the fabric instantiations must not drift apart either
    rel_nj = abs(
        results["numpy"].throughput - results["jax"].throughput
    ) / max(abs(results["numpy"].throughput), 1e-12)
    assert rel_nj <= RTOL


def test_fuzz_degenerate_single_zero_file():
    """The fully degenerate corner pinned explicitly (not left to the
    draw): a 1-file dataset whose only file is zero bytes."""
    net = _network(2.0, 10.0, 4, 0.9, 8, 0.02, 12.0, None, None)
    files = [FileSpec("empty", 0)]
    out = {
        b: _run(b, files, net, a, 4, 2, 5.0)
        for b in ("event", "numpy", "jax")
        for a in ("sc",)
    }
    for b, r in out.items():
        assert r.total_bytes == 0
        assert np.isfinite(r.total_time)
    assert out["numpy"].total_time == out["jax"].total_time
    assert abs(
        out["numpy"].total_time - out["event"].total_time
    ) <= 1e-9 * max(out["event"].total_time, 1.0)


@settings(max_examples=4, deadline=None, derandomize=True)
@given(
    bw_gbps=st.sampled_from([2.0, 30.0]),
    rtt_ms=st.sampled_from([0.2, 60.0]),
    algo=st.sampled_from(["sc", "mc", "promc"]),
    max_cc=st.sampled_from([2, 8]),
    n_variants=st.sampled_from([5, 9]),
)
def test_fuzz_async_executor_matches_event(
    bw_gbps, rtt_ms, algo, max_cc, n_variants,
):
    """The same fuzz-style draws pushed through ``run_built`` with the
    overlap-pipelined executor and a tiny chunk size (forcing several
    in-flight chunks): per-row results must match the event reference
    within the difftest bar and land at their input index."""
    from repro.eval.runner import run_built

    net = _network(bw_gbps, rtt_ms, 4, 0.9, 8, 0.02, 12.0, None, None)
    variants = [
        [FileSpec(f"f{i}", SIZE_POOL[(i + v) % len(SIZE_POOL)])
         for i in range(1 + v)]
        for v in range(n_variants)
    ]

    def make_builder(files):
        def build():
            sched = build_scheduler(
                algo, files, net, max_cc=max_cc, num_chunks=2
            )
            return Simulation(
                sched.chunks, sched.network, sched, tick_period=2.5
            )
        return build

    builders = [make_builder(f) for f in variants]
    names = [f"v{v}" for v in range(n_variants)]
    ev = [b().run() for b in builders]
    for backend in ("numpy", "jax"):
        out = run_built(
            builders, names, backend=backend, chunk_size=2,
            executor="async",
        )
        for i, (e, r) in enumerate(zip(ev, out)):
            assert r.total_bytes == e.total_bytes, i
            rel = abs(r.throughput - e.throughput) / max(
                abs(e.throughput), 1e-12
            )
            assert rel <= RTOL, (backend, i, rel)
