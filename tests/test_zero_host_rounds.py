"""Zero-host-round regressions: the three retired park classes + the
channel-ordering fidelity fix.

Before this suite's changes, the JAX device loop parked a scenario row
(one-sweep host replay of ``FabricSimulation._post``) for three edge
classes: simultaneous multi-chunk completions, SC open waves exceeding
the device channel axis, and prospective resume-stack overflow. Each
test here crafts a minimal scenario that *did* force the park on the
pre-change code (verified against the PR-3 tree) and asserts that it now
runs fully on-device — ``SYNC_STATS`` reports zero parked-row replays —
while still matching the event reference exactly.

``test_channel_order_tie_regression`` pins the fidelity bug the fuzz
harness surfaced while building this: the fabric backends recycled the
lowest free channel *column* where the event simulator appends new
channels at the end of its list, so an idle-victim tie between channels
with different residual dead times could resolve differently. Closes
now left-pack the channel axis (``kernels.compact_channels``), keeping
column order equal to list order.
"""
import numpy as np
import pytest

from repro.core import testbeds
from repro.core.runner import prepare_chunks
from repro.core.schedulers import (
    MultiChunkScheduler,
    ProActiveMultiChunkScheduler,
    SingleChunkScheduler,
)
from repro.core.simulator import Simulation
from repro.core.types import (
    GB,
    KB,
    MB,
    Chunk,
    ChunkType,
    DiskSpec,
    FileSpec,
    NetworkSpec,
    gbps,
)
from repro.eval.fabric import jax_backend
from repro.eval.fabric.driver import FabricSimulation
from repro.eval.fabric.jax_backend import JaxFabricSimulation

#: slow shared pool: long-lived huge files + a dead-time-bound swarm, the
#: regime that drives repeated ProMC moves off still-busy channels
SLOW_POOL = NetworkSpec(
    name="slow-pool",
    bandwidth=gbps(2),
    rtt=60e-3,
    buffer_size=32 * MB,
    disk=DiskSpec(
        streaming_rate=gbps(2),
        per_file_overhead=0.004,
        saturation_cc=8,
        contention=0.02,
        per_channel_rate=gbps(0.4),
    ),
    unhidden_overhead=0.055,
)


def _assert_zero_replays_and_exact(mk, name):
    """Run ``mk()`` on the jax backend: no parked-row replays, and the
    result matches a fresh event-simulator run exactly."""
    jax_backend.reset_sync_stats()
    res = JaxFabricSimulation([mk()], names=[name]).run()[0]
    stats = dict(jax_backend.SYNC_STATS)
    assert stats["post_row_replays"] == 0, (name, stats)
    assert stats["replay_rounds"] == 0, (name, stats)
    ev = mk().run()
    assert res.throughput == pytest.approx(ev.throughput, rel=1e-9), name
    assert res.n_moves == ev.n_moves, name
    return res, ev


def _sim_with_empty_classes(scheduler_cls):
    """Two empty size classes: both complete in the very first sweep —
    the multi-chunk same-sweep completion edge — and for SC the cursor
    walk co-schedules SMALL (concurrency 8) on top of HUGE's running
    wave, which needed the channel axis grown past the old
    ``max(max_cc, K)`` pre-size."""
    chunks = [
        Chunk(
            ctype=ChunkType.SMALL,
            files=[FileSpec(f"s{i}", 4 * MB) for i in range(30)],
        ),
        Chunk(ctype=ChunkType.MEDIUM, files=[]),
        Chunk(ctype=ChunkType.LARGE, files=[]),
        Chunk(
            ctype=ChunkType.HUGE,
            files=[FileSpec(f"h{i}", 8 * GB) for i in range(4)],
        ),
    ]
    sched = scheduler_cls(chunks, testbeds.XSEDE, 8)
    return Simulation(sched.chunks, testbeds.XSEDE, sched, tick_period=5.0)


@pytest.mark.parametrize(
    "scheduler_cls",
    [MultiChunkScheduler, ProActiveMultiChunkScheduler],
    ids=["mc", "promc"],
)
def test_multi_chunk_same_sweep_completion(scheduler_cls):
    """Retired park class 1: two chunks completing in the same sweep
    drain through the unrolled on-device handler loop instead of a host
    replay."""
    _assert_zero_replays_and_exact(
        lambda: _sim_with_empty_classes(scheduler_cls),
        scheduler_cls.__name__,
    )


def test_sc_open_wave_needs_no_growth():
    """Retired park class 2: the SC empty-class cascade opens SMALL's
    8-channel wave while HUGE's 2 channels still run (10 > the old
    ``max(max_cc, K) = 8`` device pre-size). The closed-form capacity
    bound sizes the axis up front, so the wave fits without a park."""
    mk = lambda: _sim_with_empty_classes(SingleChunkScheduler)  # noqa: E731
    # the bound must cover the co-scheduled waves (conc 8 + conc 2)
    fs = FabricSimulation([mk()])
    fs.start()
    need_c, need_p = fs.capacity_need()
    assert need_c >= 10
    assert need_p == need_c + 1
    _assert_zero_replays_and_exact(mk, "sc-open-wave")


def _resume_stack_sim():
    """ProMC with patience=1 on a slow pool: the huge chunk's ETA stays
    the smallest while its 512 MB files outlive many 1-second ticks, so
    each tick's move victims a *busy* huge channel and pushes its
    in-flight remainder — the resume stack reaches depth 7, past the old
    fixed P=4 that forced the prospective-overflow park."""
    files = [FileSpec(f"a{i}", 512 * MB) for i in range(10)] + [
        FileSpec(f"b{i}", 128 * KB) for i in range(12000)
    ]
    chunks = prepare_chunks(files, SLOW_POOL, 4, 30)
    sched = ProActiveMultiChunkScheduler(
        chunks, SLOW_POOL, 30, patience=1, ratio=1.2
    )
    return Simulation(sched.chunks, SLOW_POOL, sched, tick_period=1.0)


def test_resume_stack_overflow_stays_on_device():
    """Retired park class 3: resume pushes past the old stack capacity.
    First confirm the scenario really drives the stack past 4 (the old
    pre-size) on the NumPy driver, then hold the jax run to zero
    replays."""
    fs = FabricSimulation([_resume_stack_sim()])
    fs.start()
    peak = 0
    while not fs.done.all():
        fs.step()
        peak = max(peak, int(fs.prepend_n.max()))
    assert peak > 4, f"scenario lost its bite (peak stack depth {peak})"
    # the closed-form stack bound really bounds the observed depth
    # (fs.P itself grows on demand on the NumPy driver, so compare
    # against capacity_need, not the grown axis)
    assert peak < fs.capacity_need()[1]
    _assert_zero_replays_and_exact(_resume_stack_sim, "resume-stack")


def test_channel_order_tie_regression():
    """Moves into a channel-starved chunk create two idle channels with
    different residual dead times; victim selection must follow the
    event simulator's list order, not recycled column order (pre-fix the
    fabric backends drifted ~4e-4 here and dropped two moves)."""
    files = [FileSpec(f"a{i}", 512 * MB) for i in range(10)] + [
        FileSpec(f"b{i}", 256 * KB) for i in range(2000)
    ]

    def mk():
        chunks = prepare_chunks(files, SLOW_POOL, 4, 24)
        sched = ProActiveMultiChunkScheduler(
            chunks, SLOW_POOL, 24, patience=1, ratio=1.01
        )
        return Simulation(sched.chunks, SLOW_POOL, sched, tick_period=1.0)

    ev = mk().run()
    nres = FabricSimulation([mk()]).run()[0]
    assert nres.throughput == pytest.approx(ev.throughput, rel=1e-9)
    assert nres.n_moves == ev.n_moves
    _assert_zero_replays_and_exact(mk, "order-tie")


def test_full_run_reports_zero_replays_on_smoke():
    """The invariant the CI fused-jit leg gates on, at test scale: a
    smoke-matrix cross-section jax run finishes with zero parked-row
    replays (CI's ``difftest --expect-zero-replays`` covers the sampled
    full matrix)."""
    from repro.eval.runner import run_matrix
    from repro.eval.scenarios import smoke_matrix

    jax_backend.reset_sync_stats()
    run_matrix(smoke_matrix()[::3], backend="jax")
    stats = dict(jax_backend.SYNC_STATS)
    assert stats["post_row_replays"] == 0, stats
    assert stats["replay_rounds"] == 0, stats
    assert stats["scenarios"] > 0
