"""Columnar ingest equivalence: the ``ScenarioPlan`` batch constructor
must be *bit-identical* to the legacy per-row object path.

The plan path (``build_plan`` -> ``FabricSimulation(None, plan=...)``)
replaces ``build_simulation`` -> per-row ``Simulation`` objects -> driver
array packing with vectorized NumPy, building each transfer context
(network, dataset, seed, effective chunks) once and broadcasting it
across candidate-expanded rows. Nothing about that is allowed to change
numerics: every resident driver array, the runtime metadata, and the
final results must match the legacy build exactly — not within
tolerance — so the legacy path stays a usable difftest reference.
"""
from __future__ import annotations

import queue
import threading

import numpy as np
import pytest

from repro.eval.fabric import plan as plan_mod
from repro.eval.fabric.driver import (
    _ROW_ARRAYS,
    KIND_MC,
    KIND_PROMC,
    KIND_SC,
    FabricSimulation,
)
from repro.eval.fabric.plan import ScenarioPlan, build_plan, plan_supported
from repro.eval.runner import run_matrix
from repro.eval.scenarios import (
    build_simulation,
    expand_candidates,
    full_matrix,
)

#: a slice covering every algorithm family, the k/max_cc sweeps, and the
#: time-varying (profiled-bandwidth) tail of the full grid, plus a
#: candidate-expanded block so the broadcast path is exercised
_CANDS = [(0, 1, 1), (4, 4, 8), (16, 2, 2)]


def _slice():
    m = full_matrix()
    scs = m[:40] + m[700:740] + m[930:970] + m[1090:1116]
    return scs + expand_candidates(m[:5] + m[1090:1093], _CANDS)


@pytest.fixture(scope="module")
def pair():
    scs = _slice()
    assert plan_supported(scs)
    legacy = FabricSimulation(
        [build_simulation(sc) for sc in scs], names=[sc.name for sc in scs]
    )
    planned = FabricSimulation(None, plan=build_plan(scs))
    return legacy, planned


def _assert_rows_identical(legacy, planned):
    for a in _ROW_ARRAYS:
        if a == "qoff":
            continue  # buffer layouts differ; gathered slices checked below
        x, y = getattr(legacy, a), getattr(planned, a)
        assert x.shape == y.shape, a
        assert x.dtype == y.dtype, a
        if np.issubdtype(x.dtype, np.floating):
            eq = (x == y) | (np.isnan(x) & np.isnan(y))
        else:
            eq = x == y
        assert np.all(eq), (a, np.argwhere(~np.asarray(eq))[:3])


def test_plan_arrays_bit_identical(pair):
    legacy, planned = pair
    _assert_rows_identical(legacy, planned)


def test_plan_qsizes_slices_identical(pair):
    # qoff points into differently-laid-out flat buffers, so compare the
    # gathered per-(row, chunk) file-size slices instead of the offsets
    legacy, planned = pair
    for s in range(legacy.S):
        for k in range(legacy.K):
            n = int(legacy.qlen[s, k])
            lo_l, lo_p = int(legacy.qoff[s, k]), int(planned.qoff[s, k])
            assert np.array_equal(
                legacy.qsizes[lo_l:lo_l + n],
                planned.qsizes[lo_p:lo_p + n],
            ), (s, k)


def test_plan_runtime_metadata(pair):
    legacy, planned = pair
    for rl, rp in zip(legacy.rt, planned.rt):
        assert rl.name == rp.name
        assert rl.scheduler.name == rp.scheduler.name, rl.name
        assert [c.name for c in rl.chunks] == [c.name for c in rp.chunks]
        assert rl.total_bytes == rp.total_bytes
        assert rl.network.name == rp.network.name


def test_plan_run_bit_identical(pair):
    # start() materializes the remaining derived state; run() drives the
    # NumPy backend end to end — both must agree exactly, row by row
    legacy, planned = pair
    legacy.start()
    planned.start()
    _assert_rows_identical(legacy, planned)
    for a, b in zip(legacy.run(), planned.run()):
        assert a.throughput == b.throughput, a.scheduler
        assert a.total_time == b.total_time, a.scheduler
        assert a.n_events == b.n_events, a.scheduler
        assert a.per_chunk_time == b.per_chunk_time, a.scheduler


def test_run_matrix_plan_equals_legacy():
    # the runner-level toggle: same chunking, executor, and results
    # whether rows arrive as a plan or as per-row objects
    scs = _slice()[:60]
    res_p = run_matrix(scs, backend="numpy", ingest="plan")
    res_l = run_matrix(scs, backend="numpy", ingest="legacy")
    assert len(res_p) == len(res_l) == len(scs)
    for a, b in zip(res_p, res_l):
        assert a.total_time == b.total_time
        assert a.throughput == b.throughput
        assert a.n_events == b.n_events


def test_full_matrix_groups_to_258_contexts():
    # the 1116-row grid dedups to exactly the documented 258 transfer
    # contexts — the oracle plane's outer axis (258 contexts x 64
    # candidates = 16,675 evals with the heuristic rows included)
    from repro.eval.tune.oracle import context_key, group_contexts

    m = full_matrix()
    keys, reps = group_contexts(m)
    assert len(keys) == 258
    assert len(reps) == 258
    # every scenario maps onto one of the deduped keys
    assert {context_key(sc) for sc in m} == set(keys)


def test_context_key_ignores_candidate_suffix():
    # expand_candidates rewrites algorithm/static_params and suffixes the
    # name; the context key must see through all of that so candidate
    # rows land in their base scenario's context
    from repro.eval.tune.oracle import context_key

    base = full_matrix()[:24]
    for sc in base:
        for cand in expand_candidates([sc], _CANDS):
            assert cand.name != sc.name  # suffixed
            assert context_key(cand) == context_key(sc)


def test_candidate_expansion_shares_plan_contexts():
    # candidate rows differ only in static params + a name suffix, so
    # the plan's context dedup (network, dataset, seed, effective
    # chunks) partitions each context's file set once and broadcasts —
    # widening the candidate axis must add zero new contexts
    base = full_matrix()[:12]

    def n_ctx(scs):
        p = build_plan(scs)
        return len(
            {
                (int(p.net_idx[i]),)
                + tuple(p.qoff[i])
                + tuple(p.qlen[i])
                for i in range(len(p))
            }
        )

    one = n_ctx(base + expand_candidates(base, _CANDS[:1]))
    many = n_ctx(base + expand_candidates(base, _CANDS))
    assert many == one


def test_plan_kind_codes_pinned():
    # the plan's scheduler-kind codes feed straight into the driver's
    # kind column; a renumbering on either side would silently swap
    # controller semantics
    assert plan_mod._KIND_SC == KIND_SC
    assert plan_mod._KIND_MC == KIND_MC
    assert plan_mod._KIND_PROMC == KIND_PROMC


def test_take_preserves_columns():
    plan = build_plan(_slice())
    idx = [5, 0, 17, 101]
    sub = plan.take(idx)
    assert isinstance(sub, ScenarioPlan)
    assert sub.names == [plan.names[i] for i in idx]
    assert np.array_equal(sub.kind, plan.kind[idx])
    assert np.array_equal(sub.qoff, plan.qoff[idx])
    assert sub.qsizes is plan.qsizes  # shared, not copied


def test_warm_loop_stop_drops_pending():
    # fail-fast contract: once the pipeline's stop event is set, queued
    # warm work is discarded (no stray multi-second compiles after a
    # worker error) but the sentinel still terminates the thread
    from repro.eval.fabric.executor import _warm_loop

    warmed = []
    stop = threading.Event()
    q = queue.Queue()
    q.put("a")
    q.put("b")
    q.put(None)
    _warm_loop(q, stop, warm=warmed.append)
    assert warmed == ["a", "b"]

    warmed.clear()
    stop.set()
    q.put("c")
    q.put("d")
    q.put(None)
    t = threading.Thread(target=_warm_loop, args=(q, stop, warmed.append))
    t.start()
    t.join(timeout=10)
    assert not t.is_alive()
    assert warmed == []
