"""Training loop, checkpoint/restart, fault-tolerance integration tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.distributed.fault import RestartPolicy
from repro.models.config import reduce_for_smoke
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train, train_with_restarts
from repro.train.serve_step import generate
from repro.train.train_step import StepConfig, init_train_state, make_train_step


def _setup(arch="llama3.2-3b", batch=4, seq=32):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    data = SyntheticLM(cfg, DataConfig(global_batch=batch, seq_len=seq))
    scfg = StepConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                              weight_decay=0.0)
    )
    return cfg, model, data, scfg


def test_loss_decreases():
    cfg, model, data, scfg = _setup()
    res = train(
        model, scfg, data.batches(), LoopConfig(total_steps=40, log_every=5)
    )
    hist = res["history"]
    first = np.mean([h["loss"] for h in hist[:2]])
    last = np.mean([h["loss"] for h in hist[-2:]])
    assert last < first * 0.9, f"loss did not decrease: {first} -> {last}"


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, data, scfg = _setup()
    state = init_train_state(model, jax.random.PRNGKey(0))
    path = ckpt.save(state, str(tmp_path), 7)
    assert os.path.exists(os.path.join(path, "index.json"))
    loaded, step = ckpt.restore(str(tmp_path))
    assert step == 7
    orig = jax.tree.leaves(state)
    rest = jax.tree.leaves(loaded)
    assert len(orig) == len(rest)
    for a, b in zip(orig, rest):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg, model, data, scfg = _setup()
    state = init_train_state(model, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(state, str(tmp_path), s, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = ckpt._committed_steps(str(tmp_path))
    assert sorted(steps) == [4, 5]


@pytest.mark.slow
def test_crash_resume_bit_exact(tmp_path):
    """Train 30 steps straight vs train-crash-at-20-resume: same final state."""
    cfg, model, data, scfg = _setup()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    res_straight = train(
        model, scfg, data.batches(),
        LoopConfig(total_steps=30, ckpt_every=10, ckpt_dir=d1,
                   async_ckpt=False, log_every=30),
    )

    with pytest.raises(RuntimeError, match="injected crash"):
        train(
            model, scfg, data.batches(),
            LoopConfig(total_steps=30, ckpt_every=10, ckpt_dir=d2,
                       async_ckpt=False, log_every=30),
            crash_at=25,  # crashes after ckpt at step 20
        )
    res_resumed = train(
        model, scfg, data.batches(),
        LoopConfig(total_steps=30, ckpt_every=10, ckpt_dir=d2,
                   async_ckpt=False, log_every=30),
    )
    a = jax.tree.leaves(res_straight["state"]["params"])
    b = jax.tree.leaves(res_resumed["state"]["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_supervisor_restarts_until_success(tmp_path):
    cfg, model, data, scfg = _setup()
    d = str(tmp_path / "sup")
    attempts = {"n": 0}

    def run_once(batches):
        attempts["n"] += 1
        crash = 12 if attempts["n"] == 1 else None
        return train(
            model, scfg, batches,
            LoopConfig(total_steps=20, ckpt_every=5, ckpt_dir=d,
                       async_ckpt=False, log_every=20),
            crash_at=crash,
        )

    res = train_with_restarts(
        lambda: data.batches(), run_once, RestartPolicy(max_failures=3)
    )
    assert attempts["n"] == 2
    assert int(res["state"]["step"]) == 20


def test_async_checkpointer(tmp_path):
    cfg, model, data, scfg = _setup()
    state = init_train_state(model, jax.random.PRNGKey(1))
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(state, 3)
    saver.wait()
    loaded, step = ckpt.restore(str(tmp_path))
    assert step == 3


def test_generate_runs():
    cfg, model, data, scfg = _setup()
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.array([[1, 2, 3, 4]], jnp.int32)
    toks = generate(model, params, prompt, max_new_tokens=4, max_len=16)
    assert toks.shape == (1, 4)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab_size).all())
