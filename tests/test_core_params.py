"""Algorithm-1 parameter estimation: exact values + the paper's Eq.-1 bound."""
import math

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import MB, GB, find_optimal_parameters
from repro.core import testbeds
from repro.core.params import MAX_PIPELINING
from repro.core.types import gbps


def test_xsede_small_files():
    """XSEDE (Table 1): BDP 75 MB, buffer 32 MB. 1 MB files."""
    bdp = gbps(10) * 60e-3  # 75 MB
    p = find_optimal_parameters(1 * MB, bdp, 32 * MB, max_cc=8)
    # pipelining = ceil(75MB / 1MB) = ceil(71.5...) -- BDP in binary MB ~71.5
    assert p.pipelining == math.ceil(bdp / (1 * MB))
    assert p.pipelining > 30  # large for small files
    # parallelism = min(ceil(BDP/buf)=3, ceil(1MB/32MB)=1) = 1
    assert p.parallelism == 1
    # concurrency = min(max(BDP/avg, 2), 8) = 8 (BDP/avg huge)
    assert p.concurrency == 8


def test_xsede_huge_files():
    bdp = gbps(10) * 60e-3
    p = find_optimal_parameters(10 * GB, bdp, 32 * MB, max_cc=8)
    assert p.pipelining <= 1  # ceil(75MB/10GB) = 1
    # parallelism = min(ceil(BDP/buf)=3, ceil(10GB/32MB)=320) = 3
    assert p.parallelism == 3
    # concurrency = min(max(BDP/avg < 1, 2), 8) = 2: the self-limit of Sec 4.1
    assert p.concurrency == 2


def test_loni_no_buffer_limitation():
    """LONI: BDP (12.5 MB computed) < buffer 16 MB => parallelism 1."""
    bdp = gbps(10) * 10e-3
    p = find_optimal_parameters(10 * GB, bdp, 16 * MB, max_cc=8)
    assert p.parallelism == 1


def test_concurrency_lower_bound_two():
    """Sec. 3.1: lower limit 2 'since concurrency is mostly helpful'."""
    p = find_optimal_parameters(100 * GB, gbps(10) * 40e-3, 32 * MB, max_cc=16)
    assert p.concurrency == 2


def test_concurrency_capped_by_max_cc():
    p = find_optimal_parameters(1 * MB, gbps(10) * 40e-3, 32 * MB, max_cc=6)
    assert p.concurrency == 6


def test_eq1_medium_chunk_self_limit():
    """Paper Eq. 1: for Medium chunks, BDP/avgFileSize in (5*RTT, 20*RTT)
    (RTT in seconds) => concurrency self-limits to 2 whenever RTT < 100 ms."""
    for rtt in (10e-3, 40e-3, 60e-3, 99e-3):
        bw = gbps(10)
        bdp = bw * rtt
        lo, hi = bw / 20, bw / 5  # Medium-chunk size range
        for avg in (lo * 1.01, (lo + hi) / 2, hi * 0.999):
            y = bdp / avg
            assert 5 * rtt < y < 20 * rtt  # the Eq. 1 bound itself
            p = find_optimal_parameters(avg, bdp, 32 * MB, max_cc=32)
            if 20 * rtt < 2:
                assert p.concurrency == 2


def test_num_files_caps():
    p = find_optimal_parameters(1 * MB, gbps(10) * 60e-3, 32 * MB, 8, num_files=3)
    assert p.concurrency <= 3
    assert p.pipelining <= 2


def test_invalid_inputs():
    with pytest.raises(ValueError):
        find_optimal_parameters(0, 1.0, 1.0, 4)
    with pytest.raises(ValueError):
        find_optimal_parameters(1.0, 1.0, 1.0, 0)


@settings(max_examples=300, deadline=None)
@given(
    avg=st.floats(min_value=1.0, max_value=1e12),
    bw_gbps=st.floats(min_value=0.1, max_value=400),
    rtt=st.floats(min_value=1e-5, max_value=0.5),
    buf=st.integers(min_value=64 * 1024, max_value=1024 * MB),
    max_cc=st.integers(min_value=1, max_value=128),
)
def test_param_bounds_property(avg, bw_gbps, rtt, buf, max_cc):
    """Property: outputs are always in their valid ranges."""
    bdp = gbps(bw_gbps) * rtt
    p = find_optimal_parameters(avg, bdp, buf, max_cc)
    assert 0 <= p.pipelining <= MAX_PIPELINING
    assert 1 <= p.parallelism
    assert p.parallelism <= max(1, math.ceil(bdp / buf))
    assert 1 <= p.concurrency <= max(2, max_cc)
    if max_cc >= 2:
        assert 2 <= p.concurrency <= max_cc
    # monotonicity: smaller files never get *less* pipelining
    p_small = find_optimal_parameters(max(avg / 2, 1.0), bdp, buf, max_cc)
    assert p_small.pipelining >= p.pipelining
