"""Data pipeline (prefetcher, engine ingestion) + MoE layer semantics."""
import os
import time

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import testbeds
from repro.data.pipeline import Prefetcher, ingest_files
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import moe as moe_lib
from repro.models.config import reduce_for_smoke
from repro.configs import get_config


# ------------------------------------------------------------------ #
# prefetcher
# ------------------------------------------------------------------ #


def test_prefetcher_preserves_order_and_values():
    out = list(Prefetcher(iter(range(50)), depth=4))
    assert out == list(range(50))


def test_prefetcher_overlaps_production():
    def slow_gen():
        for i in range(5):
            time.sleep(0.02)
            yield i

    pf = Prefetcher(slow_gen(), depth=4)
    time.sleep(0.15)  # producer should have buffered ahead by now
    t0 = time.monotonic()
    first_three = [next(pf), next(pf), next(pf)]
    elapsed = time.monotonic() - t0
    assert first_three == [0, 1, 2]
    assert elapsed < 0.05  # served from the buffer, not the 20ms producer


def test_prefetcher_propagates_exceptions():
    def bad():
        yield 1
        raise ValueError("boom")

    pf = Prefetcher(bad(), depth=2)
    assert next(pf) == 1
    with pytest.raises(ValueError, match="boom"):
        for _ in pf:
            pass


def test_prefetcher_with_synthetic_batches():
    cfg = reduce_for_smoke(get_config("llama3.2-3b"))
    data = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=16))
    direct = [b["tokens"] for b in data.batches(3)]
    prefetched = [b["tokens"] for b in Prefetcher(data.batches(3), depth=2)]
    for a, b in zip(direct, prefetched):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------ #
# engine-backed ingestion
# ------------------------------------------------------------------ #


def test_ingest_files_roundtrip(tmp_path):
    paths = []
    blobs = {}
    rng = np.random.RandomState(0)
    for i, size in enumerate([1024, 64 * 1024, 5 * 1024 * 1024]):
        p = str(tmp_path / f"f{i}.bin")
        data = rng.bytes(size)
        with open(p, "wb") as f:
            f.write(data)
        paths.append(p)
        blobs[p] = data
    out = ingest_files(paths, max_cc=3)
    assert set(out) == set(paths)
    for p in paths:
        assert out[p] == blobs[p]


# ------------------------------------------------------------------ #
# MoE layer semantics
# ------------------------------------------------------------------ #


def _moe_setup(e=4, k=2, d=16, f=32, seed=0):
    key = jax.random.PRNGKey(seed)
    params = moe_lib.moe_param_init(key, d, e, f, num_shared=0, glu=True)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d)) * 0.5
    return params, x


def test_moe_output_shape_and_finite():
    params, x = _moe_setup()
    y, aux = moe_lib.moe_ffn(
        params, x, num_experts=4, top_k=2, capacity_factor=1.25,
        act="silu", glu=True,
    )
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0  # Switch aux loss is positive


def test_moe_aux_loss_near_one_for_uniform_router():
    """With near-uniform routing, E * sum(f_e * P_e) ~ 1."""
    params, x = _moe_setup()
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    _, aux = moe_lib.moe_ffn(
        params, x, num_experts=4, top_k=2, capacity_factor=1.25,
        act="silu", glu=True,
    )
    assert 0.5 < float(aux) < 2.0


def test_moe_group_count_divides_tokens():
    for t in (1, 2, 31, 32, 64, 100, 4096, 128 * 4096):
        g = moe_lib._num_groups(t)
        assert t % g == 0
        assert 1 <= g <= max(moe_lib.DISPATCH_GROUPS, 1)


def test_moe_group_target_knob():
    old = moe_lib.DISPATCH_TARGET_TG
    try:
        moe_lib.DISPATCH_TARGET_TG = 2048
        t = 1024 * 1024
        g = moe_lib._num_groups(t)
        assert t % g == 0
        assert t // g <= 2048 * 2  # group size near the target
    finally:
        moe_lib.DISPATCH_TARGET_TG = old


def test_moe_capacity_drops_overflow_gracefully():
    """With capacity factor << 1, outputs shrink toward zero but stay
    finite (dropped tokens contribute nothing)."""
    params, x = _moe_setup()
    y_full, _ = moe_lib.moe_ffn(
        params, x, num_experts=4, top_k=2, capacity_factor=2.0,
        act="silu", glu=True,
    )
    y_tight, _ = moe_lib.moe_ffn(
        params, x, num_experts=4, top_k=2, capacity_factor=0.1,
        act="silu", glu=True,
    )
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.linalg.norm(y_tight)) <= float(jnp.linalg.norm(y_full))


def test_moe_shared_experts_add_dense_path():
    key = jax.random.PRNGKey(3)
    d, f = 16, 32
    params = moe_lib.moe_param_init(key, d, 4, f, num_shared=2, glu=True)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, d)) * 0.5
    y, _ = moe_lib.moe_ffn(
        params, x, num_experts=4, top_k=2, capacity_factor=1.25,
        act="silu", glu=True,
    )
    # zero the routed experts: shared path must still produce signal
    zeroed = dict(params)
    for k_ in ("we_up", "we_down", "we_gate"):
        zeroed[k_] = jnp.zeros_like(params[k_])
    y_shared, _ = moe_lib.moe_ffn(
        zeroed, x, num_experts=4, top_k=2, capacity_factor=1.25,
        act="silu", glu=True,
    )
    assert float(jnp.linalg.norm(y_shared)) > 0


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([8, 16, 64]),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(min_value=1, max_value=2),
)
def test_moe_property_finite_everywhere(t, e, k):
    key = jax.random.PRNGKey(t * e + k)
    d, f = 8, 16
    params = moe_lib.moe_param_init(key, d, e, f, num_shared=0, glu=False)
    x = jax.random.normal(jax.random.fold_in(key, 9), (1, t, d))
    y, aux = moe_lib.moe_ffn(
        params, x, num_experts=e, top_k=k, capacity_factor=1.0,
        act="gelu", glu=False,
    )
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))
