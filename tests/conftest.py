"""Test-suite bootstrap.

Offline environments (like the container this repo grows in) don't ship the
``hypothesis`` distribution. Six test modules are property suites, so instead
of skipping them we vendor a tiny API-compatible shim under ``tests/_compat``
and put it on ``sys.path`` *only when the real library is missing* — an
installed hypothesis always takes precedence. See TESTING.md.
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))
