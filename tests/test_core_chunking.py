"""Unit + property tests for Fig.-3 dataset partitioning."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (
    MB,
    ChunkType,
    FileSpec,
    partition_files,
    size_thresholds,
)
from repro.core import testbeds
from repro.core.chunking import classify
from repro.core.types import gbps


def test_thresholds_10gbps():
    """On a 10 Gbps link the cuts are BW/20, BW/5, BW = 62.5 MB, 250 MB, 1.25 GB."""
    bw = gbps(10)
    t4 = size_thresholds(bw, 4)
    assert t4 == [bw / 20, bw / 5, bw]
    assert t4[0] == pytest.approx(62.5e9 / 8 / 20 * 20 / 20 * 20 / 20, rel=1)  # sanity
    assert t4[0] == pytest.approx(gbps(10) / 20)
    assert size_thresholds(bw, 1) == []
    assert size_thresholds(bw, 2) == [bw / 20]
    assert size_thresholds(bw, 3) == [bw / 20, bw / 5]


def test_thresholds_reject_bad_counts():
    for n in (0, 5, -1):
        with pytest.raises(ValueError):
            size_thresholds(gbps(10), n)


def test_classify_boundaries():
    th = [10.0, 100.0]
    assert classify(5.0, th) == 0
    assert classify(10.0, th) == 0  # inclusive upper bound (<=)
    assert classify(10.1, th) == 1
    assert classify(100.0, th) == 1
    assert classify(101.0, th) == 2


def test_partition_four_chunks_labels():
    net = testbeds.STAMPEDE_COMET  # 10 Gbps
    files = [
        FileSpec("tiny", 1 * MB),  # <= 62.5 MB -> SMALL
        FileSpec("med", 100 * MB),  # <= 250 MB -> MEDIUM
        FileSpec("big", 500 * MB),  # <= 1250 MB -> LARGE
        FileSpec("huge", 4000 * MB),  # > 1250 MB -> HUGE
    ]
    chunks = partition_files(files, net, 4)
    assert [c.ctype for c in chunks] == [
        ChunkType.SMALL,
        ChunkType.MEDIUM,
        ChunkType.LARGE,
        ChunkType.HUGE,
    ]
    assert all(len(c) == 1 for c in chunks)


def test_partition_two_chunks_merges_upper():
    """2-chunk = Small | rest-as-one (Sec. 4.1 example)."""
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec("a", 1 * MB), FileSpec("b", 500 * MB), FileSpec("c", 4000 * MB)]
    chunks = partition_files(files, net, 2)
    assert [c.ctype for c in chunks] == [ChunkType.SMALL, ChunkType.LARGE]
    assert len(chunks[1]) == 2


def test_one_chunk_is_all():
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec("a", 1 * MB), FileSpec("b", 4000 * MB)]
    chunks = partition_files(files, net, 1)
    assert len(chunks) == 1
    assert chunks[0].ctype == ChunkType.ALL
    assert len(chunks[0]) == 2


def test_empty_chunks_dropped():
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec("a", 1 * MB)]  # only SMALL present
    chunks = partition_files(files, net, 4)
    assert len(chunks) == 1
    assert chunks[0].ctype == ChunkType.SMALL


@settings(max_examples=200, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=20 * 1024**3), min_size=1, max_size=60),
    num_chunks=st.integers(min_value=1, max_value=4),
)
def test_partition_is_exact_partition(sizes, num_chunks):
    """Property: every file lands in exactly one chunk; bytes conserved;
    chunks are ordered by size class and internally within thresholds."""
    net = testbeds.STAMPEDE_COMET
    files = [FileSpec(f"f{i}", s) for i, s in enumerate(sizes)]
    chunks = partition_files(files, net, num_chunks)
    out_names = [f.name for c in chunks for f in c.files]
    assert sorted(out_names) == sorted(f.name for f in files)
    assert sum(c.total_bytes for c in chunks) == sum(s for s in sizes)
    assert len(chunks) <= num_chunks
    # class boundaries respected
    th = size_thresholds(net.bandwidth, num_chunks)
    for c in chunks:
        idx = [classify(f.size, th) for f in c.files]
        assert len(set(idx)) == 1
