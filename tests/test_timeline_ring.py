"""On-device timeline ring buffer: decimation-kernel properties and
cross-backend timeline equivalence.

The fabric backends record (t, aggregate rate) samples through
``kernels.timeline_push`` — a streaming uniform-stride decimator over a
fixed per-scenario budget — instead of host-side list appends, which is
what lets timeline-recording scenarios stay inside the JAX device loop.
These tests pin:

  * the kernel's invariants (monotone t, first/last sample preserved,
    budget respected, stored samples a uniform-stride subsequence);
  * bit-identical recording between the NumPy and JAX instantiations on
    the same sample stream;
  * end-to-end equivalence on every timeline-recording scenario of the
    matrix (``timeline_matrix``): numpy == jax bit-for-bit, and both
    match the event backend's host-appended timeline at the decimation
    stride's candidate indices.
"""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.eval.fabric import kernels
from repro.eval.fabric.driver import FabricSimulation
from repro.eval.fabric.shim import jax_ops, numpy_ops
from repro.eval.scenarios import build_simulation, timeline_matrix

# ------------------------------------------------------------------ #
# decimation-kernel properties (scalar stream through the batched kernel)
# ------------------------------------------------------------------ #


def _record_stream(ops, xp, samples, budget):
    """Push a (t, rate) stream through timeline_push on one row."""
    buf_t = xp.zeros((1, budget))
    buf_r = xp.zeros((1, budget))
    length = xp.zeros(1, dtype=xp.int64)
    stride = xp.ones(1, dtype=xp.int64)
    seen = xp.zeros(1, dtype=xp.int64)
    last_t = xp.zeros(1)
    last_r = xp.zeros(1)
    rec = xp.ones(1, dtype=bool)
    for t, r in samples:
        buf_t, buf_r, length, stride, seen, last_t, last_r = (
            kernels.timeline_push(
                ops, rec, xp.full(1, t), xp.full(1, r), buf_t, buf_r,
                length, stride, seen, last_t, last_r,
            )
        )
    return buf_t, buf_r, length, stride, seen, last_t, last_r


@settings(max_examples=60, deadline=None)
@given(
    dts=st.lists(
        st.floats(min_value=1e-3, max_value=100.0), min_size=1, max_size=200
    ),
    budget=st.sampled_from([2, 3, 4, 7, 8, 16, 32]),
)
def test_timeline_push_invariants(dts, budget):
    ops = numpy_ops()
    ts = np.cumsum(dts)
    samples = [(float(t), float(i)) for i, t in enumerate(ts)]
    state = _record_stream(ops, np, samples, budget)
    buf_t, buf_r, length, stride, seen, last_t, last_r = (
        np.asarray(a) for a in state
    )
    n, s = int(length[0]), int(stride[0])
    assert int(seen[0]) == len(samples)
    assert 0 < n <= budget
    # stored samples are exactly the candidates at indices {0, s, 2s, ...}
    for j in range(n):
        want_t, want_r = samples[j * s]
        assert buf_t[0, j] == want_t and buf_r[0, j] == want_r
    # monotone t, first sample preserved
    assert (np.diff(buf_t[0, :n]) > 0).all() or n == 1
    assert buf_t[0, 0] == samples[0][0]
    # finalize: budget respected, first/last preserved
    out = kernels.timeline_samples(
        buf_t[0], buf_r[0], length[0], stride[0], seen[0], last_t[0],
        last_r[0],
    )
    assert len(out) <= budget
    assert out[0] == samples[0] or len(samples) > 1 and out[0] == samples[0]
    assert out[-1] == samples[-1]
    assert all(a[0] < b[0] for a, b in zip(out, out[1:]))


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=120),
    budget=st.sampled_from([2, 4, 8, 16]),
)
def test_timeline_push_numpy_jax_bit_identical(n, budget):
    """The same sample stream records bit-identically on both ArrayOps
    instantiations (the kernel is pure selects — no float arithmetic)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    rng = np.random.RandomState(n * 1000 + budget)
    ts = np.cumsum(rng.uniform(1e-3, 10.0, size=n))
    samples = [(float(t), float(rng.uniform(0, 1e9))) for t in ts]
    np_state = _record_stream(numpy_ops(), np, samples, budget)
    with enable_x64():
        jx_state = _record_stream(jax_ops(), jnp, samples, budget)
    for a, b in zip(np_state, jx_state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_timeline_push_masked_rows_freeze():
    """Rows with rec=False pass through every array untouched."""
    ops = numpy_ops()
    buf_t = np.arange(8, dtype=np.float64).reshape(1, 8)
    buf_r = buf_t * 2
    state = kernels.timeline_push(
        ops, np.zeros(1, dtype=bool), np.full(1, 99.0), np.full(1, 1.0),
        buf_t, buf_r, np.full(1, 3, dtype=np.int64),
        np.ones(1, dtype=np.int64), np.full(1, 3, dtype=np.int64),
        np.zeros(1), np.zeros(1),
    )
    np.testing.assert_array_equal(state[0], buf_t)
    np.testing.assert_array_equal(state[1], buf_r)
    assert int(state[2][0]) == 3 and int(state[4][0]) == 3


# ------------------------------------------------------------------ #
# end-to-end: every timeline-recording scenario of the matrix
# ------------------------------------------------------------------ #


def _fabric_timelines(cls, scenarios, **kw):
    sims = [build_simulation(s) for s in scenarios]
    results = cls(sims, names=[s.name for s in scenarios], **kw).run()
    return [r.timeline for r in results]


def _assert_ordered_submatch(sub, full, name, rtol=1e-9, atol=1e-6):
    """Every (t, rate) of ``sub`` matches some sample of ``full``, in
    order. The fluid backends may coalesce a zero-dt event boundary the
    scalar loop splits in two (their sweep counts differ by a handful of
    duplicate-t samples), so sample-for-sample equality is too strict —
    an ordered match within tolerance is the contract."""

    def close(a, b):
        return all(
            abs(x - y) <= atol + rtol * abs(y) for x, y in zip(a, b)
        )

    i = 0
    for s in sub:
        while i < len(full) and not close(s, full[i]):
            i += 1
        assert i < len(full), (
            f"{name}: fabric sample {s} not found in order in the event "
            "timeline"
        )
        i += 1


def _check_timeline_grid(scenarios):
    from repro.eval.fabric.jax_backend import JaxFabricSimulation

    assert scenarios and all(s.record_timeline for s in scenarios)
    event = [build_simulation(s).run().timeline for s in scenarios]
    numpy_tl = _fabric_timelines(FabricSimulation, scenarios)
    jax_tl = _fabric_timelines(JaxFabricSimulation, scenarios)
    for s, te, tn, tj in zip(scenarios, event, numpy_tl, jax_tl):
        assert tn == tj, f"numpy/jax timelines differ on {s.name}"
        assert abs(len(tn) - len(te)) <= max(2, len(te) // 20), s.name
        assert tn[0] == te[0], s.name
        np.testing.assert_allclose(
            np.asarray(tn[-1]), np.asarray(te[-1]), rtol=1e-9, atol=1e-6,
            err_msg=s.name,
        )
        _assert_ordered_submatch(tn, te, s.name)


def test_timeline_slice_backends_agree():
    """Tier-1 slice: the jax ring buffer is bit-identical to the numpy
    kernel's and both match the event backend's host-appended timeline
    (ordered match within tolerance) on a cross-section of the
    timeline-recording matrix."""
    _check_timeline_grid(timeline_matrix()[::4])


@pytest.mark.slow
def test_timeline_matrix_backends_agree():
    """Every timeline-recording scenario of the matrix, all three
    backends (the satellite acceptance grid; tier-1 runs the slice)."""
    _check_timeline_grid(timeline_matrix())


def test_timeline_decimated_slice():
    """Force decimation with a tiny budget: the decimated timeline is the
    exact uniform-stride subsequence of the same backend's full recording
    (bit-for-bit, first/last preserved), and numpy/jax stay identical."""
    from repro.eval.fabric.jax_backend import JaxFabricSimulation

    scenarios = timeline_matrix()[:3]
    budget = 16
    full_tl = _fabric_timelines(
        FabricSimulation, scenarios, timeline_budget=1 << 16
    )
    numpy_tl = _fabric_timelines(
        FabricSimulation, scenarios, timeline_budget=budget
    )
    jax_tl = _fabric_timelines(
        JaxFabricSimulation, scenarios, timeline_budget=budget
    )
    for s, tf, tn, tj in zip(scenarios, full_tl, numpy_tl, jax_tl):
        assert tn == tj, f"numpy/jax timelines differ on {s.name}"
        assert len(tf) > budget, (
            f"{s.name} too short to exercise decimation"
        )
        assert len(tn) <= budget
        # expected stride follows the kernel's halve-when-full walk over
        # the same candidate stream the full recording captured
        stride, length = 1, 0
        for i in range(len(tf)):
            if i % stride == 0:
                if length >= budget:
                    length, stride = (length + 1) // 2, stride * 2
                if i % stride == 0 and length < budget:
                    length += 1
        body, last = tn[:-1], tn[-1]
        assert body == [tf[j * stride] for j in range(len(body))], s.name
        assert last == tf[-1], s.name


def test_timeline_budget_validation():
    sims = [build_simulation(timeline_matrix()[0])]
    with pytest.raises(ValueError):
        FabricSimulation(sims, timeline_budget=1)
