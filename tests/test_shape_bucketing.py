"""Canonical shape bucketing + compile-tax regressions.

The jax driver's only ``jax.jit`` (``jax_backend._device_rounds``) keys
its compile cache on the shape of every carried array, so any raw shape
that leaks into the signature is a fresh multi-second XLA compile. These
tests pin the three layers that keep the signature set small:

* the pad-ladder primitives (:mod:`repro.eval.fabric.bucketing`);
* the *compile count* itself — two batches with different raw shapes
  that bucket identically must share one compiled program;
* the ladder canary — the planned full 1116-scenario grid, including
  every quarter-step compaction rung, stays within 8 signatures.

Plus the two bugfix satellites that ride along: the byte-bounded
fileset cache (:mod:`repro.eval.scenarios`) and the fused Pallas
advance+feed step (:mod:`repro.eval.fabric.kernels.fused_step_pallas`).
"""
import tracemalloc

import pytest

from repro.eval import Scenario
from repro.eval import scenarios as scenarios_mod
from repro.eval.fabric import jax_backend
from repro.eval.fabric.bucketing import (
    MIN_ROW_PAD,
    MIN_SPAN,
    QSIZES_FLOOR,
    bucket,
    canonical_signature,
    chunk_spans,
    signature_ladder,
)
from repro.eval.fabric.driver import FabricSimulation
from repro.eval.fabric.jax_backend import JaxFabricSimulation
from repro.eval.fabric.kernels import waterfill_pallas as wf_pallas
from repro.eval.runner import (
    BACKEND_CHUNK_SIZE,
    _cost_proxy,
    _effective_cc,
    build_matrix,
    shape_hint,
)
from repro.eval.scenarios import build_simulation

# ------------------------------------------------------------------ #
# pad-ladder primitives
# ------------------------------------------------------------------ #


def test_bucket_is_pow2_ceiling():
    assert bucket(1) == 1
    assert bucket(2) == 2
    assert bucket(3) == 4
    assert bucket(276) == 512
    assert bucket(1024) == 1024
    assert bucket(1025) == 2048
    # floors
    assert bucket(0) == 1
    assert bucket(3, MIN_ROW_PAD) == MIN_ROW_PAD
    assert bucket(5, QSIZES_FLOOR) == QSIZES_FLOOR


@pytest.mark.parametrize("n", [1, 7, 63, 64, 276, 1000, 1116, 4096])
@pytest.mark.parametrize("size", [64, 256, 1024])
@pytest.mark.parametrize("aligned", [False, True])
def test_chunk_spans_cover_exactly(n, size, aligned):
    spans = chunk_spans(n, size, pad_aligned=aligned)
    # contiguous, non-overlapping, complete
    assert spans[0][0] == 0 and spans[-1][1] == n
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    assert all(hi > lo for lo, hi in spans)


def test_chunk_spans_aligned_cuts_pow2():
    # the motivating case: 276 rows become 256 + 20(pad 32), not one
    # 276-row batch sweeping a 512-row device shape
    spans = chunk_spans(276, 1024, pad_aligned=True)
    assert spans == ((0, 256), (256, 276))
    # every span except the final scraps is a power of two >= MIN_SPAN
    for lo, hi in chunk_spans(1116, 1024, pad_aligned=True)[:-1]:
        w = hi - lo
        assert w >= MIN_SPAN and w & (w - 1) == 0


def test_chunk_spans_plain_is_uniform():
    assert chunk_spans(10, 4) == ((0, 4), (4, 8), (8, 10))


# ------------------------------------------------------------------ #
# compile-count regression: raw-shape-different, bucket-identical
# ------------------------------------------------------------------ #

from repro.core import testbeds

_SMALL = Scenario(
    network=testbeds.LAN.name, dataset="uniform_small",
    algorithm="promc", max_cc=1,
)


def _jax_batch(n_rows):
    sims = [build_simulation(_SMALL) for _ in range(n_rows)]
    return JaxFabricSimulation(sims, names=[f"r{i}" for i in range(n_rows)])


def test_bucketed_batches_share_one_compiled_program():
    """3 rows / 120 files and 5 rows / 200 files land on the same
    (S=8, ..., Q=1024) signature: the second batch must add zero
    compiled programs (jit caches for both donation twins plus the
    AOT cache — direct runs may use either)."""
    a, b = _jax_batch(3), _jax_batch(5)
    assert a.S != b.S  # genuinely different raw shapes
    assert a.qsizes.shape != b.qsizes.shape
    ra = a.run()
    n_compiles = jax_backend.compiled_program_count()
    rb = b.run()
    assert jax_backend.compiled_program_count() == n_compiles
    # same scenario -> identical results regardless of batch shape
    assert rb[0].total_time == pytest.approx(ra[0].total_time)
    assert rb[0].total_bytes == ra[0].total_bytes


def test_canonical_signature_matches_planned_shapes():
    fs = FabricSimulation(
        [build_simulation(_SMALL) for _ in range(3)], names=list("abc")
    )
    need_c, need_p = fs.capacity_need()
    while fs.C < need_c:
        fs._grow()
    while fs.P < need_p:
        fs._grow_prepend()
    rows, C, K, P, B, T, Q = canonical_signature(fs)
    assert rows == MIN_ROW_PAD  # 3 -> 8
    assert Q == QSIZES_FLOOR  # 120 files -> 1024 slots
    for axis in (C, K, P, B):
        assert axis & (axis - 1) == 0  # on the ladder


# ------------------------------------------------------------------ #
# pad-ladder canary: the full grid plans to <= 8 signatures
# ------------------------------------------------------------------ #


def test_full_grid_pad_ladder_stays_small():
    """Plan the full 1116-scenario grid exactly as ``run_matrix`` chunks
    it for jax (hint-grouped, cost-sorted, pow2-aligned spans) and count
    canonical signatures, including every quarter-step compaction rung
    each batch could descend through. More than 8 means a shape axis
    started leaking raw values into the jit signature again."""
    m = build_matrix("full")
    size = BACKEND_CHUNK_SIZE["jax"]
    costs = [_cost_proxy(s) for s in m]
    hints = [shape_hint(_effective_cc(s)) for s in m]
    order = sorted(range(len(m)), key=lambda i: (hints[i], costs[i]))
    sigs = set()
    for lo, hi in chunk_spans(len(m), size, pad_aligned=True):
        part = [m[i] for i in order[lo:hi]]
        fs = FabricSimulation(
            [build_simulation(s) for s in part],
            names=[s.name for s in part],
        )
        need_c, need_p = fs.capacity_need()
        while fs.C < need_c:
            fs._grow()
        while fs.P < need_p:
            fs._grow_prepend()
        sig = canonical_signature(fs)
        sigs.add(sig)
        # deterministic quarter-step compaction rungs, COMPACT_FLOOR
        # floor (JaxFabricSimulation._maybe_compact) — the same ladder
        # the executor AOT-warms per chunk
        sigs.update(signature_ladder(sig))
    assert len(sigs) <= 8, sorted(sigs)
    # and each one is entirely on the ladder
    for rows, C, K, P, B, T, Q in sigs:
        for axis in (rows, C, K, P, B, Q):
            assert axis & (axis - 1) == 0


# ------------------------------------------------------------------ #
# byte-bounded fileset cache
# ------------------------------------------------------------------ #


def _drain_files_cache():
    scenarios_mod._files_cache.clear()
    scenarios_mod._files_cache_bytes = 0


def test_files_cache_bounded_by_bytes(monkeypatch):
    """A 64-candidate sweep over distinct filesets must not pin memory
    proportional to the sweep: the cache evicts by approximate bytes and
    the allocation high-water mark stays flat."""
    cap = 64 * 1024  # small enough that 64 uniform_small sets overflow
    monkeypatch.setattr(scenarios_mod, "FILES_CACHE_MAX_BYTES", cap)
    _drain_files_cache()
    before = dict(scenarios_mod.files_cache_info())
    tracemalloc.start()
    try:
        for seed in range(64):
            sc = Scenario(
                network=testbeds.LAN.name, dataset="uniform_small",
                algorithm="sc", seed=seed,
            )
            files = scenarios_mod.build_files(sc)
            assert files  # the builder still works under eviction
            assert scenarios_mod.files_cache_info()["bytes"] <= cap
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    info = scenarios_mod.files_cache_info()
    assert info["evictions"] > before["evictions"]
    assert info["bytes"] <= cap
    # 64 sweeps of a ~6 KB fileset under a 64 KB cap: peak Python
    # allocations stay within a couple MB, not O(sweep size) growth
    assert peak < 8 * 1024 * 1024
    _drain_files_cache()


def test_files_cache_hits_and_identity():
    _drain_files_cache()
    sc = Scenario(network=testbeds.LAN.name, dataset="uniform_small", algorithm="sc")
    a = scenarios_mod.build_files(sc)
    h0 = scenarios_mod.files_cache_info()["hits"]
    b = scenarios_mod.build_files(sc)
    assert scenarios_mod.files_cache_info()["hits"] == h0 + 1
    # fresh list per call, shared frozen specs underneath
    assert a is not b
    assert all(x is y for x, y in zip(a, b))


def test_files_cache_oversized_entry_not_pinned(monkeypatch):
    monkeypatch.setattr(scenarios_mod, "FILES_CACHE_MAX_BYTES", 128)
    _drain_files_cache()
    sc = Scenario(network=testbeds.LAN.name, dataset="uniform_small", algorithm="sc")
    files = scenarios_mod.build_files(sc)
    assert len(files) == 40
    info = scenarios_mod.files_cache_info()
    assert info["entries"] == 0 and info["bytes"] == 0
    _drain_files_cache()


# ------------------------------------------------------------------ #
# Pallas: lowering detection, call caching, fused step fidelity
# ------------------------------------------------------------------ #


def test_pallas_lowering_detection_matches_backend():
    import jax

    expected = jax.default_backend() in wf_pallas._COMPILED_BACKENDS
    assert wf_pallas.supports_compiled_pallas() is expected


def test_pallas_call_cached_per_shape():
    wf_pallas._build_call.cache_clear()
    a = wf_pallas._build_call(8, 4, "float64", True)
    b = wf_pallas._build_call(8, 4, "float64", True)
    c = wf_pallas._build_call(8, 8, "float64", True)
    assert a is b and a is not c
    assert wf_pallas._build_call.cache_info().hits == 1


def test_fused_pallas_step_matches_classic_driver():
    """REPRO_FABRIC_FUSED_STEP=pallas routes resume-free sweeps through
    the single fused kernel; results must match the split-kernel NumPy
    path (identical math modulo the bisected water level, ~1e-12)."""
    scs = [
        _SMALL,
        Scenario(network=testbeds.XSEDE.name, dataset="mixed",
                 algorithm="mc", max_cc=16),
    ]
    classic = FabricSimulation(
        [build_simulation(s) for s in scs], names=[s.name for s in scs]
    ).run()
    fused = FabricSimulation(
        [build_simulation(s) for s in scs],
        names=[s.name for s in scs],
        fused_step="pallas",
    ).run()
    for c, f in zip(classic, fused):
        assert f.total_bytes == c.total_bytes
        assert f.total_time == pytest.approx(c.total_time, rel=1e-9)
        assert f.n_events == c.n_events
