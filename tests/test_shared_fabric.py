"""Shared-fabric multi-tenant coupling: the coupled water-fill kernel vs
the scalar progressive-filling reference, single-tenant bit-identity with
the uncoupled path, multi-tenant difftests across all three backends, and
the coupled capacity pre-sizing bound.

TESTING.md's "Shared fabrics" section documents the coupling semantics
these tests pin.
"""
import math

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.eval.fabric import kernels
from repro.eval.fabric.reference import coupled_fair_share
from repro.eval.fabric.shim import jax_ops, numpy_ops

_NP = numpy_ops()


# ------------------------------------------------------------------ #
# the coupled water-fill kernel
# ------------------------------------------------------------------ #


def _random_coupling(rng, rows, links):
    demand = rng.uniform(0.0, 1e3, size=rows)
    member = rng.uniform(size=(links, rows)) < 0.5
    link_cap = rng.uniform(1.0, 1e3, size=links)
    return demand, member, link_cap


def test_waterfill_coupled_two_link_hand_example():
    # row A rides links 0 and 1 (caps 10 / 2), row B link 0 only: A is
    # bottlenecked at 2 by link 1, B takes the remaining 8 of link 0
    demand = np.array([10.0, 10.0])
    member = np.array([[True, True], [True, False]])
    link_cap = np.array([10.0, 2.0])
    x, levels = kernels.waterfill_coupled(_NP, demand, member, link_cap)
    np.testing.assert_allclose(x, [2.0, 8.0], rtol=1e-9)
    np.testing.assert_allclose(levels, [8.0, 2.0], rtol=1e-9)


def test_waterfill_coupled_no_links_passes_demand_through():
    demand = np.array([3.0, 7.0])
    x, levels = kernels.waterfill_coupled(
        _NP, demand, np.zeros((0, 2), dtype=bool), np.zeros((0,))
    )
    np.testing.assert_allclose(x, demand)
    assert levels.shape == (0,)


def test_waterfill_coupled_unsaturated_links_grant_full_demand():
    demand = np.array([1.0, 2.0, 3.0])
    member = np.ones((2, 3), dtype=bool)
    link_cap = np.array([100.0, 50.0])
    x, levels = kernels.waterfill_coupled(_NP, demand, member, link_cap)
    np.testing.assert_allclose(x, demand)
    assert np.isinf(levels).all()


@settings(max_examples=150, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=12),
    links=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_waterfill_coupled_matches_progressive_filling(rows, links, seed):
    rng = np.random.RandomState(seed)
    demand, member, link_cap = _random_coupling(rng, rows, links)
    x, _ = kernels.waterfill_coupled(_NP, demand, member, link_cap)
    ref = coupled_fair_share(
        list(demand), [list(row) for row in member], list(link_cap)
    )
    np.testing.assert_allclose(x, ref, rtol=1e-6, atol=1e-6)
    # feasibility: no link over capacity, no row over demand
    assert (x <= demand + 1e-6).all()
    load = member @ x
    assert (load <= link_cap * (1 + 1e-6) + 1e-6).all()


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_waterfill_coupled_numpy_and_jax_agree(seed):
    from jax.experimental import enable_x64

    rng = np.random.RandomState(seed)
    demand, member, link_cap = _random_coupling(rng, 8, 3)
    ref, ref_levels = kernels.waterfill_coupled(_NP, demand, member, link_cap)
    with enable_x64():
        import jax.numpy as jnp

        x, levels = kernels.waterfill_coupled(
            jax_ops(), jnp.asarray(demand), jnp.asarray(member),
            jnp.asarray(link_cap),
        )
    np.testing.assert_allclose(np.asarray(x), ref, rtol=1e-12, atol=0)
    np.testing.assert_allclose(
        np.asarray(levels), ref_levels, rtol=1e-12, atol=0
    )


def test_waterfill_level_matches_waterfill_allocation():
    rng = np.random.RandomState(7)
    caps = rng.uniform(0, 100.0, size=(32, 6))
    pool = rng.uniform(0, 400.0, size=32)
    lam = kernels.waterfill_level(_NP, caps, pool)
    alloc = kernels.waterfill(_NP, caps, pool)
    # finite level => allocation is min(caps, level) and sums to the pool
    finite = np.isfinite(lam)
    np.testing.assert_allclose(
        alloc[finite],
        np.minimum(caps[finite], lam[finite, None]),
        rtol=1e-9,
        atol=1e-9,
    )
    np.testing.assert_allclose(
        alloc[finite].sum(axis=-1), pool[finite], rtol=1e-9, atol=1e-6
    )
    # infinite level <=> slack pool: everyone takes their full cap
    np.testing.assert_allclose(alloc[~finite], caps[~finite])
    assert (pool[~finite] >= caps[~finite].sum(axis=-1)).all()


# ------------------------------------------------------------------ #
# end-to-end coupling fidelity (driver / plan / jax vs the event ref)
# ------------------------------------------------------------------ #

import dataclasses  # noqa: E402

from repro.core import testbeds  # noqa: E402
from repro.eval import scenarios as scenarios_mod  # noqa: E402
from repro.eval.fabric import jax_backend  # noqa: E402
from repro.eval.fabric.driver import _NO_CHUNK, FabricSimulation  # noqa: E402
from repro.eval.fabric.plan import build_plan  # noqa: E402
from repro.eval.fabric.shared import SharedFabric  # noqa: E402
from repro.eval.runner import _group_atomic_parts, run_matrix  # noqa: E402
from repro.eval.scenarios import (  # noqa: E402
    Scenario,
    build_simulation,
    tenant_matrix,
)


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-12)


def _fab(group, cap, tenant="", links=("bb",)):
    return SharedFabric(
        group=group, links=tuple(links),
        capacity=(float(cap),) * len(links), tenant=tenant,
    )


def test_single_tenant_generous_link_bit_identical():
    """A lone tenant on a link that never binds must be BIT-identical to
    the uncoupled path on both batched backends: the coupled demand is
    min(pool, caps_total) and an unsaturated water-fill grants it back
    unchanged, so the physics never sees the fabric."""
    base = Scenario(
        network="didclab-lan-glusterfs", dataset="mixed", algorithm="mc"
    )
    bw = testbeds.TESTBEDS[base.network].bandwidth
    coupled = dataclasses.replace(
        base, shared_fabric=_fab("solo", 2.0 * bw)
    )
    for backend in ("numpy", "jax"):
        a = run_matrix([coupled], backend=backend)[0]
        b = run_matrix([base], backend=backend)[0]
        assert a.total_time == b.total_time, backend
        assert a.total_bytes == b.total_bytes, backend
        assert a.n_events == b.n_events, backend


def test_single_tenant_binding_link_throttles_and_matches_event():
    """A lone tenant on a link capped below its bandwidth must slow
    down, and all three backends must agree on the throttled physics."""
    base = Scenario(
        network="didclab-lan-glusterfs", dataset="mixed", algorithm="mc"
    )
    bw = testbeds.TESTBEDS[base.network].bandwidth
    coupled = dataclasses.replace(
        base, shared_fabric=_fab("solo", 0.2 * bw)
    )
    ev = run_matrix([coupled], backend="event")[0]
    nr = run_matrix([coupled], backend="numpy")[0]
    jr = run_matrix([coupled], backend="jax")[0]
    assert _rel(nr.throughput, ev.throughput) <= 1e-9
    assert _rel(jr.throughput, ev.throughput) <= 1e-9
    un = run_matrix([base], backend="numpy")[0]
    assert nr.total_time > un.total_time  # the cap actually binds


def test_tenant_matrix_smoke_difftest_zero_replays():
    """The fleet acceptance bar in miniature: a 3-group tenant matrix
    holds the 2% difftest bar on numpy AND jax against the coupled
    event reference, with zero parked-row replays (coupled rows stay in
    the fused zero-host-round loop; a capacity-guard park would show up
    here as a replay)."""
    scenarios = tenant_matrix(n_groups=3)
    assert len(scenarios) >= 12
    ev = run_matrix(scenarios, backend="event")
    nr = run_matrix(scenarios, backend="numpy")
    jax_backend.reset_sync_stats()
    jr = run_matrix(scenarios, backend="jax")
    for e, n, j in zip(ev, nr, jr):
        assert _rel(n.throughput, e.throughput) <= 0.02
        assert _rel(j.throughput, e.throughput) <= 0.02
    stats = jax_backend.SYNC_STATS
    assert stats["post_row_replays"] == 0
    assert stats["replay_rounds"] == 0


def test_plan_and_legacy_ingest_agree_on_coupled_rows():
    """Columnar plan ingest and the legacy per-row object chain must
    produce identical coupled results (the plan carries the fabric
    column through build_plan/take; legacy resolves it row-wise)."""
    scenarios = tenant_matrix(n_groups=2)
    plan_r = run_matrix(scenarios, backend="numpy", ingest="plan")
    legacy_r = run_matrix(scenarios, backend="numpy", ingest="legacy")
    for p, l in zip(plan_r, legacy_r):
        assert p.total_time == l.total_time
        assert p.n_events == l.n_events


def test_fuzz_random_link_membership_difftest():
    """Random (links x tenants) membership tables: numpy vs the coupled
    event reference under the 2% bar (empirically exact)."""
    nets = list(scenarios_mod.NETWORKS)[:3]
    datasets = ("mixed", "small_dominated", "des")
    for seed in range(3):
        rng = np.random.RandomState(1234 + seed)
        n_t = 3
        picks = [nets[rng.randint(len(nets))] for _ in range(n_t)]
        bws = [testbeds.TESTBEDS[n].bandwidth for n in picks]
        cap_bb = float(rng.uniform(0.3, 0.8) * sum(bws))
        sub = [t for t in range(n_t) if rng.rand() < 0.5]
        cap_l1 = float(
            rng.uniform(0.3, 0.9) * sum(bws[t] for t in sub)
        ) if len(sub) >= 2 else None
        rows = []
        for t in range(n_t):
            links, caps = ["bb"], [cap_bb]
            if cap_l1 is not None and t in sub:
                links.append("l1")
                caps.append(cap_l1)
            fab = SharedFabric(
                group=f"fz{seed}", links=tuple(links),
                capacity=tuple(caps), tenant=f"t{t}",
            )
            rows.append(
                Scenario(
                    network=picks[t],
                    dataset=datasets[rng.randint(len(datasets))],
                    algorithm=("sc", "mc", "promc")[t % 3],
                    seed=seed,
                    shared_fabric=fab,
                )
            )
        ev = run_matrix(rows, backend="event")
        nr = run_matrix(rows, backend="numpy")
        for e, n in zip(ev, nr):
            assert _rel(n.throughput, e.throughput) <= 0.02, e.scenario


# ------------------------------------------------------------------ #
# coupled capacity pre-sizing bound
# ------------------------------------------------------------------ #


def test_coupled_sc_capacity_bound_worst_case():
    """Coupled SC rows can co-schedule waves (group-horizon ties land
    several chunk completions in one sweep), so the coupled closed form
    must be the all-waves bound sum(conc) — strictly above the
    uncoupled sequential-wave bound — and the observed open-channel
    peak of a hard-throttled coupled run must stay inside it."""
    a = Scenario(
        network="stampede-comet", dataset="small_dominated",
        algorithm="sc", max_cc=8,
    )
    b = Scenario(
        network="didclab-lan-glusterfs", dataset="mixed",
        algorithm="mc", max_cc=8,
    )
    bw = sum(testbeds.TESTBEDS[s.network].bandwidth for s in (a, b))
    cap = 0.2 * bw  # bind hard: long lockstep tail, maximal overlap
    a = dataclasses.replace(a, shared_fabric=_fab("wc", cap, "t0"))
    b = dataclasses.replace(b, shared_fabric=_fab("wc", cap, "t1"))
    sims = [build_simulation(s) for s in (a, b)]
    drv = FabricSimulation(
        sims, names=[s.name for s in (a, b)],
        fabric=[a.shared_fabric, b.shared_fabric],
    )
    # the coupled closed form dominates the uncoupled sequential bound
    for rt in drv.rt:
        un = drv._worst_case_channels(rt, coupled=False)
        co = drv._worst_case_channels(rt, coupled=True)
        assert co >= un
        assert co == max(1, sum(
            max(int(p.concurrency), 1)
            for c, p in zip(rt.chunks, rt.params)
            if c.files or p.concurrency
        )) or co >= un  # SC: all waves live at once
    drv.start()
    need_c, _ = drv.capacity_need()
    peak = 0
    for _ in range(200000):
        if drv.done.all():
            break
        drv.step()
        open_now = int((drv.chunk_of != _NO_CHUNK).sum(axis=1).max())
        peak = max(peak, open_now)
        assert open_now <= need_c
    assert drv.done.all()
    assert peak <= need_c
    # the plan-ingest mirror agrees with the legacy bound
    plan = build_plan([a, b])
    assert list(plan.cap_need) == list(drv.cap_need)


def test_group_atomic_parts_never_split_groups():
    fabs = [
        None,
        _fab("g1", 10.0, "t0"), _fab("g1", 10.0, "t1"),
        None,
        _fab("g2", 5.0, "t0"), _fab("g2", 5.0, "t1"),
        _fab("g2", 5.0, "t2"),
        None,
    ]
    order = [7, 5, 3, 1, 6, 0, 4, 2]
    uncoupled, parts = _group_atomic_parts(order, fabs, size=3)
    assert uncoupled == [7, 3, 0]  # order preserved, coupled removed
    by_group = {}
    for part in parts:
        assert len(part) <= 3 or len({fabs[i].group for i in part}) == 1
        for i in part:
            by_group.setdefault(fabs[i].group, set()).add(id(part))
    # every group lives in exactly one part
    assert all(len(v) == 1 for v in by_group.values())
    # oversized group stays whole
    _, parts2 = _group_atomic_parts(order, fabs, size=2)
    g2_parts = [
        p for p in parts2 if any(fabs[i].group == "g2" for i in p)
    ]
    assert len(g2_parts) == 1 and len(g2_parts[0]) == 3


# ------------------------------------------------------------------ #
# files-cache byte accounting (measured _entry_bytes)
# ------------------------------------------------------------------ #


def test_entry_bytes_measures_real_footprint():
    """The old fixed 120 B/FileSpec estimate undershot ~3-4x; the
    measured accounting must at least cover the raw object sizes."""
    import sys

    specs = scenarios_mod._build_files_cached("mixed", 0)
    measured = scenarios_mod._entry_bytes(specs)
    floor = sys.getsizeof(specs) + sum(sys.getsizeof(f) for f in specs)
    assert measured >= floor
    assert measured > 120 * len(specs)


def test_heavy_tail_sweep_respects_cache_byte_bound(monkeypatch):
    """A candidate sweep pulling many distinct heavy filesets must keep
    the LRU's measured byte total under FILES_CACHE_MAX_BYTES, evicting
    oldest entries — the PR 6 bound can no longer be overshot by the
    per-entry estimate."""
    with scenarios_mod._files_cache_lock:
        saved = dict(scenarios_mod._files_cache)
        saved_bytes = scenarios_mod._files_cache_bytes
        scenarios_mod._files_cache.clear()
        scenarios_mod._files_cache_bytes = 0
    one = scenarios_mod._entry_bytes(
        scenarios_mod._build_files_cached("des", 0)
    )
    bound = int(one * 2.5)  # fits two entries, never three
    monkeypatch.setattr(scenarios_mod, "FILES_CACHE_MAX_BYTES", bound)
    try:
        for seed in range(8):
            scenarios_mod._build_files_cached("des", seed)
            info = scenarios_mod.files_cache_info()
            assert info["bytes"] <= bound
        info = scenarios_mod.files_cache_info()
        assert info["evictions"] > 0
        assert info["entries"] <= 2
        # the running total matches a from-scratch re-measurement
        with scenarios_mod._files_cache_lock:
            remeasured = sum(
                scenarios_mod._entry_bytes(e)
                for e in scenarios_mod._files_cache.values()
            )
            assert remeasured == scenarios_mod._files_cache_bytes
    finally:
        with scenarios_mod._files_cache_lock:
            scenarios_mod._files_cache.clear()
            scenarios_mod._files_cache.update(saved)
            scenarios_mod._files_cache_bytes = saved_bytes


# ------------------------------------------------------------------ #
# contention report (greedy per-tenant tuning vs the contended oracle)
# ------------------------------------------------------------------ #


def test_contention_report_structure_and_sanity():
    from repro.eval.tune.contention import contention_report

    rep = contention_report(
        tenant_matrix(n_groups=2), backend="numpy", n_candidates=4
    )
    agg = rep.aggregate
    assert agg["groups"] == 2
    assert agg["tenants"] == sum(g["tenants"] for g in rep.per_group)
    assert agg["oracle_evals"] > 0
    assert 0.0 < agg["regret_median"] <= 1.5
    for g in rep.per_group:
        # coupling only removes capacity: the coupled fleet can never
        # beat the same tenants run in isolation
        assert g["contention_factor"] <= 1.0 + 1e-9
        assert len(g["oracle_params"]) == g["tenants"]
        assert all(len(t) == 3 for t in g["oracle_params"])
    # summary() is the bench-JSON embed: flat and JSON-serializable
    import json

    summary = rep.summary()
    json.dumps(summary)
    assert "regret_median" in summary


def test_contention_report_rejects_uncoupled_matrix():
    import pytest as _pytest

    from repro.eval.scenarios import smoke_matrix
    from repro.eval.tune.contention import contention_report

    with _pytest.raises(ValueError, match="coupled"):
        contention_report(smoke_matrix()[:2])
