"""End-to-end behaviour tests for the whole system + launch-layer units."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import run_transfer, testbeds
from repro.data.filesets import mixed_dataset
from repro.launch import hlo_analysis
from repro.launch.flops_audit import audit_step
from repro.launch.roofline import _cum_factor, _loop_chain, derive
from repro.launch.shapes import SHAPES, cell_supported, input_specs


# ------------------------------------------------------------------ #
# the paper's pipeline, end to end
# ------------------------------------------------------------------ #


def test_end_to_end_transfer_pipeline():
    """Mixed dataset -> chunking -> Algorithm 1 -> ProMC -> faster than
    untuned baseline; all bytes delivered."""
    files = mixed_dataset(scale=0.02)
    base = run_transfer(files, testbeds.STAMPEDE_COMET, "untuned", max_cc=8)
    tuned = run_transfer(files, testbeds.STAMPEDE_COMET, "promc", max_cc=8)
    assert tuned.total_bytes == base.total_bytes == sum(f.size for f in files)
    assert tuned.throughput > 2.5 * base.throughput


def test_transfer_optimizer_example_tune_demo():
    """The example drives the autotuner end to end on the smoke matrix
    and its regret table backs the paper's claims: the adaptive
    controllers sit near the static oracle (ProMC median within 10%)
    while the untuned baseline is nowhere close."""
    import sys

    sys.path.insert(0, "examples")
    try:
        from transfer_optimizer import tune_demo
    finally:
        sys.path.pop(0)

    report = tune_demo(backend="numpy", n_candidates=16)
    per_algo = report.per_algorithm
    assert set(per_algo) == {"sc", "mc", "promc", "globus", "untuned"}
    assert per_algo["promc"]["median"] > 0.9
    assert per_algo["mc"]["median"] > 0.9
    assert per_algo["untuned"]["median"] < 0.5
    for agg in per_algo.values():
        assert agg["n"] > 0 and agg["min"] > 0


# ------------------------------------------------------------------ #
# flops audit
# ------------------------------------------------------------------ #


def test_audit_counts_scan_trip_counts():
    w = jnp.ones((8, 64, 64))

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((64, 64))
    flops, dbytes = audit_step(scanned, x, w)
    # 8 matmuls of 64^3: 2 * 64^3 * 8
    assert flops == pytest.approx(2 * 64**3 * 8)
    assert dbytes == pytest.approx(8 * 3 * 64 * 64 * 4)


def test_audit_counts_grad_flops():
    w = jnp.ones((32, 32))

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    x = jnp.ones((16, 32))
    fwd, _ = audit_step(loss, w, x)
    both, _ = audit_step(jax.grad(loss), w, x)
    # fwd dot + one bwd dot (dL/dw = x^T @ dy) = exactly 2x here
    assert both == pytest.approx(2 * fwd)


# ------------------------------------------------------------------ #
# HLO collective parsing
# ------------------------------------------------------------------ #

_FAKE_HLO = """
  %ag = f32[8,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}, metadata={op_name="jit(f)/while/body/gather"}
  %ar = bf16[64]{0} all-reduce(%y), channel_id=2, replica_groups=[128,2]<=[2,128]T(1,0), to_apply=%add, metadata={op_name="jit(f)/sync"}
  %cp = f32[4,4]{1,0} collective-permute(%z), channel_id=3, replica_groups={{0,1},{2,3}}, metadata={op_name="jit(f)/while/body/while/body/shift"}
"""


def test_parse_collectives_bytes_and_depth():
    out = hlo_analysis.parse_collectives(_FAKE_HLO, n_devices=256, pod_size=128)
    assert out["kinds"]["all-gather"]["bytes"] == 8 * 128 * 4
    assert out["kinds"]["all-reduce"]["bytes"] == 64 * 2
    assert out["kinds"]["collective-permute"]["bytes"] == 16 * 4
    # the transposed iota [128,2]<=[2,128]T(1,0) pairs devices {0,128},... ->
    # every group spans both pods of size 128 -> DCN
    assert out["dcn_bytes"] == 64 * 2
    assert out["by_depth"]["0"]["dcn"] == 64 * 2
    assert out["by_depth"]["1"]["ici"] == 8 * 128 * 4
    assert out["by_depth"]["2"]["ici"] == 16 * 4


def test_loop_chain_factors():
    chain = _loop_chain("yi-9b", "train_4k")
    assert chain == [8, 48, 4]
    assert _cum_factor(chain, 0) == 1
    assert _cum_factor(chain, 1) == 8
    assert _cum_factor(chain, 2) == 8 * 48
    assert _cum_factor(chain, 5) == 8 * 48 * 4  # clamped
    assert _loop_chain("recurrentgemma-9b", "decode_32k") == [12]


def test_roofline_derive_from_record():
    rec = {
        "status": "ok", "arch": "yi-9b", "shape": "train_4k",
        "mesh": "single", "n_devices": 256,
        "flops_per_device": 1e12, "bytes_per_device": 1e9,
        "flops_audit_global": 5.0e16, "dot_bytes_audit_global": 2.56e14,
        "active_params": 8.8e9,
        "collectives": {
            "ici_bytes": 1e6, "dcn_bytes": 0,
            "by_depth": {"1": {"ici": 1e6, "dcn": 0}},
        },
    }
    r = derive(rec)
    assert r is not None
    assert r.compute_s == pytest.approx(5.0e16 / 256 / 197e12)
    assert r.ici_s == pytest.approx(8 * 1e6 / 50e9)
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_ratio < 1.5


# ------------------------------------------------------------------ #
# shapes / eligibility / artifacts
# ------------------------------------------------------------------ #


def test_input_specs_are_abstract():
    from repro.configs import get_config

    for arch in ("yi-9b", "whisper-base", "paligemma-3b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_long_context_eligibility():
    from repro.configs import get_config

    ok, _ = cell_supported(get_config("rwkv6-3b"), "long_500k")
    assert ok
    ok, reason = cell_supported(get_config("yi-9b"), "long_500k")
    assert not ok and "full-attention" in reason


def test_dryrun_artifacts_complete():
    """All 40 cells accounted for on both meshes (33 ok + 7 skips)."""
    import os

    from repro.launch.roofline import ART_DIR, load_all

    for mesh in ("single", "multi"):
        if not os.path.isdir(os.path.join(ART_DIR, mesh)):
            pytest.skip("dry-run artifacts not generated")
        recs = load_all(mesh)
        assert len(recs) == 40
        by_status = {}
        for r in recs:
            by_status.setdefault(r["status"], []).append(r)
        assert len(by_status.get("ok", [])) == 33, [
            (r["arch"], r["shape"], r.get("error", "")[:60])
            for r in by_status.get("error", [])
        ]
        assert len(by_status.get("skip", [])) == 7
