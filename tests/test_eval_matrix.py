"""Scheduler edge cases exercised through the matrix harness plus direct
unit assertions: weighted-distribution invariants, ProMC streak reset,
laggard ETA discounting, SC with empty chunks."""
import math

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import GB, MB, testbeds
from repro.core.schedulers import (
    ChunkView,
    Move,
    Open,
    ProActiveMultiChunkScheduler,
    Scheduler,
    SingleChunkScheduler,
    weighted_distribution,
)
from repro.core.types import Chunk, ChunkType, FileSpec
from repro.eval import Scenario, run_matrix
from repro.eval.fabric import FabricSimulation as BatchSimulation
from repro.eval.scenarios import build_simulation


def _chunk(ctype, n, size):
    return Chunk(
        ctype=ctype,
        files=[FileSpec(f"{ctype.name}{i}", size) for i in range(n)],
    )


# ------------------------------------------------------------------ #
# weighted_distribution: budget + min-1-channel invariants
# ------------------------------------------------------------------ #


@settings(max_examples=150, deadline=None)
@given(
    spec=st.lists(
        st.tuples(
            st.sampled_from(list(ChunkType)[:4]),
            st.integers(min_value=0, max_value=40),
            st.integers(min_value=1 * MB, max_value=int(4 * GB)),
        ),
        min_size=1,
        max_size=4,
        unique_by=lambda t: t[0],
    ),
    max_cc=st.integers(min_value=1, max_value=24),
)
def test_weighted_distribution_invariants(spec, max_cc):
    chunks = [_chunk(ct, n, size) for ct, n, size in spec]
    alloc = weighted_distribution(chunks, max_cc)
    live = [i for i, c in enumerate(chunks) if len(c) > 0]
    # every non-empty chunk gets at least one channel; empty chunks get none
    assert set(alloc) == set(live)
    assert all(alloc[i] >= 1 for i in live)
    # budget: exactly max(max_cc, #live) channels in total (the floor keeps
    # every chunk alive even when maxCC < #chunks)
    if live:
        assert sum(alloc.values()) == max(max_cc, len(live))


def test_weighted_distribution_empty_input():
    assert weighted_distribution([], 8) == {}
    assert weighted_distribution([_chunk(ChunkType.SMALL, 0, MB)], 8) == {}


# ------------------------------------------------------------------ #
# ProMC: streak reset on chunk completion
# ------------------------------------------------------------------ #


def _views(etas_and_channels):
    """(eta, n_channels) pairs -> ChunkViews with throughput arranged so
    eta = bytes_remaining / throughput."""
    views = []
    for i, (eta, n_ch) in enumerate(etas_and_channels):
        views.append(
            ChunkView(
                index=i,
                ctype=list(ChunkType)[i % 4],
                bytes_remaining=eta * 100.0 if math.isfinite(eta) else 1e12,
                files_remaining=5,
                throughput=100.0 if math.isfinite(eta) else 0.0,
                n_channels=n_ch,
                done=False,
                predicted_rate=0.0,
            )
        )
    return views


def _promc(patience=3):
    chunks = [
        _chunk(ChunkType.SMALL, 4, 1 * MB),
        _chunk(ChunkType.HUGE, 4, 2 * GB),
    ]
    return ProActiveMultiChunkScheduler(
        chunks, testbeds.XSEDE, max_cc=8, patience=patience
    )


def test_promc_streak_reset_on_chunk_completion():
    s = _promc(patience=3)
    imbalanced = _views([(10.0, 4), (100.0, 4)])  # 10x ETA gap
    assert s.on_tick(imbalanced) == []
    assert s.on_tick(imbalanced) == []
    assert s._streak == 2
    # a chunk completes between ticks: accumulated evidence must be dropped
    done_view = _views([(0.0, 4), (100.0, 4)])
    s.on_chunk_complete(done_view, 0)
    assert s._streak == 0 and s._streak_pair is None
    # the streak restarts from scratch afterwards
    assert s.on_tick(imbalanced) == []
    assert s._streak == 1


def test_promc_patience_then_single_move():
    s = _promc(patience=2)
    imbalanced = _views([(10.0, 4), (100.0, 4)])
    assert s.on_tick(imbalanced) == []
    actions = s.on_tick(imbalanced)
    assert actions == [Move(src=0, dst=1, n=1)]
    # streak resets after firing; no runaway moves
    assert s._streak == 0
    assert s.on_tick(imbalanced) == []


def test_promc_never_strands_fast_chunk():
    s = _promc(patience=1)
    views = _views([(10.0, 1), (100.0, 7)])  # fast chunk has its last channel
    assert s.on_tick(views) == []


# ------------------------------------------------------------------ #
# distribute_to_laggards: ETA discounting
# ------------------------------------------------------------------ #


def test_distribute_to_laggards_discounts_eta():
    """Freed channels spread across laggards instead of dogpiling the
    single largest-ETA chunk: each grant discounts the receiver's ETA by
    n/(n+1) before the next pick."""
    views = _views([(0.0, 4), (100.0, 2), (90.0, 2)])
    actions = Scheduler.distribute_to_laggards(views, src=0, n_channels=4)
    grants = {a.dst: a.n for a in actions}
    assert sum(grants.values()) == 4
    # 100s chunk: 100 -> 66.7 (3ch) -> 50 (4ch); 90s chunk: 90 -> 60
    # pick order: 100, 90, 66.7, 60 => 2 channels each
    assert grants == {1: 2, 2: 2}
    assert all(a.src == 0 for a in actions)


def test_distribute_to_laggards_infinite_eta_first_then_spreads():
    views = _views([(0.0, 3), (math.inf, 1), (50.0, 2)])
    actions = Scheduler.distribute_to_laggards(views, src=0, n_channels=3)
    grants = {a.dst: a.n for a in actions}
    # the starved (no-measurement) chunk keeps absorbing: inf stays inf
    # under multiplicative discounting — documented greedy behaviour
    assert grants[1] == 3


def test_distribute_to_laggards_no_live_targets():
    views = _views([(0.0, 4)])
    assert Scheduler.distribute_to_laggards(views, src=0, n_channels=4) == []


# ------------------------------------------------------------------ #
# SC ordering with empty chunks
# ------------------------------------------------------------------ #


def test_sc_skips_empty_chunks_and_orders_huge_first():
    chunks = [
        _chunk(ChunkType.SMALL, 3, 1 * MB),
        _chunk(ChunkType.MEDIUM, 0, 100 * MB),  # empty: must be skipped
        _chunk(ChunkType.HUGE, 2, 1 * GB),
    ]
    s = SingleChunkScheduler(chunks, testbeds.XSEDE, max_cc=4)
    first = s.initial_actions([])
    assert len(first) == 1 and isinstance(first[0], Open)
    assert first[0].chunk == 2  # HUGE first
    # completing HUGE must open SMALL (index 0), never the empty MEDIUM
    views = _views([(5.0, 0), (0.0, 0), (0.0, 4)])
    actions = s.on_chunk_complete(views, 2)
    opens = [a for a in actions if isinstance(a, Open)]
    assert [a.chunk for a in opens] == [0]


def test_sc_all_empty_dataset_opens_nothing():
    chunks = [_chunk(ChunkType.SMALL, 0, MB), _chunk(ChunkType.HUGE, 0, GB)]
    s = SingleChunkScheduler(chunks, testbeds.XSEDE, max_cc=4)
    assert s.initial_actions([]) == []


# ------------------------------------------------------------------ #
# the same edges end-to-end through the matrix harness
# ------------------------------------------------------------------ #


@pytest.mark.parametrize("algorithm", ["sc", "mc", "promc"])
def test_matrix_run_with_missing_size_classes(algorithm):
    """uniform datasets produce empty chunks for absent classes; every
    scheduler must still complete them on both backends."""
    for ds in ("uniform_small", "uniform_huge"):
        sc = Scenario(
            network=testbeds.STAMPEDE_COMET.name, dataset=ds,
            algorithm=algorithm, num_chunks=4,
        )
        ev, ba = (
            run_matrix([sc], backend="event")[0],
            run_matrix([sc], backend="batch")[0],
        )
        assert ev.total_bytes > 0
        assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9)


def test_bandwidth_profile_lookup_and_horizon():
    """Piecewise-constant capacity: bandwidth_at steps at the breakpoints
    and next_profile_change exposes the following one (inf at the end)."""
    net = testbeds.impaired_variant(
        testbeds.STAMPEDE_COMET, "step-test",
        bandwidth_steps=((10.0, 0.5), (20.0, 0.8)),
    )
    bw = net.bandwidth
    assert net.bandwidth_at(0.0) == bw
    assert net.bandwidth_at(9.999) == bw
    assert net.bandwidth_at(10.0) == 0.5 * bw
    assert net.bandwidth_at(25.0) == 0.8 * bw
    assert net.next_profile_change(0.0) == 10.0
    assert net.next_profile_change(10.0) == 20.0
    assert net.next_profile_change(20.0) == math.inf
    # static paths: nominal capacity, no horizon
    assert testbeds.STAMPEDE_COMET.bandwidth_at(1e9) == bw
    assert testbeds.STAMPEDE_COMET.next_profile_change(0.0) == math.inf


def test_bandwidth_ramp_builds_monotone_step_ladder():
    net = testbeds.impaired_variant(
        testbeds.LONI, "ramp-test", bandwidth_ramp=(5.0, 25.0, 0.5, 4)
    )
    prof = net.bandwidth_profile
    assert prof[0] == (0.0, 1.0)
    assert len(prof) == 5
    assert prof[-1] == (25.0, 0.5)
    mults = [m for _, m in prof]
    assert mults == sorted(mults, reverse=True)


@pytest.mark.parametrize("algorithm", ["promc", "mc", "untuned"])
def test_time_varying_bandwidth_scenarios_agree_across_backends(algorithm):
    """Step/ramp capacity profiles run through the profile-aware horizon
    on every backend; multi-channel schedulers (which actually reach the
    link capacity) lose throughput relative to the static base, while a
    single window-limited untuned stream is unaffected by design."""
    sc = Scenario(
        network=testbeds.STEPPY_BACKBONE.name, dataset="mixed",
        algorithm=algorithm,
    )
    base = Scenario(
        network=testbeds.STAMPEDE_COMET.name, dataset="mixed",
        algorithm=algorithm,
    )
    ev = run_matrix([sc], backend="event")[0]
    ba = run_matrix([sc], backend="batch")[0]
    assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9)
    ev_base = run_matrix([base], backend="event")[0]
    if algorithm == "untuned":
        assert ev.throughput == pytest.approx(ev_base.throughput, rel=1e-6)
    else:
        assert ev.throughput < ev_base.throughput


def test_matrix_promc_starved_concurrency():
    """maxCC=1 with 4 live chunks: the min-1-channel floor overrides the
    budget and nothing deadlocks."""
    sc = Scenario(
        network=testbeds.LAN.name, dataset="mixed", algorithm="promc",
        max_cc=1, num_chunks=4,
    )
    ev = build_simulation(sc).run()
    ba = BatchSimulation([build_simulation(sc)], names=[sc.name]).run()[0]
    assert ev.total_time > 0
    assert ba.throughput == pytest.approx(ev.throughput, rel=1e-9)


# ------------------------------------------------------------------ #
# result-ordering invariant: the executor never reorders rows
# ------------------------------------------------------------------ #


def test_matrix_results_keep_input_order_across_executors():
    """``run_matrix`` promises results in input order. The pipelined
    executor completes chunks out of order (different devices, different
    runtimes), chunking regroups rows by shape hint, and per-scenario
    results must still land at the row's input index — pin that against
    a deliberately shuffled, heterogeneous matrix."""
    scenarios = [
        Scenario(
            network=net, dataset=ds, algorithm=algo, max_cc=cc, seed=i,
        )
        for i, (net, ds, algo, cc) in enumerate(
            (
                (testbeds.XSEDE.name, "mixed", "promc", 8),
                (testbeds.LAN.name, "uniform_small", "sc", 2),
                (testbeds.LONI.name, "mixed", "mc", 4),
                (testbeds.LAN.name, "mixed", "promc", 2),
                (testbeds.XSEDE.name, "uniform_small", "mc", 6),
                (testbeds.LONI.name, "uniform_small", "sc", 4),
                (testbeds.LAN.name, "mixed", "sc", 6),
            )
        )
    ]
    reference = {
        sc.name: build_simulation(sc).run() for sc in scenarios
    }
    for executor in ("serial", "async"):
        for chunk_size in (2, 3, 64):
            out = run_matrix(
                scenarios, backend="numpy", chunk_size=chunk_size,
                executor=executor,
            )
            assert len(out) == len(scenarios)
            for sc, r in zip(scenarios, out):
                ref = reference[sc.name]
                assert r.total_bytes == ref.total_bytes, sc.name
                assert r.throughput == pytest.approx(
                    ref.throughput, rel=1e-9
                ), (executor, chunk_size, sc.name)
