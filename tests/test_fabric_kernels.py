"""Fabric-kernel equivalence: the backend-neutral array kernels must match
the scalar references (``netmodel.waterfill``, ``fabric.reference``) on
random inputs, and the NumPy and JAX instantiations must agree with each
other bit-for-bit on the same inputs.

These are the property tests backing the fidelity contract in the
``repro.eval.fabric`` package docstring.
"""
import math

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import netmodel, testbeds
from repro.core.types import TransferParams
from repro.eval.fabric import kernels
from repro.eval.fabric.reference import next_event_dt, tick_rate_update
from repro.eval.fabric.shim import jax_ops, numpy_ops

_NP = numpy_ops()


def _jax_ops_x64():
    import jax

    if not jax.config.jax_enable_x64:
        pytest.skip("needs scoped x64 (exercised via enable_x64 below)")


# ------------------------------------------------------------------ #
# water-filling
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    caps=st.lists(
        st.floats(min_value=0.0, max_value=1e10), min_size=1, max_size=12
    ),
    pool=st.floats(min_value=0.0, max_value=5e10),
)
def test_waterfill_kernel_matches_scalar_reference(caps, pool):
    batch = kernels.waterfill_batch(np.array([caps]), np.array([pool]))[0]
    scalar = netmodel.waterfill(caps, pool)
    assert batch.shape == (len(caps),)
    np.testing.assert_allclose(batch, scalar, rtol=1e-9, atol=1e-3)


@settings(max_examples=50, deadline=None)
@given(
    caps=st.lists(
        st.floats(min_value=0.0, max_value=1e10), min_size=1, max_size=12
    ),
    pool=st.floats(min_value=0.0, max_value=5e10),
)
def test_waterfill_numpy_and_jax_agree(caps, pool):
    from jax.experimental import enable_x64

    ref = kernels.waterfill(_NP, np.array([caps]), np.array([pool]))
    with enable_x64():
        import jax.numpy as jnp

        out = kernels.waterfill(
            jax_ops(), jnp.asarray(np.array([caps])),
            jnp.asarray(np.array([pool])),
        )
    # XLA may contract the water-level arithmetic into FMAs, so agreement
    # is to the ulp, not bitwise
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-12, atol=0)


def test_waterfill_conservation_many_rows():
    rng = np.random.RandomState(0)
    caps = rng.uniform(0, 1e9, size=(64, 8))
    caps[rng.uniform(size=caps.shape) < 0.3] = 0.0  # idle channels
    pool = rng.uniform(0, 4e9, size=64)
    out = kernels.waterfill_batch(caps, pool)
    assert (out <= caps + 1e-6).all()
    assert (out.sum(axis=1) <= pool + 1e-3).all()


def test_waterfill_pallas_matches_closed_form():
    pytest.importorskip("jax.experimental.pallas")
    from repro.eval.fabric.kernels.waterfill_pallas import waterfill_pallas_f64

    rng = np.random.RandomState(1)
    caps = rng.uniform(0, 1e9, size=(32, 8))
    caps[rng.uniform(size=caps.shape) < 0.3] = 0.0
    pool = rng.uniform(0, 4e9, size=32)
    ref = kernels.waterfill_batch(caps, pool)
    out = waterfill_pallas_f64(caps, pool)
    np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-3)


# ------------------------------------------------------------------ #
# per-file dead time
# ------------------------------------------------------------------ #


@settings(max_examples=100, deadline=None)
@given(
    pp=st.integers(min_value=0, max_value=32),
    net=st.sampled_from(list(testbeds.TESTBEDS)),
)
def test_dead_time_kernel_matches_netmodel(pp, net):
    network = testbeds.TESTBEDS[net]
    params = TransferParams(pipelining=pp, parallelism=2, concurrency=1)
    scalar = netmodel.file_start_dead_time(network, params)
    control = (
        network.control_rtt if network.control_rtt is not None
        else network.rtt
    )
    batch = kernels.file_dead_time(
        _NP,
        np.full(3, control),
        np.full(3, float(pp)),
        np.full(3, network.unhidden_overhead),
        np.full(3, network.disk.per_file_overhead),
    )
    np.testing.assert_allclose(batch, scalar, rtol=1e-12)


# ------------------------------------------------------------------ #
# tick EMA
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    prev=st.floats(min_value=0.0, max_value=1e10),
    delta=st.floats(min_value=0.0, max_value=1e12),
    period=st.floats(min_value=1e-3, max_value=60.0),
)
def test_tick_ema_kernel_matches_scalar_reference(prev, delta, period):
    scalar = tick_rate_update(prev, delta, period)
    batch = kernels.tick_ema(
        _NP, np.array([[prev]]), np.array([[delta]]), np.array([[0.0]]),
        np.array([[period]]),
    )
    np.testing.assert_allclose(batch[0, 0], scalar, rtol=1e-12)


# ------------------------------------------------------------------ #
# next-event horizon
# ------------------------------------------------------------------ #


@settings(max_examples=200, deadline=None)
@given(
    tick_dt=st.floats(min_value=0.0, max_value=10.0),
    chans=st.lists(
        st.tuples(
            st.floats(min_value=0.0, max_value=5.0),   # dead
            st.floats(min_value=0.0, max_value=1e9),   # remaining
            st.floats(min_value=0.0, max_value=1e9),   # rate
            st.integers(min_value=0, max_value=1),     # busy
        ),
        min_size=1,
        max_size=8,
    ),
)
def test_event_horizon_matches_scalar_reference(tick_dt, chans):
    dead = np.array([c[0] for c in chans])
    rem = np.array([c[1] for c in chans])
    rate = np.array([c[2] for c in chans])
    busy = np.array([bool(c[3]) for c in chans])
    transferring = busy & (dead <= 1e-12)
    # scalar reference considers only busy channels; in-dead-time channels
    # contribute their dead-time expiry, transferring ones rem/rate
    scalar = next_event_dt(
        tick_dt,
        dead[busy],
        rem[busy],
        np.where(transferring, rate, 0.0)[busy],
    )
    batch = kernels.event_horizon(
        _NP, np.array([tick_dt]), busy[None], dead[None],
        transferring[None], rem[None], np.where(transferring, rate, 0.0)[None],
    )
    np.testing.assert_allclose(batch[0], scalar, rtol=1e-12)


# ------------------------------------------------------------------ #
# queue feeding
# ------------------------------------------------------------------ #


def _scalar_feed(chunk_of, busy, qsizes, qoff, qlen, qptr):
    """Deque-free reference of the FIFO feed for one scenario."""
    busy = list(busy)
    qptr = list(qptr)
    assign = {}
    for c, k in enumerate(chunk_of):
        if k < 0 or busy[c]:
            continue
        if qptr[k] < qlen[k]:
            assign[c] = qsizes[qoff[k] + qptr[k]]
            qptr[k] += 1
            busy[c] = True
    return assign, qptr


@settings(max_examples=100, deadline=None)
@given(
    layout=st.lists(
        st.tuples(
            st.integers(min_value=-1, max_value=2),  # chunk_of
            st.integers(min_value=0, max_value=1),   # busy
        ),
        min_size=1,
        max_size=10,
    ),
    lens=st.lists(
        st.integers(min_value=0, max_value=4), min_size=3, max_size=3
    ),
    ptrs=st.lists(
        st.integers(min_value=0, max_value=4), min_size=3, max_size=3
    ),
)
def test_feed_kernel_matches_scalar_fifo(layout, lens, ptrs):
    K = 3
    chunk_of = np.array([c for c, _ in layout], dtype=np.int64)
    busy = np.array([bool(b) for _, b in layout])
    qlen = np.array(lens, dtype=np.int64)
    qptr = np.array([min(p, l) for p, l in zip(ptrs, lens)], dtype=np.int64)
    qoff = np.array([0, qlen[0], qlen[0] + qlen[1]], dtype=np.int64)
    qsizes = np.arange(1.0, float(qlen.sum()) + 1.0) * 1e6
    C = len(layout)
    busy2, dead2, rem2, qptr2, qb2, _pn2 = kernels.feed_queues(
        _NP, np.array([True]), chunk_of[None], busy[None],
        np.zeros((1, C)), np.zeros((1, C)), qsizes, qoff[None], qlen[None],
        qptr[None], np.zeros((1, K)), np.full((1, K), 0.25),
    )
    assign, qptr_ref = _scalar_feed(chunk_of, busy, qsizes, qoff, qlen, qptr)
    np.testing.assert_array_equal(qptr2[0], qptr_ref)
    for c in range(C):
        if c in assign:
            assert busy2[0, c] and rem2[0, c] == assign[c]
            assert dead2[0, c] == 0.25
        else:
            assert busy2[0, c] == busy[c]
            assert rem2[0, c] == 0.0


def test_feed_kernel_numpy_and_jax_agree():
    from jax.experimental import enable_x64

    rng = np.random.RandomState(2)
    S, C, K, P = 16, 6, 3, 4
    chunk_of = rng.randint(-1, K, size=(S, C)).astype(np.int64)
    busy = rng.uniform(size=(S, C)) < 0.4
    qlen = rng.randint(0, 5, size=(S, K)).astype(np.int64)
    qptr = np.minimum(rng.randint(0, 5, size=(S, K)), qlen).astype(np.int64)
    qoff = np.cumsum(np.concatenate([[0], qlen.ravel()[:-1]])).reshape(S, K)
    qsizes = rng.uniform(1e6, 1e9, size=int(qlen.sum()) + 1)
    dead = rng.uniform(0, 1, size=(S, C))
    rem = np.where(busy, rng.uniform(1e6, 1e9, size=(S, C)), 0.0)
    qb = rng.uniform(0, 1e10, size=(S, K))
    fsdt = rng.uniform(0, 1, size=(S, K))
    enabled = rng.uniform(size=S) < 0.8
    pn = rng.randint(0, P + 1, size=(S, K)).astype(np.int64)
    ps = rng.uniform(1e5, 1e8, size=(S, K, P))

    ref = kernels.feed_queues(
        _NP, enabled, chunk_of, busy, dead, rem, qsizes, qoff, qlen, qptr,
        qb, fsdt, ps, pn,
    )
    with enable_x64():
        import jax.numpy as jnp

        out = kernels.feed_queues(
            jax_ops(), jnp.asarray(enabled), jnp.asarray(chunk_of),
            jnp.asarray(busy), jnp.asarray(dead), jnp.asarray(rem),
            jnp.asarray(qsizes), jnp.asarray(qoff), jnp.asarray(qlen),
            jnp.asarray(qptr), jnp.asarray(qb), jnp.asarray(fsdt),
            jnp.asarray(ps), jnp.asarray(pn),
        )
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(o), r, rtol=1e-12, atol=0)


def test_feed_kernel_lifo_resume_stack_before_fifo():
    """Idle channels consume resume files newest-first, then fall back to
    the FIFO queue — deque.appendleft/popleft order."""
    chunk_of = np.array([[0, 0, 0, -1]], dtype=np.int64)
    busy = np.zeros((1, 4), dtype=bool)
    qsizes = np.array([111.0, 222.0])
    qoff = np.array([[0]], dtype=np.int64)
    qlen = np.array([[2]], dtype=np.int64)
    qptr = np.array([[0]], dtype=np.int64)
    qb = np.array([[999.0]])
    fsdt = np.array([[0.5]])
    ps = np.array([[[7.0, 9.0, 0.0, 0.0]]])  # stack: bottom 7, top 9
    pn = np.array([[2]], dtype=np.int64)
    busy2, dead2, rem2, qptr2, qb2, pn2 = kernels.feed_queues(
        _NP, np.array([True]), chunk_of, busy, np.zeros((1, 4)),
        np.zeros((1, 4)), qsizes, qoff, qlen, qptr, qb, fsdt, ps, pn,
    )
    # col0 pops the top (9), col1 the next (7), col2 takes FIFO head (111)
    np.testing.assert_array_equal(rem2[0, :3], [9.0, 7.0, 111.0])
    assert busy2[0, :3].all() and not busy2[0, 3]
    assert pn2[0, 0] == 0 and qptr2[0, 0] == 1
    np.testing.assert_allclose(qb2[0, 0], 999.0 - 9.0 - 7.0 - 111.0)


# ------------------------------------------------------------------ #
# disk pool + advance
# ------------------------------------------------------------------ #


@settings(max_examples=100, deadline=None)
@given(
    n_t=st.integers(min_value=0, max_value=64),
    net=st.sampled_from(list(testbeds.TESTBEDS)),
)
def test_disk_pool_matches_allocate_rates_pool(n_t, net):
    network = testbeds.TESTBEDS[net]
    pool = kernels.disk_pool(
        _NP, np.array([n_t]), np.array([network.bandwidth]),
        np.array([network.disk.streaming_rate]),
        np.array([network.disk.saturation_cc], dtype=np.int64),
        np.array([network.disk.contention]),
    )[0]
    if n_t == 0:
        assert pool == 0.0
    else:
        expected = min(
            network.bandwidth, network.disk.aggregate_rate(n_t)
        )
        np.testing.assert_allclose(pool, expected, rtol=1e-12)


def test_advance_channels_moves_fluid_and_finishes_files():
    busy = np.array([[True, True, False]])
    dead = np.array([[0.5, 0.0, 0.0]])
    rem = np.array([[1e6, 2e6, 0.0]])
    transferring = busy & (dead <= 1e-12)
    rates = np.array([[0.0, 1e6, 0.0]])
    busy2, dead2, rem2, moved, finished = kernels.advance_channels(
        _NP, np.array([True]), np.array([2.0]), busy, dead, transferring,
        rem, rates,
    )
    assert dead2[0, 0] == 0.0  # dead time burned
    assert moved[0, 1] == 2e6 and finished[0, 1]  # file completed
    assert not busy2[0, 1] and rem2[0, 1] == 0.0
    assert busy2[0, 0]  # still holds its file
