"""Differential matrix tests: fabric backends vs event simulator, golden
snapshot round-trip, and determinism of scenario construction.

The smoke cross-section runs in tier-1 (including a JAX-backend slice);
the 276-scenario default matrix (ISSUE-1 gate) and the 1000+-scenario full
matrix across all three backends (ISSUE-2 gate) run behind ``-m slow`` and
in CI's difftest jobs.
"""
import math
import os

import pytest

from repro.eval import (
    Scenario,
    assert_agreement,
    default_matrix,
    diff_matrix,
    full_matrix,
    load_golden,
    metrics_snapshot,
    run_matrix,
    save_golden,
    smoke_matrix,
)
from repro.eval.difftest import diff_backend
from repro.eval.runner import compare_golden
from repro.eval.scenarios import build_files


def test_default_matrix_is_large_and_unique():
    scs = default_matrix()
    assert len(scs) >= 200
    names = [s.name for s in scs]
    assert len(set(names)) == len(names)


def test_scenario_build_is_deterministic():
    sc = Scenario(network="xsede-lonestar-gordon", dataset="mixed",
                  algorithm="promc")
    a, b = build_files(sc), build_files(sc)
    assert [(f.name, f.size) for f in a] == [(f.name, f.size) for f in b]
    # a different seed produces a different dataset draw
    c = build_files(Scenario(network=sc.network, dataset="mixed",
                             algorithm="promc", seed=1))
    assert [f.size for f in c] != [f.size for f in a]


def test_smoke_matrix_agreement():
    reports = diff_matrix(smoke_matrix())
    assert len(reports) >= 20
    assert_agreement(reports, rtol=0.02)
    # the backends are the same semantics vectorized, so agreement is in
    # practice far tighter than the 2% acceptance bar
    assert max(r.rel_err for r in reports) < 1e-6


def test_jax_backend_smoke_slice_agreement():
    """Tier-1 slice of the JAX device loop: a cross-section of the smoke
    matrix against both the event reference and the NumPy fast path.
    diff_backend raises on any scenario beyond the 2% bar."""
    scs = smoke_matrix()[::4]
    reports = diff_backend(scs, "jax")
    assert len(reports) >= 2 * len(scs)  # event pairing + numpy pairing


def test_chunked_execution_is_composition_invariant():
    """Chunk size (memory bound) must not change any scenario's result:
    scenarios are independent, whatever batch they share."""
    scs = smoke_matrix()[:9]
    whole = run_matrix(scs, backend="numpy", chunk_size=None)
    parts = run_matrix(scs, backend="numpy", chunk_size=4)
    for w, p in zip(whole, parts):
        assert w.throughput == p.throughput
        assert w.total_time == p.total_time


@pytest.mark.slow
def test_default_matrix_agreement():
    """ISSUE-1 acceptance: >= 200 scenarios, every one within 2%."""
    scs = default_matrix()
    assert len(scs) >= 200
    reports = diff_matrix(scs)
    assert_agreement(reports, rtol=0.02)


@pytest.mark.slow
def test_full_matrix_all_backends_agreement():
    """ISSUE-2 acceptance: the >= 1000-scenario grid passes the 2% bar on
    event vs numpy vs jax (jax additionally checked against numpy);
    diff_backend raises on any violator."""
    scs = full_matrix()
    assert len(scs) >= 1000
    cache: dict = {}
    for backend in ("numpy", "jax"):
        reports = diff_backend(scs, backend, results_cache=cache)
        assert reports


def test_assert_agreement_reports_all_violators():
    reports = diff_matrix(smoke_matrix()[:3])
    bad = [
        type(r)(
            scenario=r.scenario,
            event_throughput=r.event_throughput,
            batch_throughput=r.event_throughput * 1.5,
            event_time=r.event_time,
            batch_time=r.batch_time,
        )
        for r in reports
    ]
    with pytest.raises(AssertionError) as exc:
        assert_agreement(bad, rtol=0.02)
    msg = str(exc.value)
    assert "3/3 scenarios" in msg
    for r in bad:
        assert r.scenario in msg


# ------------------------------------------------------------------ #
# golden snapshots
# ------------------------------------------------------------------ #


def test_golden_roundtrip(tmp_path):
    scs = smoke_matrix()[:4]
    res = run_matrix(scs, backend="batch")
    snap = metrics_snapshot(scs, res)
    path = str(tmp_path / "golden.json")
    save_golden(path, snap)
    assert compare_golden(load_golden(path), snap) == []


def test_golden_compare_flags_deviation_and_missing(tmp_path):
    scs = smoke_matrix()[:3]
    res = run_matrix(scs, backend="batch")
    snap = metrics_snapshot(scs, res)
    mutated = {k: dict(v) for k, v in snap.items()}
    victim = next(iter(mutated))
    mutated[victim]["throughput_gbps"] *= 1.10
    dropped = sorted(mutated)[-1]
    del mutated[dropped]
    devs = compare_golden(snap, mutated)
    kinds = {(d.scenario, d.field) for d in devs}
    assert (victim, "throughput_gbps") in kinds
    assert (dropped, "presence") in kinds
    rel = [d for d in devs if d.field == "throughput_gbps"][0].rel_err
    assert math.isclose(rel, 0.10, rel_tol=1e-6)


def test_checked_in_golden_matches_batch_backend():
    """The repo's golden file stays in lockstep with the simulator; refresh
    with `python -m repro.eval.runner --refresh-golden` when semantics
    change intentionally (see TESTING.md)."""
    scs = smoke_matrix()
    golden = load_golden(
        os.path.join(os.path.dirname(__file__), "golden", "eval_smoke.json")
    )
    snap = metrics_snapshot(scs, run_matrix(scs, backend="batch"))
    devs = compare_golden(golden, snap, rtol=1e-6)
    assert devs == [], devs[:5]
