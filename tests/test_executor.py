"""The overlap-pipelined executor: ordering, equivalence, donation, AOT
warm-start, thread-safety of the shared caches, and the multi-device
shard layer.

The invariants under test are the ones ``eval.runner`` promises:

  * results come back in **input order**, independent of chunk
    interleaving, device assignment, executor mode, and donation;
  * ``REPRO_FABRIC_EXECUTOR=serial`` preserves the historical strictly
    serial path (and the async pipeline matches it bitwise);
  * ``SYNC_STATS`` totals are identical whether chunks run serially or
    interleaved (per-run private accumulation, one locked merge);
  * the ``build_files`` byte-bounded LRU survives concurrent access;
  * AOT-warmed signatures serve runs without a fresh jit trace.
"""
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import testbeds
from repro.eval import Scenario
from repro.eval import scenarios as scenarios_mod
from repro.eval.fabric import executor as executor_mod
from repro.eval.fabric import jax_backend
from repro.eval.fabric.bucketing import (
    COMPACT_FLOOR,
    canonical_signature,
    signature_ladder,
)
from repro.eval.fabric.driver import FabricSimulation
from repro.eval.fabric.executor import execute_chunks, executor_mode
from repro.eval.fabric.jax_backend import JaxFabricSimulation
from repro.eval.runner import run_matrix
from repro.eval.scenarios import build_simulation, smoke_matrix


def _mixed_batch(n=10):
    """Scenarios with heterogeneous runtimes so interleaving reorders
    completion (but must never reorder results)."""
    nets = (testbeds.LAN.name, testbeds.XSEDE.name, testbeds.LONI.name)
    algos = ("sc", "mc", "promc")
    return [
        Scenario(
            network=nets[i % len(nets)],
            dataset="uniform_small" if i % 2 else "mixed",
            algorithm=algos[i % len(algos)],
            max_cc=2 + (i % 3) * 2,
            seed=i,
        )
        for i in range(n)
    ]


# ------------------------------------------------------------------ #
# mode resolution + serial escape hatch
# ------------------------------------------------------------------ #


def test_executor_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FABRIC_EXECUTOR", raising=False)
    assert executor_mode() == "async"
    assert executor_mode("serial") == "serial"
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "serial")
    assert executor_mode() == "serial"
    assert executor_mode("async") == "async"  # explicit arg wins
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "bogus")
    with pytest.raises(ValueError):
        executor_mode()


def test_donation_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FABRIC_DONATE", raising=False)
    monkeypatch.delenv("REPRO_FABRIC_EXECUTOR", raising=False)
    monkeypatch.setattr(jax_backend, "_persistent_cache_active", lambda: False)
    assert jax_backend.donation_enabled() is True  # async default
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "serial")
    assert jax_backend.donation_enabled() is False  # historical path
    monkeypatch.setenv("REPRO_FABRIC_DONATE", "1")
    assert jax_backend.donation_enabled() is True  # env overrides
    monkeypatch.setenv("REPRO_FABRIC_DONATE", "0")
    monkeypatch.delenv("REPRO_FABRIC_EXECUTOR", raising=False)
    assert jax_backend.donation_enabled() is False
    assert jax_backend.donation_enabled(True) is False  # env beats kwarg


def test_donation_survives_persistent_cache(monkeypatch):
    """The PR 7 guard blanket-disabled donation whenever a persistent
    compilation cache dir was configured (donated executables don't
    survive its serialize/deserialize round trip on jax 0.4.x CPU).
    Narrowed: freshly-compiled donated programs are correct — they now
    compile inside the cache-suppression window and never enter the
    cache — so ``donation_enabled`` must resolve exactly as it does
    without a cache dir; only a cache-READ executable (a failed donated
    warm) falls back undonated, per-signature, inside ``_device_call``.
    The env override still beats everything both ways."""
    monkeypatch.delenv("REPRO_FABRIC_DONATE", raising=False)
    monkeypatch.delenv("REPRO_FABRIC_EXECUTOR", raising=False)
    monkeypatch.setattr(jax_backend, "_persistent_cache_active", lambda: True)
    assert jax_backend.donation_enabled() is True  # async default, cache on
    assert jax_backend.donation_enabled(True) is True
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "serial")
    assert jax_backend.donation_enabled() is False  # serial still undonated
    monkeypatch.setenv("REPRO_FABRIC_DONATE", "0")
    monkeypatch.delenv("REPRO_FABRIC_EXECUTOR", raising=False)
    assert jax_backend.donation_enabled() is False  # kill switch wins
    monkeypatch.setenv("REPRO_FABRIC_DONATE", "1")
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "serial")
    assert jax_backend.donation_enabled() is True  # force wins


def test_suppress_persistent_cache_restores_config(monkeypatch):
    """The donated-compile suppression window must clear the configured
    cache dir for its duration (nested re-entry included) and restore
    it exactly afterwards — including on the error path."""
    import jax

    before = jax.config.jax_compilation_cache_dir
    jax.config.update("jax_compilation_cache_dir", "/tmp/fabric-test-cache")
    try:
        with jax_backend._suppress_persistent_cache():
            assert jax.config.jax_compilation_cache_dir is None
            with jax_backend._suppress_persistent_cache():  # refcounted
                assert jax.config.jax_compilation_cache_dir is None
            assert jax.config.jax_compilation_cache_dir is None
        assert (
            jax.config.jax_compilation_cache_dir == "/tmp/fabric-test-cache"
        )
        with pytest.raises(RuntimeError):
            with jax_backend._suppress_persistent_cache():
                raise RuntimeError("boom")
        assert (
            jax.config.jax_compilation_cache_dir == "/tmp/fabric-test-cache"
        )
    finally:
        jax.config.update("jax_compilation_cache_dir", before)


def test_serial_env_escape_hatch(monkeypatch):
    """REPRO_FABRIC_EXECUTOR=serial must route through the plain loop:
    no prep/compute threads are spawned at all."""
    monkeypatch.setenv("REPRO_FABRIC_EXECUTOR", "serial")
    spawned = []
    orig = threading.Thread

    class SpyThread(orig):
        def __init__(self, *a, **kw):
            spawned.append(kw.get("name"))
            super().__init__(*a, **kw)

    monkeypatch.setattr(threading, "Thread", SpyThread)
    m = _mixed_batch(6)
    out = run_matrix(m, backend="numpy", chunk_size=2)
    assert len(out) == 6 and all(r is not None for r in out)
    assert not any(n and n.startswith("fabric-") for n in spawned)


# ------------------------------------------------------------------ #
# result ordering + serial/async equivalence
# ------------------------------------------------------------------ #


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_async_matches_serial_bitwise(backend):
    m = _mixed_batch(10)
    serial = run_matrix(m, backend=backend, chunk_size=4, executor="serial")
    pipelined = run_matrix(m, backend=backend, chunk_size=4, executor="async")
    for s, a in zip(serial, pipelined):
        assert a.total_bytes == s.total_bytes
        assert a.total_time == s.total_time
        assert a.n_events == s.n_events
        assert a.n_moves == s.n_moves


def test_results_in_input_order_any_chunking():
    """Per-row results are independent of chunk composition and always
    land at the row's input index (scenarios never interact)."""
    m = _mixed_batch(9)
    baseline = run_matrix(m, backend="numpy", executor="serial")
    for chunk_size in (1, 2, 5, 64):
        out = run_matrix(
            m, backend="numpy", chunk_size=chunk_size, executor="async"
        )
        for b, o in zip(baseline, out):
            assert o.total_time == b.total_time
            assert o.total_bytes == b.total_bytes


def test_execute_chunks_writes_original_indices():
    m = _mixed_batch(6)
    builders = [(lambda sc=sc: build_simulation(sc)) for sc in m]
    names = [sc.name for sc in m]
    results = [None] * 6
    # deliberately scrambled, overlapping-free parts
    parts = [[4, 1], [5, 0], [2, 3]]
    execute_chunks(
        FabricSimulation, parts, builders, names, results, mode="async"
    )
    assert all(r is not None for r in results)
    direct = FabricSimulation(
        [build_simulation(m[1])], names=[m[1].name]
    ).run()[0]
    assert results[1].total_time == direct.total_time


def test_executor_propagates_builder_errors():
    m = _mixed_batch(4)
    builders = [(lambda sc=sc: build_simulation(sc)) for sc in m]
    names = [sc.name for sc in m]

    def boom():
        raise RuntimeError("builder exploded")

    builders[2] = boom
    with pytest.raises(RuntimeError, match="builder exploded"):
        execute_chunks(
            FabricSimulation, [[0, 1], [2, 3]], builders, names,
            [None] * 4, mode="async",
        )


# ------------------------------------------------------------------ #
# donation
# ------------------------------------------------------------------ #


def test_donation_on_off_identical_results():
    m = _mixed_batch(4)
    sims = lambda: [build_simulation(sc) for sc in m]  # noqa: E731
    names = [sc.name for sc in m]
    on = JaxFabricSimulation(sims(), names=names, donate=True).run()
    off = JaxFabricSimulation(sims(), names=names, donate=False).run()
    for a, b in zip(on, off):
        assert a.total_time == b.total_time
        assert a.total_bytes == b.total_bytes
        assert a.n_events == b.n_events


def test_donated_run_correct_with_cache_dir_configured(tmp_path):
    """The narrowed guard's end-to-end claim: with a persistent
    compilation cache dir CONFIGURED, a donated run still produces
    results identical to the undonated one (its programs compile inside
    the suppression window and never round-trip the cache), and the
    cache dir is intact afterwards."""
    import jax

    m = _mixed_batch(4)
    sims = lambda: [build_simulation(sc) for sc in m]  # noqa: E731
    names = [sc.name for sc in m]
    before = jax.config.jax_compilation_cache_dir
    jax.config.update("jax_compilation_cache_dir", str(tmp_path))
    try:
        on = JaxFabricSimulation(sims(), names=names, donate=True).run()
        off = JaxFabricSimulation(sims(), names=names, donate=False).run()
    finally:
        assert jax.config.jax_compilation_cache_dir == str(tmp_path)
        jax.config.update("jax_compilation_cache_dir", before)
    for a, b in zip(on, off):
        assert a.total_time == b.total_time
        assert a.total_bytes == b.total_bytes
        assert a.n_events == b.n_events


# ------------------------------------------------------------------ #
# SYNC_STATS: interleaved == serial
# ------------------------------------------------------------------ #


def test_sync_stats_interleaved_equals_serial():
    m = _mixed_batch(8)
    half_a = [build_simulation(sc) for sc in m[:4]]
    half_b = [build_simulation(sc) for sc in m[4:]]
    names_a = [sc.name for sc in m[:4]]
    names_b = [sc.name for sc in m[4:]]

    jax_backend.reset_sync_stats()
    JaxFabricSimulation(half_a, names=names_a).run()
    JaxFabricSimulation(half_b, names=names_b).run()
    serial_stats = dict(jax_backend.SYNC_STATS)

    jax_backend.reset_sync_stats()
    drivers = [
        JaxFabricSimulation(
            [build_simulation(sc) for sc in part],
            names=[sc.name for sc in part],
        )
        for part in (m[:4], m[4:])
    ]
    threads = [
        threading.Thread(target=d.run) for d in drivers
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    interleaved_stats = dict(jax_backend.SYNC_STATS)
    # wall keys are elapsed-seconds telemetry, not counters — they vary
    # run to run; the atomic-merge contract covers the counters
    from repro.eval.fabric.stats import WALL_KEYS

    strip = lambda d: {k: v for k, v in d.items() if k not in WALL_KEYS}
    assert strip(interleaved_stats) == strip(serial_stats)
    assert interleaved_stats["runs"] == 2
    assert interleaved_stats["scenarios"] == 8


# ------------------------------------------------------------------ #
# build_files cache under concurrency
# ------------------------------------------------------------------ #


def test_files_cache_concurrent_access(monkeypatch):
    """Hammer the byte-bounded LRU from several threads with a cap small
    enough to force constant eviction: no exceptions, consistent
    accounting, correct filesets."""
    monkeypatch.setattr(scenarios_mod, "FILES_CACHE_MAX_BYTES", 16 * 1024)
    with scenarios_mod._files_cache_lock:
        scenarios_mod._files_cache.clear()
        scenarios_mod._files_cache_bytes = 0
    expected = {
        seed: scenarios_mod.build_files(
            Scenario(
                network=testbeds.LAN.name, dataset="uniform_small",
                algorithm="sc", seed=seed,
            )
        )
        for seed in range(6)
    }
    errors = []

    def worker(tid):
        try:
            for i in range(200):
                seed = (tid + i) % 6
                files = scenarios_mod.build_files(
                    Scenario(
                        network=testbeds.LAN.name, dataset="uniform_small",
                        algorithm="sc", seed=seed,
                    )
                )
                assert [f.size for f in files] == [
                    f.size for f in expected[seed]
                ]
                info = scenarios_mod.files_cache_info()
                assert 0 <= info["bytes"] <= info["max_bytes"]
        except BaseException as exc:  # surfaced after join
            errors.append(exc)

    threads = [
        threading.Thread(target=worker, args=(t,)) for t in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    info = scenarios_mod.files_cache_info()
    assert info["bytes"] <= info["max_bytes"]
    with scenarios_mod._files_cache_lock:
        scenarios_mod._files_cache.clear()
        scenarios_mod._files_cache_bytes = 0


# ------------------------------------------------------------------ #
# AOT warm-start
# ------------------------------------------------------------------ #


def test_signature_ladder_rungs():
    sig = (1024, 8, 4, 8, 1, 1, 1024)
    assert signature_ladder(sig) == (
        (1024, 8, 4, 8, 1, 1, 1024),
        (256, 8, 4, 8, 1, 1, 1024),
        (64, 8, 4, 8, 1, 1, 1024),
    )
    # below the floor: no rungs
    assert signature_ladder((8, 4, 1, 8, 1, 1, 1024)) == (
        (8, 4, 1, 8, 1, 1, 1024),
    )
    assert (
        signature_ladder((4096, 4, 1, 4, 1, 1, 1024))[-1][0] == COMPACT_FLOOR
    )
    # all-static candidate planes stop at their own (shallower) floor
    assert signature_ladder(sig, floor=256) == (
        (1024, 8, 4, 8, 1, 1, 1024),
        (256, 8, 4, 8, 1, 1, 1024),
    )


def test_plan_batches_get_plane_compact_floor():
    """All-static plan batches compact no further than PLAN_COMPACT_FLOOR
    (a static jit argument — plane and grid programs stay disjoint);
    batches holding controller rows keep the grid floor."""
    from repro.eval.fabric.bucketing import COMPACT_FLOOR
    from repro.eval.fabric.driver import FabricSimulation
    from repro.eval.fabric.plan import PLAN_COMPACT_FLOOR, build_plan

    static = [
        Scenario(
            network=testbeds.XSEDE.name, dataset="mixed",
            algorithm="static", max_cc=4, static_params=(0, 1, cc),
        )
        for cc in (1, 2, 4)
    ]
    mixed = static + [
        Scenario(
            network=testbeds.XSEDE.name, dataset="mixed",
            algorithm="promc", max_cc=4,
        )
    ]
    drv = FabricSimulation(None, plan=build_plan(static))
    assert drv.compact_floor() == PLAN_COMPACT_FLOOR
    drv = FabricSimulation(None, plan=build_plan(mixed))
    assert drv.compact_floor() == COMPACT_FLOOR


def test_signature_shapes_matches_real_upload():
    """The AOT aval table must mirror ``_upload`` exactly — a drifted
    dtype or axis silently downgrades every warmed signature to a jit
    fallback (or worse, a runtime mismatch)."""
    from jax.experimental import enable_x64

    from repro.eval.fabric.bucketing import qsizes_pad

    sc = Scenario(
        network=testbeds.XSEDE.name, dataset="mixed", algorithm="promc",
        max_cc=8,
    )
    drv = JaxFabricSimulation(
        [build_simulation(sc) for _ in range(3)], names=list("abc")
    )
    drv.start()
    need_c, need_p = drv.capacity_need()
    while drv.C < need_c:
        drv._grow()
    while drv.P < need_p:
        drv._grow_prepend()
    drv._stall = np.zeros(drv.S, dtype=np.int64)
    drv._q_pad = qsizes_pad(drv.qsizes.shape[0])
    with enable_x64():
        mut, const = drv._upload()
    em, ec, eq = jax_backend.signature_shapes(drv._rounds_signature())
    assert set(mut) == set(em) and set(const) == set(ec)
    for real, exp in ((mut, em), (const, ec)):
        for k in real:
            assert tuple(real[k].shape) == tuple(exp[k].shape), k
            assert real[k].dtype == np.dtype(exp[k].dtype), k


def test_warm_signature_serves_runs_without_fresh_trace():
    sc = Scenario(
        network=testbeds.LONI.name, dataset="uniform_small",
        algorithm="sc", max_cc=2, seed=7,
    )
    sims = [build_simulation(sc) for _ in range(3)]
    probe = JaxFabricSimulation(sims, names=list("abc"))
    sig = canonical_signature(probe)
    jax_backend.warm_signature(sig, donate=probe.donate)
    # warming twice is a no-op (exactly-once per process)
    assert jax_backend.warm_signature(sig, donate=probe.donate) is False
    before = (
        jax_backend._device_rounds._cache_size()
        + jax_backend._device_rounds_donated._cache_size()
    )
    out = probe.run()
    after = (
        jax_backend._device_rounds._cache_size()
        + jax_backend._device_rounds_donated._cache_size()
    )
    assert after == before  # the AOT executable served the run
    assert out[0].total_bytes > 0
    assert jax_backend.compiled_program_count() >= 1


# ------------------------------------------------------------------ #
# multi-device shard layer (own process: device count is import-time)
# ------------------------------------------------------------------ #

_MULTIDEV_SCRIPT = """
import jax
assert jax.device_count() == 4, jax.device_count()
from repro.eval.runner import run_matrix
from repro.eval.scenarios import smoke_matrix
m = smoke_matrix()[:8]
ev = run_matrix(m, backend="event")
ax = run_matrix(m, backend="jax", chunk_size=2, executor="async")
for e, a in zip(ev, ax):
    assert a.total_bytes == e.total_bytes
    rel = abs(a.throughput - e.throughput) / max(e.throughput, 1e-12)
    assert rel < 0.02, rel
print("MULTIDEV-OK")
"""


@pytest.mark.slow
def test_four_device_round_robin_subprocess():
    """The shard layer on 4 simulated host devices: chunks round-robin
    across ``jax.devices()`` and results stay bit-clean vs the event
    reference. Subprocess because the XLA host device count is fixed at
    jax import."""
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=540, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTIDEV-OK" in proc.stdout
