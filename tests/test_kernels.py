"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(42)


def _tiered(cases, fast_n):
    """First ``fast_n`` cases stay in tier-1; the rest run with -m slow."""
    return [
        pytest.param(c, marks=() if i < fast_n else (pytest.mark.slow,))
        for i, c in enumerate(cases)
    ]


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #

FA_CASES = [
    # (B, H, KV, S, D, causal, window, softcap)
    (1, 4, 4, 128, 64, True, None, 0.0),     # MHA causal
    (2, 8, 2, 256, 64, True, None, 0.0),     # GQA 4:1
    (1, 4, 1, 256, 128, True, None, 0.0),    # MQA
    (1, 4, 4, 256, 64, False, None, 0.0),    # non-causal (encoder)
    (1, 4, 2, 512, 64, True, 128, 0.0),      # sliding window
    (1, 2, 1, 384, 64, True, 64, 0.0),       # window, non-pow2 seq
    (1, 4, 4, 256, 64, True, None, 50.0),    # logit softcap (gemma-style)
    (2, 2, 2, 1024, 32, True, 256, 0.0),     # longer seq, small heads
]


@pytest.mark.parametrize("case", _tiered(FA_CASES, 2))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, h, kv, s, d, causal, window, cap = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, h, s, d), dtype)
    k = _rand(k2, (b, kv, s, d), dtype)
    v = _rand(k3, (b, kv, s, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=cap,
        block_q=128, block_k=128, interpret=True,
    )
    want = ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, logit_softcap=cap
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.slow
def test_flash_attention_block_shape_independence():
    """Result must not depend on the block decomposition."""
    b, h, kv, s, d = 1, 2, 2, 512, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, h, s, d), jnp.float32)
    k = _rand(k2, (b, kv, s, d), jnp.float32)
    v = _rand(k3, (b, kv, s, d), jnp.float32)
    outs = [
        np.asarray(
            flash_attention(
                q, k, v, causal=True, window=100,
                block_q=bq, block_k=bk, interpret=True,
            )
        )
        for bq, bk in [(512, 512), (128, 256), (64, 64)]
    ]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


def test_flash_attention_window_one_token():
    """window=1 means each token attends only to itself: out == v (per head)."""
    b, h, s, d = 1, 2, 128, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, h, s, d), jnp.float32)
    k = _rand(k2, (b, h, s, d), jnp.float32)
    v = _rand(k3, (b, h, s, d), jnp.float32)
    out = flash_attention(
        q, k, v, causal=True, window=1, block_q=64, block_k=64, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# rwkv6 wkv scan
# ------------------------------------------------------------------ #

WKV_CASES = [
    (1, 2, 64, 32),
    (2, 4, 128, 64),
    (1, 1, 96, 16),  # non-pow2 T
]


@pytest.mark.parametrize("case", _tiered(WKV_CASES, 1))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_matches_ref(case, dtype):
    b, h, t, d = case
    ks = jax.random.split(KEY, 6)
    r = _rand(ks[0], (b, h, t, d), dtype, 0.5)
    k = _rand(ks[1], (b, h, t, d), dtype, 0.5)
    v = _rand(ks[2], (b, h, t, d), dtype, 0.5)
    # decay in (0, 1): w = exp(-exp(z))
    w = jnp.exp(-jnp.exp(_rand(ks[3], (b, h, t, d), jnp.float32, 0.5))).astype(dtype)
    u = _rand(ks[4], (h, d), jnp.float32, 0.5)
    s0 = _rand(ks[5], (b, h, d, d), jnp.float32, 0.1)
    y, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=32, interpret=True)
    y_ref, sf_ref = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref), rtol=tol, atol=tol)


@pytest.mark.slow
def test_rwkv6_chunk_independence():
    b, h, t, d = 1, 2, 128, 32
    ks = jax.random.split(KEY, 6)
    r = _rand(ks[0], (b, h, t, d), jnp.float32, 0.5)
    k = _rand(ks[1], (b, h, t, d), jnp.float32, 0.5)
    v = _rand(ks[2], (b, h, t, d), jnp.float32, 0.5)
    w = jnp.exp(-jnp.exp(_rand(ks[3], (b, h, t, d), jnp.float32, 0.5)))
    u = _rand(ks[4], (h, d), jnp.float32, 0.5)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    y1, s1 = rwkv6_scan(r, k, v, w, u, s0, chunk=128, interpret=True)
    y2, s2 = rwkv6_scan(r, k, v, w, u, s0, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_rwkv6_state_carry_composition():
    """scan(T) == scan(first half) then scan(second half with carried state)."""
    b, h, t, d = 1, 2, 64, 16
    ks = jax.random.split(KEY, 6)
    r = _rand(ks[0], (b, h, t, d), jnp.float32, 0.5)
    k = _rand(ks[1], (b, h, t, d), jnp.float32, 0.5)
    v = _rand(ks[2], (b, h, t, d), jnp.float32, 0.5)
    w = jnp.exp(-jnp.exp(_rand(ks[3], (b, h, t, d), jnp.float32, 0.5)))
    u = _rand(ks[4], (h, d), jnp.float32, 0.5)
    s0 = _rand(ks[5], (b, h, d, d), jnp.float32, 0.1)
    y_full, s_full = rwkv6_scan(r, k, v, w, u, s0, chunk=32, interpret=True)
    half = t // 2
    sl = lambda x, a, z: x[:, :, a:z]
    y1, s_mid = rwkv6_scan(*(sl(x, 0, half) for x in (r, k, v, w)), u, s0,
                           chunk=32, interpret=True)
    y2, s_end = rwkv6_scan(*(sl(x, half, t) for x in (r, k, v, w)), u, s_mid,
                           chunk=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_full), np.concatenate([y1, y2], axis=2), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_end),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# rg-lru scan
# ------------------------------------------------------------------ #

RG_CASES = [(1, 64, 128), (2, 128, 256), (1, 96, 512), (3, 100, 64)]


@pytest.mark.parametrize("case", _tiered(RG_CASES, 1))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(case, dtype):
    b, t, w = case
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(_rand(ks[0], (b, t, w), jnp.float32)).astype(dtype)
    x = _rand(ks[1], (b, t, w), dtype, 0.5)
    h0 = _rand(ks[2], (b, w), jnp.float32, 0.5)
    h, hf = rglru_scan(a, x, h0, chunk=32, block_w=64, interpret=True)
    h_ref, hf_ref = ref.rglru_scan_ref(a, x, h0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref), rtol=tol, atol=tol)


@pytest.mark.slow
def test_rglru_matches_associative_scan_in_model():
    """Kernel agrees with the model's associative-scan path."""
    from repro.models.rglru import rglru_scan_ref as assoc_ref

    b, t, w = 2, 64, 128
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(_rand(ks[0], (b, t, w), jnp.float32))
    x = _rand(ks[1], (b, t, w), jnp.float32, 0.5)
    h0 = _rand(ks[2], (b, w), jnp.float32, 0.5)
    h_kernel, hf_kernel = rglru_scan(a, x, h0, chunk=16, block_w=64,
                                     interpret=True)
    h_assoc, hf_assoc = assoc_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_assoc),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_kernel), np.asarray(hf_assoc),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# property sweeps (hypothesis)
# ------------------------------------------------------------------ #

from hypothesis import given, settings
import hypothesis.strategies as st


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192, 320]),
    d=st.sampled_from([32, 64]),
    kv=st.sampled_from([1, 2]),
    window=st.sampled_from([None, 32, 77]),
)
def test_flash_attention_property_sweep(s, d, kv, window):
    h = kv * 2
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s * d + kv), 3)
    q = _rand(k1, (1, h, s, d), jnp.float32)
    k = _rand(k2, (1, kv, s, d), jnp.float32)
    v = _rand(k3, (1, kv, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert bool(jnp.isfinite(out).all())
