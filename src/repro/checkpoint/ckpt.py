"""Sharded checkpointing executed by the paper's TransferEngine.

Checkpoint shards ARE the mixed-size dataset of the paper: a train state has
KB-scale scalars/norms next to GB-scale stacked weight matrices. Save/restore
therefore runs through ``repro.core``: shards are partitioned into size-class
chunks (Fig. 3 vs the storage path spec), Algorithm 1 tunes per-chunk
(pipelining = queued shard writes, parallelism = striped I/O of one big
shard, concurrency = simultaneous shard files), and MC/ProMC schedules the
channels. This layer actually executes on CPU and is benchmarked for real
(benchmarks/checkpoint_bench.py).

Layout (atomic-commit protocol):
  <dir>/step_<N>.tmp/            shards written here first
  <dir>/step_<N>/                renamed on completion (atomic on POSIX)
      index.json                 tree structure, shapes, dtypes, step
      <leafpath>.npy             one shard per leaf
Restore only ever reads directories with a committed index, so a crash
mid-save can never yield a half-checkpoint (tested by killing a save).
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import prepare_chunks
from repro.core import testbeds
from repro.core.engine import TransferEngine, TransferTask, bytes_task
from repro.core.schedulers import make_scheduler
from repro.core.types import FileSpec, NetworkSpec

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"#{p.idx}")
        else:
            parts.append(str(p))
    return ".".join(parts)


def _flatten(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    out: List[Tuple[str, np.ndarray]] = []

    def visit(path, leaf):
        out.append((_path_str(path), np.asarray(leaf)))

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def save(
    state: PyTree,
    directory: str,
    step: int,
    *,
    network: NetworkSpec = testbeds.CKPT_STORE,
    algorithm: str = "mc",
    max_cc: int = 4,
    keep: int = 3,
) -> str:
    """Write a checkpoint through the scheduled transfer engine."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten(state)
    specs: List[FileSpec] = []
    tasks: Dict[str, TransferTask] = {}
    index = {"step": step, "leaves": {}}
    for name, arr in leaves:
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        payload = buf.getvalue()
        fname = name.replace("/", "_") + ".npy"
        spec = FileSpec(name=name, size=len(payload))
        specs.append(spec)
        tasks[name] = bytes_task(spec, payload, os.path.join(tmp, fname))
        index["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }

    chunks = prepare_chunks(specs, network, num_chunks=2, max_cc=max_cc)
    sched = make_scheduler(algorithm, chunks, network, max_cc)
    engine = TransferEngine(network, tick_period=0.05)
    report = engine.run(chunks, sched, tasks)
    if report.files_done != len(specs):
        raise IOError(
            f"checkpoint save incomplete: {report.files_done}/{len(specs)}"
        )

    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(_committed_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def _committed_steps(directory: str) -> List[int]:
    out = []
    if not os.path.isdir(directory):
        return out
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "index.json")):
                out.append(int(d[len("step_"):]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Load the newest complete checkpoint (or a specific step) as a pytree
    of numpy arrays nested by the original path segments."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)

    tree: Dict = {}
    for name, meta in index["leaves"].items():
        arr = np.load(os.path.join(d, meta["file"]), allow_pickle=False)
        node = tree
        parts = name.split(".")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = arr
    return _undo_list_nodes(tree), int(index["step"])


def _undo_list_nodes(node):
    """Dict nodes whose keys are all '#<i>' were lists originally."""
    if not isinstance(node, dict):
        return node
    out = {k: _undo_list_nodes(v) for k, v in node.items()}
    if out and all(k.startswith("#") for k in out):
        return [out[f"#{i}"] for i in range(len(out))]
    return out


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight)."""

    def __init__(self, directory: str, **save_kw):
        self.directory = directory
        self.save_kw = save_kw
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, state: PyTree, step: int) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot before async

        def run():
            try:
                save(host_state, self.directory, step, **self.save_kw)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
