"""Sharded checkpointing driven by the paper's transfer engine."""
