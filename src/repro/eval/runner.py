"""Matrix runner: scenarios -> results on any backend + golden snapshots.

Backends (the ``--backend`` axis shared with ``eval.difftest``):

  - ``event``  the per-scenario discrete-event reference
                (``core.simulator.Simulation``);
  - ``numpy``  the batched fabric driver (alias ``batch``, its historical
                name);
  - ``jax``    the jit/vmap fabric driver (``fabric.jax_backend``).

Batched backends execute in *chunks* of ``chunk_size`` scenarios, ordered
by a cheap per-scenario cost proxy: memory stays bounded at matrix scale
(the 1000+-scenario grid holds every queue of every scenario otherwise)
and each chunk is cost-homogeneous, so one long-running straggler doesn't
pin the whole matrix's sweep width. Results always come back in input
order, and per-scenario outputs are independent of chunk composition —
scenarios never interact.

Golden snapshots are small JSON files mapping scenario name to the metrics
both tests and benchmarks care about (throughput, completion time, event
and move counts). They pin the simulator's behaviour across refactors: a
diff in a golden file is a *reviewable semantic change*, not a test flake.
Refresh with::

    PYTHONPATH=src python -m repro.eval.runner --refresh-golden \
        --out tests/golden/eval_matrix.json

which is also this module's __main__.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.simulator import SimResult, Simulation

from .fabric.bucketing import bucket, chunk_spans
from .fabric.executor import EXECUTOR_MODES, execute_chunks
from .scenarios import (
    Scenario,
    build_files,
    build_simulation,
    default_matrix,
    full_matrix,
    smoke_matrix,
    tenant_matrix,
)

#: default scenarios per batched execution chunk (bounds peak memory).
#: NumPy sweeps pay per-row Python dispatch, so narrower chunks win; the
#: JAX device loop amortizes fixed per-sweep overhead over width and skips
#: parked rows cheaply, so it prefers wide chunks.
BACKEND_CHUNK_SIZE = {"numpy": 256, "jax": 1024}
DEFAULT_CHUNK_SIZE: Optional[int] = None  # per-backend default above

#: metrics captured per scenario; keep additive — removing/renaming a field
#: invalidates every golden file.
SNAPSHOT_FIELDS = (
    "throughput_gbps",
    "total_time",
    "total_bytes",
    "n_moves",
)

BACKENDS = ("event", "numpy", "jax")

#: scenario ingest paths for the batched backends: the columnar
#: ``ScenarioPlan`` fast path (default) or the legacy per-row
#: ``build_simulation`` object chain (the difftest reference; also the
#: only path for custom scheduler subclasses, which have no Scenario
#: spelling). Select with ``REPRO_FABRIC_INGEST`` or the ``ingest=``
#: kwarg of :func:`run_matrix`.
INGEST_MODES = ("plan", "legacy")

#: prep threads for plan-sliced chunk construction: plan slicing is
#: pure array work (thread-safe, no shared caches), so a few workers
#: keep the device queues fed during multi-device sweeps
PLAN_PREP_WORKERS = 4


def ingest_mode(override: Optional[str] = None) -> str:
    """Resolve the scenario ingest path: explicit ``override`` wins,
    then ``REPRO_FABRIC_INGEST``, then the columnar default."""
    mode = override or os.environ.get("REPRO_FABRIC_INGEST") or "plan"
    if mode not in INGEST_MODES:
        raise ValueError(
            f"unknown ingest mode {mode!r}; options: {INGEST_MODES}"
        )
    return mode


def _resolve_backend(backend: str) -> str:
    if backend == "batch":  # historical alias for the NumPy fast path
        return "numpy"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; options: {BACKENDS} (+ 'batch')"
        )
    return backend


def _driver_cls(backend: str):
    from .fabric.registry import get_backend

    return get_backend(backend)


def cost_estimate(network, files, concurrency: int, tick_period: float) -> float:
    """Cheap *event-count* estimate for cost-homogeneous chunking.

    Batched sweep cost scales with the straggler's event count (file
    completions + controller ticks), so the proxy estimates the transfer
    duration at the *achievable* rate — window-limited streams on lossy
    paths run far below line rate — and converts it to ticks. Shared by
    the scenario cost proxy below and the autotuner's explicit-fileset
    rows (successive halving's sketch rungs).
    """
    from repro.core.netmodel import channel_rate_cap

    total = sum(f.size for f in files)
    est_rate = min(
        network.bandwidth,
        network.disk.streaming_rate,
        max(1, concurrency) * channel_rate_cap(network, 4),
    )
    duration = total / max(est_rate, 1.0)
    return duration / max(tick_period, 1e-9) + len(files)


def _effective_cc(scenario: Scenario) -> int:
    # static candidate rows run at their own fixed concurrency, not the
    # heuristics' maxCC budget
    return (
        scenario.static_params[2]
        if scenario.static_params is not None
        else scenario.max_cc
    )


def _cost_proxy(scenario: Scenario) -> float:
    from repro.core import testbeds

    net = testbeds.TESTBEDS[scenario.network]
    return cost_estimate(
        net, build_files(scenario), _effective_cc(scenario),
        scenario.tick_period,
    )


def shape_hint(concurrency: int) -> int:
    """Chunk-grouping key for shape-homogeneous batches: the pow2 bucket
    the row's worst-case channel axis lands in (the jax driver pre-sizes
    C/P from ``capacity_need`` by doubling from 4). Grouping rows by this
    hint *before* cost-sorting keeps a cc=32 candidate from dragging every
    cc<=8 row in its chunk up to the C=32 compiled program."""
    return bucket(concurrency, 4)


def run_scenario(scenario: Scenario, backend: str = "event") -> SimResult:
    backend = _resolve_backend(backend)
    if backend == "event":
        if scenario.shared_fabric is not None:
            from .fabric.coupled_event import run_event_coupled

            return run_event_coupled([scenario])[0]
        return build_simulation(scenario).run()
    return run_matrix([scenario], backend=backend)[0]


def _group_atomic_parts(
    order: Sequence[int], fabrics: Sequence, size: int
) -> tuple:
    """Split a cost-sorted row order into ``(uncoupled_order,
    coupled_parts)``.

    A shared-fabric group is only coupled when its members share a batch,
    so chunking must never split one: coupled rows leave the ordinary
    cost-sorted span stream and are packed whole-group (greedily, in
    first-appearance order) into their own execution parts of at most
    ``size`` rows — a group larger than ``size`` still stays whole in an
    oversized part. Uncoupled rows keep the untouched span path, so
    matrices without fabrics chunk exactly as before.
    """
    uncoupled = [i for i in order if fabrics[i] is None]
    groups: Dict[str, List[int]] = {}
    for i in order:
        if fabrics[i] is not None:
            groups.setdefault(fabrics[i].group, []).append(i)
    parts: List[List[int]] = []
    cur: List[int] = []
    for rows in groups.values():
        if cur and len(cur) + len(rows) > size:
            parts.append(cur)
            cur = []
        cur.extend(rows)
    if cur:
        parts.append(cur)
    return uncoupled, parts


def run_built(
    builders: Sequence,
    names: Sequence[str],
    costs: Optional[Sequence[float]] = None,
    backend: str = "numpy",
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    hints: Optional[Sequence[int]] = None,
    executor: Optional[str] = None,
    fabrics: Optional[Sequence] = None,
) -> List[SimResult]:
    """Chunked batched execution of *lazily built* Simulations.

    ``fabrics`` is the optional per-row ``SharedFabric`` column: coupled
    rows are chunked group-atomically (see :func:`_group_atomic_parts`)
    and the column is threaded to the driver so shared-link contention
    actually couples them; an all-``None`` (or absent) column keeps the
    historical chunking byte for byte.

    ``builders[i]`` is a zero-argument callable returning a fresh
    ``Simulation`` (schedulers are stateful, so every run needs its own);
    construction happens per chunk, so peak memory holds one chunk's
    queues, not the whole sweep's. ``costs`` orders rows into
    cost-homogeneous chunks exactly like :func:`run_matrix`'s scenario
    proxy. This is the execution primitive shared by the scenario-matrix
    runner and the autotuner (:mod:`repro.eval.tune`), whose
    successive-halving rungs sweep candidate rows that are not matrix
    scenarios (subsampled filesets).

    On the jax backend two more shape-canonicalization steps apply (see
    :mod:`repro.eval.fabric.bucketing`): rows are grouped by ``hints``
    (the :func:`shape_hint` capacity bucket) before cost-sorting, and
    chunk spans are cut power-of-two-aligned so live rows fill the padded
    device shape instead of sweeping dead pad width.

    ``executor`` picks the chunk execution strategy (see
    :mod:`repro.eval.fabric.executor`): the default async pipeline
    overlaps next-chunk host prep and AOT warm-compiles with in-flight
    device compute and round-robins chunks across devices;
    ``"serial"`` (or ``REPRO_FABRIC_EXECUTOR=serial``) restores the
    historical strictly-serial loop. Results are in input order and
    per-row outputs are identical under either mode — scenarios never
    interact.
    """
    backend = _resolve_backend(backend)
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    if backend == "event":
        return [b().run() for b in builders]
    cls = _driver_cls(backend)
    order = list(range(len(builders)))
    aligned = backend == "jax"
    if costs is not None:
        if aligned and hints is not None:
            order.sort(key=lambda i: (hints[i], costs[i]))
        else:
            order.sort(key=lambda i: costs[i])
    size = chunk_size or BACKEND_CHUNK_SIZE[backend]
    results: List[Optional[SimResult]] = [None] * len(builders)
    make_chunk = None
    if fabrics is not None and any(f is not None for f in fabrics):
        uncoupled, coupled_parts = _group_atomic_parts(order, fabrics, size)
        parts = [
            uncoupled[lo:hi]
            for lo, hi in chunk_spans(
                len(uncoupled), size, pad_aligned=aligned
            )
        ] + coupled_parts
        placed = getattr(cls, "supports_device_placement", False)

        def make_chunk(part, dev):
            kwargs = {"device": dev} if placed else {}
            return cls(
                [builders[i]() for i in part],
                names=[names[i] for i in part],
                fabric=[fabrics[i] for i in part],
                **kwargs,
            )

    else:
        parts = [
            order[lo:hi]
            for lo, hi in chunk_spans(len(order), size, pad_aligned=aligned)
        ]
    execute_chunks(
        cls, parts, builders, names, results, mode=executor,
        make_chunk=make_chunk,
    )
    return results  # type: ignore[return-value]


def run_plan(
    plan,
    backend: str = "numpy",
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    executor: Optional[str] = None,
) -> List[SimResult]:
    """Chunked batched execution of a columnar :class:`ScenarioPlan`.

    The plan-path twin of :func:`run_built`: same cost-homogeneous
    ordering (the plan's vectorized proxy computes the identical
    doubles), same shape-hint grouping and pow2-aligned spans on jax —
    but each chunk is ``plan.take(part)`` (thread-safe array slicing)
    handed straight to the driver's batch constructor, so the executor
    fans chunk prep over several workers instead of one ordered Python
    build thread.
    """
    backend = _resolve_backend(backend)
    if backend == "event":
        raise ValueError("the event backend has no columnar ingest path")
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    cls = _driver_cls(backend)
    n = plan.n_rows
    costs = plan.cost_proxy()
    order = list(range(n))
    aligned = backend == "jax"
    if aligned:
        hints = plan.shape_hints()
        order.sort(key=lambda i: (hints[i], costs[i]))
    else:
        order.sort(key=lambda i: costs[i])
    size = chunk_size or BACKEND_CHUNK_SIZE[backend]
    results: List[Optional[SimResult]] = [None] * n
    fabrics = getattr(plan, "fabrics", None)
    if fabrics is not None and any(f is not None for f in fabrics):
        uncoupled, coupled_parts = _group_atomic_parts(order, fabrics, size)
        parts = [
            uncoupled[lo:hi]
            for lo, hi in chunk_spans(
                len(uncoupled), size, pad_aligned=aligned
            )
        ] + coupled_parts
    else:
        parts = [
            order[lo:hi]
            for lo, hi in chunk_spans(n, size, pad_aligned=aligned)
        ]
    placed = getattr(cls, "supports_device_placement", False)
    # fleet-scale planes (at least one full chunk) floor every chunk's
    # padded row count at the batch's compaction floor: the remainder
    # spans then occupy the SAME device shape as the big chunks' bottom
    # rung instead of minting a one-off small program (each is seconds
    # of per-process trace + executable materialization). The floor is
    # the driver's own (PLAN_COMPACT_FLOOR for all-static planes), so
    # tail chunks share the plane's 256-row program.
    want_pad_floor = aligned and n >= size

    def make_chunk(part, dev):
        kwargs = {"device": dev} if placed else {}
        drv = cls(None, plan=plan.take(part), **kwargs)
        # coupled chunks never compact, so pinning the pad floor would
        # only inflate their fixed device shape
        if want_pad_floor and not drv.coupled:
            drv._pad_floor = drv.compact_floor()
        return drv

    execute_chunks(
        cls, parts, None, None, results, mode=executor,
        make_chunk=make_chunk, prep_workers=PLAN_PREP_WORKERS,
    )
    return results  # type: ignore[return-value]


def run_matrix(
    scenarios: Sequence[Scenario],
    backend: str = "numpy",
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    executor: Optional[str] = None,
    ingest: Optional[str] = None,
) -> List[SimResult]:
    """Run every scenario; order of results matches the input order.

    Batched backends default to the columnar plan ingest (one vectorized
    build per transfer context, broadcast across candidate rows); the
    event reference — and ``ingest="legacy"`` /
    ``REPRO_FABRIC_INGEST=legacy`` — keeps the per-row object chain.
    """
    backend_r = _resolve_backend(backend)
    if backend_r == "event" and any(
        sc.shared_fabric is not None for sc in scenarios
    ):
        from .fabric.coupled_event import run_event_coupled

        return run_event_coupled(scenarios)
    if backend_r != "event" and ingest_mode(ingest) == "plan":
        from .fabric.plan import build_plan, plan_supported

        if plan_supported(scenarios):
            return run_plan(
                build_plan(scenarios), backend=backend_r,
                chunk_size=chunk_size, executor=executor,
            )
    return run_built(
        [
            (lambda sc=sc: build_simulation(sc))
            for sc in scenarios
        ],
        names=[sc.name for sc in scenarios],
        costs=[_cost_proxy(sc) for sc in scenarios],
        backend=backend,
        chunk_size=chunk_size,
        hints=[shape_hint(_effective_cc(sc)) for sc in scenarios],
        executor=executor,
        fabrics=[sc.shared_fabric for sc in scenarios],
    )


def run_simulations(
    sims: Sequence["Simulation"],
    names: Optional[Sequence[str]] = None,
    backend: str = "numpy",
) -> List[SimResult]:
    """Batch-execute prebuilt Simulations (for sweeps that don't fit the
    Scenario grid, e.g. the figure benchmarks' custom dataset scales)."""
    backend = _resolve_backend(backend)
    if backend == "event":
        return [sim.run() for sim in sims]
    return _driver_cls(backend)(sims, names=names).run()


# --------------------------------------------------------------------------
# golden snapshots
# --------------------------------------------------------------------------


def metrics_snapshot(
    scenarios: Sequence[Scenario], results: Sequence[SimResult]
) -> Dict[str, Dict[str, float]]:
    snap: Dict[str, Dict[str, float]] = {}
    for sc, r in zip(scenarios, results):
        snap[sc.name] = {
            "throughput_gbps": round(r.throughput_gbps, 6),
            "total_time": round(r.total_time, 6),
            "total_bytes": float(r.total_bytes),
            "n_moves": int(r.n_moves),
        }
    return snap


def save_golden(path: str, snapshot: Dict[str, Dict[str, float]]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")


def load_golden(path: str) -> Dict[str, Dict[str, float]]:
    with open(path) as f:
        return json.load(f)


@dataclasses.dataclass(frozen=True)
class GoldenDeviation:
    scenario: str
    field: str
    golden: float
    observed: float

    @property
    def rel_err(self) -> float:
        denom = max(abs(self.golden), 1e-12)
        return abs(self.observed - self.golden) / denom


def compare_golden(
    golden: Dict[str, Dict[str, float]],
    observed: Dict[str, Dict[str, float]],
    rtol: float = 1e-6,
    fields: Iterable[str] = ("throughput_gbps", "total_time"),
) -> List[GoldenDeviation]:
    """Deviations of ``observed`` from ``golden`` beyond ``rtol`` (plus any
    scenario missing from either side, reported with NaN metrics)."""
    out: List[GoldenDeviation] = []
    for name in sorted(set(golden) | set(observed)):
        if name not in golden or name not in observed:
            out.append(
                GoldenDeviation(name, "presence", float("nan"), float("nan"))
            )
            continue
        for f in fields:
            dev = GoldenDeviation(name, f, golden[name][f], observed[name][f])
            if dev.rel_err > rtol:
                out.append(dev)
    return out


MATRIX_NAMES = ("default", "smoke", "full", "tenant", "tenant-smoke")


def build_matrix(name: str) -> List[Scenario]:
    if name == "default":
        return default_matrix()
    if name == "smoke":
        return smoke_matrix()
    if name == "full":
        return full_matrix()
    if name == "tenant":
        return tenant_matrix()
    if name == "tenant-smoke":
        return tenant_matrix(n_groups=6)
    raise ValueError(
        f"unknown matrix {name!r}; options: {', '.join(MATRIX_NAMES)}"
    )


def run_tune(args, scenarios: Sequence[Scenario]) -> int:
    """The ``--tune`` subcommand: search the static knob space over the
    matrix and report every heuristic's regret against the result."""
    from . import tune

    history = tune.HistoryStore(args.history) if args.history else None
    searchers = {
        "oracle": tune.oracle_search,
        "sha": tune.successive_halving,
        "hill": tune.hill_climb,
    }
    search = searchers[args.tune]
    result = search(
        scenarios,
        backend=args.backend,
        n_candidates=args.candidates,
        history=history,
        chunk_size=args.chunk_size,
        executor=args.executor,
    )
    heuristics = run_matrix(
        scenarios, backend=args.backend, chunk_size=args.chunk_size,
        executor=args.executor,
    )
    report = tune.regret_report(scenarios, heuristics, result)
    n_ctx = len(result.tables)
    print(
        f"tune[{args.tune}]: {len(scenarios)} scenarios, {n_ctx} contexts, "
        f"{result.evals} candidate evaluations "
        f"({result.equivalent_evals:.1f} full-fidelity-equivalent)"
    )
    print(f"regret = heuristic_throughput / {args.tune}_throughput:")
    print(report.format_table())
    if history is not None:
        history.save()
        print(f"warm-start history ({len(history)} winners) -> {args.history}")
    if args.regret_out:
        tune.save_report(args.regret_out, report, result)
        print(f"regret report -> {args.regret_out}")
    return 0


def _print_wall_breakdown() -> None:
    """The ``--verbose`` prep-vs-compute split: aggregate thread-seconds
    per pipeline phase from the shared wall accumulators (phases overlap
    under the async executor, so they need not sum to elapsed time)."""
    from .fabric import stats as fabric_stats

    s = dict(fabric_stats.SYNC_STATS)
    build = s["build_wall_s"]
    compute = s["compute_wall_s"]
    download = s["download_wall_s"]
    total = max(build + compute, 1e-9)
    print(
        "wall breakdown (thread-seconds, phases overlap): "
        f"build {build:.3f}s ({100.0 * build / total:.1f}%) | "
        f"compute {compute:.3f}s ({100.0 * compute / total:.1f}%) | "
        f"download {download:.3f}s (inside compute)"
    )


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--matrix", choices=MATRIX_NAMES, default="default"
    )
    ap.add_argument(
        "--backend", choices=BACKENDS + ("batch",), default="event"
    )
    ap.add_argument(
        "--chunk-size", type=int, default=DEFAULT_CHUNK_SIZE,
        help="scenarios per batched execution chunk (bounds memory)",
    )
    ap.add_argument(
        "--executor", choices=EXECUTOR_MODES, default=None,
        help="chunk execution strategy: the overlap-pipelined multi-"
        "device default ('async') or the historical strictly-serial "
        "loop ('serial'); also via REPRO_FABRIC_EXECUTOR",
    )
    ap.add_argument("--out", default="tests/golden/eval_matrix.json")
    ap.add_argument("--refresh-golden", action="store_true")
    ap.add_argument(
        "--verbose", action="store_true",
        help="print the prep-vs-compute wall breakdown (host chunk "
        "build, driver run, device->host downloads) after the run",
    )
    ap.add_argument(
        "--tune", choices=("oracle", "sha", "hill"), default=None,
        help="search the static (pipelining, parallelism, concurrency) "
        "space over the matrix (exhaustive grid / successive halving / "
        "hill climbing) and report per-algorithm regret vs the result",
    )
    ap.add_argument(
        "--candidates", type=int, default=64,
        help="--tune: candidate-grid budget per scenario context",
    )
    ap.add_argument(
        "--history", default=None, metavar="PATH",
        help="--tune: JSON warm-start store; read to seed the search, "
        "updated with the winners afterwards",
    )
    ap.add_argument(
        "--regret-out", default=None, metavar="PATH",
        help="--tune: write the regret report + search tables as JSON",
    )
    args = ap.parse_args(argv)

    scenarios = build_matrix(args.matrix)
    if args.verbose:
        from .fabric import stats as fabric_stats

        fabric_stats.reset_sync_stats()
    if args.tune:
        rc = run_tune(args, scenarios)
        if args.verbose:
            _print_wall_breakdown()
        return rc
    results = run_matrix(
        scenarios, backend=args.backend, chunk_size=args.chunk_size,
        executor=args.executor,
    )
    if args.verbose:
        _print_wall_breakdown()
    snap = metrics_snapshot(scenarios, results)
    if args.refresh_golden:
        save_golden(args.out, snap)
        print(f"wrote {len(snap)} scenario metrics to {args.out}")
        return 0
    golden = load_golden(args.out)
    devs = compare_golden(golden, snap)
    for d in devs[:20]:
        print(f"DEVIATION {d.scenario} {d.field}: "
              f"golden={d.golden} observed={d.observed}")
    print(f"{len(snap)} scenarios, {len(devs)} deviations")
    return 1 if devs else 0


if __name__ == "__main__":
    raise SystemExit(main())
