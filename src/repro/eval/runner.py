"""Matrix runner: scenarios -> results on either backend + golden snapshots.

Golden snapshots are small JSON files mapping scenario name to the metrics
both tests and benchmarks care about (throughput, completion time, event and
move counts). They pin the simulator's behaviour across refactors: a diff in
a golden file is a *reviewable semantic change*, not a test flake. Refresh
with::

    PYTHONPATH=src python -m repro.eval.runner --refresh-golden \
        --out tests/golden/eval_matrix.json

which is also this module's __main__.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.simulator import SimResult, Simulation

from .batchsim import BatchSimulation
from .scenarios import Scenario, build_simulation, default_matrix, smoke_matrix

#: metrics captured per scenario; keep additive — removing/renaming a field
#: invalidates every golden file.
SNAPSHOT_FIELDS = (
    "throughput_gbps",
    "total_time",
    "total_bytes",
    "n_moves",
)


def run_scenario(scenario: Scenario, backend: str = "event") -> SimResult:
    if backend == "event":
        return build_simulation(scenario).run()
    if backend == "batch":
        return run_matrix([scenario], backend="batch")[0]
    raise ValueError(f"unknown backend {backend!r}; options: event, batch")


def run_matrix(
    scenarios: Sequence[Scenario], backend: str = "batch"
) -> List[SimResult]:
    """Run every scenario; order of results matches the input order."""
    if backend == "event":
        return [build_simulation(sc).run() for sc in scenarios]
    if backend == "batch":
        sims = [build_simulation(sc) for sc in scenarios]
        return BatchSimulation(sims, names=[sc.name for sc in scenarios]).run()
    raise ValueError(f"unknown backend {backend!r}; options: event, batch")


def run_simulations(
    sims: Sequence["Simulation"],
    names: Optional[Sequence[str]] = None,
    backend: str = "batch",
) -> List[SimResult]:
    """Batch-execute prebuilt Simulations (for sweeps that don't fit the
    Scenario grid, e.g. the figure benchmarks' custom dataset scales)."""
    if backend == "event":
        return [sim.run() for sim in sims]
    if backend == "batch":
        return BatchSimulation(sims, names=names).run()
    raise ValueError(f"unknown backend {backend!r}; options: event, batch")


# --------------------------------------------------------------------------
# golden snapshots
# --------------------------------------------------------------------------


def metrics_snapshot(
    scenarios: Sequence[Scenario], results: Sequence[SimResult]
) -> Dict[str, Dict[str, float]]:
    snap: Dict[str, Dict[str, float]] = {}
    for sc, r in zip(scenarios, results):
        snap[sc.name] = {
            "throughput_gbps": round(r.throughput_gbps, 6),
            "total_time": round(r.total_time, 6),
            "total_bytes": float(r.total_bytes),
            "n_moves": int(r.n_moves),
        }
    return snap


def save_golden(path: str, snapshot: Dict[str, Dict[str, float]]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")


def load_golden(path: str) -> Dict[str, Dict[str, float]]:
    with open(path) as f:
        return json.load(f)


@dataclasses.dataclass(frozen=True)
class GoldenDeviation:
    scenario: str
    field: str
    golden: float
    observed: float

    @property
    def rel_err(self) -> float:
        denom = max(abs(self.golden), 1e-12)
        return abs(self.observed - self.golden) / denom


def compare_golden(
    golden: Dict[str, Dict[str, float]],
    observed: Dict[str, Dict[str, float]],
    rtol: float = 1e-6,
    fields: Iterable[str] = ("throughput_gbps", "total_time"),
) -> List[GoldenDeviation]:
    """Deviations of ``observed`` from ``golden`` beyond ``rtol`` (plus any
    scenario missing from either side, reported with NaN metrics)."""
    out: List[GoldenDeviation] = []
    for name in sorted(set(golden) | set(observed)):
        if name not in golden or name not in observed:
            out.append(
                GoldenDeviation(name, "presence", float("nan"), float("nan"))
            )
            continue
        for f in fields:
            dev = GoldenDeviation(name, f, golden[name][f], observed[name][f])
            if dev.rel_err > rtol:
                out.append(dev)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--matrix", choices=("default", "smoke"), default="default")
    ap.add_argument("--backend", choices=("event", "batch"), default="event")
    ap.add_argument("--out", default="tests/golden/eval_matrix.json")
    ap.add_argument("--refresh-golden", action="store_true")
    args = ap.parse_args(argv)

    scenarios = default_matrix() if args.matrix == "default" else smoke_matrix()
    results = run_matrix(scenarios, backend=args.backend)
    snap = metrics_snapshot(scenarios, results)
    if args.refresh_golden:
        save_golden(args.out, snap)
        print(f"wrote {len(snap)} scenario metrics to {args.out}")
        return 0
    golden = load_golden(args.out)
    devs = compare_golden(golden, snap)
    for d in devs[:20]:
        print(f"DEVIATION {d.scenario} {d.field}: "
              f"golden={d.golden} observed={d.observed}")
    print(f"{len(snap)} scenarios, {len(devs)} deviations")
    return 1 if devs else 0


if __name__ == "__main__":
    raise SystemExit(main())
