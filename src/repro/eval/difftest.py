"""Differential testing: fabric backends vs the event-driven reference.

The fabric drivers re-implement the event semantics with vectorized
numerics; this harness is the contract that keeps every implementation
equivalent. For each scenario in a matrix it runs a backend pair and
compares throughput (and completion time, which is 1:1 with throughput for
a fixed byte count) under a relative tolerance — the acceptance bar is 2%
on every scenario, not on the average. In practice agreement is bit-level
(~1e-16): all backends execute the same per-scenario event sequence.

The JAX backend is held to the bar twice: against the event simulator
(the semantics ground truth) *and* against the NumPy fast path (so the
two fabric instantiations cannot drift apart silently)::

    PYTHONPATH=src python -m repro.eval.difftest --backend jax --smoke
    PYTHONPATH=src python -m repro.eval.difftest --backend all --matrix full
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .runner import DEFAULT_CHUNK_SIZE, build_matrix, run_matrix
from .scenarios import Scenario

DEFAULT_RTOL = 0.02


@dataclasses.dataclass(frozen=True)
class DiffReport:
    scenario: str
    event_throughput: float  # reference backend
    batch_throughput: float  # backend under test
    event_time: float
    batch_time: float
    reference: str = "event"
    backend: str = "numpy"

    @property
    def rel_err(self) -> float:
        denom = max(abs(self.event_throughput), 1e-12)
        return abs(self.batch_throughput - self.event_throughput) / denom

    def ok(self, rtol: float = DEFAULT_RTOL) -> bool:
        return self.rel_err <= rtol


def pair_results(
    scenarios: Sequence[Scenario],
    ref_results,
    test_results,
    reference: str = "event",
    backend: str = "numpy",
) -> List[DiffReport]:
    """Pair two backends' already-computed results into DiffReports."""
    return [
        DiffReport(
            scenario=sc.name,
            event_throughput=e.throughput,
            batch_throughput=b.throughput,
            event_time=e.total_time,
            batch_time=b.total_time,
            reference=reference,
            backend=backend,
        )
        for sc, e, b in zip(scenarios, ref_results, test_results)
    ]


def diff_matrix(
    scenarios: Sequence[Scenario],
    backend: str = "numpy",
    reference: str = "event",
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
) -> List[DiffReport]:
    """Run ``reference`` and ``backend`` over the matrix and pair results."""
    ref = run_matrix(scenarios, backend=reference, chunk_size=chunk_size)
    test = run_matrix(scenarios, backend=backend, chunk_size=chunk_size)
    return pair_results(scenarios, ref, test, reference, backend)


def assert_agreement(
    reports: Sequence[DiffReport], rtol: float = DEFAULT_RTOL
) -> None:
    """Raise with a readable table of every violator (not just the first)."""
    bad = [r for r in reports if not r.ok(rtol)]
    if not bad:
        return
    lines = [
        f"{len(bad)}/{len(reports)} scenarios exceed rtol={rtol:.3%}:"
    ]
    for r in sorted(bad, key=lambda r: -r.rel_err)[:25]:
        lines.append(
            f"  {r.scenario}: {r.reference}={r.event_throughput:.4g} B/s "
            f"{r.backend}={r.batch_throughput:.4g} B/s rel_err={r.rel_err:.3%}"
        )
    raise AssertionError("\n".join(lines))


def diff_backend(
    scenarios: Sequence[Scenario],
    backend: str,
    rtol: float = DEFAULT_RTOL,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    results_cache: Optional[dict] = None,
) -> List[DiffReport]:
    """Enforce the bar for one backend: vs the event reference, and — for
    the JAX backend — additionally vs the NumPy fast path. Each backend
    runs at most once (pass ``results_cache`` to share runs across calls);
    the pairings reuse the computed results."""
    cache = results_cache if results_cache is not None else {}

    def results(b: str):
        if b not in cache:
            cache[b] = run_matrix(scenarios, backend=b, chunk_size=chunk_size)
        return cache[b]

    reports = pair_results(
        scenarios, results("event"), results(backend), "event", backend
    )
    assert_agreement(reports, rtol)
    if backend == "jax":
        cross = pair_results(
            scenarios, results("numpy"), results("jax"), "numpy", "jax"
        )
        assert_agreement(cross, rtol)
        reports = reports + cross
    return reports


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--backend", choices=("numpy", "jax", "batch", "all"),
        default="numpy",
    )
    ap.add_argument(
        "--matrix",
        choices=("smoke", "default", "full", "tenant", "tenant-smoke"),
        default="full",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="shorthand for --matrix smoke (CI fast path)",
    )
    ap.add_argument("--rtol", type=float, default=DEFAULT_RTOL)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument(
        "--sample", type=int, default=None, metavar="N",
        help="run a deterministic N-scenario subsample of the matrix "
        "(seeded shuffle; CI uses this for a fast full-matrix slice)",
    )
    ap.add_argument(
        "--sample-seed", type=int, default=0,
        help="seed for --sample (vary to cover different slices)",
    )
    ap.add_argument(
        "--expect-zero-replays", action="store_true",
        help="after a jax run, fail unless SYNC_STATS shows zero parked-"
        "row replays (the zero-host-round invariant for built-in "
        "schedulers; CI gates the fused-jit leg on this)",
    )
    args = ap.parse_args(argv)

    matrix = "smoke" if args.smoke else args.matrix
    scenarios = build_matrix(matrix)
    if args.sample is not None and args.sample < len(scenarios):
        import random

        scenarios = random.Random(args.sample_seed).sample(
            scenarios, args.sample
        )
        matrix = f"{matrix}[sample {args.sample}]"
    backends = ("numpy", "jax") if args.backend == "all" else (
        "numpy" if args.backend == "batch" else args.backend,
    )
    if args.expect_zero_replays and "jax" not in backends:
        ap.error(
            "--expect-zero-replays checks the jax backend's SYNC_STATS; "
            "run with --backend jax or --backend all"
        )
    if args.expect_zero_replays:
        from .fabric import jax_backend

        jax_backend.reset_sync_stats()
    cache: dict = {}
    for backend in backends:
        reports = diff_backend(
            scenarios, backend, rtol=args.rtol, chunk_size=args.chunk_size,
            results_cache=cache,
        )
        worst = max((r.rel_err for r in reports), default=0.0)
        print(
            f"difftest OK: backend={backend} matrix={matrix} "
            f"({len(scenarios)} scenarios, worst rel_err {worst:.3e})"
        )
    if args.expect_zero_replays:
        stats = jax_backend.SYNC_STATS
        if stats["post_row_replays"] or stats["replay_rounds"]:
            print(
                "FAIL: expected zero parked-row replays, got "
                f"{stats['post_row_replays']} parked rows across "
                f"{stats['replay_rounds']} replay rounds"
            )
            return 1
        print(
            "SYNC_STATS OK: 0 host rounds/scenario "
            f"(0 parked-row replays across {stats['runs']} runs, "
            f"{stats['scenarios']} scenario-runs)"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
