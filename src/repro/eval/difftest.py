"""Differential testing: batch fast-path vs the event-driven reference.

The batch simulator re-implements the event semantics with vectorized
numerics; this harness is the contract that keeps the two implementations
equivalent. For every scenario in a matrix it runs both backends and
compares throughput (and completion time, which is 1:1 with throughput for
a fixed byte count) under a relative tolerance — the acceptance bar is 2%
on every scenario, not on the average.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from .runner import run_matrix
from .scenarios import Scenario

DEFAULT_RTOL = 0.02


@dataclasses.dataclass(frozen=True)
class DiffReport:
    scenario: str
    event_throughput: float
    batch_throughput: float
    event_time: float
    batch_time: float

    @property
    def rel_err(self) -> float:
        denom = max(abs(self.event_throughput), 1e-12)
        return abs(self.batch_throughput - self.event_throughput) / denom

    def ok(self, rtol: float = DEFAULT_RTOL) -> bool:
        return self.rel_err <= rtol


def diff_matrix(scenarios: Sequence[Scenario]) -> List[DiffReport]:
    """Run both backends over the matrix and pair up their results."""
    event = run_matrix(scenarios, backend="event")
    batch = run_matrix(scenarios, backend="batch")
    return [
        DiffReport(
            scenario=sc.name,
            event_throughput=e.throughput,
            batch_throughput=b.throughput,
            event_time=e.total_time,
            batch_time=b.total_time,
        )
        for sc, e, b in zip(scenarios, event, batch)
    ]


def assert_agreement(
    reports: Sequence[DiffReport], rtol: float = DEFAULT_RTOL
) -> None:
    """Raise with a readable table of every violator (not just the first)."""
    bad = [r for r in reports if not r.ok(rtol)]
    if not bad:
        return
    lines = [
        f"{len(bad)}/{len(reports)} scenarios exceed rtol={rtol:.3%}:"
    ]
    for r in sorted(bad, key=lambda r: -r.rel_err)[:25]:
        lines.append(
            f"  {r.scenario}: event={r.event_throughput:.4g} B/s "
            f"batch={r.batch_throughput:.4g} B/s rel_err={r.rel_err:.3%}"
        )
    raise AssertionError("\n".join(lines))
