"""Autotuner: batched parameter-space search + static-oracle regret.

The fourth layer of the evaluation stack — above the controller layer
the way the controllers sit above the fluid kernels — and the first
consumer that composes *multiple* fused sweeps in a host loop. The
batched fabric sweep (NumPy driver or zero-host-round JAX device loop)
is treated as a vectorized black-box objective
``f(scenario, pp, p, cc) -> throughput``; everything here is about
choosing which (scenario x candidate) plane to hand it next:

  - :mod:`space`    BDP-capped log-spaced (pp, p, cc) axes per testbed
                    + the ``StaticParamsScheduler`` candidate vehicle
  - :mod:`oracle`   exhaustive grid search as ONE batched sweep over
                    the candidate-expanded scenario matrix; per-scenario
                    argmax tables and the heuristic-vs-oracle regret
                    report (the paper's "approaches the best static
                    setting" claim, quantified)
  - :mod:`search`   successive halving (subsampled rungs, shrink the
                    candidate axis between sweeps) and axis-neighbor
                    hill climbing — within a few percent of the oracle
                    at a fraction of its evaluations
  - :mod:`history`  JSON warm-start store of per-testbed winners that
                    seeds subsequent searches
  - :mod:`contention` the fleet question: greedy per-tenant Algorithm-1
                    tuning vs the coordinate-descent static oracle under
                    shared-link contention (``scenarios.tenant_matrix``)

``eval/runner.py --tune {oracle,sha,hill}`` is the CLI; TESTING.md
documents the regret semantics and the candidate-axis chunking.
"""
from __future__ import annotations

from .contention import (
    ContentionReport,
    contention_report,
    greedy_static_oracle,
)
from .history import HistoryStore, history_key
from .oracle import (
    ContextTable,
    RegretReport,
    TuneEntry,
    TuneResult,
    context_key,
    oracle_search,
    regret_report,
    save_report,
)
from .search import hill_climb, successive_halving
from .space import (
    ParamSpace,
    StaticParamsScheduler,
    algorithm1_params,
    param_space,
    scenario_space,
)

__all__ = [
    "ContentionReport",
    "ContextTable",
    "HistoryStore",
    "ParamSpace",
    "RegretReport",
    "StaticParamsScheduler",
    "TuneEntry",
    "TuneResult",
    "algorithm1_params",
    "contention_report",
    "context_key",
    "greedy_static_oracle",
    "hill_climb",
    "history_key",
    "oracle_search",
    "param_space",
    "regret_report",
    "save_report",
    "scenario_space",
    "successive_halving",
]
