"""Warm-start history: per-testbed winners persisted to JSON.

Mirrors the historical-analysis approach of the related tuning work
(offline knowledge discovery feeding online refinement): every search
records its per-context winner keyed by ``network/dataset/ccN``, and
subsequent searches seed from the store — the oracle and successive
halving inject the remembered winner into their candidate sets, the
hill climber starts walking from it instead of the Algorithm-1 point.
Transfers over a path that was tuned before therefore begin at (or
near) the known optimum and spend their budget *refining* it.

The store is a plain JSON document (human-diffable, append-friendly)::

    {
      "version": 1,
      "winners": {
        "xsede/mixed/cc8": {
          "pipelining": 16, "parallelism": 4, "concurrency": 8,
          "throughput": 1.04e9, "method": "oracle"
        }, ...
      }
    }

A winner is replaced only by a strictly better measured throughput, so
interleaved cheap searches cannot clobber an exhaustive result.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.core.types import TransferParams, param_triple

VERSION = 1


def history_key(scenario) -> str:
    """Per-testbed warm-start key: path + dataset shape + maxCC budget
    (the budget caps the admissible space, so winners are not portable
    across it)."""
    return f"{scenario.network}/{scenario.dataset}/cc{scenario.max_cc}"


class HistoryStore:
    """JSON-backed map of per-testbed winning static settings."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._winners: Dict[str, dict] = {}
        if path is not None and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._winners)

    # ---------------------------------------------------------------- #

    def load(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if path is None:
            raise ValueError("no path given and store was created without one")
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != VERSION:
            raise ValueError(
                f"history store {path!r} has version "
                f"{data.get('version')!r}, expected {VERSION}"
            )
        self._winners = dict(data.get("winners", {}))

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if path is None:
            raise ValueError("no path given and store was created without one")
        payload = {"version": VERSION, "winners": self._winners}
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")

    # ---------------------------------------------------------------- #

    def record(
        self,
        scenario,
        params,
        throughput: float,
        method: str = "unknown",
    ) -> bool:
        """Remember ``params`` for the scenario's testbed if it beats the
        stored winner (strictly). Returns whether the store changed."""
        key = history_key(scenario)
        prev = self._winners.get(key)
        if prev is not None and prev["throughput"] >= throughput:
            return False
        trip = param_triple(params)
        self._winners[key] = {
            "pipelining": trip[0],
            "parallelism": trip[1],
            "concurrency": trip[2],
            "throughput": float(throughput),
            "method": method,
        }
        return True

    def seed(self, scenario) -> Optional[TransferParams]:
        """The remembered winner for the scenario's testbed, if any."""
        entry = self._winners.get(history_key(scenario))
        if entry is None:
            return None
        return TransferParams(
            pipelining=int(entry["pipelining"]),
            parallelism=int(entry["parallelism"]),
            concurrency=int(entry["concurrency"]),
        )

    def best_throughput(self, scenario) -> Optional[float]:
        entry = self._winners.get(history_key(scenario))
        return None if entry is None else float(entry["throughput"])

