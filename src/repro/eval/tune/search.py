"""Adaptive searchers: cheaper-than-oracle tuning over the fused sweep.

Two budgeted strategies over the same candidate machinery the oracle
uses (:mod:`repro.eval.tune.oracle`), both composing *multiple* batched
sweeps in a host loop — the device evaluates a whole (context x
candidate) plane per rung / iteration, the host only shrinks and
re-batches the candidate axis between sweeps:

* :func:`successive_halving` — evaluate every candidate on a small
  deterministic *subsample* of the dataset, keep the top ``1/eta`` per
  context, re-evaluate the survivors on an ``eta``-times larger
  subsample, and so on until the final rung runs the full dataset. With
  the default schedule (64 candidates, eta=4: 64 @ 1/16 -> 16 @ 1/4 ->
  4 @ full) the *full-fidelity-equivalent* cost is 12 evaluations per
  context — under 1/4 of the oracle's 64 — while the final-rung argmax
  is exact (full dataset) for every survivor.

* :func:`hill_climb` — coordinate descent on the log-spaced axes of
  :class:`repro.eval.tune.space.ParamSpace`: start at the remembered
  per-testbed winner (:mod:`repro.eval.tune.history`) or the
  Algorithm-1 point, evaluate the <= 6 one-step axis neighbors of every
  context's current setting in one batched sweep, move each context to
  its best neighbor, repeat until no context improves. The knob
  responses are unimodal in the model (saturating rate curves, one
  contention sweet spot), which is what makes local search competitive.

Subsampled rungs measure throughput on a deterministic *sketch* of the
fileset — equal-count buckets over the size-sorted files, one synthetic
file per bucket at the bucket's mean size (see ``_Context.subset``),
identical for every candidate within a rung — so rung comparisons are
fair, the dataset's byte shares survive even 1/16-sized samples, and
the cost of a fractional evaluation is proportional to its fraction
(event count scales with file count). ``equivalent_evals`` accounts
rungs at the fraction actually simulated — the budget the acceptance
bar compares against the oracle's full-fidelity evaluation count.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import testbeds
from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.core.types import FileSpec

from ..runner import DEFAULT_CHUNK_SIZE, cost_estimate, run_built, shape_hint
from ..scenarios import Scenario, build_files
from .oracle import (
    ContextKey,
    ContextTable,
    TuneEntry,
    TuneResult,
    candidate_lists,
    context_key,
    group_contexts,
)
from .space import ParamSpace, algorithm1_params, scenario_space

Triple = Tuple[int, int, int]


def _builder(network, files, triple: Triple, max_cc: int, tick: float):
    """Zero-arg builder of one fresh static-candidate Simulation (via
    the canonical ``build_scheduler("static")`` path, so rung rows carry
    exactly the semantics of the oracle's matrix rows)."""

    def build() -> Simulation:
        sched = build_scheduler(
            "static", files, network, max_cc=max_cc, static_params=triple
        )
        return Simulation(
            sched.chunks, sched.network, sched, tick_period=tick
        )

    return build


class _Context:
    """Host-side search state for one deduplicated transfer context."""

    def __init__(self, key: ContextKey, rep: Scenario):
        self.key = key
        self.rep = rep
        self.network = testbeds.TESTBEDS[rep.network]
        self.files = build_files(rep)
        #: file indices ordered by size: subsets are *size-stratified*
        #: (files at evenly spaced size quantiles), so a rung's sample
        #: keeps the dataset's small/huge mix — a uniform random draw
        #: at 1/16 routinely misses the heavy tail and misranks the
        #: concurrency/parallelism axes
        self.by_size = sorted(
            range(len(self.files)),
            key=lambda i: (self.files[i].size, i),
        )
        #: fraction -> sketch; every candidate of a rung (and the rung's
        #: cost accounting) shares one deterministic sketch, so build it
        #: once instead of once per candidate row
        self._sketch: Dict[float, list] = {}

    def subset(self, fraction: float) -> list:
        """Deterministic ~``fraction``-sized *sketch* of the fileset (the
        whole set at 1.0).

        The size-sorted files are split into ``n = ceil(fraction * m)``
        equal-count buckets and each bucket becomes one synthetic file at
        the bucket's mean size. Equal-count buckets mean every sketch
        file downweights its bucket's bytes by the same factor, so the
        byte *shares* of the size distribution survive — unlike picking
        actual files at size quantiles, which keeps the raw multi-GB tail
        file while dropping most of the bytes around it, leaving a
        one-channel critical path that makes every concurrency setting
        rank equal (the tail file dominates the makespan). Identical for
        every candidate within a rung, so rung comparisons stay fair.
        """
        if fraction >= 1.0 or not self.files:
            return self.files
        cached = self._sketch.get(fraction)
        if cached is not None:
            return cached
        m = len(self.files)
        n = max(1, int(math.ceil(fraction * m)))
        if n >= m:
            return self.files
        out = []
        for b in range(n):
            lo = round(b * m / n)
            hi = max(round((b + 1) * m / n), lo + 1)
            run = [self.files[i].size for i in self.by_size[lo:hi]]
            out.append(
                FileSpec(
                    name=f"sketch{b}",
                    size=int(round(sum(run) / len(run))),
                )
            )
        self._sketch[fraction] = out
        return out


def _evaluate(
    rows: Sequence[Tuple[_Context, Triple, float]],
    backend: str,
    chunk_size: Optional[int],
    executor: Optional[str] = None,
) -> List[float]:
    """One batched sweep over (context, candidate, fraction) rows ->
    throughputs, input order. Rows carry a capacity shape hint (a static
    candidate holds exactly its own ``cc`` channels) so the jax backend
    can group them into capacity-homogeneous — hence compile-shape-
    homogeneous — chunks."""
    builders, names, costs, hints = [], [], [], []
    for ctx, triple, fraction in rows:
        files = ctx.subset(fraction)
        builders.append(
            _builder(
                ctx.network, files, triple, ctx.rep.max_cc,
                ctx.rep.tick_period,
            )
        )
        names.append(
            "{}|pp{}.p{}.cc{}|f{:g}".format(ctx.rep.name, *triple, fraction)
        )
        costs.append(
            cost_estimate(ctx.network, files, triple[2], ctx.rep.tick_period)
        )
        hints.append(shape_hint(triple[2]))
    results = run_built(
        builders, names, costs, backend=backend, chunk_size=chunk_size,
        hints=hints, executor=executor,
    )
    return [r.throughput for r in results]


def _entries(
    scenarios: Sequence[Scenario],
    tables: Dict[ContextKey, ContextTable],
    n_cands: Dict[ContextKey, int],
) -> List[TuneEntry]:
    return [
        TuneEntry(
            scenario=sc.name,
            context=context_key(sc),
            best_params=tables[context_key(sc)].best_params,
            best_throughput=tables[context_key(sc)].best_throughput,
            n_candidates=n_cands[context_key(sc)],
        )
        for sc in scenarios
    ]


# --------------------------------------------------------------------------
# successive halving
# --------------------------------------------------------------------------


def _diverse_keep(
    by_idx: Dict[int, float],
    cands: Sequence[Triple],
    keep: int,
) -> List[int]:
    """Top-``keep`` selection that never collapses the concurrency axis.

    Subsampled rungs rank pipelining / parallelism reliably (their
    effects are per-file and local) but are systematically biased on
    concurrency: a sketch dataset shifts where the disk-saturation sweet
    spot appears, and a plain top-k then keeps ONE cc value into the
    final rung, deciding the most fidelity-sensitive knob at the lowest
    fidelity. So the keep rule is stratified: first the best candidate
    of each distinct cc value (cc groups ordered by their group best),
    then the remaining slots by plain rank — the full-fidelity rung
    always gets to compare concurrency levels head to head.
    """
    groups: Dict[int, List[int]] = {}
    for i in by_idx:
        groups.setdefault(cands[i][2], []).append(i)
    for cc in groups:
        groups[cc].sort(key=lambda i: -by_idx[i])
    order = sorted(groups, key=lambda cc: -by_idx[groups[cc][0]])
    kept = [groups[cc][0] for cc in order[:keep]]
    taken = set(kept)
    rest = sorted(
        (i for i in by_idx if i not in taken), key=lambda i: -by_idx[i]
    )
    kept += rest[: keep - len(kept)]
    return sorted(kept)


def _sha_schedule(n: int, eta: int) -> Tuple[List[int], List[float]]:
    """Candidate counts per rung and the dataset fraction each rung
    evaluates at (final rung always full fidelity).

    The rung count is ``round(log_eta n)`` — rounding, not flooring, so
    a candidate set a hair over a power of eta (the Algorithm-1 /
    history injections add one or two to a 64-grid) does not grow an
    extra near-zero-fidelity rung that both misranks and halves every
    later rung's budget.
    """
    rungs = max(1, round(math.log(max(n, 1)) / math.log(eta)))
    counts = [max(1, round(n / eta**r)) for r in range(rungs)]
    counts = [min(n, c) for c in counts]
    fractions = [float(eta) ** -(rungs - 1 - r) for r in range(rungs)]
    return counts, fractions


def successive_halving(
    scenarios: Sequence[Scenario],
    *,
    backend: str = "numpy",
    n_candidates: int = 64,
    eta: int = 4,
    space: Optional[Callable[[Scenario], Sequence]] = None,
    history=None,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    executor: Optional[str] = None,
) -> TuneResult:
    """Budgeted grid search: shrink the candidate axis between sweeps."""
    if eta < 2:
        raise ValueError("eta must be >= 2")
    keys, reps, cands = candidate_lists(
        scenarios, n_candidates=n_candidates, space=space, history=history
    )
    contexts = {key: _Context(key, reps[key]) for key in keys}
    survivors: Dict[ContextKey, List[int]] = {
        key: list(range(len(cands[key]))) for key in keys
    }
    schedules = {key: _sha_schedule(len(cands[key]), eta) for key in keys}
    rungs = max(len(s[0]) for s in schedules.values())
    trace: Dict[ContextKey, List[dict]] = {key: [] for key in keys}
    final: Dict[ContextKey, Dict[int, float]] = {key: {} for key in keys}
    evals = 0
    equivalent = 0.0
    for r in range(rungs):
        rows: List[Tuple[_Context, Triple, float]] = []
        row_of: List[Tuple[ContextKey, int]] = []
        actual_frac: Dict[ContextKey, float] = {}
        scores: Dict[ContextKey, Dict[int, float]] = {}
        for key in keys:
            counts, fractions = schedules[key]
            if r >= len(counts):
                continue  # this context's schedule already finished
            fraction = fractions[r]
            ctx = contexts[key]
            # cost accounting uses the fraction actually simulated
            # (ceil() and the 1-file floor round small rungs up)
            actual_frac[key] = (
                len(ctx.subset(fraction)) / len(ctx.files)
                if ctx.files
                else 1.0
            )
            for idx in survivors[key]:
                if idx in final[key]:
                    # already scored at full fidelity in an earlier rung
                    # (tiny filesets: the sketch IS the whole set well
                    # before the nominal schedule reaches 1.0) — reuse,
                    # don't re-simulate an identical row
                    scores.setdefault(key, {})[idx] = final[key][idx]
                    continue
                rows.append((ctx, cands[key][idx], fraction))
                row_of.append((key, idx))
        throughputs = _evaluate(rows, backend, chunk_size, executor)
        evals += len(rows)
        for (key, idx), thr in zip(row_of, throughputs):
            scores.setdefault(key, {})[idx] = thr
            equivalent += actual_frac[key]
            # record by the fraction actually simulated, not the nominal
            # schedule: a sketch covering the whole fileset is already
            # the exact objective
            if actual_frac[key] >= 1.0:
                final[key][idx] = thr
        for key, by_idx in scores.items():
            counts, fractions = schedules[key]
            keep = (
                counts[r + 1] if r + 1 < len(counts) else 1
            )
            survivors[key] = _diverse_keep(by_idx, cands[key], keep)
            trace[key].append(
                {
                    "rung": r,
                    "fraction": fractions[r],
                    "evaluated": sorted(by_idx),
                    "best_throughput": max(by_idx.values()),
                    "kept": list(survivors[key]),
                }
            )
    tables: Dict[ContextKey, ContextTable] = {}
    for key in keys:
        by_idx = final[key]
        assert by_idx, "final rung must evaluate at full fidelity"
        idxs = sorted(by_idx)
        tables[key] = ContextTable(
            candidates=tuple(cands[key][i] for i in idxs),
            throughputs=tuple(by_idx[i] for i in idxs),
        )
        if history is not None:
            history.record(
                reps[key], tables[key].best_params,
                tables[key].best_throughput, method="sha",
            )
    return TuneResult(
        method="sha",
        entries=_entries(
            scenarios, tables, {k: len(cands[k]) for k in keys}
        ),
        tables=tables,
        evals=evals,
        equivalent_evals=equivalent,
        trace=trace,
    )


# --------------------------------------------------------------------------
# hill climbing
# --------------------------------------------------------------------------


def hill_climb(
    scenarios: Sequence[Scenario],
    *,
    backend: str = "numpy",
    n_candidates: int = 64,
    max_iters: int = 12,
    space_builder: Optional[Callable[[Scenario], ParamSpace]] = None,
    history=None,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    executor: Optional[str] = None,
) -> TuneResult:
    """Coordinate descent on the log-spaced knob axes.

    ``n_candidates`` sets the axis *density* of the default space (the
    budget actually spent depends on the walk length). Every iteration
    is one batched sweep over all live contexts' unevaluated neighbor
    settings; a context converges when no axis neighbor beats its
    current point.

    Unlike the flat candidate *sequences* the oracle / successive
    halving accept via ``space``, the climber needs axis structure, so
    its override is named differently: ``space_builder`` maps a
    scenario to a :class:`repro.eval.tune.space.ParamSpace`.
    """
    keys, reps = group_contexts(scenarios)
    spaces: Dict[ContextKey, ParamSpace] = {}
    contexts: Dict[ContextKey, _Context] = {}
    current: Dict[ContextKey, Tuple[int, int, int]] = {}
    cache: Dict[ContextKey, Dict[Tuple[int, int, int], float]] = {}
    trace: Dict[ContextKey, List[dict]] = {}
    for key in keys:
        rep = reps[key]
        spaces[key] = (
            space_builder(rep) if space_builder is not None
            else scenario_space(rep, n_candidates=n_candidates)
        )
        contexts[key] = _Context(key, rep)
        start_params = history.seed(rep) if history is not None else None
        if start_params is None:
            start_params = algorithm1_params(rep)
        current[key] = spaces[key].nearest(start_params)
        cache[key] = {}
        trace[key] = []
    live = set(keys)
    evals = 0
    for it in range(max_iters):
        rows: List[Tuple[_Context, Triple, float]] = []
        row_of: List[Tuple[ContextKey, Tuple[int, int, int]]] = []
        for key in sorted(live, key=keys.index):
            sp = spaces[key]
            frontier = [current[key]] + sp.neighbors(current[key])
            for idx in frontier:
                if idx not in cache[key]:
                    rows.append((contexts[key], _triple_of(sp, idx), 1.0))
                    row_of.append((key, idx))
        if rows:
            throughputs = _evaluate(rows, backend, chunk_size, executor)
            evals += len(rows)
            for (key, idx), thr in zip(row_of, throughputs):
                cache[key][idx] = thr
        next_live = set()
        for key in live:
            sp = spaces[key]
            frontier = [current[key]] + sp.neighbors(current[key])
            best = max(frontier, key=lambda i: cache[key][i])
            trace[key].append(
                {
                    "iter": it,
                    "current": current[key],
                    "throughput": cache[key][current[key]],
                    "best_neighbor": best,
                }
            )
            if cache[key][best] > cache[key][current[key]]:
                current[key] = best
                next_live.add(key)
        live = next_live
        if not live:
            break
    tables: Dict[ContextKey, ContextTable] = {}
    for key in keys:
        sp = spaces[key]
        idxs = sorted(cache[key])
        tables[key] = ContextTable(
            candidates=tuple(_triple_of(sp, i) for i in idxs),
            throughputs=tuple(cache[key][i] for i in idxs),
        )
        if history is not None:
            history.record(
                reps[key], tables[key].best_params,
                tables[key].best_throughput, method="hill",
            )
    return TuneResult(
        method="hill",
        entries=_entries(
            scenarios, tables, {k: len(cache[k]) for k in keys}
        ),
        tables=tables,
        evals=evals,
        equivalent_evals=float(evals),
        trace=trace,
    )


def _triple_of(sp: ParamSpace, idx: Tuple[int, int, int]) -> Triple:
    p = sp.params_at(idx)
    return (p.pipelining, p.parallelism, p.concurrency)
