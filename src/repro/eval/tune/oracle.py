"""Exhaustive static-parameter oracle + regret over the fused sweep.

The paper's headline claim — the adaptive heuristics approach the
throughput of the *best static* (pipelining, parallelism, concurrency)
setting without knowing it in advance — needs that optimum computed.
:func:`oracle_search` treats the batched fabric sweep as a vectorized
black-box objective ``f(scenario, pp, p, cc) -> throughput``: the
scenario matrix is expanded along the candidate axis
(:func:`repro.eval.scenarios.expand_candidates`), every (scenario x
candidate) row becomes an ordinary ``static`` scenario, and *one*
:func:`repro.eval.runner.run_matrix` call sweeps the whole plane through
the chosen backend, chunked by the runner's cost-proxy scheduler. There
is no per-candidate Python loop over scenarios — the candidate axis
rides the same (S,)-row batching as everything else.

Scenarios that share a transfer context — same testbed, dataset, seed,
tick period, and maxCC budget — have identical candidate objectives
(the static rows ignore the heuristic-only ``num_chunks`` /
``algorithm`` fields), so the search evaluates each *context* once and
broadcasts the argmax back to every member row. On the full 1116-grid
this deduplication cuts the candidate plane ~4x.

:func:`regret_report` then scores the heuristics:
``regret = heuristic_throughput / oracle_throughput`` per scenario,
aggregated per algorithm. A regret near 1.0 is the paper's claim held
quantitatively; above 1.0 means the adaptive controller *beat* every
static setting (possible — per-chunk parameters and re-allocation are
exactly what a single static setting cannot express).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import SimResult
from repro.core.types import param_triple

from ..runner import DEFAULT_CHUNK_SIZE, run_matrix
from ..scenarios import Scenario, expand_candidates
from .space import algorithm1_params, scenario_space

#: context key: the scenario fields a static candidate's throughput
#: depends on (``num_chunks`` / ``algorithm`` / ``record_timeline`` are
#: heuristic-row concerns; maxCC stays because it caps the search space)
ContextKey = Tuple[str, str, int, float, int]


def context_key(sc: Scenario) -> ContextKey:
    return (sc.network, sc.dataset, sc.seed, sc.tick_period, sc.max_cc)


def group_contexts(
    scenarios: Sequence[Scenario],
) -> Tuple[List[ContextKey], Dict[ContextKey, Scenario]]:
    """Unique transfer contexts (insertion-ordered) + one representative
    scenario per context."""
    keys: List[ContextKey] = []
    reps: Dict[ContextKey, Scenario] = {}
    for sc in scenarios:
        key = context_key(sc)
        if key not in reps:
            keys.append(key)
            reps[key] = sc
    return keys, reps


@dataclasses.dataclass(frozen=True)
class ContextTable:
    """Per-context candidate evaluations: the searched settings and the
    throughput each achieved (aligned lists, search order)."""

    candidates: Tuple[Tuple[int, int, int], ...]
    throughputs: Tuple[float, ...]

    @property
    def best_index(self) -> int:
        return int(np.argmax(self.throughputs))

    @property
    def best_params(self) -> Tuple[int, int, int]:
        return self.candidates[self.best_index]

    @property
    def best_throughput(self) -> float:
        return float(self.throughputs[self.best_index])


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """Per-scenario search outcome (broadcast from its context)."""

    scenario: str
    context: ContextKey
    best_params: Tuple[int, int, int]
    best_throughput: float
    n_candidates: int


@dataclasses.dataclass
class TuneResult:
    """Outcome of one search over a scenario matrix.

    ``entries`` aligns with the input scenario order; ``tables`` holds
    the per-context evidence; ``evals`` counts candidate simulations
    actually run and ``equivalent_evals`` their full-fidelity cost (the
    two differ only for successive halving's subsampled rungs).
    """

    method: str
    entries: List[TuneEntry]
    tables: Dict[ContextKey, ContextTable]
    evals: int
    equivalent_evals: float
    #: per-context search trace (successive halving: one dict per rung)
    trace: Optional[Dict[ContextKey, List[dict]]] = None

    def to_json(self) -> dict:
        return {
            "method": self.method,
            "evals": self.evals,
            "equivalent_evals": round(self.equivalent_evals, 3),
            "entries": [
                {
                    "scenario": e.scenario,
                    "best_params": {
                        "pipelining": e.best_params[0],
                        "parallelism": e.best_params[1],
                        "concurrency": e.best_params[2],
                    },
                    "best_throughput": e.best_throughput,
                    "n_candidates": e.n_candidates,
                }
                for e in self.entries
            ],
        }


def _as_triple(params) -> Tuple[int, int, int]:
    return param_triple(params)  # type: ignore[return-value]


def candidate_lists(
    scenarios: Sequence[Scenario],
    *,
    n_candidates: int = 64,
    space: Optional[Callable[[Scenario], Sequence]] = None,
    history=None,
) -> Tuple[List[ContextKey], Dict[ContextKey, Scenario], Dict[ContextKey, List[Tuple[int, int, int]]]]:
    """Deduplicated contexts + their candidate sets.

    ``space`` overrides the default BDP-capped grid
    (:func:`repro.eval.tune.space.scenario_space`); the Algorithm-1
    whole-dataset point always joins the set (the heuristics' own
    operating point must be inside the searched space, or grid
    granularity alone would hand them regret > 1 on one-chunk
    datasets), as does a ``history`` store's remembered winner for the
    context (warm start) when the grid does not already contain it.
    """
    keys, reps = group_contexts(scenarios)
    cands: Dict[ContextKey, List[Tuple[int, int, int]]] = {}
    for key in keys:
        rep = reps[key]
        if space is not None:
            raw = space(rep)
        else:
            raw = scenario_space(rep, n_candidates=n_candidates).grid()
        triples = [_as_triple(p) for p in raw]
        alg1 = _as_triple(algorithm1_params(rep))
        if alg1 not in triples:
            triples.append(alg1)
        if history is not None:
            seed = history.seed(rep)
            if seed is not None and _as_triple(seed) not in triples:
                triples.append(_as_triple(seed))
        if not triples:
            raise ValueError(f"empty candidate set for context {key}")
        cands[key] = triples
    return keys, reps, cands


def oracle_search(
    scenarios: Sequence[Scenario],
    *,
    backend: str = "numpy",
    n_candidates: int = 64,
    space: Optional[Callable[[Scenario], Sequence]] = None,
    history=None,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
    executor: Optional[str] = None,
) -> TuneResult:
    """Exhaustive grid search, executed as one batched sweep.

    Per-context argmax over the full candidate grid: ground truth for
    the regret claims and the budget baseline the cheaper searchers
    (:mod:`repro.eval.tune.search`) are measured against.

    The pre-expanded plane is canonical-shape by construction: every row
    is a one-chunk (K=1) static scenario, and :func:`run_matrix` groups
    rows by their capacity shape hint (a static row holds exactly ``cc``
    channels) and cuts power-of-two-aligned chunk spans, so on the jax
    backend the whole 10k+-row plane executes as a handful of compiled
    programs instead of one per chunk (see
    :mod:`repro.eval.fabric.bucketing`).
    """
    keys, reps, cands = candidate_lists(
        scenarios, n_candidates=n_candidates, space=space, history=history
    )
    expanded: List[Scenario] = []
    spans: List[Tuple[ContextKey, int, int]] = []
    for key in keys:
        rows = expand_candidates([reps[key]], cands[key])
        spans.append((key, len(expanded), len(expanded) + len(rows)))
        expanded.extend(rows)
    results = run_matrix(
        expanded, backend=backend, chunk_size=chunk_size,
        executor=executor,
    )
    tables: Dict[ContextKey, ContextTable] = {}
    for key, lo, hi in spans:
        tables[key] = ContextTable(
            candidates=tuple(cands[key]),
            throughputs=tuple(r.throughput for r in results[lo:hi]),
        )
    if history is not None:
        for key in keys:
            history.record(
                reps[key],
                tables[key].best_params,
                tables[key].best_throughput,
                method="oracle",
            )
    entries = [
        TuneEntry(
            scenario=sc.name,
            context=context_key(sc),
            best_params=tables[context_key(sc)].best_params,
            best_throughput=tables[context_key(sc)].best_throughput,
            n_candidates=len(cands[context_key(sc)]),
        )
        for sc in scenarios
    ]
    return TuneResult(
        method="oracle",
        entries=entries,
        tables=tables,
        evals=len(expanded),
        equivalent_evals=float(len(expanded)),
    )


# --------------------------------------------------------------------------
# regret
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RegretReport:
    """Heuristic-vs-oracle scoring of one matrix run.

    ``per_scenario`` holds ``(name, algorithm, heuristic_throughput,
    oracle_throughput, regret)`` rows; ``per_algorithm`` aggregates
    (median / mean / min / max regret, and the fraction of scenarios
    where the adaptive controller beat every static candidate).
    """

    method: str
    per_scenario: List[dict]
    per_algorithm: Dict[str, dict]

    def to_json(self) -> dict:
        return {
            "method": self.method,
            "per_algorithm": self.per_algorithm,
            "n_scenarios": len(self.per_scenario),
            "per_scenario": [
                dict(row, oracle_params=list(row["oracle_params"]))
                for row in self.per_scenario
            ],
        }

    def format_table(self) -> str:
        lines = [
            f"{'algorithm':<12} {'median':>8} {'mean':>8} {'min':>8} "
            f"{'max':>8} {'beats-oracle':>13} {'n':>5}"
        ]
        for algo, agg in sorted(self.per_algorithm.items()):
            lines.append(
                f"{algo:<12} {agg['median']:>8.3f} {agg['mean']:>8.3f} "
                f"{agg['min']:>8.3f} {agg['max']:>8.3f} "
                f"{agg['frac_above_1']:>12.0%} {agg['n']:>5d}"
            )
        return "\n".join(lines)


def regret_report(
    scenarios: Sequence[Scenario],
    heuristic_results: Sequence[SimResult],
    tune_result: TuneResult,
) -> RegretReport:
    """Score every heuristic scenario against its context's static
    optimum: ``regret = heuristic_throughput / oracle_throughput``."""
    by_context = {e.context: e for e in tune_result.entries}
    rows: List[dict] = []
    buckets: Dict[str, List[float]] = {}
    for sc, res in zip(scenarios, heuristic_results):
        if sc.algorithm == "static":
            continue  # static rows ARE candidates, not contestants
        entry = by_context[context_key(sc)]
        denom = max(entry.best_throughput, 1e-12)
        regret = res.throughput / denom
        rows.append(
            {
                "scenario": sc.name,
                "algorithm": sc.algorithm,
                "heuristic_throughput": res.throughput,
                "oracle_throughput": entry.best_throughput,
                "oracle_params": entry.best_params,
                "regret": regret,
            }
        )
        buckets.setdefault(sc.algorithm, []).append(regret)
    per_algorithm = {
        algo: {
            "median": float(np.median(vals)),
            "mean": float(np.mean(vals)),
            "min": float(np.min(vals)),
            "max": float(np.max(vals)),
            "frac_above_1": float(np.mean(np.asarray(vals) > 1.0)),
            "n": len(vals),
        }
        for algo, vals in buckets.items()
    }
    return RegretReport(
        method=tune_result.method,
        per_scenario=rows,
        per_algorithm=per_algorithm,
    )


def save_report(path: str, report: RegretReport, tune_result: TuneResult) -> None:
    """Serialize a regret report + the search it scored to JSON: the
    per-algorithm aggregates AND the per-scenario regret rows, plus each
    context's full candidate table (what every setting scored)."""
    payload = {
        "regret": report.to_json(),
        "search": tune_result.to_json(),
        "tables": {
            "/".join(str(part) for part in key): {
                "candidates": [list(c) for c in table.candidates],
                "throughputs": list(table.throughputs),
            }
            for key, table in tune_result.tables.items()
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
