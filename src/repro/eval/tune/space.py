"""Parameter-space construction for the autotuner.

The paper's knobs — pipelining, parallelism, concurrency — live on very
different scales, but Algorithm 1 bounds all three from the path's
physics: pipelining is useful up to ~BDP/avgFileSize (the command-queue
depth that hides the per-file control gap), parallelism up to
~BDP/bufferSize (streams beyond a full window add only CPU tax, and
servers clamp the stream count), concurrency up to the user's maxCC
budget. :func:`param_space` turns those caps into log-spaced axes — the
response to each knob saturates, so geometric spacing covers the range
with few points — and :class:`ParamSpace` is the object the searchers
walk: exhaustive grids for the oracle (:mod:`repro.eval.tune.oracle`),
shrinking candidate sets for successive halving, axis-neighbor steps for
hill climbing (:mod:`repro.eval.tune.search`).

:class:`StaticParamsScheduler` (re-exported from
:mod:`repro.core.baselines`) is the evaluation vehicle: one undivided
chunk at a fixed candidate setting, running through the batched fabric
drivers as a trivial controller — zero host rounds on the JAX backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core import testbeds
from repro.core.baselines import StaticParamsScheduler  # noqa: F401  (re-export)
from repro.core.params import MAX_PIPELINING, find_optimal_parameters
from repro.core.types import NetworkSpec, TransferParams

__all__ = [
    "ParamSpace",
    "StaticParamsScheduler",
    "algorithm1_params",
    "axis_sizes",
    "param_space",
    "scenario_space",
]


def _thin(values: Sequence[int], n: int) -> Tuple[int, ...]:
    """At most ``n`` values, uniform in index space, endpoints kept
    (a 1-point budget keeps the low endpoint)."""
    vals = sorted(set(int(v) for v in values))
    if len(vals) <= n:
        return tuple(vals)
    if n <= 1:
        return (vals[0],)
    idx = {round(i * (len(vals) - 1) / (n - 1)) for i in range(n)}
    return tuple(vals[i] for i in sorted(idx))


def _axis(
    n: int,
    cap: int,
    *,
    include_zero: bool = False,
    extend_cap: Optional[int] = None,
    pin: Optional[int] = None,
) -> Tuple[int, ...]:
    """Up to ``n`` axis values over the useful range ``[lo, cap]``.

    Dense integers when the range fits the budget, log-spaced (powers of
    two + endpoints, ``pin`` kept when it falls inside) otherwise. When
    the dense range is *smaller* than the budget and ``extend_cap`` is
    given, the axis continues past the useful cap by powers of two —
    settings out there are admissible (a user can configure them), just
    predicted useless by the closed form, and an oracle that never looks
    should not get credit for the heuristic's own blind spot.
    """
    cap = max(1, int(cap))
    lo = 0 if include_zero else 1
    if cap - lo + 1 <= n:
        vals = set(range(lo, cap + 1))
    else:
        vals = {lo, 1, cap}
        v = 2
        while v < cap:
            vals.add(v)
            v *= 2
        if pin is not None and lo < pin < cap:
            vals.add(int(pin))
        vals = set(_thin(sorted(vals), n))
        if pin is not None and lo < pin < cap:
            vals.add(int(pin))
    if extend_cap is not None:
        v = max(cap, 1)
        while len(vals) < n:
            v *= 2
            if v > extend_cap:
                break
            vals.add(v)
    return tuple(sorted(vals))


def axis_sizes(n_candidates: int) -> Tuple[int, int, int]:
    """Split a candidate budget into (n_pp, n_par, n_cc) axis sizes.

    Concurrency is the paper's most sensitive knob (disk saturation and
    contention put a sweet spot strictly inside the range), so spare
    budget grows the cc axis first, then pipelining, then parallelism.
    """
    if n_candidates < 1:
        raise ValueError("n_candidates must be >= 1")
    base = max(1, int(math.floor(n_candidates ** (1.0 / 3.0) + 1e-9)))
    sizes = [base, base, base]  # [pp, par, cc]
    for axis in (2, 0, 1):
        grown = list(sizes)
        grown[axis] *= 2
        if grown[0] * grown[1] * grown[2] <= n_candidates:
            sizes = grown
    return tuple(sizes)  # type: ignore[return-value]


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Log-spaced (pipelining, parallelism, concurrency) axes.

    The cartesian product is the oracle's grid; the axis structure is
    what the hill climber walks (one step along one axis at a time).
    """

    pp_axis: Tuple[int, ...]
    par_axis: Tuple[int, ...]
    cc_axis: Tuple[int, ...]

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (len(self.pp_axis), len(self.par_axis), len(self.cc_axis))

    @property
    def size(self) -> int:
        return len(self.pp_axis) * len(self.par_axis) * len(self.cc_axis)

    def params_at(self, idx: Tuple[int, int, int]) -> TransferParams:
        i, j, k = idx
        return TransferParams(
            pipelining=self.pp_axis[i],
            parallelism=self.par_axis[j],
            concurrency=self.cc_axis[k],
        )

    def grid(self) -> List[TransferParams]:
        """Every axis combination, pp-major (stable candidate order)."""
        return [
            TransferParams(pipelining=pp, parallelism=par, concurrency=cc)
            for pp in self.pp_axis
            for par in self.par_axis
            for cc in self.cc_axis
        ]

    def nearest(self, params: TransferParams) -> Tuple[int, int, int]:
        """Axis indices of the grid point nearest ``params`` (geometric
        distance per axis — the axes are log-spaced)."""

        def pick(axis: Tuple[int, ...], v: int) -> int:
            return min(
                range(len(axis)),
                key=lambda i: abs(
                    math.log1p(float(axis[i])) - math.log1p(float(v))
                ),
            )

        return (
            pick(self.pp_axis, params.pipelining),
            pick(self.par_axis, params.parallelism),
            pick(self.cc_axis, params.concurrency),
        )

    def neighbors(
        self, idx: Tuple[int, int, int]
    ) -> List[Tuple[int, int, int]]:
        """The one-step axis neighborhood of ``idx`` (<= 6 points)."""
        out = []
        for axis in range(3):
            for step in (-1, 1):
                nxt = list(idx)
                nxt[axis] += step
                if 0 <= nxt[axis] < self.shape[axis]:
                    out.append(tuple(nxt))
        return out  # type: ignore[return-value]


def param_space(
    network: NetworkSpec,
    max_cc: int,
    avg_file_size: float,
    *,
    n_candidates: int = 64,
) -> ParamSpace:
    """BDP-capped log-spaced axes for one path / dataset shape.

    pipelining   0 .. BDP/avgFileSize (queue depth that fully hides the
                 per-file control gap; deeper queues change nothing)
    parallelism  1 .. min(ceil(BDP/buffer), server stream clamp)
    concurrency  1 .. maxCC (the same end-system budget the heuristics
                 get — regret compares equals), with the disk saturation
                 point pinned into the axis when it falls inside (the
                 Fig-9a sweet spot a thinned ladder could miss)
    """
    if avg_file_size <= 0:
        avg_file_size = 1.0
    pp_cap = int(
        min(MAX_PIPELINING, max(1, round(network.bdp / avg_file_size)))
    )
    par_cap = int(
        min(
            network.max_streams_per_channel,
            max(1, math.ceil(network.bdp / max(network.buffer_size, 1))),
        )
    )
    cc_cap = max(1, int(max_cc))
    sat = int(network.disk.saturation_cc)

    def build(n_pp: int, n_par: int, n_cc: int) -> ParamSpace:
        return ParamSpace(
            pp_axis=_axis(
                n_pp, pp_cap, include_zero=True, extend_cap=MAX_PIPELINING
            ),
            par_axis=_axis(
                n_par, par_cap, extend_cap=network.max_streams_per_channel
            ),
            cc_axis=_axis(n_cc, cc_cap, pin=sat),
        )

    sizes = list(axis_sizes(n_candidates))
    space = build(*sizes)
    # honor the candidate budget: when tight BDP caps leave the grid
    # short (huge-file datasets cap pipelining at ~1), grow axes —
    # concurrency first (dense up to the maxCC budget, a hard fairness
    # cap), then pipelining / parallelism past their useful ranges —
    # until the product reaches the budget or nothing can grow
    for _ in range(64):
        if space.size >= n_candidates:
            break
        for axis in (2, 0, 1):  # cc, pp, par
            trial = list(sizes)
            trial[axis] += 1
            grown = build(*trial)
            if grown.shape[axis] > space.shape[axis]:
                sizes, space = trial, grown
                break
        else:
            break
    return space


def scenario_space(scenario, *, n_candidates: int = 64) -> ParamSpace:
    """The scenario's search space: its testbed's caps + its dataset's
    average file size (import-light — scenario ducks as anything with
    ``network`` / ``max_cc`` / dataset fields understood by
    ``eval.scenarios.build_files``)."""
    from repro.eval.scenarios import build_files

    network = testbeds.TESTBEDS[scenario.network]
    files = build_files(scenario)
    avg = (
        sum(f.size for f in files) / len(files) if files else 1.0
    )
    return param_space(
        network, scenario.max_cc, max(avg, 1.0), n_candidates=n_candidates
    )


def algorithm1_params(scenario) -> TransferParams:
    """The Algorithm-1 setting for the scenario's *whole* dataset (one
    undivided chunk): the hill climber's default start point."""
    from repro.eval.scenarios import build_files

    network = testbeds.TESTBEDS[scenario.network]
    files = build_files(scenario)
    avg = sum(f.size for f in files) / len(files) if files else 1.0
    return find_optimal_parameters(
        avg_file_size=max(avg, 1.0),
        bdp=network.bdp,
        buffer_size=network.buffer_size,
        max_cc=scenario.max_cc,
        num_files=len(files),
    )
