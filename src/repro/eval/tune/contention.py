"""Fleet contention report: greedy per-tenant tuning vs a coupled oracle.

The paper's heuristics tune each transfer as if it owned the network;
:func:`repro.eval.scenarios.tenant_matrix` breaks that assumption by
coupling tenants through shared backbone links. This module answers the
first fleet question ROADMAP poses: **does greedy per-tenant Algorithm-1
tuning collapse under contention versus the static oracle?**

Two sides are compared per fabric group:

  - **heuristic** — the tenant matrix run as-is: every adaptive tenant
    (SC / MC / ProMC) applies its controller selfishly, blind to the
    other tenants on its links. This *is* greedy per-tenant Algorithm-1
    tuning: each controller's chunk parameters come from Algorithm 1 on
    its own testbed/dataset, contention or not.
  - **oracle** — the best *static* per-tenant settings found with full
    knowledge of the contention: coordinate descent over the tenants of
    a group (sweep one tenant's static candidates while the others hold
    their incumbent settings, accept the argmax of the **group
    aggregate** throughput, move to the next tenant). Initialized at
    each tenant's own Algorithm-1 setting; the candidate set is that
    setting's grid neighborhood (the hill climber's axis moves — the
    interesting contended adjustments are local back-off/grow steps),
    and the incumbent is always a candidate, so each accepted step is
    monotone in the aggregate.

``regret = heuristic_aggregate / oracle_aggregate`` per group — the
contended analogue of :func:`repro.eval.tune.oracle.regret_report`'s
uncontended claim. An **isolated** leg (the same rows with the fabric
stripped) rides along so the report also records how hard contention
binds: ``contention_factor = coupled_aggregate / isolated_aggregate``.

Every candidate evaluation is an ordinary coupled scenario batch: the
trial group is cloned under a renamed fabric group (``g000.p0k2c5``) so
clones never couple with each other or the original, and all clones of
one descent step sweep through ONE :func:`repro.eval.runner.run_matrix`
call — no per-candidate Python loop.

``benchmarks/mega_sweep.py --matrix tenant-smoke`` embeds the summary in
the ``tenant_fleet`` row of ``BENCH_eval_matrix.json``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import TransferParams, param_triple

from ..runner import DEFAULT_CHUNK_SIZE, run_matrix
from ..scenarios import Scenario, tenant_matrix
from .space import algorithm1_params, scenario_space


def _group_rows(
    scenarios: Sequence[Scenario],
) -> Dict[str, List[Scenario]]:
    """Coupled rows keyed by fabric group (insertion-ordered); uncoupled
    rows are not contention subjects and are skipped."""
    groups: Dict[str, List[Scenario]] = {}
    for sc in scenarios:
        if sc.shared_fabric is not None:
            groups.setdefault(sc.shared_fabric.group, []).append(sc)
    return groups


def _configured_group(
    rows: Sequence[Scenario],
    settings: Sequence[Tuple[int, int, int]],
    tag: str,
) -> List[Scenario]:
    """The group pinned at fixed static settings, cloned under a renamed
    fabric group so the clone never couples with the original (or with
    any sibling clone carrying a different tag)."""
    out: List[Scenario] = []
    for sc, trip in zip(rows, settings):
        fab = dataclasses.replace(
            sc.shared_fabric, group=f"{sc.shared_fabric.group}.{tag}"
        )
        out.append(
            dataclasses.replace(
                sc,
                algorithm="static",
                static_params=tuple(trip),
                record_timeline=False,
                shared_fabric=fab,
            )
        )
    return out


def _candidate_grid(
    sc: Scenario, n_candidates: int
) -> List[Tuple[int, int, int]]:
    """The tenant's candidate set: its Algorithm-1 setting snapped to
    the search grid, plus one step along each axis (the hill climber's
    neighborhood). Deliberately *not* the full grid: degenerate corners
    (``cc=1, pp=0`` on a many-file dataset) make a lockstep coupled
    group crawl at the pace of its slowest member for tens of thousands
    of sweeps, and under contention the interesting moves are exactly
    the local back-off/grow steps around the uncontended optimum."""
    space = scenario_space(sc, n_candidates=max(n_candidates, 8))
    anchor = (
        sc.static_params
        if sc.static_params is not None
        else param_triple(algorithm1_params(sc))
    )
    start = space.nearest(
        TransferParams(
            pipelining=anchor[0],
            parallelism=anchor[1],
            concurrency=anchor[2],
        )
    )
    idxs = [tuple(start)]
    for axis in range(3):
        for d in (-1, 1):
            j = list(start)
            j[axis] += d
            if 0 <= j[axis] < space.shape[axis] and tuple(j) not in idxs:
                idxs.append(tuple(j))
    out: List[Tuple[int, int, int]] = []
    for idx in idxs:
        trip = param_triple(space.params_at(idx))
        if trip not in out:
            out.append(trip)
    return out[:n_candidates]


@dataclasses.dataclass
class ContentionReport:
    """Per-group and aggregate contention outcomes (see module doc)."""

    backend: str
    n_candidates: int
    per_group: List[dict]
    per_algorithm: Dict[str, dict]
    aggregate: dict

    def to_json(self) -> dict:
        return {
            "backend": self.backend,
            "candidates": self.n_candidates,
            "aggregate": self.aggregate,
            "per_algorithm": self.per_algorithm,
            "per_group": self.per_group,
        }

    def summary(self) -> dict:
        """The compact form the bench JSON embeds: aggregate stats plus
        per-algorithm median regret."""
        return {
            "backend": self.backend,
            "candidates": self.n_candidates,
            **self.aggregate,
            "regret_median_by_algorithm": {
                algo: agg["median"]
                for algo, agg in self.per_algorithm.items()
            },
        }


def greedy_static_oracle(
    groups: Dict[str, List[Scenario]],
    *,
    backend: str = "numpy",
    n_candidates: int = 8,
    passes: int = 1,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
) -> Tuple[Dict[str, List[Tuple[int, int, int]]], int]:
    """Coordinate-descent static oracle under contention.

    Returns ``(settings, evals)``: per-group per-tenant static triples
    and the number of coupled candidate rows simulated. All groups
    advance the same tenant slot together, so each descent step is one
    batched ``run_matrix`` call over every group's candidate clones.
    """
    settings: Dict[str, List[Tuple[int, int, int]]] = {}
    cands: Dict[str, List[List[Tuple[int, int, int]]]] = {}
    for g, rows in groups.items():
        settings[g] = [
            sc.static_params
            if sc.static_params is not None
            else param_triple(algorithm1_params(sc))
            for sc in rows
        ]
        cands[g] = [_candidate_grid(sc, n_candidates) for sc in rows]
    evals = 0
    max_tenants = max((len(rows) for rows in groups.values()), default=0)
    for p in range(passes):
        for k in range(max_tenants):
            batch: List[Scenario] = []
            spans: List[Tuple[str, int, int, int]] = []
            for g, rows in groups.items():
                if k >= len(rows):
                    continue
                # the incumbent is always candidate 0: an accepted step
                # can only improve the aggregate
                options = [settings[g][k]] + [
                    c for c in cands[g][k] if c != settings[g][k]
                ]
                cands[g][k] = options
                for ci, trip in enumerate(options):
                    trial = list(settings[g])
                    trial[k] = trip
                    clone = _configured_group(rows, trial, f"p{p}k{k}c{ci}")
                    spans.append((g, ci, len(batch), len(batch) + len(clone)))
                    batch.extend(clone)
            if not batch:
                continue
            results = run_matrix(
                batch, backend=backend, chunk_size=chunk_size
            )
            evals += len(batch)
            best: Dict[str, Tuple[float, int]] = {}
            for g, ci, lo, hi in spans:
                agg = float(sum(r.throughput for r in results[lo:hi]))
                if g not in best or agg > best[g][0]:
                    best[g] = (agg, ci)
            for g, (_, ci) in best.items():
                settings[g][k] = cands[g][k][ci]
    return settings, evals


def contention_report(
    scenarios: Optional[Sequence[Scenario]] = None,
    *,
    backend: str = "numpy",
    n_candidates: int = 8,
    passes: int = 1,
    chunk_size: Optional[int] = DEFAULT_CHUNK_SIZE,
) -> ContentionReport:
    """Run all three legs (heuristic coupled, isolated, greedy static
    oracle) over a tenant matrix and score the contended regret."""
    if scenarios is None:
        scenarios = tenant_matrix()
    groups = _group_rows(scenarios)
    if not groups:
        raise ValueError(
            "contention_report needs coupled scenarios (every row had "
            "shared_fabric=None) — build the matrix with tenant_matrix()"
        )

    # legs 1+2 in one sweep: the coupled fleet as-is + fabric-stripped
    # copies (isolated rows are independent, so batching them alongside
    # the coupled groups changes nothing)
    coupled: List[Scenario] = [sc for rows in groups.values() for sc in rows]
    isolated = [
        dataclasses.replace(sc, shared_fabric=None) for sc in coupled
    ]
    res = run_matrix(
        coupled + isolated, backend=backend, chunk_size=chunk_size
    )
    h_res, iso_res = res[: len(coupled)], res[len(coupled):]
    h_of = {sc.name: r for sc, r in zip(coupled, h_res)}
    iso_of = {sc.name: r for sc, r in zip(coupled, iso_res)}

    # leg 3: the contended static oracle + one final evaluation at the
    # chosen settings for per-tenant oracle throughputs
    settings, evals = greedy_static_oracle(
        groups,
        backend=backend,
        n_candidates=n_candidates,
        passes=passes,
        chunk_size=chunk_size,
    )
    final: List[Scenario] = []
    fspans: Dict[str, Tuple[int, int]] = {}
    for g, rows in groups.items():
        clone = _configured_group(rows, settings[g], "opt")
        fspans[g] = (len(final), len(final) + len(clone))
        final.extend(clone)
    fin_res = run_matrix(final, backend=backend, chunk_size=chunk_size)
    evals += len(final)

    per_group: List[dict] = []
    algo_regret: Dict[str, List[float]] = {}
    for g, rows in groups.items():
        lo, hi = fspans[g]
        o_rows = fin_res[lo:hi]
        h_agg = float(sum(h_of[sc.name].throughput for sc in rows))
        iso_agg = float(sum(iso_of[sc.name].throughput for sc in rows))
        o_agg = float(sum(r.throughput for r in o_rows))
        for sc, o in zip(rows, o_rows):
            algo_regret.setdefault(sc.algorithm, []).append(
                h_of[sc.name].throughput / max(o.throughput, 1e-12)
            )
        per_group.append(
            {
                "group": g,
                "tenants": len(rows),
                "links": len(
                    {ln for sc in rows for ln in sc.shared_fabric.links}
                ),
                "algorithms": [sc.algorithm for sc in rows],
                "heuristic_bps": h_agg,
                "oracle_bps": o_agg,
                "isolated_bps": iso_agg,
                "regret": h_agg / max(o_agg, 1e-12),
                "contention_factor": h_agg / max(iso_agg, 1e-12),
                "oracle_params": [list(t) for t in settings[g]],
            }
        )
    regrets = np.asarray([row["regret"] for row in per_group])
    factors = np.asarray([row["contention_factor"] for row in per_group])
    per_algorithm = {
        algo: {
            "median": float(np.median(vals)),
            "mean": float(np.mean(vals)),
            "min": float(np.min(vals)),
            "n": len(vals),
        }
        for algo, vals in algo_regret.items()
    }
    aggregate = {
        "groups": len(per_group),
        "tenants": len(coupled),
        "oracle_evals": evals,
        "regret_median": float(np.median(regrets)),
        "regret_mean": float(np.mean(regrets)),
        "regret_min": float(np.min(regrets)),
        "frac_groups_above_1": float(np.mean(regrets > 1.0)),
        "contention_factor_median": float(np.median(factors)),
    }
    return ContentionReport(
        backend=backend,
        n_candidates=n_candidates,
        per_group=per_group,
        per_algorithm=per_algorithm,
        aggregate=aggregate,
    )


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--candidates", type=int, default=8)
    ap.add_argument("--passes", type=int, default=1)
    ap.add_argument("--groups", type=int, default=None,
                    help="tenant_matrix n_groups (default: full 36)")
    ap.add_argument("--json", action="store_true",
                    help="print the full report, not just the summary")
    args = ap.parse_args(argv)
    matrix = (
        tenant_matrix(n_groups=args.groups)
        if args.groups
        else tenant_matrix()
    )
    report = contention_report(
        matrix,
        backend=args.backend,
        n_candidates=args.candidates,
        passes=args.passes,
    )
    payload = report.to_json() if args.json else report.summary()
    print(json.dumps(payload, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
