"""Declarative scenario matrix: (testbed x dataset x scheduler x maxCC).

A :class:`Scenario` is a pure value — building it twice yields bit-identical
simulations because every dataset generator is seeded from the scenario
itself. The golden-pinned default matrix crosses the paper's six WAN
testbeds with scaled paper datasets and all five schedulers (SC / MC /
ProMC / GlobusOnline / untuned) plus a maxCC sweep (276 scenarios);
:func:`full_matrix` widens it with impaired-path testbeds (loss / jitter /
asymmetric control RTT) and heavy-tail / small-file-swarm datasets to
1000+ scenarios. Every backend — event simulator, NumPy fabric driver,
JAX device loop — consumes the same grids unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import testbeds
from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.core.types import GB, MB, FileSpec, param_triple
from repro.data import filesets

from .fabric.shared import SharedFabric, resolve_fabric  # noqa: F401 (re-export)

# --------------------------------------------------------------------------
# dataset registry
# --------------------------------------------------------------------------

#: name -> builder(seed) -> list[FileSpec]. Scales are chosen so event-driven
#: runs stay cheap (tens of files) while keeping every size class populated —
#: the matrix trades per-scenario size for scenario count.
DATASET_BUILDERS: Dict[str, Callable[[int], List[FileSpec]]] = {
    "des": lambda seed: filesets.dark_energy_survey(scale=0.05, seed=seed),
    "genome": lambda seed: filesets.genome_sequencing(scale=0.0004, seed=seed),
    "mixed": lambda seed: filesets.mixed_dataset(scale=0.008, seed=seed),
    "small_dominated": lambda seed: filesets.small_dominated_mixed(
        scale=0.006, seed=seed
    ),
    "uniform_small": lambda seed: filesets.uniform_files(40, 4 * MB),
    "uniform_huge": lambda seed: filesets.uniform_files(6, 8 * GB),
    "heavy_tail": lambda seed: filesets.heavy_tail_dataset(
        scale=0.012, seed=seed
    ),
    "small_file_swarm": lambda seed: filesets.small_file_swarm(
        scale=0.004, seed=seed
    ),
}

#: the paper's physical WAN testbeds (Tables 1-2); DCN/CKPT presets are
#: exercised by grad-sync suites, not the transfer matrix. This tuple is
#: pinned — golden snapshots cover ``default_matrix`` — so impaired-path
#: additions go to EXTENDED_NETWORKS / ``full_matrix`` instead.
NETWORKS: Sequence[str] = (
    testbeds.XSEDE.name,
    testbeds.LONI.name,
    testbeds.BLUEWATERS_STAMPEDE.name,
    testbeds.STAMPEDE_COMET.name,
    testbeds.SUPERMIC_BRIDGES.name,
    testbeds.LAN.name,
)

#: paper testbeds + the impaired-path variants (loss / jitter / asymmetric
#: control RTT) driven only by the 1000+-scenario ``full_matrix``.
EXTENDED_NETWORKS: Sequence[str] = NETWORKS + (
    testbeds.LOSSY_TRANSATLANTIC.name,
    testbeds.JITTERY_OVERLAY.name,
    testbeds.ASYM_CONTROL_PATH.name,
)

#: time-varying-capacity variants (step / ramp bandwidth profiles);
#: crossed with a focused dataset/scheduler slice in ``full_matrix`` —
#: "network conditions vary over time" is exactly the regime the paper's
#: adaptive controllers (and their ETA estimates) must absorb.
TIME_VARYING_NETWORKS: Sequence[str] = (
    testbeds.STEPPY_BACKBONE.name,
    testbeds.RAMPY_EVENING.name,
)

#: datasets of the golden-pinned default/smoke matrices. Pinned for the
#: same reason as NETWORKS: new generators join ``full_matrix`` via
#: DATASET_BUILDERS without silently reshaping the snapshotted grids.
CORE_DATASETS: Sequence[str] = (
    "des", "genome", "mixed", "small_dominated", "uniform_small",
    "uniform_huge",
)

ALGORITHMS: Sequence[str] = ("sc", "mc", "promc", "globus", "untuned")

#: reserved separator of :attr:`Scenario.name`. Name components are joined
#: with it and suffixes like ``|tl`` (timeline recording) and ``|pp…``
#: (static candidate parameters) are appended behind it, so a component
#: containing the separator would make two different scenarios collide on
#: one name (e.g. network ``"x|tl"`` vs network ``"x"`` recording its
#: timeline). ``Scenario`` validates its string components against it.
NAME_SEP = "|"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the evaluation matrix. Hash-stable and JSON-friendly."""

    network: str  # key into testbeds.TESTBEDS
    dataset: str  # key into DATASET_BUILDERS
    algorithm: str  # sc | mc | promc | globus | untuned | static
    max_cc: int = 8
    num_chunks: int = 4
    tick_period: float = 5.0
    seed: int = 0
    #: record the (t, aggregate rate) timeline. On the fabric backends the
    #: samples stream into the fixed-budget on-device ring buffer
    #: (uniform-stride decimation past the budget); the event backend
    #: keeps the full host-appended timeline.
    record_timeline: bool = False
    #: fixed (pipelining, parallelism, concurrency) for ``algorithm ==
    #: "static"`` rows — the autotuner's candidate axis
    #: (:mod:`repro.eval.tune`): one static row per candidate setting,
    #: flowing through the same matrix runner / cost-proxy chunking /
    #: difftest machinery as every heuristic row.
    static_params: Optional[Tuple[int, int, int]] = None
    #: attachment to a coupled fabric group (shared backbone links with
    #: finite capacity). ``None`` — the default everywhere outside
    #: :func:`tenant_matrix` — keeps the row independent and its name
    #: (and thus every golden snapshot) byte-identical to before the
    #: shared-fabric layer existed.
    shared_fabric: Optional[SharedFabric] = None

    def __post_init__(self):
        for field in ("network", "dataset", "algorithm"):
            value = getattr(self, field)
            if NAME_SEP in value:
                raise ValueError(
                    f"scenario {field} {value!r} contains the reserved "
                    f"name separator {NAME_SEP!r} (names would collide "
                    "with suffixed variants like the '|tl' timeline rows)"
                )
        if (self.algorithm == "static") != (self.static_params is not None):
            raise ValueError(
                "static_params is required for algorithm 'static' and "
                f"reserved to it (got algorithm={self.algorithm!r}, "
                f"static_params={self.static_params!r})"
            )
        if self.static_params is not None:
            pp, par, cc = self.static_params
            if pp < 0 or par < 1 or cc < 1:
                raise ValueError(
                    f"invalid static_params {self.static_params!r}: need "
                    "pipelining >= 0, parallelism >= 1, concurrency >= 1"
                )

    @property
    def name(self) -> str:
        st = (
            "|pp{}.p{}.cc{}".format(*self.static_params)
            if self.static_params is not None
            else ""
        )
        tl = "|tl" if self.record_timeline else ""
        fab = (
            f"|{self.shared_fabric.name_suffix}"
            if self.shared_fabric is not None
            else ""
        )
        return (
            f"{self.network}|{self.dataset}|{self.algorithm}"
            f"|cc{self.max_cc}|k{self.num_chunks}|s{self.seed}{st}{tl}{fab}"
        )

    @property
    def dataset_seed(self) -> int:
        """Seed for the dataset generator: scenario-unique, order-free."""
        digest = hashlib.sha256(
            f"{self.dataset}:{self.seed}".encode("utf-8")
        ).digest()
        return int.from_bytes(digest[:4], "little")


#: cap on the *approximate bytes* the built-fileset cache may pin. An
#: entry's footprint scales with its file count (FileSpec objects +
#: name strings), not the entry count — a 512-entry LRU let a candidate
#: sweep over heavy-tail filesets pin hundreds of 100k-file lists while
#: counting them the same as 10-file smoke sets. 64 MiB holds every
#: matrix dataset with room to spare and bounds the worst case.
FILES_CACHE_MAX_BYTES = 64 * 1024 * 1024

_files_cache: "OrderedDict[Tuple[str, int], tuple]" = OrderedDict()
_files_cache_bytes = 0
_files_cache_stats = {"hits": 0, "misses": 0, "evictions": 0}
#: guards every lookup/insert/evict above: the pipelined executor's prep
#: thread builds the next chunk's filesets while the main thread's cost
#: proxy reads the same cache, and OrderedDict.move_to_end during a
#: concurrent popitem corrupts the dict. An RLock (not Lock) so a
#: re-entrant builder that itself calls build_files can't self-deadlock.
_files_cache_lock = threading.RLock()


def _entry_bytes(specs: tuple) -> int:
    """Measured footprint of one cached fileset entry.

    The original fixed ~120 B/FileSpec estimate undershot reality (a
    FileSpec dataclass instance plus its ``__dict__``, name string, and
    size int measure ~3-4x that on CPython 3.11), so a heavy-tail
    candidate sweep could pin several times :data:`FILES_CACHE_MAX_BYTES`
    while the accounting said it fit. Sum ``sys.getsizeof`` over the
    entry tuple and each spec's object/dict/fields instead — O(n) once
    per cache insert, identical cost shape to building the entry. The
    same function sizes inserts and evictions, so the running
    ``_files_cache_bytes`` total stays exact regardless of the estimate's
    absolute accuracy.
    """
    import sys

    size = sys.getsizeof(specs)
    for f in specs:
        size += sys.getsizeof(f)
        d = getattr(f, "__dict__", None)
        if d is not None:
            size += sys.getsizeof(d)
        size += sys.getsizeof(f.name) + sys.getsizeof(f.size)
        if f.path is not None:
            size += sys.getsizeof(f.path)
    return size


def files_cache_info() -> dict:
    """Introspection for tests/benchmarks: current byte footprint,
    entry count, and hit/miss/eviction counters."""
    with _files_cache_lock:
        return dict(
            _files_cache_stats,
            entries=len(_files_cache),
            bytes=_files_cache_bytes,
            max_bytes=FILES_CACHE_MAX_BYTES,
        )


def _build_files_cached(dataset: str, dataset_seed: int) -> tuple:
    """Byte-bounded, thread-safe LRU over built filesets.

    ``functools.lru_cache(maxsize=512)`` keyed eviction on *entry count*;
    datasets differ in size by four orders of magnitude, so the bound is
    on the approximate bytes pinned instead — oldest entries fall out
    until the new entry fits. Entries are immutable tuples of frozen
    FileSpecs, shared across every caller (sweeps over the same context
    reference one fileset, they don't copy it). The lock covers the
    build too: two threads missing on the same key then build it once,
    not twice (generator cost is the whole point of the cache).
    """
    global _files_cache_bytes
    key = (dataset, dataset_seed)
    with _files_cache_lock:
        entry = _files_cache.get(key)
        if entry is not None:
            _files_cache.move_to_end(key)
            _files_cache_stats["hits"] += 1
            return entry
        try:
            builder = DATASET_BUILDERS[dataset]
        except KeyError:
            raise ValueError(
                f"unknown dataset {dataset!r}; "
                f"options: {sorted(DATASET_BUILDERS)}"
            )
        _files_cache_stats["misses"] += 1
        entry = tuple(builder(dataset_seed))
        cost = _entry_bytes(entry)
        while (
            _files_cache
            and _files_cache_bytes + cost > FILES_CACHE_MAX_BYTES
        ):
            _, old = _files_cache.popitem(last=False)
            _files_cache_bytes -= _entry_bytes(old)
            _files_cache_stats["evictions"] += 1
        if cost <= FILES_CACHE_MAX_BYTES:
            _files_cache[key] = entry
            _files_cache_bytes += cost
        return entry


def build_files(scenario: Scenario) -> List[FileSpec]:
    """The scenario's dataset (deterministic in (dataset, seed)).

    Memoized: the autotuner expands each scenario along a candidate axis
    (dozens of static rows sharing one dataset), and the cost-proxy sort
    builds files a second time per row — generator calls would otherwise
    dominate candidate-sweep setup. FileSpecs are frozen, so sharing the
    specs across rows is safe; the list itself is fresh per call. The
    cache is bounded by approximate bytes (:data:`FILES_CACHE_MAX_BYTES`),
    not entry count.
    """
    return list(_build_files_cached(scenario.dataset, scenario.dataset_seed))


def build_simulation(
    scenario: Scenario, record_timeline: Optional[bool] = None
) -> Simulation:
    """Scenario -> ready-to-run event-driven Simulation (fresh scheduler).

    ``record_timeline`` overrides the scenario's own flag when given."""
    network = testbeds.TESTBEDS[scenario.network]
    extra = (
        {"static_params": scenario.static_params}
        if scenario.static_params is not None
        else {}
    )
    sched = build_scheduler(
        scenario.algorithm,
        build_files(scenario),
        network,
        max_cc=scenario.max_cc,
        num_chunks=scenario.num_chunks,
        **extra,
    )
    if record_timeline is None:
        record_timeline = scenario.record_timeline
    return Simulation(
        sched.chunks,
        sched.network,  # baselines may degrade the path (GCP mode)
        sched,
        tick_period=scenario.tick_period,
        record_timeline=record_timeline,
    )


# --------------------------------------------------------------------------
# matrices
# --------------------------------------------------------------------------


def default_matrix(seed: int = 0) -> List[Scenario]:
    """The golden-pinned grid: 6 networks x 6 core datasets x 5 schedulers
    (maxCC=8) = 180 scenarios, plus a maxCC sweep {1, 2, 4, 16} of the
    adaptive schedulers (MC, ProMC) on two contrasting datasets = 96 more,
    for 276 total. The 1000+-scenario acceptance grid is
    :func:`full_matrix`."""
    out: List[Scenario] = []
    for net in NETWORKS:
        for ds in CORE_DATASETS:
            for algo in ALGORITHMS:
                out.append(
                    Scenario(network=net, dataset=ds, algorithm=algo, seed=seed)
                )
    for net in NETWORKS:
        for ds in ("mixed", "uniform_huge"):
            for algo in ("mc", "promc"):
                for cc in (1, 2, 4, 16):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            max_cc=cc, seed=seed,
                        )
                    )
    return out


def full_matrix(seed: int = 0) -> List[Scenario]:
    """The 1000+-scenario acceptance grid for backend difftests and the
    matrix benchmarks.

    Base cross: 9 networks (paper testbeds + lossy/jittery/asymmetric-RTT
    variants) x 8 datasets (core + heavy-tail + small-file swarm) x 5
    schedulers x 2 dataset seeds = 720 scenarios. On top: a maxCC sweep
    {1, 2, 4, 16} of the adaptive schedulers on three contrasting datasets
    (216), a chunk-count sweep {1, 2, 3} (vs the default 4) of the tuned
    schedulers on the new shapes (162), and a time-varying-bandwidth slice
    (step/ramp capacity profiles x 3 datasets x the tuned schedulers, 18),
    for 1116 total.
    """
    out: List[Scenario] = []
    for s in (seed, seed + 1):
        for net in EXTENDED_NETWORKS:
            for ds in DATASET_BUILDERS:
                for algo in ALGORITHMS:
                    out.append(
                        Scenario(network=net, dataset=ds, algorithm=algo, seed=s)
                    )
    for net in EXTENDED_NETWORKS:
        for ds in ("mixed", "uniform_huge", "heavy_tail"):
            for algo in ("mc", "promc"):
                for cc in (1, 2, 4, 16):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            max_cc=cc, seed=seed,
                        )
                    )
    for net in EXTENDED_NETWORKS:
        for ds in ("heavy_tail", "small_file_swarm"):
            for algo in ("sc", "mc", "promc"):
                for k in (1, 2, 3):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            num_chunks=k, seed=seed,
                        )
                    )
    for net in TIME_VARYING_NETWORKS:
        for ds in ("mixed", "heavy_tail", "uniform_huge"):
            for algo in ("sc", "mc", "promc"):
                out.append(
                    Scenario(network=net, dataset=ds, algorithm=algo, seed=seed)
                )
    return out


def expand_candidates(
    scenarios: Sequence[Scenario],
    candidates,
) -> List[Scenario]:
    """Expand a scenario matrix along the autotuner's candidate axis.

    ``candidates`` is either one shared sequence of ``(pp, p, cc)``
    settings (``TransferParams`` accepted) or a callable
    ``scenario -> sequence`` for per-scenario spaces (BDP-derived caps
    differ across testbeds). Each base scenario yields one ``static``
    row per candidate — same network / dataset / seed / tick, so the
    candidate transfers exactly the bytes the heuristic row transfers —
    returned scenario-major (``len(scenarios) * n_candidates`` rows,
    candidate order preserved). The expanded rows are ordinary
    scenarios: one :func:`repro.eval.runner.run_matrix` call sweeps the
    whole (scenario x candidate) plane through the batched fabric
    backends, chunked by the runner's cost proxy — no per-candidate
    Python loop over scenarios.
    """
    out: List[Scenario] = []
    for sc in scenarios:
        cands = candidates(sc) if callable(candidates) else candidates
        for params in cands:
            trip = param_triple(params)
            out.append(
                dataclasses.replace(
                    sc,
                    algorithm="static",
                    static_params=trip,
                    record_timeline=False,
                )
            )
    return out


def timeline_matrix(seed: int = 0) -> List[Scenario]:
    """Timeline-recording variants of the smoke cross-section (every
    network / core dataset / scheduler appears): the grid the
    timeline-equivalence tests run through all three backends, asserting
    the on-device ring buffer matches the event backend's host-appended
    samples."""
    return [
        dataclasses.replace(sc, record_timeline=True)
        for sc in smoke_matrix(seed)
    ]


def tenant_matrix(
    seed: int = 0,
    n_groups: int = 36,
    tenants_per_group: Tuple[int, int] = (4, 8),
) -> List[Scenario]:
    """Fleet matrix: N tenants coupled through shared backbone links.

    Shaped after the fdtcp ``loadtest/`` fleet harness named in ROADMAP:
    many concurrent transfer jobs, each a perfectly ordinary scenario row
    (its own testbed, dataset, controller), launched against shared
    infrastructure. Each of ``n_groups`` fabric groups holds 4-8 tenants
    drawn from the SC / MC / ProMC / static mix; every tenant rides the
    group's backbone link (sized at 35-85% of the members' summed
    bandwidth, so contention actually binds) and 0-3 additional regional
    links each shared by a random subset. The default 36 groups yield
    ~216 scenarios — the >=200-row fleet the coupled difftests and the
    contention report run on. Deterministic in ``seed``: groups, mixes,
    and link capacities all come from one seeded PRNG.
    """
    import random

    rng = random.Random(0xFAB ^ (seed * 2654435761 % 2**32))
    algos = ("sc", "mc", "promc", "static")
    datasets = ("des", "mixed", "small_dominated", "uniform_small")
    out: List[Scenario] = []
    for g in range(n_groups):
        n_t = rng.randint(*tenants_per_group)
        nets = [rng.choice(list(NETWORKS)) for _ in range(n_t)]
        bws = [testbeds.TESTBEDS[n].bandwidth for n in nets]
        group = f"g{g:03d}"
        # backbone: all members; regional links: random subsets of >= 2
        links = [("bb", rng.uniform(0.35, 0.85) * sum(bws))]
        subsets = [list(range(n_t))]
        for li in range(1, rng.randint(1, 4)):
            members = sorted(
                rng.sample(range(n_t), rng.randint(2, n_t))
            )
            cap = rng.uniform(0.4, 0.9) * sum(bws[m] for m in members)
            links.append((f"l{li}", cap))
            subsets.append(members)
        for t in range(n_t):
            mine = [
                (name, cap)
                for (name, cap), mem in zip(links, subsets)
                if t in mem
            ]
            fab = SharedFabric(
                group=group,
                links=tuple(name for name, _ in mine),
                capacity=tuple(cap for _, cap in mine),
                tenant=f"t{t}",
            )
            algo = algos[(g + t) % len(algos)]
            cc = rng.choice((4, 8))
            sp = None
            if algo == "static":
                sp = (rng.choice((0, 2, 4)), rng.choice((2, 4)), cc)
            out.append(
                Scenario(
                    network=nets[t],
                    dataset=rng.choice(datasets),
                    algorithm=algo,
                    max_cc=cc,
                    seed=seed,
                    static_params=sp,
                    shared_fabric=fab,
                )
            )
    return out


def smoke_matrix(seed: int = 0) -> List[Scenario]:
    """A 24-scenario cross-section (every network, core dataset, and
    scheduler appears) for tier-1 tests and CI; the full matrix runs
    behind -m slow."""
    out: List[Scenario] = []
    datasets = list(CORE_DATASETS)
    for i, net in enumerate(NETWORKS):
        for j, algo in enumerate(ALGORITHMS):
            ds = datasets[(i + j) % len(datasets)]
            out.append(Scenario(network=net, dataset=ds, algorithm=algo, seed=seed))
    # cheap extremes: concurrency starvation and oversubscription
    out.append(
        Scenario(
            network=testbeds.LAN.name, dataset="uniform_small",
            algorithm="promc", max_cc=1, seed=seed,
        )
    )
    out.append(
        Scenario(
            network=testbeds.XSEDE.name, dataset="mixed",
            algorithm="mc", max_cc=16, seed=seed,
        )
    )
    return out
