"""Declarative scenario matrix: (testbed x dataset x scheduler x maxCC).

A :class:`Scenario` is a pure value — building it twice yields bit-identical
simulations because every dataset generator is seeded from the scenario
itself. The golden-pinned default matrix crosses the paper's six WAN
testbeds with scaled paper datasets and all five schedulers (SC / MC /
ProMC / GlobusOnline / untuned) plus a maxCC sweep (276 scenarios);
:func:`full_matrix` widens it with impaired-path testbeds (loss / jitter /
asymmetric control RTT) and heavy-tail / small-file-swarm datasets to
1000+ scenarios. Every backend — event simulator, NumPy fabric driver,
JAX device loop — consumes the same grids unchanged.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import testbeds
from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.core.types import GB, MB, FileSpec
from repro.data import filesets

# --------------------------------------------------------------------------
# dataset registry
# --------------------------------------------------------------------------

#: name -> builder(seed) -> list[FileSpec]. Scales are chosen so event-driven
#: runs stay cheap (tens of files) while keeping every size class populated —
#: the matrix trades per-scenario size for scenario count.
DATASET_BUILDERS: Dict[str, Callable[[int], List[FileSpec]]] = {
    "des": lambda seed: filesets.dark_energy_survey(scale=0.05, seed=seed),
    "genome": lambda seed: filesets.genome_sequencing(scale=0.0004, seed=seed),
    "mixed": lambda seed: filesets.mixed_dataset(scale=0.008, seed=seed),
    "small_dominated": lambda seed: filesets.small_dominated_mixed(
        scale=0.006, seed=seed
    ),
    "uniform_small": lambda seed: filesets.uniform_files(40, 4 * MB),
    "uniform_huge": lambda seed: filesets.uniform_files(6, 8 * GB),
    "heavy_tail": lambda seed: filesets.heavy_tail_dataset(
        scale=0.012, seed=seed
    ),
    "small_file_swarm": lambda seed: filesets.small_file_swarm(
        scale=0.004, seed=seed
    ),
}

#: the paper's physical WAN testbeds (Tables 1-2); DCN/CKPT presets are
#: exercised by grad-sync suites, not the transfer matrix. This tuple is
#: pinned — golden snapshots cover ``default_matrix`` — so impaired-path
#: additions go to EXTENDED_NETWORKS / ``full_matrix`` instead.
NETWORKS: Sequence[str] = (
    testbeds.XSEDE.name,
    testbeds.LONI.name,
    testbeds.BLUEWATERS_STAMPEDE.name,
    testbeds.STAMPEDE_COMET.name,
    testbeds.SUPERMIC_BRIDGES.name,
    testbeds.LAN.name,
)

#: paper testbeds + the impaired-path variants (loss / jitter / asymmetric
#: control RTT) driven only by the 1000+-scenario ``full_matrix``.
EXTENDED_NETWORKS: Sequence[str] = NETWORKS + (
    testbeds.LOSSY_TRANSATLANTIC.name,
    testbeds.JITTERY_OVERLAY.name,
    testbeds.ASYM_CONTROL_PATH.name,
)

#: time-varying-capacity variants (step / ramp bandwidth profiles);
#: crossed with a focused dataset/scheduler slice in ``full_matrix`` —
#: "network conditions vary over time" is exactly the regime the paper's
#: adaptive controllers (and their ETA estimates) must absorb.
TIME_VARYING_NETWORKS: Sequence[str] = (
    testbeds.STEPPY_BACKBONE.name,
    testbeds.RAMPY_EVENING.name,
)

#: datasets of the golden-pinned default/smoke matrices. Pinned for the
#: same reason as NETWORKS: new generators join ``full_matrix`` via
#: DATASET_BUILDERS without silently reshaping the snapshotted grids.
CORE_DATASETS: Sequence[str] = (
    "des", "genome", "mixed", "small_dominated", "uniform_small",
    "uniform_huge",
)

ALGORITHMS: Sequence[str] = ("sc", "mc", "promc", "globus", "untuned")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the evaluation matrix. Hash-stable and JSON-friendly."""

    network: str  # key into testbeds.TESTBEDS
    dataset: str  # key into DATASET_BUILDERS
    algorithm: str  # sc | mc | promc | globus | untuned
    max_cc: int = 8
    num_chunks: int = 4
    tick_period: float = 5.0
    seed: int = 0
    #: record the (t, aggregate rate) timeline. On the fabric backends the
    #: samples stream into the fixed-budget on-device ring buffer
    #: (uniform-stride decimation past the budget); the event backend
    #: keeps the full host-appended timeline.
    record_timeline: bool = False

    @property
    def name(self) -> str:
        tl = "|tl" if self.record_timeline else ""
        return (
            f"{self.network}|{self.dataset}|{self.algorithm}"
            f"|cc{self.max_cc}|k{self.num_chunks}|s{self.seed}{tl}"
        )

    @property
    def dataset_seed(self) -> int:
        """Seed for the dataset generator: scenario-unique, order-free."""
        digest = hashlib.sha256(
            f"{self.dataset}:{self.seed}".encode("utf-8")
        ).digest()
        return int.from_bytes(digest[:4], "little")


def build_files(scenario: Scenario) -> List[FileSpec]:
    try:
        builder = DATASET_BUILDERS[scenario.dataset]
    except KeyError:
        raise ValueError(
            f"unknown dataset {scenario.dataset!r}; "
            f"options: {sorted(DATASET_BUILDERS)}"
        )
    return builder(scenario.dataset_seed)


def build_simulation(
    scenario: Scenario, record_timeline: Optional[bool] = None
) -> Simulation:
    """Scenario -> ready-to-run event-driven Simulation (fresh scheduler).

    ``record_timeline`` overrides the scenario's own flag when given."""
    network = testbeds.TESTBEDS[scenario.network]
    sched = build_scheduler(
        scenario.algorithm,
        build_files(scenario),
        network,
        max_cc=scenario.max_cc,
        num_chunks=scenario.num_chunks,
    )
    if record_timeline is None:
        record_timeline = scenario.record_timeline
    return Simulation(
        sched.chunks,
        sched.network,  # baselines may degrade the path (GCP mode)
        sched,
        tick_period=scenario.tick_period,
        record_timeline=record_timeline,
    )


# --------------------------------------------------------------------------
# matrices
# --------------------------------------------------------------------------


def default_matrix(seed: int = 0) -> List[Scenario]:
    """The golden-pinned grid: 6 networks x 6 core datasets x 5 schedulers
    (maxCC=8) = 180 scenarios, plus a maxCC sweep {1, 2, 4, 16} of the
    adaptive schedulers (MC, ProMC) on two contrasting datasets = 96 more,
    for 276 total. The 1000+-scenario acceptance grid is
    :func:`full_matrix`."""
    out: List[Scenario] = []
    for net in NETWORKS:
        for ds in CORE_DATASETS:
            for algo in ALGORITHMS:
                out.append(
                    Scenario(network=net, dataset=ds, algorithm=algo, seed=seed)
                )
    for net in NETWORKS:
        for ds in ("mixed", "uniform_huge"):
            for algo in ("mc", "promc"):
                for cc in (1, 2, 4, 16):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            max_cc=cc, seed=seed,
                        )
                    )
    return out


def full_matrix(seed: int = 0) -> List[Scenario]:
    """The 1000+-scenario acceptance grid for backend difftests and the
    matrix benchmarks.

    Base cross: 9 networks (paper testbeds + lossy/jittery/asymmetric-RTT
    variants) x 8 datasets (core + heavy-tail + small-file swarm) x 5
    schedulers x 2 dataset seeds = 720 scenarios. On top: a maxCC sweep
    {1, 2, 4, 16} of the adaptive schedulers on three contrasting datasets
    (216), a chunk-count sweep {1, 2, 3} (vs the default 4) of the tuned
    schedulers on the new shapes (162), and a time-varying-bandwidth slice
    (step/ramp capacity profiles x 3 datasets x the tuned schedulers, 18),
    for 1116 total.
    """
    out: List[Scenario] = []
    for s in (seed, seed + 1):
        for net in EXTENDED_NETWORKS:
            for ds in DATASET_BUILDERS:
                for algo in ALGORITHMS:
                    out.append(
                        Scenario(network=net, dataset=ds, algorithm=algo, seed=s)
                    )
    for net in EXTENDED_NETWORKS:
        for ds in ("mixed", "uniform_huge", "heavy_tail"):
            for algo in ("mc", "promc"):
                for cc in (1, 2, 4, 16):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            max_cc=cc, seed=seed,
                        )
                    )
    for net in EXTENDED_NETWORKS:
        for ds in ("heavy_tail", "small_file_swarm"):
            for algo in ("sc", "mc", "promc"):
                for k in (1, 2, 3):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            num_chunks=k, seed=seed,
                        )
                    )
    for net in TIME_VARYING_NETWORKS:
        for ds in ("mixed", "heavy_tail", "uniform_huge"):
            for algo in ("sc", "mc", "promc"):
                out.append(
                    Scenario(network=net, dataset=ds, algorithm=algo, seed=seed)
                )
    return out


def timeline_matrix(seed: int = 0) -> List[Scenario]:
    """Timeline-recording variants of the smoke cross-section (every
    network / core dataset / scheduler appears): the grid the
    timeline-equivalence tests run through all three backends, asserting
    the on-device ring buffer matches the event backend's host-appended
    samples."""
    return [
        dataclasses.replace(sc, record_timeline=True)
        for sc in smoke_matrix(seed)
    ]


def smoke_matrix(seed: int = 0) -> List[Scenario]:
    """A 24-scenario cross-section (every network, core dataset, and
    scheduler appears) for tier-1 tests and CI; the full matrix runs
    behind -m slow."""
    out: List[Scenario] = []
    datasets = list(CORE_DATASETS)
    for i, net in enumerate(NETWORKS):
        for j, algo in enumerate(ALGORITHMS):
            ds = datasets[(i + j) % len(datasets)]
            out.append(Scenario(network=net, dataset=ds, algorithm=algo, seed=seed))
    # cheap extremes: concurrency starvation and oversubscription
    out.append(
        Scenario(
            network=testbeds.LAN.name, dataset="uniform_small",
            algorithm="promc", max_cc=1, seed=seed,
        )
    )
    out.append(
        Scenario(
            network=testbeds.XSEDE.name, dataset="mixed",
            algorithm="mc", max_cc=16, seed=seed,
        )
    )
    return out
