"""Declarative scenario matrix: (testbed x dataset x scheduler x maxCC).

A :class:`Scenario` is a pure value — building it twice yields bit-identical
simulations because every dataset generator is seeded from the scenario
itself. The default matrix crosses the paper's six WAN testbeds with scaled
paper datasets and all five schedulers (SC / MC / ProMC / GlobusOnline /
untuned) plus a maxCC sweep, giving 200+ scenarios that both the event-driven
simulator and the batch fast-path consume unchanged.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Sequence

from repro.core import testbeds
from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.core.types import GB, MB, FileSpec
from repro.data import filesets

# --------------------------------------------------------------------------
# dataset registry
# --------------------------------------------------------------------------

#: name -> builder(seed) -> list[FileSpec]. Scales are chosen so event-driven
#: runs stay cheap (tens of files) while keeping every size class populated —
#: the matrix trades per-scenario size for scenario count.
DATASET_BUILDERS: Dict[str, Callable[[int], List[FileSpec]]] = {
    "des": lambda seed: filesets.dark_energy_survey(scale=0.05, seed=seed),
    "genome": lambda seed: filesets.genome_sequencing(scale=0.0004, seed=seed),
    "mixed": lambda seed: filesets.mixed_dataset(scale=0.008, seed=seed),
    "small_dominated": lambda seed: filesets.small_dominated_mixed(
        scale=0.006, seed=seed
    ),
    "uniform_small": lambda seed: filesets.uniform_files(40, 4 * MB),
    "uniform_huge": lambda seed: filesets.uniform_files(6, 8 * GB),
}

#: the paper's physical WAN testbeds (Tables 1-2); DCN/CKPT presets are
#: exercised by grad-sync suites, not the transfer matrix.
NETWORKS: Sequence[str] = (
    testbeds.XSEDE.name,
    testbeds.LONI.name,
    testbeds.BLUEWATERS_STAMPEDE.name,
    testbeds.STAMPEDE_COMET.name,
    testbeds.SUPERMIC_BRIDGES.name,
    testbeds.LAN.name,
)

ALGORITHMS: Sequence[str] = ("sc", "mc", "promc", "globus", "untuned")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the evaluation matrix. Hash-stable and JSON-friendly."""

    network: str  # key into testbeds.TESTBEDS
    dataset: str  # key into DATASET_BUILDERS
    algorithm: str  # sc | mc | promc | globus | untuned
    max_cc: int = 8
    num_chunks: int = 4
    tick_period: float = 5.0
    seed: int = 0

    @property
    def name(self) -> str:
        return (
            f"{self.network}|{self.dataset}|{self.algorithm}"
            f"|cc{self.max_cc}|k{self.num_chunks}|s{self.seed}"
        )

    @property
    def dataset_seed(self) -> int:
        """Seed for the dataset generator: scenario-unique, order-free."""
        digest = hashlib.sha256(
            f"{self.dataset}:{self.seed}".encode("utf-8")
        ).digest()
        return int.from_bytes(digest[:4], "little")


def build_files(scenario: Scenario) -> List[FileSpec]:
    try:
        builder = DATASET_BUILDERS[scenario.dataset]
    except KeyError:
        raise ValueError(
            f"unknown dataset {scenario.dataset!r}; "
            f"options: {sorted(DATASET_BUILDERS)}"
        )
    return builder(scenario.dataset_seed)


def build_simulation(
    scenario: Scenario, record_timeline: bool = False
) -> Simulation:
    """Scenario -> ready-to-run event-driven Simulation (fresh scheduler)."""
    network = testbeds.TESTBEDS[scenario.network]
    sched = build_scheduler(
        scenario.algorithm,
        build_files(scenario),
        network,
        max_cc=scenario.max_cc,
        num_chunks=scenario.num_chunks,
    )
    return Simulation(
        sched.chunks,
        sched.network,  # baselines may degrade the path (GCP mode)
        sched,
        tick_period=scenario.tick_period,
        record_timeline=record_timeline,
    )


# --------------------------------------------------------------------------
# matrices
# --------------------------------------------------------------------------


def default_matrix(seed: int = 0) -> List[Scenario]:
    """The full grid: 6 networks x 6 datasets x 5 schedulers (maxCC=8)
    = 180 scenarios, plus a maxCC sweep {1, 2, 4, 16} of the adaptive
    schedulers (MC, ProMC) on two contrasting datasets = 96 more,
    for 276 total."""
    out: List[Scenario] = []
    for net in NETWORKS:
        for ds in DATASET_BUILDERS:
            for algo in ALGORITHMS:
                out.append(
                    Scenario(network=net, dataset=ds, algorithm=algo, seed=seed)
                )
    for net in NETWORKS:
        for ds in ("mixed", "uniform_huge"):
            for algo in ("mc", "promc"):
                for cc in (1, 2, 4, 16):
                    out.append(
                        Scenario(
                            network=net, dataset=ds, algorithm=algo,
                            max_cc=cc, seed=seed,
                        )
                    )
    return out


def smoke_matrix(seed: int = 0) -> List[Scenario]:
    """A 24-scenario cross-section (every network, dataset, and scheduler
    appears) for tier-1 tests and CI; the full matrix runs behind -m slow."""
    out: List[Scenario] = []
    datasets = list(DATASET_BUILDERS)
    for i, net in enumerate(NETWORKS):
        for j, algo in enumerate(ALGORITHMS):
            ds = datasets[(i + j) % len(datasets)]
            out.append(Scenario(network=net, dataset=ds, algorithm=algo, seed=seed))
    # cheap extremes: concurrency starvation and oversubscription
    out.append(
        Scenario(
            network=testbeds.LAN.name, dataset="uniform_small",
            algorithm="promc", max_cc=1, seed=seed,
        )
    )
    out.append(
        Scenario(
            network=testbeds.XSEDE.name, dataset="mixed",
            algorithm="mc", max_cc=16, seed=seed,
        )
    )
    return out
