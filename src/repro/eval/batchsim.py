"""Vectorized fluid fast-path: all scenarios advance in batched NumPy arrays.

The event-driven :class:`repro.core.simulator.Simulation` spends its time in
per-event Python: water-filling over channels, horizon search, per-channel
advancement, queue feeding. This module runs the *same* event semantics for
S scenarios at once — channel state lives in (S, C) arrays, per-chunk queue
state in (S, K) arrays over one flat file-size buffer, and rates come from
the closed-form ``netmodel.waterfill_batch``. Each outer iteration advances
every live scenario to its own next event simultaneously; scenarios are
independent, so their clocks drift apart freely.

Python only runs where the controller genuinely needs it: scheduler
callbacks (``on_tick`` of ProMC, ``on_chunk_complete`` of SC/MC/ProMC) and
the rare re-queue of an interrupted file after a channel closure. Baseline
schedulers inherit the no-op callbacks, so their scenarios complete without
leaving the vectorized path at all.

Fidelity contract: state transitions mirror ``Simulation.step`` exactly —
same rate model (``netmodel.channel_rate_cap`` / disk aggregate / max-min
fill), same dead-time accounting (``netmodel.file_start_dead_time``,
``channel_open_cost``), same tick EMA (``simulator.tick_rate_update``), same
feed -> completions -> tick ordering. ``eval.difftest`` enforces agreement
on every matrix scenario; if you change one side, change the other.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core import netmodel
from repro.core.schedulers import Close, ChunkView, Move, Open, Scheduler
from repro.core.simulator import (
    SimResult,
    Simulation,
    next_event_dt,  # noqa: F401  (scalar reference the arrays mirror)
    resume_file,
    tick_rate_update,
)
from repro.core.types import TransferParams

_EPS = 1e-12
_NO_CHUNK = -1


class _ScenarioRuntime:
    """Python-side (non-vectorizable) per-scenario state: the controller,
    chunk metadata, and re-queued (resume) files."""

    __slots__ = (
        "index", "name", "network", "scheduler", "chunks", "params",
        "prepend", "trivial_tick", "trivial_complete", "tick_period",
        "finish_t", "n_moves", "total_bytes", "avg_fs", "predict_cache",
        "timeline",
    )

    def __init__(self, index: int, name: str, sim: Simulation):
        self.index = index
        self.name = name
        self.network = sim.network
        self.scheduler = sim.scheduler
        self.chunks = [st.chunk for st in sim.states]
        self.params: List[TransferParams] = [c.params for c in self.chunks]
        #: re-queued resume files per chunk, LIFO (deque.appendleft mirror)
        self.prepend: List[List[float]] = [[] for _ in self.chunks]
        cls = type(sim.scheduler)
        self.trivial_tick = cls.on_tick is Scheduler.on_tick
        self.trivial_complete = (
            cls.on_chunk_complete is Scheduler.on_chunk_complete
        )
        self.tick_period = sim.tick_period
        self.finish_t = 0.0
        self.n_moves = 0
        self.total_bytes = float(sum(st.queue_bytes for st in sim.states))
        self.avg_fs = [max(c.avg_file_size, 1.0) for c in self.chunks]
        self.timeline: List[tuple] = []
        #: (chunk, n_channels, total_channels) -> predicted rate; the model
        #: is pure, and allocations revisit the same few tuples constantly
        self.predict_cache: dict = {}


class BatchSimulation:
    """Run many scenarios through the fluid transfer model simultaneously.

    Construction takes ready ``Simulation`` objects (one per scenario, fresh
    schedulers) so scenario assembly stays in one place (eval.scenarios);
    only their initial state is consumed, never their event loop.
    """

    def __init__(
        self,
        sims: Sequence[Simulation],
        names: Optional[Sequence[str]] = None,
    ):
        if names is None:
            names = [f"scenario{i}" for i in range(len(sims))]
        self.rt = [
            _ScenarioRuntime(i, n, sim)
            for i, (n, sim) in enumerate(zip(names, sims))
        ]
        S = len(self.rt)
        self.S = S
        self.C = 4  # channel capacity; grows on demand
        K = max((len(r.chunks) for r in self.rt), default=1)
        self.K = K

        # scenario scalars
        self.t = np.zeros(S)
        self.done = np.zeros(S, dtype=bool)
        self.next_tick = np.array([r.tick_period for r in self.rt])
        self.tick_period = np.array([r.tick_period for r in self.rt])
        self.n_events = np.zeros(S, dtype=np.int64)
        # per-scenario settings carried over from the event Simulations
        self.max_time = np.array([sim.max_time for sim in sims])
        self.record_timeline = np.array(
            [sim.record_timeline for sim in sims], dtype=bool
        )
        self.has_prepend = np.zeros(S, dtype=bool)
        self.trivial_tick = np.array([r.trivial_tick for r in self.rt])
        self.trivial_complete = np.array(
            [r.trivial_complete for r in self.rt]
        )
        # network constants
        self.bw = np.array([r.network.bandwidth for r in self.rt])
        self.disk_rate = np.array(
            [r.network.disk.streaming_rate for r in self.rt]
        )
        self.sat_cc = np.array(
            [r.network.disk.saturation_cc for r in self.rt], dtype=np.int64
        )
        self.contention = np.array(
            [r.network.disk.contention for r in self.rt]
        )

        # channel state, padded to capacity C
        self.chunk_of = np.full((S, self.C), _NO_CHUNK, dtype=np.int64)
        self.dead = np.zeros((S, self.C))
        self.rem = np.zeros((S, self.C))
        self.busy = np.zeros((S, self.C), dtype=bool)
        self.cap = np.zeros((S, self.C))

        # per-chunk state, padded to K (padding slots are born done/empty)
        self.n_chunks = np.array(
            [len(r.chunks) for r in self.rt], dtype=np.int64
        )
        self.chunk_done = np.zeros((S, K), dtype=bool)
        self.chunk_done[np.arange(K)[None, :] >= self.n_chunks[:, None]] = True
        self.completed_at = np.full((S, K), math.nan)
        self.delivered = np.zeros((S, K))
        self.delivered_at_tick = np.zeros((S, K))
        self.rate_est = np.zeros((S, K))
        self.queue_bytes = np.zeros((S, K))
        #: serial per-file dead time per chunk (params are fixed per chunk)
        self.fsdt = np.zeros((S, K))

        # FIFO queues: one flat size buffer + (offset, length, cursor) per
        # (scenario, chunk). Resume files go to rt.prepend (LIFO), consumed
        # before the cursor moves — exactly deque.appendleft/popleft order.
        sizes: List[float] = []
        self.qoff = np.zeros((S, K), dtype=np.int64)
        self.qlen = np.zeros((S, K), dtype=np.int64)
        self.qptr = np.zeros((S, K), dtype=np.int64)
        #: count of re-queued resume files per (scenario, chunk)
        self.prepend_n = np.zeros((S, K), dtype=np.int64)
        for r in self.rt:
            for k, chunk in enumerate(r.chunks):
                self.qoff[r.index, k] = len(sizes)
                self.qlen[r.index, k] = len(chunk.files)
                self.queue_bytes[r.index, k] = chunk.total_bytes
                sizes.extend(float(f.size) for f in chunk.files)
                self.fsdt[r.index, k] = netmodel.file_start_dead_time(
                    r.network, r.params[k]
                )
        self.qsizes = np.asarray(sizes, dtype=np.float64)

    # ------------------------------------------------------------------ #
    # channel bookkeeping (mirrors Simulation._open_channel/_close_channels)
    # ------------------------------------------------------------------ #

    def _grow(self) -> None:
        pad = self.C
        self.C *= 2

        def z(a, fill):
            return np.concatenate(
                [a, np.full((self.S, pad), fill, dtype=a.dtype)], axis=1
            )

        self.chunk_of = z(self.chunk_of, _NO_CHUNK)
        self.dead = z(self.dead, 0.0)
        self.rem = z(self.rem, 0.0)
        self.busy = z(self.busy, False)
        self.cap = z(self.cap, 0.0)

    def _open_channel(
        self, r: _ScenarioRuntime, chunk: int, prev: Optional[TransferParams]
    ) -> None:
        s = r.index
        free = np.flatnonzero(self.chunk_of[s] == _NO_CHUNK)
        if free.size == 0:
            self._grow()
            free = np.flatnonzero(self.chunk_of[s] == _NO_CHUNK)
        c = free[0]
        params = r.params[chunk]
        self.chunk_of[s, c] = chunk
        self.dead[s, c] = netmodel.channel_open_cost(r.network, params, prev)
        self.rem[s, c] = 0.0
        self.busy[s, c] = False
        self.cap[s, c] = netmodel.channel_rate_cap(r.network, params.parallelism)

    def _close_channels(
        self, r: _ScenarioRuntime, chunk: int, n: int
    ) -> List[TransferParams]:
        s = r.index
        cols = np.flatnonzero(self.chunk_of[s] == chunk)
        # idle first, matching the event simulator's preference
        cols = sorted(cols, key=lambda c: bool(self.busy[s, c]))
        closed: List[TransferParams] = []
        for c in cols[:n]:
            if self.busy[s, c] and self.rem[s, c] > 0:
                f = resume_file(self.rem[s, c])
                r.prepend[chunk].append(float(f.size))
                self.queue_bytes[s, chunk] += f.size
                self.prepend_n[s, chunk] += 1
                self.has_prepend[s] = True
            self.chunk_of[s, c] = _NO_CHUNK
            self.busy[s, c] = False
            self.dead[s, c] = 0.0
            self.rem[s, c] = 0.0
            self.cap[s, c] = 0.0
            closed.append(r.params[chunk])
        return closed

    def _apply(self, r: _ScenarioRuntime, actions) -> None:
        for act in actions:
            if isinstance(act, Open):
                for _ in range(act.n):
                    self._open_channel(r, act.chunk, prev=None)
            elif isinstance(act, Close):
                self._close_channels(r, act.chunk, act.n)
            elif isinstance(act, Move):
                moved = self._close_channels(r, act.src, act.n)
                for prev in moved:
                    self._open_channel(r, act.dst, prev=prev)
                r.n_moves += len(moved)

    # ------------------------------------------------------------------ #
    # queue feeding
    # ------------------------------------------------------------------ #

    def _files_left(self, s: int, k: int) -> int:
        return int(self.qlen[s, k] - self.qptr[s, k]) + len(
            self.rt[s].prepend[k]
        )

    def _feed_py(self, r: _ScenarioRuntime) -> None:
        """Scalar feed for one scenario (resume files present / after
        scheduler actions). Mirrors Simulation._feed_channels."""
        s = r.index
        idle = np.flatnonzero((self.chunk_of[s] != _NO_CHUNK) & ~self.busy[s])
        for c in idle:
            k = int(self.chunk_of[s, c])
            if r.prepend[k]:
                size = r.prepend[k].pop()
                self.prepend_n[s, k] -= 1
            elif self.qptr[s, k] < self.qlen[s, k]:
                size = self.qsizes[self.qoff[s, k] + self.qptr[s, k]]
                self.qptr[s, k] += 1
            else:
                continue
            self.queue_bytes[s, k] -= size
            self.busy[s, c] = True
            self.rem[s, c] = size
            self.dead[s, c] += self.fsdt[s, k]
        self.has_prepend[s] = bool(self.prepend_n[s].any())

    def _feed_vec(self, rows: np.ndarray) -> None:
        """Batched feed for scenarios without resume files: every idle open
        channel pulls the next file of its chunk straight off the flat
        buffer. Channels of one chunk are interchangeable (same params), so
        assignment order within a chunk is immaterial."""
        idle = (self.chunk_of != _NO_CHUNK) & ~self.busy
        idle[~rows] = False
        s_idx, c_idx = np.nonzero(idle)
        if s_idx.size == 0:
            return
        k_idx = self.chunk_of[s_idx, c_idx]
        # rank of each idle channel within its (scenario, chunk) group;
        # (s, c) pairs arrive lexicographically sorted, so stable-sorting by
        # group key keeps column order and a running offset gives the rank
        group = s_idx * self.K + k_idx
        order = np.argsort(group, kind="stable")
        g_sorted = group[order]
        boundary = np.concatenate([[True], g_sorted[1:] != g_sorted[:-1]])
        idx = np.arange(g_sorted.size)
        rank = idx - np.maximum.accumulate(np.where(boundary, idx, 0))
        fidx = self.qptr[s_idx[order], k_idx[order]] + rank
        valid = fidx < self.qlen[s_idx[order], k_idx[order]]
        so, co, ko = s_idx[order][valid], c_idx[order][valid], k_idx[order][valid]
        sizes = self.qsizes[self.qoff[so, ko] + fidx[valid]]
        self.busy[so, co] = True
        self.rem[so, co] = sizes
        self.dead[so, co] += self.fsdt[so, ko]
        np.add.at(self.queue_bytes, (so, ko), -sizes)
        np.add.at(self.qptr, (so, ko), 1)

    # ------------------------------------------------------------------ #
    # controller plumbing (mirrors Simulation._view)
    # ------------------------------------------------------------------ #

    def _bytes_remaining(self, r: _ScenarioRuntime, k: int) -> float:
        s = r.index
        mask = (self.chunk_of[s] == k) & self.busy[s]
        return float(self.queue_bytes[s, k]) + float(self.rem[s][mask].sum())

    def _view(self, r: _ScenarioRuntime) -> List[ChunkView]:
        s = r.index
        ko = self.chunk_of[s]
        open_mask = ko != _NO_CHUNK
        n_open_total = int(open_mask.sum())
        nK = len(r.chunks)
        n_ch = np.bincount(ko[open_mask], minlength=nK)
        busy_ch = np.bincount(ko[open_mask & self.busy[s]], minlength=nK)
        inflight = np.zeros(nK)
        np.add.at(
            inflight, ko[open_mask & self.busy[s]],
            self.rem[s][open_mask & self.busy[s]],
        )
        views = []
        for k, chunk in enumerate(r.chunks):
            key = (k, int(n_ch[k]), n_open_total)
            predicted = r.predict_cache.get(key)
            if predicted is None:
                predicted = netmodel.predict_chunk_rate(
                    r.network,
                    r.avg_fs[k],
                    chunk.params,
                    max(int(n_ch[k]), 1),
                    total_active_channels=max(1, n_open_total),
                )
                r.predict_cache[key] = predicted
            views.append(
                ChunkView(
                    index=k,
                    ctype=chunk.ctype,
                    bytes_remaining=float(self.queue_bytes[s, k])
                    + float(inflight[k]),
                    files_remaining=self._files_left(s, k) + int(busy_ch[k]),
                    throughput=float(self.rate_est[s, k]),
                    n_channels=int(n_ch[k]),
                    done=bool(self.chunk_done[s, k]),
                    predicted_rate=predicted,
                )
            )
        return views

    def _check_completions_py(self, r: _ScenarioRuntime) -> List[int]:
        s = r.index
        completed = []
        for k in range(len(r.chunks)):
            if self.chunk_done[s, k]:
                continue
            busy = bool(((self.chunk_of[s] == k) & self.busy[s]).any())
            if self._files_left(s, k) == 0 and not busy:
                self._mark_complete(s, k)
                completed.append(k)
        return completed

    def _mark_complete(self, s: int, k: int) -> None:
        self.chunk_done[s, k] = True
        self.queue_bytes[s, k] = 0.0
        self.completed_at[s, k] = self.t[s]

    # ------------------------------------------------------------------ #
    # the vectorized event loop
    # ------------------------------------------------------------------ #

    def start(self) -> None:
        for r in self.rt:
            self._apply(r, r.scheduler.initial_actions(self._view(r)))
            self._feed_py(r)

    def step(self) -> None:
        """One synchronized sweep: every live scenario advances to its own
        next event. Mirrors Simulation.step; keep the orders in lockstep."""
        act = ~self.done
        if not act.any():
            return
        over = act & (self.t > self.max_time)
        if over.any():
            s = int(np.flatnonzero(over)[0])
            raise RuntimeError(
                f"batch scenario {self.rt[s].name!r} exceeded max_time="
                f"{self.max_time[s]}s (t={self.t[s]:.1f})"
            )
        self.n_events[act] += 1

        transferring = self.busy & (self.dead <= _EPS)
        n_t = transferring.sum(axis=1)
        over_sat = np.maximum(0, n_t - self.sat_cc)
        agg_disk = self.disk_rate / (1.0 + self.contention * over_sat)
        pool = np.where(n_t > 0, np.minimum(self.bw, agg_disk), 0.0)
        # water-fill only live rows: the sort inside is the costliest
        # per-iteration op and finished scenarios would pay it for nothing
        rates = np.zeros_like(self.rem)
        act_rows = np.flatnonzero(act)
        rates[act_rows] = netmodel.waterfill_batch(
            np.where(transferring[act_rows], self.cap[act_rows], 0.0),
            pool[act_rows],
        )
        rec = act & self.record_timeline
        if rec.any():
            agg = rates.sum(axis=1)
            for s in np.flatnonzero(rec):
                self.rt[s].timeline.append((float(self.t[s]), float(agg[s])))

        # horizon: min over dead-time expiries, file completions, tick
        dead_evt = np.where(self.busy & (self.dead > _EPS), self.dead, np.inf)
        with np.errstate(divide="ignore", invalid="ignore"):
            xfer_evt = np.where(
                transferring & (rates > _EPS), self.rem / rates, np.inf
            )
        dt = np.minimum(
            self.next_tick - self.t,
            np.minimum(dead_evt.min(axis=1), xfer_evt.min(axis=1)),
        )
        dt = np.where(act, np.maximum(dt, 0.0), 0.0)

        # stranded-chunk detection (scheduler bug), as in the event sim
        no_busy = act & ~self.busy.any(axis=1)
        for s in np.flatnonzero(no_busy):
            r = self.rt[s]
            live = np.flatnonzero(~self.chunk_done[s])
            held = set(self.chunk_of[s][self.chunk_of[s] != _NO_CHUNK].tolist())
            if any(int(k) not in held for k in live):
                raise RuntimeError(
                    f"scheduler {r.scheduler.name} stranded chunks "
                    f"{[r.chunks[int(k)].name for k in live]} in {r.name!r}"
                )

        # advance every live scenario by its own dt
        self.t += np.where(act, dt, 0.0)
        dtc = dt[:, None]
        in_dead = self.busy & (self.dead > _EPS)
        np.copyto(
            self.dead,
            np.maximum(0.0, self.dead - dtc),
            where=in_dead & act[:, None],
        )
        moving = transferring & (rates > _EPS) & act[:, None]
        moved = np.where(moving, np.minimum(self.rem, rates * dtc), 0.0)
        self.rem -= moved
        s_idx, c_idx = np.nonzero(moved)
        if s_idx.size:
            np.add.at(
                self.delivered,
                (s_idx, self.chunk_of[s_idx, c_idx]),
                moved[s_idx, c_idx],
            )
        finished = transferring & act[:, None] & (self.rem <= _EPS)
        self.busy[finished] = False
        self.rem[finished] = 0.0

        # ---- feed (vector fast path; scalar where resume files exist) ----
        fin_any = finished.any(axis=1)
        self._feed_vec(act & ~self.has_prepend)
        for s in np.flatnonzero(act & self.has_prepend):
            self._feed_py(self.rt[s])

        # ---- chunk completions ----
        # a chunk can only complete in an iteration where one of its
        # channels finished a file (or lost its channels to an action, which
        # is handled inside the python branches below)
        busy_per_chunk = np.zeros((self.S, self.K), dtype=np.int64)
        bs, bc = np.nonzero(self.busy)
        if bs.size:
            np.add.at(busy_per_chunk, (bs, self.chunk_of[bs, bc]), 1)
        files_left = self.qlen - self.qptr + self.prepend_n
        completed = (
            act[:, None]
            & ~self.chunk_done
            & (files_left == 0)
            & (busy_per_chunk == 0)
        )
        comp_rows = completed.any(axis=1)
        # trivial controllers (baselines): pure vector bookkeeping
        vec_rows = comp_rows & self.trivial_complete & ~self.has_prepend
        if vec_rows.any():
            m = completed & vec_rows[:, None]
            self.chunk_done |= m
            self.queue_bytes[m] = 0.0
            rs, ks = np.nonzero(m)
            self.completed_at[rs, ks] = self.t[rs]
        # real controllers: event-ordered python (detect -> callback -> feed)
        for s in np.flatnonzero(comp_rows & ~vec_rows):
            r = self.rt[s]
            for k in self._check_completions_py(r):
                actions = r.scheduler.on_chunk_complete(self._view(r), k)
                if actions:
                    self._apply(r, actions)
                    self._feed_py(r)

        # ---- controller tick ----
        tick_hit = act & (self.t >= self.next_tick - _EPS)
        if tick_hit.any():
            delta = self.delivered - self.delivered_at_tick
            inst = delta / self.tick_period[:, None]
            ema = np.where(
                self.rate_est == 0.0, inst, 0.5 * self.rate_est + 0.5 * inst
            )
            rows = tick_hit[:, None]
            np.copyto(self.rate_est, ema, where=rows)
            np.copyto(self.delivered_at_tick, self.delivered, where=rows)
            for s in np.flatnonzero(tick_hit & ~self.trivial_tick):
                r = self.rt[s]
                actions = r.scheduler.on_tick(self._view(r))
                if actions:
                    self._apply(r, actions)
                    self._feed_py(r)
            self.next_tick += np.where(tick_hit, self.tick_period, 0.0)

        # ---- scenario completion ----
        newly = act & self.chunk_done.all(axis=1) & (fin_any | comp_rows)
        for s in np.flatnonzero(newly):
            self.rt[s].finish_t = float(self.t[s])
        self.done |= newly

    def run(self) -> List[SimResult]:
        self.start()
        while not self.done.all():
            self.step()
        return [self._result(r) for r in self.rt]

    def _result(self, r: _ScenarioRuntime) -> SimResult:
        s = r.index
        total_time = max(r.finish_t, _EPS)
        return SimResult(
            network=r.network.name,
            scheduler=r.scheduler.name,
            total_bytes=r.total_bytes,
            total_time=total_time,
            throughput=r.total_bytes / total_time,
            per_chunk_time={
                c.name: float(self.completed_at[s, k])
                for k, c in enumerate(r.chunks)
            },
            per_chunk_bytes={
                c.name: float(self.delivered[s, k])
                for k, c in enumerate(r.chunks)
            },
            timeline=r.timeline,
            n_events=int(self.n_events[s]),
            n_moves=r.n_moves,
        )
