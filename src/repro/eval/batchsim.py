"""DEPRECATED compatibility shim: the vectorized fluid fast-path moved to
:mod:`repro.eval.fabric`.

``BatchSimulation`` is the NumPy instantiation of the backend-neutral
fabric driver (:class:`repro.eval.fabric.driver.FabricSimulation`); the
JAX instantiation lives in :mod:`repro.eval.fabric.jax_backend` and the
array-native controller layer in :mod:`repro.eval.fabric.controllers`.
The fidelity contract that used to live here is now the
:mod:`repro.eval.fabric` package docstring.

Importing this module emits a :class:`DeprecationWarning`; it is slated
for removal in the next PR — import from ``repro.eval.fabric`` instead.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.eval.batchsim is deprecated and will be removed in the next "
    "PR; import BatchSimulation from repro.eval.fabric "
    "(FabricSimulation) instead",
    DeprecationWarning,
    stacklevel=2,
)

from .fabric.driver import FabricSimulation as BatchSimulation  # noqa: E402
from .fabric.driver import _ScenarioRuntime  # noqa: E402,F401  (test hooks)

__all__ = ["BatchSimulation"]
