"""Compatibility shim: the vectorized fluid fast-path moved to
:mod:`repro.eval.fabric`.

``BatchSimulation`` is the NumPy instantiation of the backend-neutral
fabric driver (:class:`repro.eval.fabric.driver.FabricSimulation`); the
JAX instantiation lives in :mod:`repro.eval.fabric.jax_backend`. The
fidelity contract that used to live here is now the
:mod:`repro.eval.fabric` package docstring.
"""
from __future__ import annotations

from .fabric.driver import FabricSimulation as BatchSimulation
from .fabric.driver import _ScenarioRuntime  # noqa: F401  (test hooks)

__all__ = ["BatchSimulation"]
