"""Columnar scenario ingest: ``Scenario`` specs -> driver-ready columns.

The legacy ingest path builds every matrix row as a chain of Python
objects — ``build_simulation`` -> partition -> per-chunk ``Chunk`` lists
-> a scheduler facade -> ``Simulation`` -> per-row packing loops in
``FabricSimulation.__init__``. At sweep scale (16k+ candidate rows) that
host-side build tax dominates the wall clock: the device loop is fast,
the per-row Python is not.

:func:`build_plan` replaces the chain with one vectorized pass:

  * rows are grouped by **transfer context** ``(network, dataset,
    dataset_seed, effective_chunks)``; each context's file set is built
    (via the shared LRU) and partitioned exactly once with array ops
    (``np.searchsorted`` over the Fig.-3 thresholds == ``classify``),
    and its per-chunk columns (queue offsets, totals, averages, SC
    order, round-robin ranks, ProMC weights) are shared by every row in
    the context — the tuner's candidate planes
    (``scenarios.expand_candidates``: contexts x 64 candidates) reuse
    one context build per 64 rows instead of re-deriving it 64x;
  * per-row parameters go through the *same* array kernels the scalar
    facades wrap — Algorithm 1 via
    :func:`repro.eval.fabric.controllers.tuning.optimal_params`, the
    initial channel allocations via
    :func:`repro.eval.fabric.controllers.alloc.round_robin_alloc` /
    :func:`weighted_alloc` — so the resulting state is bit-identical to
    the legacy path (``tests/test_plan_ingest.py`` pins every row array
    exactly);
  * file sizes land in ONE flat ``qsizes`` buffer shared by every row
    referencing the context (rows address it through per-row offsets),
    which also collapses the jax backend's queue-pad signature axis to
    a single rung per plan.

``FabricSimulation(None, plan=plan.take(rows))`` then materializes the
resident ``(S, K, C, P, B)`` state directly from the columns — no
``Simulation`` objects, no scalar packing loop. ``plan.take`` is plain
array slicing: thread-safe, so the executor can parallelize chunk prep.

Every built-in algorithm is supported (``sc``/``mc``/``promc``/
``globus``/``untuned``/``static`` — the whole ``Scenario`` vocabulary);
custom scheduler subclasses have no ``Scenario`` spelling and keep the
legacy object path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import netmodel, testbeds
from repro.core.baselines import GLOBUS_PRESETS, MB
from repro.core.chunking import _CLASS_LABELS, size_thresholds
from repro.core.params import MAX_PIPELINING
from repro.core.types import (
    MC_ROUND_ROBIN_ORDER,
    PROMC_DELTA,
    ChunkType,
    NetworkSpec,
)

from .bucketing import bucket
from .controllers.alloc import round_robin_alloc, weighted_alloc
from .controllers.tuning import optimal_params, sc_chunk_order
from .shim import numpy_ops

#: algorithms the columnar path can ingest (== the Scenario vocabulary)
PLAN_ALGORITHMS = frozenset(
    {"sc", "mc", "promc", "globus", "untuned", "static"}
)

#: floor on the plan path's channel-capacity hint bucket: the driver pads
#: every plan chunk's worst-case channel axis up to this, merging the
#: cc<=4 and cc<=8 rows into ONE compiled (C=8) program family — the pad
#: is a few never-selected channel columns, while each extra C value is a
#: full trace+compile (or cache read) per rows-rung per process
PLAN_C_FLOOR = 8

#: channel floor for batches holding profiled (time-varying) rows: all
#: profiled rows form a single shape-hint group regardless of cc, so
#: their chunks pad to one (C=16, B=16) program family instead of a B=16
#: twin of every capacity bucket
PLAN_PROFILED_C_FLOOR = 16

#: compaction floor for all-static candidate-plane batches (every row
#: ``kind <= _KIND_STATIC``, no timelines): plane rows of one context
#: share parameters up to the candidate axis, so whole chunks drain
#: together and the narrow straggler rungs the heterogeneous grid needs
#: (``bucketing.COMPACT_FLOOR`` = 64) never earn their keep — each rung
#: below 256 is one more device re-entry plus a download sync per chunk.
#: The floor is a *static* argument of the fused device loop, so plane
#: and grid batches occupy disjoint compiled programs by construction
PLAN_COMPACT_FLOOR = 256

#: shape-hint value that sorts rows on profiled (time-varying) networks
#: after all static-bandwidth rows, so the B=16 profile pad stays
#: confined to the trailing chunks instead of widening every chunk the
#: cost sort scatters a profiled row into
_PROFILED_HINT = 1 << 16

#: driver kind codes, mirrored from the driver to avoid an import cycle
#: (`driver.py` imports this module's runtime refs); pinned by a test.
_KIND_TRIVIAL, _KIND_STATIC, _KIND_SC, _KIND_MC, _KIND_PROMC = 0, 1, 2, 3, 4

_KIND_OF = {
    "sc": _KIND_SC,
    "mc": _KIND_MC,
    "promc": _KIND_PROMC,
    "static": _KIND_STATIC,
    "globus": _KIND_TRIVIAL,
    "untuned": _KIND_TRIVIAL,
}

#: (trivial_tick, trivial_complete) per kind: which controller callbacks
#: are the base no-op (SC/MC override completion, ProMC also ticks)
_TRIVIAL_OF = {
    _KIND_TRIVIAL: (True, True),
    _KIND_STATIC: (True, True),
    _KIND_SC: (True, False),
    _KIND_MC: (True, False),
    _KIND_PROMC: (False, False),
}

#: round-robin service rank by int ChunkType (Alg. 2 order H,S,L,M,A)
_RR_RANK_BY_CT = np.zeros(len(ChunkType), dtype=np.int64)
for _i, _ct in enumerate(MC_ROUND_ROBIN_ORDER):
    _RR_RANK_BY_CT[int(_ct)] = _i

#: ProMC delta weight by int ChunkType (Alg. 3)
_DELTA_BY_CT = np.array(
    [PROMC_DELTA[ChunkType(_i)] for _i in range(len(ChunkType))],
    dtype=np.int64,
)

#: Globus Online class presets as parallel (pp, p, cc) columns
_GLOBUS_CLASSES = ("small", "medium", "large")
_GLOBUS_PP = np.array(
    [GLOBUS_PRESETS[c].pipelining for c in _GLOBUS_CLASSES], dtype=np.int64
)
_GLOBUS_P = np.array(
    [GLOBUS_PRESETS[c].parallelism for c in _GLOBUS_CLASSES], dtype=np.int64
)
_GLOBUS_CC = np.array(
    [GLOBUS_PRESETS[c].concurrency for c in _GLOBUS_CLASSES], dtype=np.int64
)

#: pad-slot chunk type: large-negative so the SC order kernel sorts pads
#: strictly after every real chunk (its key grows with ``hi - ctype``)
_PAD_CTYPE = -(10**6)

#: pad-slot round-robin rank: sorts pads after every real chunk in the
#: MC service order (real ranks are < len(ChunkType))
_PAD_RANK = 10**6


class _NameRef:
    """Tiny shared stand-in for scheduler/chunk objects: the driver's
    result assembly and error paths only ever read ``.name``."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_NameRef({self.name!r})"


@dataclasses.dataclass
class _Context:
    """One transfer context: a (network, fileset, partitioning) triple
    whose chunk columns every row in the context shares."""

    net_idx: int
    n_chunks: int
    chunk_refs: tuple  # shared _NameRef per chunk
    total_bytes: int  # exact int byte total over all files
    n_files: int
    globus_avg: float  # unclamped avg file size (Globus preset class)
    qoff: np.ndarray  # (n_chunks,) int64 offsets into the shared buffer
    qlen: np.ndarray  # (n_chunks,) int64
    chunk_total: np.ndarray  # (n_chunks,) int64
    ctype: np.ndarray  # (n_chunks,) int64


@dataclasses.dataclass
class ScenarioPlan:
    """Columnar scenario table: everything ``FabricSimulation`` needs to
    materialize its resident arrays, pre-padded to a shared chunk width
    ``K``. Row order == input scenario order. ``take(rows)`` slices a
    sub-plan (shared ``networks``/``qsizes``, copied row axes) — plain
    array work, safe to call from several executor prep threads."""

    K: int
    networks: List[NetworkSpec]
    qsizes: np.ndarray  # flat f64 file-size buffer, shared by all rows
    names: List[str]
    sched_refs: List[_NameRef]
    chunk_refs: List[tuple]
    #: per-row Optional[SharedFabric] coupling spec (None == uncoupled);
    #: value-like frozen dataclasses shared with the source scenarios
    fabrics: List
    # (S,) row columns
    net_idx: np.ndarray
    kind: np.ndarray
    trivial_tick: np.ndarray
    trivial_complete: np.ndarray
    tick_period: np.ndarray
    record_timeline: np.ndarray
    max_cc: np.ndarray
    eff_cc: np.ndarray
    total_bytes: np.ndarray  # f64 (exact int values)
    n_files: np.ndarray
    n_chunks: np.ndarray
    cap_need: np.ndarray
    # (S, K) row-chunk columns
    qoff: np.ndarray
    qlen: np.ndarray
    queue_bytes: np.ndarray
    avg_fs_k: np.ndarray
    conc: np.ndarray
    par: np.ndarray
    cap_k: np.ndarray
    fsdt: np.ndarray
    sc_order: np.ndarray
    open_n: np.ndarray
    visit_rank: np.ndarray

    @property
    def n_rows(self) -> int:
        return len(self.names)

    def __len__(self) -> int:
        return self.n_rows

    def take(self, rows: Sequence[int]) -> "ScenarioPlan":
        idx = np.asarray(list(rows), dtype=np.int64)
        pick = lambda seq: [seq[int(i)] for i in idx]  # noqa: E731
        return ScenarioPlan(
            K=self.K,
            networks=self.networks,
            qsizes=self.qsizes,
            names=pick(self.names),
            sched_refs=pick(self.sched_refs),
            chunk_refs=pick(self.chunk_refs),
            fabrics=pick(self.fabrics),
            **{
                f.name: getattr(self, f.name)[idx]
                for f in dataclasses.fields(self)
                if f.name
                not in (
                    "K", "networks", "qsizes", "names", "sched_refs",
                    "chunk_refs", "fabrics",
                )
            },
        )

    # ------------------------------------------------------------------ #
    # chunking keys for the matrix runner (vectorized twins of
    # runner._cost_proxy / runner.shape_hint — same values, no file I/O)
    # ------------------------------------------------------------------ #

    def cost_proxy(self) -> np.ndarray:
        """Vectorized :func:`repro.eval.runner.cost_estimate` over the
        rows (bit-identical: same FP operation order on the same
        doubles), so plan-ordered chunks match the legacy ordering."""
        nets = self.networks
        bw = np.array([n.bandwidth for n in nets], dtype=np.float64)
        sr = np.array(
            [n.disk.streaming_rate for n in nets], dtype=np.float64
        )
        crc4 = np.array(
            [netmodel.channel_rate_cap(n, 4) for n in nets],
            dtype=np.float64,
        )
        ni = self.net_idx
        est = np.minimum(
            np.minimum(bw[ni], sr[ni]),
            np.maximum(1, self.eff_cc) * crc4[ni],
        )
        duration = self.total_bytes / np.maximum(est, 1.0)
        return duration / np.maximum(self.tick_period, 1e-9) + self.n_files

    def shape_hints(self) -> List[int]:
        """Chunk-grouping keys for shape-homogeneous batches.

        Two refinements over the legacy ``runner.shape_hint`` (which
        buckets the worst-case channel axis alone):

        * the capacity bucket is floored at :data:`PLAN_C_FLOOR` — the
          driver pads every plan chunk's channel axis to at least that,
          so merging the tiny-cc buckets into one group costs nothing
          and halves the distinct compiled ``C`` values;
        * rows on profiled (time-varying) networks form ONE trailing
          group regardless of capacity (the driver floors their channel
          axis at :data:`PLAN_PROFILED_C_FLOOR`): one scattered profiled
          row widens its whole chunk's bandwidth-profile axis to the
          B=16 pad, so letting the cost sort deal them everywhere used
          to mint a ``B=16`` twin of nearly every ``(rows, C)`` program.
        """
        plens = np.array(
            [
                len(getattr(n, "bandwidth_profile", None) or ((0.0, 1.0),))
                for n in self.networks
            ],
            dtype=np.int64,
        )[self.net_idx]
        return [
            _PROFILED_HINT if p > 1 else int(bucket(int(c), PLAN_C_FLOOR))
            for c, p in zip(self.eff_cc, plens)
        ]


# ---------------------------------------------------------------------- #
# plan construction
# ---------------------------------------------------------------------- #


def plan_supported(scenarios: Sequence) -> bool:
    """True when every scenario's algorithm has a columnar ingest."""
    return all(
        sc.algorithm.lower() in PLAN_ALGORITHMS for sc in scenarios
    )


def _effective_chunks(algorithm: str, num_chunks: int) -> int:
    # static/globus/untuned run one merged ALL chunk regardless of the
    # scenario's num_chunks (globus/untuned partition with num_chunks=1
    # and the one-chunk schedulers re-merge; static never partitions)
    return 1 if algorithm in ("static", "globus", "untuned") else num_chunks


def _build_context(
    sc, net_idx: int, network: NetworkSpec, eff_chunks: int,
    size_chunks: List[np.ndarray],
    qsizes_len: int,
) -> Tuple[_Context, int]:
    """Partition one context's file set with array ops and append its
    sizes (chunk-major, file order preserved) to the flat buffer."""
    from ..scenarios import _build_files_cached

    files = _build_files_cached(sc.dataset, sc.dataset_seed)
    fsizes = np.array([f.size for f in files], dtype=np.int64)
    thresholds = np.asarray(
        size_thresholds(network.bandwidth, eff_chunks), dtype=np.float64
    )
    # classify(size, thr) == first i with size <= thr[i]: exactly
    # searchsorted-left over the ascending thresholds
    cls_idx = np.searchsorted(thresholds, fsizes, side="left")
    labels = _CLASS_LABELS[eff_chunks]
    qoff: List[int] = []
    qlen: List[int] = []
    totals: List[int] = []
    ctypes: List[int] = []
    refs: List[_NameRef] = []
    off = qsizes_len
    for ci, label in enumerate(labels):
        members = np.flatnonzero(cls_idx == ci)
        if members.size == 0:
            continue  # empty size classes are dropped (Sec. 4.1)
        csizes = fsizes[members]
        size_chunks.append(csizes.astype(np.float64))
        qoff.append(off)
        qlen.append(int(members.size))
        totals.append(int(csizes.sum()))
        ctypes.append(int(label))
        refs.append(_chunk_ref(label))
        off += int(members.size)
    total_all = int(fsizes.sum())
    ctx = _Context(
        net_idx=net_idx,
        n_chunks=len(qlen),
        chunk_refs=tuple(refs),
        total_bytes=total_all,
        n_files=len(files),
        globus_avg=total_all / len(files) if files else 1.0,
        qoff=np.array(qoff, dtype=np.int64),
        qlen=np.array(qlen, dtype=np.int64),
        chunk_total=np.array(totals, dtype=np.int64),
        ctype=np.array(ctypes, dtype=np.int64),
    )
    return ctx, off


#: shared ChunkType name refs (every context's "SMALL" is the same object)
_CHUNK_REFS: Dict[int, _NameRef] = {}


def _chunk_ref(label: ChunkType) -> _NameRef:
    ref = _CHUNK_REFS.get(int(label))
    if ref is None:
        ref = _CHUNK_REFS[int(label)] = _NameRef(ChunkType(label).name)
    return ref


_SCHED_NAME_OF = {
    _KIND_SC: "SC",
    _KIND_MC: "MC",
    _KIND_PROMC: "ProMC",
}


def build_plan(scenarios: Sequence) -> ScenarioPlan:
    """Vectorized ingest of ``scenarios`` into a :class:`ScenarioPlan`.

    One context build per unique ``(network, dataset, dataset_seed,
    effective_chunks)``; everything per-row is (S,)/(S,K) array math.
    """
    S = len(scenarios)
    ops = numpy_ops()

    networks: List[NetworkSpec] = []
    net_of: Dict[str, int] = {}
    contexts: List[_Context] = []
    ctx_of: Dict[tuple, int] = {}
    size_chunks: List[np.ndarray] = []
    qsizes_len = 0
    sched_ref_of: Dict[str, _NameRef] = {}
    seed_cache: Dict[Tuple[str, int], int] = {}

    ctx_idx = np.zeros(S, dtype=np.int64)
    net_idx = np.zeros(S, dtype=np.int64)
    kind = np.zeros(S, dtype=np.int64)
    max_cc = np.zeros(S, dtype=np.int64)
    eff_cc = np.zeros(S, dtype=np.int64)
    tick_period = np.zeros(S, dtype=np.float64)
    record_timeline = np.zeros(S, dtype=bool)
    sp_pp = np.zeros(S, dtype=np.int64)
    sp_p = np.ones(S, dtype=np.int64)
    sp_cc = np.ones(S, dtype=np.int64)
    names: List[str] = [""] * S
    sched_refs: List[Optional[_NameRef]] = [None] * S
    chunk_refs: List[tuple] = [()] * S
    fabrics: List = [None] * S

    for i, sc in enumerate(scenarios):
        alg = sc.algorithm.lower()
        if alg not in PLAN_ALGORITHMS:
            raise ValueError(
                f"no columnar ingest for algorithm {sc.algorithm!r}; "
                "use the legacy object path"
            )
        nkey = sc.network
        n = net_of.get(nkey)
        if n is None:
            n = net_of[nkey] = len(networks)
            networks.append(testbeds.TESTBEDS[nkey])
        skey = (sc.dataset, sc.seed)
        dseed = seed_cache.get(skey)
        if dseed is None:
            dseed = seed_cache[skey] = sc.dataset_seed
        eff_k = _effective_chunks(alg, sc.num_chunks)
        ckey = (nkey, sc.dataset, dseed, eff_k)
        c = ctx_of.get(ckey)
        if c is None:
            ctx, qsizes_len = _build_context(
                sc, n, networks[n], eff_k, size_chunks, qsizes_len
            )
            c = ctx_of[ckey] = len(contexts)
            contexts.append(ctx)
        ctx_idx[i] = c
        net_idx[i] = n
        kd = _KIND_OF[alg]
        kind[i] = kd
        max_cc[i] = sc.max_cc
        tick_period[i] = sc.tick_period
        record_timeline[i] = sc.record_timeline
        names[i] = sc.name
        chunk_refs[i] = contexts[c].chunk_refs
        fabrics[i] = getattr(sc, "shared_fabric", None)
        if alg == "static":
            pp, p, cc = sc.static_params
            sp_pp[i], sp_p[i], sp_cc[i] = pp, p, cc
            eff_cc[i] = cc
            sname = f"Static(pp={pp},p={p},cc={cc})"
        elif alg == "untuned":
            sp_pp[i], sp_p[i], sp_cc[i] = 0, 1, 1
            eff_cc[i] = sc.max_cc
            sname = "Untuned"
        elif alg == "globus":
            avg = contexts[c].globus_avg
            gi = 0 if avg < 50 * MB else (1 if avg <= 250 * MB else 2)
            sp_pp[i] = _GLOBUS_PP[gi]
            sp_p[i] = _GLOBUS_P[gi]
            sp_cc[i] = _GLOBUS_CC[gi]
            eff_cc[i] = sc.max_cc
            sname = "GlobusOnline"
        else:
            eff_cc[i] = sc.max_cc
            sname = _SCHED_NAME_OF[kd]
        ref = sched_ref_of.get(sname)
        if ref is None:
            ref = sched_ref_of[sname] = _NameRef(sname)
        sched_refs[i] = ref

    qsizes = (
        np.concatenate(size_chunks)
        if size_chunks
        else np.zeros(0, dtype=np.float64)
    )

    # ---- context tables, padded to the shared chunk width K ---------- #
    n_ctx = len(contexts)
    K = bucket(max((c.n_chunks for c in contexts), default=1))
    c_qoff = np.zeros((n_ctx, K), dtype=np.int64)
    c_qlen = np.zeros((n_ctx, K), dtype=np.int64)
    c_total = np.zeros((n_ctx, K), dtype=np.int64)
    c_ctype = np.full((n_ctx, K), _PAD_CTYPE, dtype=np.int64)
    c_nk = np.zeros(n_ctx, dtype=np.int64)
    for j, ctx in enumerate(contexts):
        nk = ctx.n_chunks
        c_qoff[j, :nk] = ctx.qoff
        c_qlen[j, :nk] = ctx.qlen
        c_total[j, :nk] = ctx.chunk_total
        c_ctype[j, :nk] = ctx.ctype
        c_nk[j] = nk
    c_nonempty = np.arange(K)[None, :] < c_nk[:, None]
    # clamped per-chunk average file size (pads hold the neutral 1.0)
    c_avg = np.ones((n_ctx, K), dtype=np.float64)
    real = c_nonempty
    c_avg[real] = np.maximum(
        c_total[real].astype(np.float64) / c_qlen[real].astype(np.float64),
        1.0,
    )
    # SC transfer order over padded ctypes (pads sort last), tail zeroed
    # exactly as the legacy packing loop leaves it
    c_order = sc_chunk_order(ops, c_ctype)
    c_order = np.where(c_nonempty, c_order, 0)
    # MC round-robin rank / ProMC delta weight per chunk
    safe_ct = np.where(c_nonempty, c_ctype, 0)
    c_rank = np.where(c_nonempty, _RR_RANK_BY_CT[safe_ct], _PAD_RANK)
    c_weight = np.where(
        c_nonempty,
        _DELTA_BY_CT[safe_ct].astype(np.float64)
        * c_total.astype(np.float64),
        0.0,
    )

    # ---- gather context columns to rows ------------------------------ #
    qoff = c_qoff[ctx_idx]
    qlen = c_qlen[ctx_idx]
    queue_bytes = c_total[ctx_idx].astype(np.float64)
    avg_fs_k = c_avg[ctx_idx]
    sc_order = c_order[ctx_idx]
    nonempty = c_nonempty[ctx_idx]
    n_chunks = c_nk[ctx_idx]
    rank = c_rank[ctx_idx]
    weight = c_weight[ctx_idx]
    total_bytes = np.array(
        [float(contexts[c].total_bytes) for c in ctx_idx], dtype=np.float64
    )
    n_files = np.array(
        [contexts[c].n_files for c in ctx_idx], dtype=np.int64
    )

    # ---- per-row network scalars ------------------------------------- #
    bdp = np.array([n.bdp for n in networks], dtype=np.float64)[net_idx]
    buf = np.array(
        [n.buffer_size for n in networks], dtype=np.float64
    )[net_idx]
    crtt = np.array(
        [
            n.control_rtt if n.control_rtt is not None else n.rtt
            for n in networks
        ],
        dtype=np.float64,
    )[net_idx]
    unhidden = np.array(
        [n.unhidden_overhead for n in networks], dtype=np.float64
    )[net_idx]
    pfo = np.array(
        [n.disk.per_file_overhead for n in networks], dtype=np.float64
    )[net_idx]
    # per-stream window rate and disk lane, computed per network with the
    # exact scalar expressions (types.NetworkSpec.stream_rate_cap /
    # DiskSpec.channel_lane) so the vectorized caps match bit for bit
    per_stream = np.array(
        [
            n.window_efficiency * n.buffer_size / max(n.rtt, 1e-9)
            for n in networks
        ],
        dtype=np.float64,
    )[net_idx]
    lane = np.array(
        [n.disk.channel_lane for n in networks], dtype=np.float64
    )[net_idx]
    msc = np.array(
        [n.max_streams_per_channel for n in networks], dtype=np.int64
    )[net_idx]
    sco = np.array(
        [n.stream_cpu_overhead for n in networks], dtype=np.float64
    )[net_idx]
    bw = np.array(
        [n.bandwidth for n in networks], dtype=np.float64
    )[net_idx]

    # ---- Algorithm 1 over every (row, chunk) at once ----------------- #
    pp, par, conc = optimal_params(
        ops,
        avg_fs_k,
        bdp[:, None],
        buf[:, None],
        max_cc[:, None].astype(np.float64),
        qlen,
        MAX_PIPELINING,
    )
    # static-parameter family (static candidates, Globus presets,
    # untuned defaults): one merged chunk driven by the row triple
    static_like = kind <= _KIND_STATIC
    pp = np.where(static_like[:, None], sp_pp[:, None], pp)
    par = np.where(static_like[:, None], sp_p[:, None], par)
    conc = np.where(static_like[:, None], sp_cc[:, None], conc)
    # pad slots: born-done chunks keep the legacy constructor's zeros
    pp = np.where(nonempty, pp, 0)
    par = np.where(nonempty, par, 1)
    conc = np.where(nonempty, conc, 0)

    # serial per-file dead time (netmodel.file_start_dead_time, same
    # left-to-right FP order: gap + unhidden + per-file disk overhead)
    gap = crtt[:, None] / (1.0 + pp.astype(np.float64))
    fsdt = np.where(
        nonempty, gap + unhidden[:, None] + pfo[:, None], 0.0
    )
    # channel rate cap (netmodel.channel_rate_cap == min(stream cap,
    # disk lane); stream cap per types.NetworkSpec.stream_rate_cap)
    p_eff = np.maximum(1, np.minimum(par, msc[:, None]))
    stream_eff = 1.0 / (1.0 + sco[:, None] * (p_eff - 1))
    stream_cap = np.minimum(
        p_eff * per_stream[:, None] * stream_eff, bw[:, None]
    )
    cap_k = np.where(nonempty, np.minimum(stream_cap, lane[:, None]), 0.0)

    # ---- initial channel allocation per controller kind -------------- #
    arangeK = np.arange(K)[None, :]
    # SC: one Open at the first chunk of the transfer order
    first = sc_order[:, :1]
    open_sc = np.where(
        arangeK == first, np.take_along_axis(conc, first, axis=1), 0
    )
    # MC: Alg.-2 round-robin split of maxCC over the service order
    open_mc = round_robin_alloc(ops, rank, nonempty, max_cc)
    # MC opens chunk by chunk in service order (rank, index): that order
    # is the channel-column layout contract (kernels.compact_channels)
    key = rank * K + arangeK
    rank_mc = np.sum(key[:, None, :] < key[:, :, None], axis=2)
    # ProMC: Alg.-3 delta-weighted split, opened in ascending chunk index
    open_promc = weighted_alloc(ops, weight, nonempty, max_cc, trim_iters=K)
    # static family: Open(chunk=0, n=cc)
    open_static = np.where(arangeK == 0, conc, 0)

    is_sc = kind == _KIND_SC
    is_mc = kind == _KIND_MC
    is_promc = kind == _KIND_PROMC
    # the legacy constructor populates sc_order only for SC rows (other
    # kinds never read it); keep the zeros for bit-identity
    sc_order = np.where(is_sc[:, None], sc_order, 0)
    open_n = np.where(
        is_sc[:, None],
        open_sc,
        np.where(
            is_mc[:, None],
            open_mc,
            np.where(is_promc[:, None], open_promc, open_static),
        ),
    ).astype(np.int64)
    visit_rank = np.where(
        is_mc[:, None], rank_mc, np.broadcast_to(arangeK, (S, K))
    ).astype(np.int64)

    # ---- closed-form capacity bound (driver._worst_case_channels) ---- #
    conc_real = np.where(nonempty, conc, 0)
    cap_sc = np.maximum(1, conc_real.max(axis=1, initial=0))
    cap_mc = np.maximum(np.maximum(1, max_cc), n_chunks)
    cap_static = np.maximum(1, conc_real.sum(axis=1))
    # coupled SC rows advance on the group horizon, so cursor-advancing
    # completion ties can co-schedule every wave: widen to the full
    # concurrency sum (mirrors driver._worst_case_channels exactly)
    coupled_row = np.array([f is not None for f in fabrics], dtype=bool)
    cap_sc = np.where(coupled_row, cap_static, cap_sc)
    cap_need = np.where(
        is_sc, cap_sc, np.where(is_mc | is_promc, cap_mc, cap_static)
    ).astype(np.int64)

    trivial = np.array([_TRIVIAL_OF[int(k)] for k in kind], dtype=bool)

    return ScenarioPlan(
        K=K,
        networks=networks,
        qsizes=qsizes,
        names=names,
        sched_refs=sched_refs,  # type: ignore[arg-type]
        chunk_refs=chunk_refs,
        fabrics=fabrics,
        net_idx=net_idx,
        kind=kind,
        trivial_tick=trivial[:, 0] if S else np.zeros(0, dtype=bool),
        trivial_complete=trivial[:, 1] if S else np.zeros(0, dtype=bool),
        tick_period=tick_period,
        record_timeline=record_timeline,
        max_cc=max_cc,
        eff_cc=eff_cc,
        total_bytes=total_bytes,
        n_files=n_files,
        n_chunks=n_chunks,
        cap_need=cap_need,
        qoff=qoff,
        qlen=qlen,
        queue_bytes=queue_bytes,
        avg_fs_k=avg_fs_k,
        conc=conc,
        par=par,
        cap_k=cap_k,
        fsdt=fsdt,
        sc_order=sc_order,
        open_n=open_n,
        visit_rank=visit_rank,
    )
