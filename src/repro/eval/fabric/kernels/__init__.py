"""Backend-neutral array kernels of the fluid transfer model.

Every function takes an :class:`repro.eval.fabric.shim.ArrayOps` first and
treats channel (C) / chunk (K) structure as the trailing axes, so one
definition serves both the batched NumPy driver (leading scenario axis S)
and the JAX backend (no leading axis; ``vmap`` supplies it). The scalar
references these mirror live in ``core.netmodel`` (water-filling, dead
time) and :mod:`repro.eval.fabric.reference` (horizon, tick EMA);
``tests/test_fabric_kernels.py`` pins the correspondence on random inputs.

Nothing here may import from ``repro.core`` — ``core.netmodel`` re-exports
:func:`waterfill_batch` from this module and a core import would cycle.
"""
from __future__ import annotations

from ..shim import NO_CHUNK, ArrayOps, numpy_ops

_EPS = 1e-12


def waterfill(ops: ArrayOps, caps, pool):
    """Max-min fair allocation of ``pool`` across entities capped at ``caps``.

    ``caps``: (..., C) per-entity rate ceilings — absent/idle channels must
    carry 0 (a zero cap allocates zero, exactly like being excluded).
    ``pool``: (...,). Returns (..., C) allocations.

    Closed form of max-min fairness with ceilings: every entity gets
    ``min(cap, lam)`` for the water level ``lam`` solving
    ``sum_i min(cap_i, lam) = min(pool, sum_i cap_i)`` — the fixpoint the
    scalar progressive-filling loop (``netmodel.waterfill``) converges to,
    found here by sorting each row once instead of iterating.
    """
    xp = ops.xp
    C = caps.shape[-1]
    if C == 0:
        return xp.zeros_like(caps)
    caps_sorted = xp.sort(caps, axis=-1)
    prefix = xp.cumsum(caps_sorted, axis=-1)
    pool_eff = xp.clip(xp.minimum(pool, prefix[..., -1]), 0.0, None)
    # candidate level if the k smallest caps are filled outright:
    #   lam_k = (pool_eff - prefix[k-1]) / (C - k); valid when lam_k <= c_(k)
    prev = xp.concatenate(
        [xp.zeros_like(prefix[..., :1]), prefix[..., :-1]], axis=-1
    )
    denom = (C - xp.arange(C)).astype(caps_sorted.dtype)
    lam_k = (pool_eff[..., None] - prev) / denom
    valid = lam_k <= caps_sorted + 1e-9 * xp.maximum(caps_sorted, 1.0)
    # rows with pool >= sum(caps) have every candidate invalid except the
    # last; argmax picks the first valid k
    k = xp.argmax(valid, axis=-1)
    no_valid = ~xp.any(valid, axis=-1)
    lam = ops.table_lookup(lam_k, k[..., None])[..., 0]
    lam = xp.where(no_valid, caps_sorted[..., -1], lam)
    return xp.minimum(caps, lam[..., None])


def waterfill_batch(caps, pool):
    """NumPy instantiation of :func:`waterfill` over (S, C) scenario rows.

    Re-exported by ``core.netmodel`` as the batched form of its scalar
    ``waterfill`` reference.
    """
    import numpy as np

    caps = np.asarray(caps, dtype=np.float64)
    pool = np.asarray(pool, dtype=np.float64)
    return waterfill(numpy_ops(), caps, pool)


def waterfill_level(ops: ArrayOps, caps, pool):
    """The water level ``lam`` of :func:`waterfill`, not the allocation.

    ``caps``: (..., C) per-entity ceilings (idle entities carry 0);
    ``pool``: (...,). Returns (...,): the level solving
    ``sum_i min(cap_i, lam) = pool`` when the pool binds, and ``+inf``
    when it does not (``pool >= sum(caps)`` — every entity takes its full
    cap and the constraint is slack). The ``+inf`` convention is what the
    coupled water-fill needs: an unsaturated link imposes no ceiling on
    its members.
    """
    xp = ops.xp
    inf = float("inf")
    C = caps.shape[-1]
    if C == 0:
        return pool * 0.0 + inf
    caps_sorted = xp.sort(caps, axis=-1)
    prefix = xp.cumsum(caps_sorted, axis=-1)
    pool_eff = xp.clip(xp.minimum(pool, prefix[..., -1]), 0.0, None)
    prev = xp.concatenate(
        [xp.zeros_like(prefix[..., :1]), prefix[..., :-1]], axis=-1
    )
    denom = (C - xp.arange(C)).astype(caps_sorted.dtype)
    lam_k = (pool_eff[..., None] - prev) / denom
    valid = lam_k <= caps_sorted + 1e-9 * xp.maximum(caps_sorted, 1.0)
    k = xp.argmax(valid, axis=-1)
    no_valid = ~xp.any(valid, axis=-1)
    lam = ops.table_lookup(lam_k, k[..., None])[..., 0]
    lam = xp.where(no_valid, caps_sorted[..., -1], lam)
    return xp.where(pool >= prefix[..., -1], inf, lam)


#: fixed Jacobi sweep count of :func:`waterfill_coupled`. Constraint
#: information propagates one link-sharing hop per sweep, so this bounds
#: the fabric-graph diameter the relaxation resolves exactly; tenant
#: groups use 1-4 links, and the same constant on every backend keeps
#: event / NumPy / JAX allocations bit-aligned by construction.
COUPLED_ITERS = 12


def waterfill_coupled(ops: ArrayOps, demand, member, link_cap):
    """Max-min fair share *across* scenario rows coupled by shared links.

    Two-level fairness: each backbone link grants tenant-level max-min
    fair shares (a per-link water level), and each row then water-fills
    its grant across its own channels (:func:`waterfill`, done by the
    caller). ``demand``: (R,) per-row offered load (the rate the row
    could use this sweep — ``min(pool, sum of transferring caps)``);
    ``member``: (L, R) boolean link membership; ``link_cap``: (L,)
    capacities. Returns ``(x, levels)``: the (R,) per-row grant
    ``x_r = min(d_r, min over member links of level_l)`` and the (L,)
    final levels (``+inf`` on unsaturated links).

    Solved by Jacobi relaxation on the per-link levels: each sweep
    re-solves every link's single-link level (:func:`waterfill_level`)
    with members capped at ``min(demand, best level among the row's
    *other* links)``, starting from all-unsaturated. Fixed
    :data:`COUPLED_ITERS` sweeps on every backend — the fixpoint is the
    bottleneck-link characterization of progressive filling
    (``reference.coupled_fair_share``), and a fixed count keeps the
    computation identical across event / NumPy / JAX.

    Rows with no link membership pass through: ``x_r = demand_r``.
    """
    xp = ops.xp
    inf = float("inf")
    L = member.shape[0]
    if L == 0:
        return demand, xp.zeros((0,), dtype=demand.dtype)
    member = member != 0  # accept 0/1 tables
    levels = xp.full((L,), inf)
    # (L, L') exclusion mask: sweep l sees every link but itself
    off_diag = xp.arange(L)[:, None] != xp.arange(L)[None, :]
    for _ in range(COUPLED_ITERS):
        lvl_mat = xp.where(member, levels[:, None], inf)  # (L, R)
        # min over the row's OTHER links: (L, L', R) -> (L, R)
        excl = xp.min(
            xp.where(off_diag[:, :, None], lvl_mat[None, :, :], inf),
            axis=1,
        )
        caps = xp.where(member, xp.minimum(demand[None, :], excl), 0.0)
        levels = waterfill_level(ops, caps, link_cap)
    row_lvl = xp.min(xp.where(member, levels[:, None], inf), axis=0)
    return xp.minimum(demand, row_lvl), levels


def caps_total(ops: ArrayOps, caps):
    """Per-row cap total via the *same* sorted prefix sum ``waterfill``
    uses internally (not ``xp.sum``, whose pairwise accumulation can
    differ in the last ulp). The coupled drivers form a row's offered
    load as ``min(pool, caps_total)``; matching the summation order makes
    ``waterfill(caps, min(pool, caps_total))`` bit-identical to
    ``waterfill(caps, pool)`` — the single-tenant/unsaturated identity
    the coupled path's difftests pin.
    """
    xp = ops.xp
    C = caps.shape[-1]
    if C == 0:
        return xp.zeros(caps.shape[:-1], dtype=caps.dtype)
    return xp.cumsum(xp.sort(caps, axis=-1), axis=-1)[..., -1]


def disk_pool(
    ops: ArrayOps, n_transferring, bandwidth, disk_rate, saturation_cc,
    contention,
):
    """Shared rate pool: link capacity vs disk aggregate under contention.

    Mirrors ``netmodel.allocate_rates``'s pool =
    ``min(bandwidth, disk.aggregate_rate(active))`` with the DiskSpec
    contention penalty past saturation; 0 when nothing transfers.
    """
    xp = ops.xp
    over_sat = xp.maximum(0, n_transferring - saturation_cc)
    agg_disk = disk_rate / (1.0 + contention * over_sat)
    return xp.where(
        n_transferring > 0, xp.minimum(bandwidth, agg_disk), 0.0
    )


def file_dead_time(
    ops: ArrayOps, control_rtt, pipelining, unhidden_overhead,
    per_file_overhead,
):
    """Batched ``netmodel.file_start_dead_time``: serial per-file overhead.

    control gap ``control_rtt/(1+pipelining)`` + server-side processing the
    pipelining cannot hide + per-file disk overhead.
    """
    gap = control_rtt / (1.0 + pipelining)
    return gap + unhidden_overhead + per_file_overhead


def event_horizon(
    ops: ArrayOps, tick_dt, busy, dead, transferring, rem, rates,
    eps: float = _EPS,
):
    """Time to the next state change, capped by the controller tick.

    Batched ``fabric.reference.next_event_dt``: min over dead-time expiries
    and file completions of busy channels, floored at 0.
    """
    xp = ops.xp
    inf = float("inf")
    dead_evt = xp.where(busy & (dead > eps), dead, inf)
    xcond = transferring & (rates > eps)
    xfer_evt = xp.where(xcond, rem, inf) / xp.where(xcond, rates, 1.0)
    dt = xp.minimum(
        tick_dt,
        xp.minimum(xp.min(dead_evt, axis=-1), xp.min(xfer_evt, axis=-1)),
    )
    return xp.maximum(dt, 0.0)


def advance_channels(
    ops: ArrayOps, active, dt, busy, dead, transferring, rem, rates,
    eps: float = _EPS,
):
    """Advance channel state by ``dt``: burn dead time, move fluid bytes.

    ``active`` (...,) masks scenarios that advance this sweep. Returns
    ``(busy, dead, rem, moved, finished)`` — ``moved`` is the per-channel
    byte delta (0 on inactive rows), ``finished`` the channels that
    completed their file.
    """
    xp = ops.xp
    a = xp.expand_dims(active, -1)
    dtc = xp.expand_dims(dt, -1)
    in_dead = busy & (dead > eps) & a
    dead2 = xp.where(in_dead, xp.maximum(0.0, dead - dtc), dead)
    moving = transferring & (rates > eps) & a
    moved = xp.where(moving, xp.minimum(rem, rates * dtc), 0.0)
    rem2 = rem - moved
    finished = transferring & a & (rem2 <= eps)
    busy2 = busy & ~finished
    rem3 = xp.where(finished, 0.0, rem2)
    return busy2, dead2, rem3, moved, finished


def tick_ema(ops: ArrayOps, rate_est, delivered, delivered_at_tick, period):
    """Batched ``fabric.reference.tick_rate_update`` over chunk slots."""
    xp = ops.xp
    inst = (delivered - delivered_at_tick) / period
    return xp.where(rate_est == 0.0, inst, 0.5 * rate_est + 0.5 * inst)


def compact_channels(ops: ArrayOps, trig, chunk_of, busy, dead, rem, cap):
    """Left-pack the channel axis: open channels shift to the lowest
    columns *preserving their relative order*, freed columns collect at
    the tail (empty state). Applied after every close so that column
    order stays the event simulator's channel-*list* order — the scalar
    loop removes closed channels from its list and appends opens at the
    end, and both the feed ranking and idle-victim selection key on that
    order. ``trig`` (...,) gates rows (others pass through untouched).

    Returns ``(chunk_of, busy, dead, rem, cap)``.
    """
    xp = ops.xp
    C = chunk_of.shape[-1]
    is_open = chunk_of != NO_CHUNK
    # destination of each open column = its rank among open columns
    dest = xp.cumsum(is_open, axis=-1) - 1
    # (..., C_src, C_dst) one-hot routing: source c lands at dest[c]
    route = (
        is_open[..., :, None]
        & (dest[..., :, None] == xp.arange(C))
        & xp.expand_dims(trig, -1)[..., :, None]
    )

    def pack(arr, empty):
        if arr.dtype == bool:
            out = xp.any(route & arr[..., :, None], axis=-2)
        else:
            packed = xp.sum(
                xp.where(route, arr[..., :, None], arr.dtype.type(0)),
                axis=-2,
            )
            filled = xp.any(route, axis=-2)
            out = xp.where(filled, packed, arr.dtype.type(empty))
        return xp.where(xp.expand_dims(trig, -1), out, arr)

    return (
        pack(chunk_of, NO_CHUNK),
        pack(busy, False),
        pack(dead, 0.0),
        pack(rem, 0.0),
        pack(cap, 0.0),
    )


def timeline_push(
    ops: ArrayOps, rec, t, rate, buf_t, buf_r, length, stride, seen,
    last_t, last_r,
):
    """Streaming append into the on-device timeline ring buffer with
    uniform-stride decimation.

    The buffer holds every candidate sample whose index is a multiple of
    ``stride`` (so it is always a uniform-stride decimation of the full
    timeline, first sample included). When a store would overflow the
    fixed budget ``T = buf_t.shape[-1]``, the buffer is compacted in
    place — keep every other stored sample — and the stride doubles, so
    the budget amortizes over arbitrarily long runs. ``rec`` (...,)
    gates which rows record this sweep; ``seen`` counts candidates so
    far; ``last_t``/``last_r`` always track the newest candidate (the
    host finalize, :func:`timeline_samples`, re-attaches the final
    sample when decimation dropped it).

    Pure selects and integer bookkeeping — no float arithmetic — so the
    NumPy and JAX instantiations record bit-identically given the same
    sample stream. Returns the seven updated arrays in argument order.
    """
    xp = ops.xp
    T = buf_t.shape[-1]
    stride_safe = xp.maximum(stride, 1)  # padded device rows carry 0
    want = rec & (seen % stride_safe == 0)
    full = want & (length >= T)
    # stride-2 compaction: storage position j keeps old position 2j
    half = (T + 1) // 2
    comp_t = xp.concatenate(
        [buf_t[..., 0::2], xp.zeros_like(buf_t[..., : T - half])], axis=-1
    )
    comp_r = xp.concatenate(
        [buf_r[..., 0::2], xp.zeros_like(buf_r[..., : T - half])], axis=-1
    )
    full_e = xp.expand_dims(full, -1)
    buf_t = xp.where(full_e, comp_t, buf_t)
    buf_r = xp.where(full_e, comp_r, buf_r)
    length = xp.where(full, (length + 1) // 2, length)
    stride = xp.where(full, stride_safe * 2, stride)
    # re-check under the (possibly doubled) stride
    store = rec & (seen % xp.maximum(stride, 1) == 0) & (length < T)
    at = xp.arange(T) == xp.expand_dims(length, -1)
    store_e = xp.expand_dims(store, -1)
    buf_t = xp.where(store_e & at, xp.expand_dims(t, -1), buf_t)
    buf_r = xp.where(store_e & at, xp.expand_dims(rate, -1), buf_r)
    length = length + xp.where(store, 1, 0)
    seen = seen + xp.where(rec, 1, 0)
    last_t = xp.where(rec, t, last_t)
    last_r = xp.where(rec, rate, last_r)
    return buf_t, buf_r, length, stride, seen, last_t, last_r


def timeline_samples(buf_t, buf_r, length, stride, seen, last_t, last_r):
    """Finalize one scenario's recorded timeline (host side, 1-D rows).

    Returns the stored ``(t, rate)`` samples plus the *last* candidate
    sample when decimation dropped it (appended while the budget allows,
    else replacing the final stored slot) — so first and last samples
    are always preserved and the result never exceeds the budget.
    """
    n, s, seen = int(length), max(int(stride), 1), int(seen)
    out = [(float(buf_t[j]), float(buf_r[j])) for j in range(n)]
    if seen > 0 and (seen - 1) % s != 0:
        final = (float(last_t), float(last_r))
        if n < buf_t.shape[-1]:
            out.append(final)
        else:
            out[-1] = final
    return out


def feed_queues(
    ops: ArrayOps, enabled, chunk_of, busy, dead, rem, qsizes, qoff, qlen,
    qptr, queue_bytes, fsdt, prepend_sizes=None, prepend_n=None,
):
    """Idle open channels pull the next file of their chunk: resume files
    off the LIFO prepend stack first, then the FIFO queue.

    Channels of one chunk are interchangeable (same params). Ranking the
    chunk's idle channels in column order, the channel of rank ``r`` takes
    the resume file at stack depth ``prepend_n - 1 - r`` while ``r <
    prepend_n`` (deque.appendleft/popleft order), and the queued file at
    ``qptr + r - prepend_n`` afterwards — byte-for-byte the assignment the
    scalar feed loop produces. ``enabled`` (...,) gates whole scenarios.
    ``prepend_sizes`` (..., K, P) / ``prepend_n`` (..., K) may be omitted
    when no resume files can exist (pure-FIFO callers/tests).

    Returns ``(busy, dead, rem, qptr, queue_bytes, prepend_n)``.
    """
    xp = ops.xp
    K = qptr.shape[-1]
    if prepend_n is None:
        prepend_n = xp.zeros(qptr.shape, dtype=qptr.dtype)
    if qsizes.shape[0] == 0 and prepend_sizes is None:
        # no files anywhere: nothing can feed
        return busy, dead, rem, qptr, queue_bytes, prepend_n
    open_oh = chunk_of[..., :, None] == xp.arange(K)  # NO_CHUNK matches none
    idle = (chunk_of >= 0) & ~busy & xp.expand_dims(enabled, -1)
    incl = open_oh & idle[..., :, None]
    # rank of each idle channel within its (scenario, chunk) group, in
    # channel order: inclusive cumsum down the channel axis, gathered at
    # the channel's own chunk column
    cum = xp.cumsum(incl, axis=-2)
    rank = xp.sum(xp.where(incl, cum, 0), axis=-1) - 1  # -1 when not idle
    # chunk-indexed gathers; junk values on unassigned channels are
    # harmless because ``valid`` requires ``idle`` (=> assigned)
    ch_clip = xp.clip(chunk_of, 0, K - 1)
    qptr_c = ops.table_lookup(qptr, ch_clip)
    qlen_c = ops.table_lookup(qlen, ch_clip)
    qoff_c = ops.table_lookup(qoff, ch_clip)
    fsdt_c = ops.table_lookup(fsdt, ch_clip)

    if prepend_sizes is not None:
        pn_c = ops.table_lookup(prepend_n, ch_clip)
        use_pre = idle & (rank >= 0) & (rank < pn_c)
        P = prepend_sizes.shape[-1]
        ps_flat = xp.reshape(
            prepend_sizes, prepend_sizes.shape[:-2] + (K * P,)
        )
        pidx = ch_clip * P + xp.clip(pn_c - 1 - rank, 0, P - 1)
        # a real gather, not the one-hot table_lookup: the stack axis is
        # pre-sized from the worst-case bound (P up to ~2x the channel
        # axis), so a one-hot here would cost O(C*K*P) on every
        # stack-path sweep; C scalar loads are cheaper on both backends
        pre_sz = xp.take_along_axis(ps_flat, pidx, axis=-1)
    else:
        # pure-FIFO fast path: callers pass None exactly when no resume
        # files exist anywhere, so skip the stack bookkeeping entirely
        pn_c = xp.zeros(rank.shape, dtype=qptr.dtype)
        use_pre = xp.zeros(rank.shape, dtype=bool)
        pre_sz = xp.zeros(rank.shape, dtype=xp.float64)

    fidx = qptr_c + rank - pn_c
    valid_fifo = idle & (rank >= pn_c) & (fidx < qlen_c)
    if qsizes.shape[0] == 0:
        valid_fifo = valid_fifo & False
        fifo_sz = xp.zeros(rank.shape, dtype=xp.float64)
    else:
        flat = xp.clip(qoff_c + fidx, 0, qsizes.shape[0] - 1)
        fifo_sz = xp.take(qsizes, flat)
    valid = use_pre | valid_fifo
    sizes = xp.where(use_pre, pre_sz, xp.where(valid_fifo, fifo_sz, 0.0))
    busy2 = busy | valid
    rem2 = xp.where(valid, sizes, rem)
    dead2 = dead + xp.where(valid, fsdt_c, 0.0)
    # per-chunk counts/sums reuse the one-hot built for ranking; sizes are
    # integer-valued doubles, so the summation order is exact either way
    qptr2 = qptr + xp.sum(open_oh & valid_fifo[..., :, None], axis=-2)
    pn2 = prepend_n - xp.sum(open_oh & use_pre[..., :, None], axis=-2)
    qb2 = queue_bytes - xp.sum(
        xp.where(open_oh & valid[..., :, None], sizes[..., :, None], 0.0),
        axis=-2,
    )
    return busy2, dead2, rem2, qptr2, qb2, pn2
