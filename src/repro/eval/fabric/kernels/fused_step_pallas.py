"""Fused Pallas sweep step: water-fill + horizon + advance + FIFO feed.

One ``pallas_call`` per sweep instead of four kernel launches plus the
intermediate arrays between them: each grid program owns one scenario
row and carries rates -> dt -> byte movement -> queue feed through
registers/VMEM. The water level comes from the same bisection as
:mod:`repro.eval.fabric.kernels.waterfill_pallas` (no in-kernel sort);
everything else mirrors the backend-neutral kernels in
:mod:`repro.eval.fabric.kernels` — ``disk_pool``, ``event_horizon``,
``advance_channels``, and the pure-FIFO branch of ``feed_queues`` —
which remain the semantic reference (``tests/test_fabric_kernels.py``
pins the equivalence).

Scope: the *pure-FIFO* common case. The driver only routes a sweep here
while no resume file exists anywhere in the batch
(``REPRO_FABRIC_FUSED_STEP=pallas`` or ``FabricSimulation(...,
fused_step="pallas")``); sweeps with a live LIFO stack take the
classic split path. Compiled on TPU/GPU, interpreted on CPU, exactly
like the standalone water-fill kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..bucketing import qsizes_pad
from .waterfill_pallas import _BISECT_ITERS, supports_compiled_pallas

_EPS = 1e-12
_INF = float("inf")


def _fused_kernel(
    act_ref, busy_ref, dead_ref, rem_ref, cap_ref, chunk_ref,
    tick_dt_ref, bw_ref, disk_ref, sat_ref, cont_ref,
    qoff_ref, qlen_ref, qptr_ref, qb_ref, fsdt_ref, qsizes_ref,
    dt_ref, rsum_ref, fin_ref, busy_out, dead_out, rem_out, moved_out,
    qptr_out, qb_out,
):
    row = lambda ref: jnp.reshape(ref[...], (-1,))  # (1, W) block -> (W,)
    enabled = act_ref[0]
    busy = row(busy_ref)
    dead = row(dead_ref)
    rem = row(rem_ref)
    chunk_of = row(chunk_ref)
    K = qptr_ref.shape[-1]

    # ---- disk_pool ----
    transferring = busy & (dead <= _EPS)
    n_t = jnp.sum(transferring)
    over = jnp.maximum(0, n_t - sat_ref[0])
    agg_disk = disk_ref[0] / (1.0 + cont_ref[0] * over)
    pool = jnp.where(n_t > 0, jnp.minimum(bw_ref[0], agg_disk), 0.0)

    # ---- water-fill (bisected level, as waterfill_pallas) ----
    caps = jnp.where(transferring, row(cap_ref), 0.0)
    total = jnp.sum(caps)
    pool_eff = jnp.clip(jnp.minimum(pool, total), 0.0, None)
    hi = jnp.max(caps)
    lo = jnp.zeros_like(hi)

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        low = jnp.sum(jnp.minimum(caps, mid)) < pool_eff
        return jnp.where(low, mid, lo), jnp.where(low, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, bisect, (lo, hi))
    rates = jnp.where(enabled, jnp.minimum(caps, hi), 0.0)

    # ---- event_horizon ----
    dead_evt = jnp.where(busy & (dead > _EPS), dead, _INF)
    xcond = transferring & (rates > _EPS)
    xfer_evt = jnp.where(xcond, rem, _INF) / jnp.where(xcond, rates, 1.0)
    dt = jnp.minimum(
        tick_dt_ref[0], jnp.minimum(jnp.min(dead_evt), jnp.min(xfer_evt))
    )
    dt = jnp.where(enabled, jnp.maximum(dt, 0.0), 0.0)

    # ---- advance_channels ----
    in_dead = busy & (dead > _EPS) & enabled
    dead2 = jnp.where(in_dead, jnp.maximum(0.0, dead - dt), dead)
    moving = transferring & (rates > _EPS) & enabled
    moved = jnp.where(moving, jnp.minimum(rem, rates * dt), 0.0)
    rem2 = rem - moved
    finished = transferring & enabled & (rem2 <= _EPS)
    busy2 = busy & ~finished
    rem2 = jnp.where(finished, 0.0, rem2)

    # ---- feed_queues, pure-FIFO branch ----
    open_oh = chunk_of[:, None] == jnp.arange(K)
    idle = (chunk_of >= 0) & ~busy2 & enabled
    incl = open_oh & idle[:, None]
    cum = jnp.cumsum(incl, axis=0)
    rank = jnp.sum(jnp.where(incl, cum, 0), axis=1) - 1
    ch = jnp.clip(chunk_of, 0, K - 1)
    qptr = row(qptr_ref)
    fidx = qptr[ch] + rank
    valid = idle & (rank >= 0) & (fidx < row(qlen_ref)[ch])
    flat = jnp.clip(row(qoff_ref)[ch] + fidx, 0, qsizes_ref.shape[-1] - 1)
    sz = jnp.where(valid, qsizes_ref[...][flat], 0.0)
    busy3 = busy2 | valid
    rem3 = jnp.where(valid, sz, rem2)
    dead3 = dead2 + jnp.where(valid, row(fsdt_ref)[ch], 0.0)
    fed = open_oh & valid[:, None]
    qptr2 = qptr + jnp.sum(fed, axis=0)
    qb2 = row(qb_ref) - jnp.sum(jnp.where(fed, sz[:, None], 0.0), axis=0)

    dt_ref[0] = dt
    rsum_ref[0] = jnp.sum(rates)
    fin_ref[0] = jnp.any(finished)
    busy_out[...] = busy3[None, :]
    dead_out[...] = dead3[None, :]
    rem_out[...] = rem3[None, :]
    moved_out[...] = moved[None, :]
    qptr_out[...] = qptr2[None, :]
    qb_out[...] = qb2[None, :]


@functools.lru_cache(maxsize=64)
def _build_call(S: int, C: int, K: int, Q: int, interpret: bool):
    """One fused ``pallas_call`` per bucketed (S, C, K, Q) signature —
    the canonical pad ladder keeps this a handful of entries."""
    f8, i8 = jnp.float64, jnp.int64
    row = lambda width: pl.BlockSpec((1, width), lambda s: (s, 0))
    scalar = pl.BlockSpec((1,), lambda s: (s,))
    shared = pl.BlockSpec((Q,), lambda s: (0,))
    return pl.pallas_call(
        _fused_kernel,
        grid=(S,),
        in_specs=[
            scalar,                                    # act
            row(C), row(C), row(C), row(C), row(C),    # busy dead rem cap chunk
            scalar, scalar, scalar, scalar, scalar,    # tick_dt bw disk sat cont
            row(K), row(K), row(K), row(K), row(K),    # qoff qlen qptr qb fsdt
            shared,                                    # qsizes
        ],
        out_specs=[
            scalar, scalar, scalar,                    # dt rate_sum fin_any
            row(C), row(C), row(C), row(C),            # busy dead rem moved
            row(K), row(K),                            # qptr queue_bytes
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S,), f8),
            jax.ShapeDtypeStruct((S,), f8),
            jax.ShapeDtypeStruct((S,), jnp.bool_),
            jax.ShapeDtypeStruct((S, C), jnp.bool_),
            jax.ShapeDtypeStruct((S, C), f8),
            jax.ShapeDtypeStruct((S, C), f8),
            jax.ShapeDtypeStruct((S, C), f8),
            jax.ShapeDtypeStruct((S, K), i8),
            jax.ShapeDtypeStruct((S, K), f8),
        ],
        interpret=interpret,
    )


def fused_advance_feed_f64(
    act, busy, dead, rem, cap, chunk_of, tick_dt, bw, disk_rate, sat_cc,
    contention, qoff, qlen, qptr, queue_bytes, fsdt, qsizes,
    interpret=None,
):
    """Run one fused sweep step for the NumPy driver (f64 in, NumPy out).

    Returns ``(dt, rate_sum, fin_any, busy, dead, rem, moved, qptr,
    queue_bytes)``; inactive rows pass through with ``dt = 0``.
    """
    from jax.experimental import enable_x64

    if interpret is None:
        interpret = not supports_compiled_pallas()
    S, C = busy.shape
    K = qptr.shape[1]
    # Q rides the canonical quarter-step ladder like the jax driver's
    # upload: the feed only reads below qoff+qlen, so zero pad is inert
    qsizes = np.asarray(qsizes, dtype=np.float64)
    q_pad = qsizes_pad(qsizes.shape[0])
    if q_pad > qsizes.shape[0]:
        qsizes = np.concatenate(
            [qsizes, np.zeros(q_pad - qsizes.shape[0])]
        )
    with enable_x64():
        call = _build_call(S, C, K, int(qsizes.shape[0]), bool(interpret))
        out = call(
            jnp.asarray(np.asarray(act, dtype=bool)),
            jnp.asarray(np.asarray(busy, dtype=bool)),
            jnp.asarray(np.asarray(dead, dtype=np.float64)),
            jnp.asarray(np.asarray(rem, dtype=np.float64)),
            jnp.asarray(np.asarray(cap, dtype=np.float64)),
            jnp.asarray(np.asarray(chunk_of, dtype=np.int64)),
            jnp.asarray(np.asarray(tick_dt, dtype=np.float64)),
            jnp.asarray(np.asarray(bw, dtype=np.float64)),
            jnp.asarray(np.asarray(disk_rate, dtype=np.float64)),
            jnp.asarray(np.asarray(sat_cc, dtype=np.int64)),
            jnp.asarray(np.asarray(contention, dtype=np.float64)),
            jnp.asarray(np.asarray(qoff, dtype=np.int64)),
            jnp.asarray(np.asarray(qlen, dtype=np.int64)),
            jnp.asarray(np.asarray(qptr, dtype=np.int64)),
            jnp.asarray(np.asarray(queue_bytes, dtype=np.float64)),
            jnp.asarray(np.asarray(fsdt, dtype=np.float64)),
            jnp.asarray(np.asarray(qsizes, dtype=np.float64)),
        )
        # np.array (not asarray): device buffers come back as read-only
        # zero-copy views, and the driver mutates these in place
        return tuple(np.array(o) for o in out)
