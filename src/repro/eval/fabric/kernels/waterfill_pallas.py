"""Optional Pallas water-fill kernel behind the ``(caps, pool) -> rates``
signature of :func:`repro.eval.fabric.kernels.waterfill`.

Instead of the sort-based closed form, the kernel bisects the water level
``lam`` solving ``sum_i min(cap_i, lam) = min(pool, sum_i cap_i)`` — pure
element-wise math plus row reductions, which maps onto the TPU VPU (and
Triton on GPU) without needing an in-kernel sort. 80 halvings from
``max(caps)`` pin ``lam`` to f64 resolution, so allocations agree with
the closed form to ~1e-12 relative.

Pallas has real lowerings on TPU (Mosaic) and GPU (Triton); only plain
CPU lacks one, so interpreter mode is the fallback *there alone* — a GPU
host gets the genuinely compiled kernel, not the silent interpreted
crawl it used to. CI and the equivalence test in
``tests/test_fabric_kernels.py`` exercise the interpreter path. Opt in
on the NumPy driver with ``FabricSimulation(..., waterfill_impl=
"pallas")`` or ``REPRO_FABRIC_WATERFILL=pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BISECT_ITERS = 80

#: backends with a real Pallas lowering (TPU: Mosaic; GPU: Triton).
#: Everything else (cpu, plugins without kernel support) interprets.
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def supports_compiled_pallas() -> bool:
    """True when the default JAX backend can lower ``pallas_call``
    natively instead of interpreting it."""
    return jax.default_backend() in _COMPILED_BACKENDS


def _waterfill_kernel(caps_ref, pool_ref, out_ref):
    caps = caps_ref[...]
    pool = pool_ref[...]  # (S, 1)
    total = jnp.sum(caps, axis=1, keepdims=True)
    pool_eff = jnp.clip(jnp.minimum(pool, total), 0.0, None)
    hi = jnp.max(caps, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        filled = jnp.sum(jnp.minimum(caps, mid), axis=1, keepdims=True)
        low = filled < pool_eff
        return jnp.where(low, mid, lo), jnp.where(low, hi, mid)

    # invariant: sum(min(caps, hi)) >= pool_eff >= sum(min(caps, lo))
    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
    out_ref[...] = jnp.minimum(caps, hi)


@functools.lru_cache(maxsize=64)
def _build_call(S: int, C: int, dtype: str, interpret: bool):
    """One ``pallas_call`` per (shape, dtype, mode): kernel construction
    re-walks the grid/block specs every time, so rebuilding it per sweep
    put Python dispatch on the hot path of every water-fill. Shapes are
    bucketed upstream (:mod:`repro.eval.fabric.bucketing`), so the cache
    stays a handful of entries."""
    return pl.pallas_call(
        _waterfill_kernel,
        out_shape=jax.ShapeDtypeStruct((S, C), dtype),
        interpret=interpret,
    )


def waterfill_pallas(caps, pool, interpret=None):
    """Max-min fair allocation of ``pool`` across ``caps`` rows via Pallas.

    ``caps``: (S, C) per-entity ceilings (idle entries 0); ``pool``: (S,).
    ``interpret=None`` auto-selects: compiled wherever the backend has a
    Pallas lowering (TPU/GPU), interpreter mode only on CPU.
    """
    caps = jnp.asarray(caps)
    pool = jnp.asarray(pool)
    S, C = caps.shape
    if S == 0 or C == 0:
        return jnp.zeros_like(caps)
    if interpret is None:
        interpret = not supports_compiled_pallas()
    pool2 = pool.reshape(S, 1).astype(caps.dtype)
    call = _build_call(S, C, jnp.dtype(caps.dtype).name, bool(interpret))
    return call(caps, pool2)


def waterfill_pallas_f64(caps, pool):
    """float64 wrapper for the NumPy driver: runs the kernel under the
    scoped x64 context and hands back a NumPy array."""
    import numpy as np
    from jax.experimental import enable_x64

    with enable_x64():
        out = waterfill_pallas(
            jnp.asarray(np.asarray(caps, dtype=np.float64)),
            jnp.asarray(np.asarray(pool, dtype=np.float64)),
        )
        return np.asarray(out)
