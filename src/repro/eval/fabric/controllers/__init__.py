"""Array-native controller layer: the paper's decision algorithms as
backend-neutral kernels.

The transfer *controllers* — SC / MC / ProMC chunk scheduling (Algorithms
1-3) — were the last layer of the stack still written in scalar Python:
every batched scenario had to park at each controller decision and
round-trip through the host. This package re-expresses the full decision
layer against the same :class:`repro.eval.fabric.shim.ArrayOps` namespace
the fluid kernels use, so one definition serves three consumers:

  * the scalar facade in :mod:`repro.core.schedulers` /
    :mod:`repro.core.params` (single-scenario instantiation, preserving
    the event-simulator API and golden snapshots),
  * the batched NumPy driver (:meth:`FabricSimulation._post` dispatches
    whole rows of decisions at once),
  * the JAX device loop, which fuses controller ticks and chunk-completion
    handling into its ``lax.while_loop`` body so steady-state scenarios
    never leave the device.

Module map (all kernels take ``ops`` first, chunk (K) / channel (C)
structure on the trailing axes, and broadcast over any leading batch):

  * :mod:`tuning`      — Algorithm 1 (``find_optimal_parameters``) as pure
    table math + the SC largest-class-first chunk ordering;
  * :mod:`alloc`       — Alg. 2 round-robin and Alg. 3 delta-weighted
    channel distributions as batched allocation kernels;
  * :mod:`decide`      — chunk ETA / predicted-rate views, the ProMC
    streak state machine (Sec. 3.4) and the laggard-ETA-discounting
    grant loop (Sec. 3.3);
  * :mod:`transitions` — masked channel ``Open``/``Close``/``Move`` state
    updates over per-scenario ``(channel, chunk)`` arrays, including the
    LIFO resume-file push when a busy channel is closed mid-transfer.

Like :mod:`repro.eval.fabric.kernels`, nothing here may import from
``repro.core`` — the core schedulers import *this* package, and numeric
tables (delta weights, round-robin ranks) are passed in as arrays.
The scalar semantics these kernels must reproduce bit-for-bit are pinned
by ``tests/test_controller_kernels.py`` against standalone references.
"""
from __future__ import annotations

from .alloc import round_robin_alloc, weighted_alloc
from .decide import chunk_eta, laggard_grants, predicted_chunk_rate, promc_tick
from .transitions import (
    apply_grants,
    close_chunk,
    move_channel,
    open_ranked,
    sc_advance_cursor,
)
from .tuning import optimal_params, sc_chunk_order

__all__ = [
    "apply_grants",
    "chunk_eta",
    "close_chunk",
    "laggard_grants",
    "move_channel",
    "open_ranked",
    "optimal_params",
    "predicted_chunk_rate",
    "promc_tick",
    "round_robin_alloc",
    "sc_advance_cursor",
    "sc_chunk_order",
    "weighted_alloc",
]
