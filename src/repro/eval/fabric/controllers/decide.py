"""Decision kernels: chunk ETA views, the ProMC streak state machine, and
the laggard-ETA-discounting grant loop.

Scalar references: ``ChunkView.eta``, ``netmodel.predict_chunk_rate``,
``ProActiveMultiChunkScheduler.on_tick`` and
``Scheduler.distribute_to_laggards`` in ``repro.core`` — every arithmetic
step here mirrors the scalar operation order so the batched decisions are
bit-identical to the Python controllers they replace.

Tie-breaking note: the scalar code picks minima/maxima with Python's
``min``/``max`` over index-ordered sequences (first winner on ties). The
kernels reproduce that with mask-and-argmax — ``argmax`` of a boolean
"equals the extremum" mask returns the first (lowest-index) hit on both
NumPy and JAX, including when the extremum is ``inf``.
"""
from __future__ import annotations

from ..shim import ArrayOps


def _gather(xp, table, idx):
    """``table[..., idx]`` for per-row indices: (..., K) x (...,) -> (...,)."""
    return xp.take_along_axis(table, xp.expand_dims(idx, -1), axis=-1)[..., 0]


def chunk_eta(ops: ArrayOps, bytes_remaining, throughput, predicted, done):
    """Estimated completion time per chunk (Sec. 3.3), ``ChunkView.eta``:
    remaining bytes over the measured rate, falling back to the model
    prediction before data flows; 0 for finished chunks, inf when no rate
    information exists at all. All args (..., K)."""
    xp = ops.xp
    rate = xp.where(throughput > 0.0, throughput, predicted)
    eta = xp.where(
        rate > 0.0,
        bytes_remaining / xp.where(rate > 0.0, rate, 1.0),
        xp.inf,
    )
    return xp.where(done | (bytes_remaining <= 0.0), 0.0, eta)


def predicted_chunk_rate(
    ops: ArrayOps,
    avg_file_size,
    cap,
    dead_time,
    n_channels,
    total_open,
    bandwidth,
    disk_rate,
    saturation_cc,
    contention,
):
    """Batched ``netmodel.predict_chunk_rate``: closed-form steady-state
    throughput estimate for cold ETAs.

    ``avg_file_size``/``cap``/``dead_time``/``n_channels`` are (..., K)
    per-chunk tables (``cap`` the per-channel rate ceiling for the chunk's
    parallelism, ``dead_time`` its per-file serial overhead); the network
    scalars are (...,). Callers pass ``n_channels``/``total_open`` already
    floored at 1, as the scalar call sites do.
    """
    xp = ops.xp
    n = xp.maximum(n_channels, 1)
    total = xp.maximum(total_open, 1)[..., None]
    over = xp.maximum(0, total - saturation_cc[..., None])
    penalty = 1.0 / (1.0 + contention[..., None] * over)
    agg = disk_rate[..., None] * penalty
    pool = xp.minimum(bandwidth[..., None], agg)
    rate = xp.minimum(cap, pool / total)
    t_file = dead_time + avg_file_size / xp.maximum(rate, 1e-9)
    return n * avg_file_size / t_file


def promc_tick(
    ops: ArrayOps,
    eta,
    throughput,
    n_channels,
    live,
    streak,
    pair_fast,
    pair_slow,
    ratio,
    patience,
):
    """One ProMC periodic check (Sec. 3.4, Alg. 3) as a masked state-machine
    update.

    ``eta``/``throughput``/``n_channels``/``live`` are (..., K) views
    (``live`` = not done and bytes remaining); ``streak``/``pair_fast``/
    ``pair_slow`` the (...,) persistent streak state (-1 = no pair);
    ``ratio``/``patience`` the scheduler constants, broadcastable (...,).

    Returns ``(streak, pair_fast, pair_slow, move, src, dst)`` — ``move``
    is True where a channel moves from ``src`` (fast) to ``dst`` (slow)
    this tick. State-transition semantics mirror the scalar ``on_tick``:
    fewer than two contenders resets the streak; an unmeasured
    infinite-ETA laggard freezes it (wait for data); an imbalanced pair
    extends or restarts it; ``patience`` consecutive imbalanced periods
    fire the move and reset.
    """
    xp = ops.xp
    lv = live & (n_channels > 0)
    few = xp.sum(lv, axis=-1) < 2

    min_eta = xp.min(xp.where(lv, eta, xp.inf), axis=-1)
    max_eta = xp.max(xp.where(lv, eta, -xp.inf), axis=-1)
    fast = xp.argmax(lv & (eta == min_eta[..., None]), axis=-1)
    slow = xp.argmax(lv & (eta == max_eta[..., None]), axis=-1)
    eta_f = _gather(xp, eta, fast)
    eta_s = _gather(xp, eta, slow)
    wait_meas = (
        ~few
        & ~xp.isfinite(eta_s)
        & (_gather(xp, throughput, slow) == 0.0)
    )

    imb = (
        (eta_s >= ratio * eta_f)
        & (fast != slow)
        & (_gather(xp, n_channels, fast) > 1)
    )
    same = (fast == pair_fast) & (slow == pair_slow)
    streak_upd = xp.where(imb & same, streak + 1, xp.where(imb, 1, 0))
    fire = ~few & ~wait_meas & imb & (streak_upd >= patience)

    hold = wait_meas  # unmeasured laggard: state untouched, no decision
    reset = few | fire
    streak_out = xp.where(
        hold, streak, xp.where(reset, 0, streak_upd)
    )
    pair_ok = ~hold & ~reset & imb
    pf_out = xp.where(hold, pair_fast, xp.where(pair_ok, fast, -1))
    ps_out = xp.where(hold, pair_slow, xp.where(pair_ok, slow, -1))
    return streak_out, pf_out, ps_out, fire, fast, slow


def laggard_grants(ops: ArrayOps, eta, owners, live, n_grants, max_iters: int):
    """``Scheduler.distribute_to_laggards``'s grant loop (Sec. 3.3): hand
    ``n_grants`` freed channels to the largest-ETA chunks one at a time,
    discounting a receiver's ETA by ``n/(n+1)`` as it gains channels.

    ``eta`` (..., K) absolute ETAs (inf allowed — an unmeasured chunk
    keeps absorbing, the scalar reference's documented greedy behaviour);
    ``owners`` (..., K) current channel counts; ``live`` (..., K) the
    eligible receivers (not done, bytes remaining, not the source chunk);
    ``n_grants`` (...,) int; ``max_iters`` a static bound >= max grants.

    Returns ``(grants, first_rank)``: per-chunk grant counts and the step
    index of each chunk's first grant (``max_iters`` if never granted) —
    the order in which the scalar reference emits its ``Move`` actions,
    which fixes the channel-slot assignment downstream.
    """
    xp = ops.xp
    K = eta.shape[-1]
    e = xp.asarray(eta, dtype=xp.float64)
    grants = xp.zeros_like(xp.asarray(owners, dtype=xp.int64))
    first = xp.full(grants.shape, max_iters, dtype=xp.int64)
    any_live = xp.any(live, axis=-1)
    for i in range(max_iters):
        active = (i < n_grants) & any_live
        cur = xp.max(xp.where(live, e, -xp.inf), axis=-1)
        dst = xp.argmax(live & (e == cur[..., None]), axis=-1)
        hit = (xp.arange(K) == dst[..., None]) & active[..., None]
        grants = grants + hit
        first = xp.where(hit & (first == max_iters), i, first)
        n = _gather(xp, owners + grants, dst)
        factor = xp.where(n > 1, (n - 1.0) / xp.maximum(n, 1), 0.5)
        e = xp.where(hit & xp.isfinite(e), e * factor[..., None], e)
    return grants, first
