"""Algorithm 1 (protocol-parameter estimation) and SC chunk ordering as
array kernels.

The scalar reference is ``repro.core.params.find_optimal_parameters``;
``core.params`` is a thin facade over :func:`optimal_params` and the two
must stay bit-identical (integer outputs, f64 intermediate math in the
same operation order).
"""
from __future__ import annotations

from ..shim import ArrayOps


def optimal_params(
    ops: ArrayOps,
    avg_file_size,
    bdp,
    buffer_size,
    max_cc,
    num_files,
    max_pipelining: int,
):
    """Algorithm 1, elementwise over any batch shape.

    ``avg_file_size``/``bdp``/``buffer_size``/``max_cc``/``num_files`` are
    broadcast-compatible float/int arrays; ``num_files <= 0`` means "no
    file-count cap" (the scalar API's ``num_files=None``). Returns int64
    ``(pipelining, parallelism, concurrency)`` arrays.
    """
    xp = ops.xp
    avg = xp.asarray(avg_file_size, dtype=xp.float64)
    bdp = xp.asarray(bdp, dtype=xp.float64)
    buf = xp.asarray(buffer_size, dtype=xp.float64)
    mc = xp.asarray(max_cc, dtype=xp.float64)
    nf = xp.asarray(num_files, dtype=xp.int64)

    # line 2: pipelining = BDP / avgFileSize, clamped to a practical depth
    pp = xp.clip(xp.ceil(bdp / avg), 0.0, float(max_pipelining))
    pp = pp.astype(xp.int64)

    # line 3: parallelism = Min(ceil(BDP/buffer), ceil(avgFileSize/buffer))
    par = xp.minimum(xp.ceil(bdp / buf), xp.ceil(avg / buf))
    par = xp.maximum(par, 1.0).astype(xp.int64)

    # line 4: concurrency = Min(Max(BDP/avgFileSize, 2), maxCC)
    cc = xp.minimum(xp.maximum(bdp / avg, 2.0), mc)
    cc = xp.maximum(xp.floor(cc), 1.0).astype(xp.int64)

    capped = nf > 0
    pp = xp.where(capped, xp.minimum(pp, xp.maximum(nf - 1, 0)), pp)
    cc = xp.where(capped, xp.minimum(cc, nf), cc)
    return pp, par, cc


def sc_chunk_order(ops: ArrayOps, ctypes):
    """SC transfer order: largest size class first, stable by index.

    ``ctypes`` (..., K) integer chunk types. Returns the (..., K) index
    permutation matching ``sorted(range(K), key=lambda i: -ctype[i])``
    (Python's stable sort), via a unique composite integer key.
    """
    xp = ops.xp
    ct = xp.asarray(ctypes, dtype=xp.int64)
    K = ct.shape[-1]
    hi = xp.max(ct, axis=-1, keepdims=True) if K else ct
    key = (hi - ct) * K + xp.arange(K)  # unique => any sort is stable
    return xp.argsort(key, axis=-1)
