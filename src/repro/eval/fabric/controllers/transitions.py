"""Channel Open/Close/Move transitions as masked array updates.

These kernels re-express ``Simulation._apply`` / the fabric driver's
``_open_channel``/``_close_channels`` bookkeeping without per-channel
Python: channel slots live on the trailing C axis, chunk tables on the
trailing K axis, and a ``trig`` (...,) mask gates which rows transition.
Slot-assignment rules mirror the scalar code exactly — closes take a
chunk's channels idle-first in column order, opens take the lowest free
column — because future feed/close decisions key on column order.

``prepend_sizes``/``prepend_n`` implement the LIFO resume-file stack: a
busy channel closed mid-transfer re-queues its in-flight remainder
(conservative restart, matching GridFTP), consumed before the FIFO queue
cursor moves. Callers guarantee stack capacity (the drivers pre-size it
from the closed-form worst-case bound; the device loop parks a row on
prospective overflow as an assertion-guarded fallback).

Every close here ends with :func:`repro.eval.fabric.kernels.\
compact_channels`: the scalar simulator keeps channels in a Python list
(closes *remove*, opens *append*), and idle-victim selection plus the
feed ranking key on that order — left-packing the channel axis after a
close keeps column order equal to list order, so recycled columns can
never resolve an idle-channel tie differently from the event reference.
"""
from __future__ import annotations

from ..shim import NO_CHUNK, ArrayOps


def _gather(xp, table, idx):
    return xp.take_along_axis(table, xp.expand_dims(idx, -1), axis=-1)[..., 0]


def close_chunk(ops: ArrayOps, trig, k, chunk_of, busy, dead, rem, cap):
    """Close every channel of chunk ``k`` (all idle — the chunk just
    completed) on ``trig`` rows, then left-pack the survivors. ``k`` may
    be a Python int or a (...,) array. Returns the updated channel
    arrays."""
    from .. import kernels

    xp = ops.xp
    k = xp.expand_dims(xp.asarray(k), -1)
    sel = xp.expand_dims(trig, -1) & (chunk_of == k)
    return kernels.compact_channels(
        ops,
        trig,
        xp.where(sel, NO_CHUNK, chunk_of),
        xp.where(sel, False, busy),
        xp.where(sel, 0.0, dead),
        xp.where(sel, 0.0, rem),
        xp.where(sel, 0.0, cap),
    )


def open_ranked(
    ops: ArrayOps, n_open, target, chunk_of, dead, cap, setup_cost, cap_k
):
    """Open ``n_open`` (...,) fresh channels for chunk ``target`` (...,) at
    the lowest free columns (full setup cost: ``prev=None`` opens).
    Callers guarantee enough free slots. Returns (chunk_of, dead, cap)."""
    xp = ops.xp
    free = chunk_of == NO_CHUNK
    rank = xp.cumsum(free, axis=-1) - 1
    sel = free & (rank < xp.expand_dims(n_open, -1))
    tgt = xp.expand_dims(target, -1)
    return (
        xp.where(sel, tgt, chunk_of),
        xp.where(sel, xp.expand_dims(setup_cost, -1), dead),
        xp.where(sel, xp.expand_dims(_gather(xp, cap_k, target), -1), cap),
    )


def sc_advance_cursor(ops: ArrayOps, trig, cursor, order, nfiles, n_chunks):
    """SC cursor step after a chunk completion: advance one position, then
    skip empty size classes (``SingleChunkScheduler._open_current``'s
    walk). ``order`` (..., K) is the largest-class-first permutation;
    ``n_chunks`` (...,) the real (unpadded) chunk count."""
    xp = ops.xp
    K = order.shape[-1]
    cursor = xp.where(trig, cursor + 1, cursor)
    for _ in range(K):
        idx = _gather(xp, order, xp.clip(cursor, 0, K - 1))
        adv = trig & (cursor < n_chunks) & (_gather(xp, nfiles, idx) == 0)
        cursor = xp.where(adv, cursor + 1, cursor)
    return cursor


def move_channel(
    ops: ArrayOps,
    trig,
    src,
    dst,
    chunk_of,
    busy,
    dead,
    rem,
    cap,
    queue_bytes,
    prepend_sizes,
    prepend_n,
    n_moves,
    par,
    cap_k,
    setup_cost,
):
    """Move one channel from chunk ``src`` to chunk ``dst`` (...,) on
    ``trig`` rows — the ProMC tick re-allocation.

    Mirrors ``Move(src, dst, n=1)`` through ``_apply``: the source's
    idle-first lowest column closes (a busy victim re-queues its
    remainder on the LIFO resume stack), then the lowest free column
    opens for ``dst`` — at a quarter of the setup cost when the two
    chunks share a parallelism level (cached data channels, Sec. 3.2).
    """
    xp = ops.xp
    C = chunk_of.shape[-1]
    K = queue_bytes.shape[-1]
    P = prepend_sizes.shape[-1]
    cols = xp.arange(C)

    is_src = chunk_of == xp.expand_dims(src, -1)
    idle_key = xp.where(is_src & ~busy, cols, 2 * C)
    busy_key = xp.where(is_src & busy, cols, 2 * C)
    have_idle = xp.min(idle_key, axis=-1) < 2 * C
    chosen = xp.where(
        have_idle,
        xp.argmin(idle_key, axis=-1),
        xp.argmin(busy_key, axis=-1),
    )
    oh = (cols == xp.expand_dims(chosen, -1)) & xp.expand_dims(trig, -1)

    # resume push: a busy victim's in-flight remainder restarts later
    rem_c = xp.sum(xp.where(oh, rem, 0.0), axis=-1)
    push = trig & xp.any(oh & busy, axis=-1) & (rem_c > 0.0)
    size = xp.ceil(rem_c)
    koh = (xp.arange(K) == xp.expand_dims(src, -1)) & xp.expand_dims(push, -1)
    queue_bytes = queue_bytes + xp.where(koh, xp.expand_dims(size, -1), 0.0)
    pn_src = _gather(xp, prepend_n, src)
    shape = prepend_sizes.shape[:-2] + (K * P,)
    ps_flat = xp.reshape(prepend_sizes, shape)
    slot = src * P + xp.clip(pn_src, 0, P - 1)
    ps_flat = xp.where(
        (xp.arange(K * P) == xp.expand_dims(slot, -1))
        & xp.expand_dims(push, -1),
        xp.expand_dims(size, -1),
        ps_flat,
    )
    prepend_sizes = xp.reshape(ps_flat, prepend_sizes.shape)
    prepend_n = prepend_n + xp.where(koh, 1, 0)

    # close the chosen column (left-packing the survivors, so the open
    # below appends at the end of the channel list like the scalar loop),
    # then open the first free column for dst
    from .. import kernels

    chunk_of, busy, dead, rem, cap = kernels.compact_channels(
        ops,
        trig,
        xp.where(oh, NO_CHUNK, chunk_of),
        xp.where(oh, False, busy),
        xp.where(oh, 0.0, dead),
        xp.where(oh, 0.0, rem),
        xp.where(oh, 0.0, cap),
    )

    free = chunk_of == NO_CHUNK
    fcol = xp.argmax(free, axis=-1)  # first free; the close guarantees one
    oh2 = (cols == xp.expand_dims(fcol, -1)) & xp.expand_dims(trig, -1)
    cost = xp.where(
        _gather(xp, par, src) == _gather(xp, par, dst),
        0.25 * setup_cost,
        setup_cost,
    )
    chunk_of = xp.where(oh2, xp.expand_dims(dst, -1), chunk_of)
    dead = xp.where(oh2, xp.expand_dims(cost, -1), dead)
    cap = xp.where(oh2, xp.expand_dims(_gather(xp, cap_k, dst), -1), cap)
    n_moves = n_moves + xp.where(trig, 1, 0)
    return (
        chunk_of, busy, dead, rem, cap, queue_bytes, prepend_sizes,
        prepend_n, n_moves,
    )


def apply_grants(
    ops: ArrayOps,
    trig,
    src,
    grants,
    first_rank,
    chunk_of,
    busy,
    dead,
    rem,
    cap,
    n_moves,
    par,
    cap_k,
    setup_cost,
):
    """Re-target the freed (idle) channels of completed chunk ``src`` to
    the laggard chunks chosen by :func:`decide.laggard_grants`.

    Equivalent to the scalar ``[Move(src, d, n=k_d) ...]`` action list in
    first-grant order: the source's columns free up lowest-first, and the
    flattened grant sequence claims the lowest free columns in order —
    the same final slot assignment as the per-Move close/open batches,
    because closes always release the lowest remaining source columns
    before the corresponding opens run. ``src`` may be a Python int or a
    (...,) array.
    """
    xp = ops.xp
    K = grants.shape[-1]
    C = chunk_of.shape[-1]
    total = xp.sum(grants, axis=-1)
    src = xp.broadcast_to(xp.asarray(src), total.shape)

    from .. import kernels

    sel = xp.expand_dims(trig, -1) & (chunk_of == xp.expand_dims(src, -1))
    closed, busy, dead, rem, cap0 = kernels.compact_channels(
        ops,
        trig,
        xp.where(sel, NO_CHUNK, chunk_of),
        xp.where(sel, False, busy),
        xp.where(sel, 0.0, dead),
        xp.where(sel, 0.0, rem),
        xp.where(sel, 0.0, cap),
    )

    # offsets of each destination's slice in the flattened grant sequence
    big = C * K + 1
    fr = xp.where(grants > 0, first_rank, big)
    earlier = fr[..., None, :] < fr[..., :, None]
    off = xp.sum(xp.where(earlier, grants[..., None, :], 0), axis=-1)

    free = closed == NO_CHUNK
    frank = xp.cumsum(free, axis=-1) - 1
    assign = (
        free
        & (frank < xp.expand_dims(total, -1))
        & xp.expand_dims(trig, -1)
    )
    # (..., K, C) membership of each column's sequence slot in dst d's slice
    fr_c = frank[..., None, :]
    ind = (
        (fr_c >= off[..., :, None])
        & (fr_c < (off + grants)[..., :, None])
        & (grants > 0)[..., :, None]
        & assign[..., None, :]
    )
    dst_col = xp.sum(xp.arange(K)[..., :, None] * ind, axis=-2)
    hit = xp.any(ind, axis=-2)
    par_dst = xp.take_along_axis(par, xp.clip(dst_col, 0, K - 1), axis=-1)
    cost = xp.where(
        par_dst == xp.expand_dims(_gather(xp, par, src), -1),
        0.25 * xp.expand_dims(setup_cost, -1),
        xp.expand_dims(setup_cost, -1),
    )
    chunk_of = xp.where(hit, dst_col, closed)
    dead = xp.where(hit, cost, dead)
    cap = xp.where(
        hit, xp.take_along_axis(cap_k, xp.clip(dst_col, 0, K - 1), axis=-1),
        cap0,
    )
    n_moves = n_moves + xp.where(trig, total, 0)
    return chunk_of, busy, dead, rem, cap, n_moves
