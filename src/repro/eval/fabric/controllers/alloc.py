"""Initial channel-allocation kernels: Alg. 2 round-robin and Alg. 3
delta-weighted distribution.

Scalar references: ``repro.core.schedulers.round_robin_distribution`` and
``weighted_distribution`` (now facades over these kernels). Both operate
on the trailing chunk axis (K) and broadcast over any batch shape;
``max_cc`` may itself be an array (the matrix sweeps vary it per
scenario).
"""
from __future__ import annotations

from ..shim import ArrayOps


def round_robin_alloc(ops: ArrayOps, order_rank, nonempty, max_cc):
    """Alg. 2 lines 8-12: maxCC channels round-robin over the live chunks
    ordered {Huge, Small, Large, Medium}.

    ``order_rank`` (..., K): each chunk's position of its ctype in the
    round-robin ordering (lower = served earlier); ``nonempty`` (..., K)
    bool. Closed form of the round-robin loop: the chunk at position ``p``
    of the (rank, index) order receives ``maxCC // n_live`` channels plus
    one if ``p < maxCC % n_live``. Returns (..., K) int64 allocations
    (0 for empty chunks).
    """
    xp = ops.xp
    rank = xp.asarray(order_rank, dtype=xp.int64)
    K = rank.shape[-1]
    key = rank * K + xp.arange(K)
    pos = xp.sum(
        (key[..., :, None] > key[..., None, :]) & nonempty[..., None, :],
        axis=-1,
    )
    n_live = xp.maximum(xp.sum(nonempty, axis=-1), 1)[..., None]
    mc = xp.broadcast_to(
        xp.asarray(max_cc, dtype=xp.int64)[..., None], pos.shape
    )
    alloc = mc // n_live + (pos < mc % n_live)
    return xp.where(nonempty, alloc, 0)


def weighted_alloc(ops: ArrayOps, weights, nonempty, max_cc, trim_iters: int):
    """Alg. 3 lines 5-12: ``concurrency_i = floor(weight_i/total * maxCC)``
    with the two working-system deviations of the scalar reference:

      * every non-empty chunk gets at least one channel;
      * flooring leftovers are granted round-robin by descending
        fractional share (stable by index), and over-allocation from the
        min-1 floor is trimmed from the largest allocations (ties broken
        toward the smallest share, then lowest index), never below 1.

    ``weights`` (..., K) = delta_i * size_i (anything on empty slots is
    ignored); ``trim_iters`` must be >= K (the excess over budget is at
    most one per zero-floored chunk). Returns (..., K) int64 allocations
    summing to ``max(maxCC, n_live)`` wherever any chunk is live.
    """
    xp = ops.xp
    w = xp.where(nonempty, xp.asarray(weights, dtype=xp.float64), 0.0)
    total = xp.sum(w, axis=-1, keepdims=True)
    total = xp.where(total == 0.0, 1.0, total)  # scalar's ``sum(...) or 1.0``
    mc = xp.asarray(max_cc, dtype=xp.float64)[..., None]
    shares = w / total * mc
    floors = xp.floor(shares)
    alloc = xp.where(nonempty, xp.maximum(floors, 1.0), 0.0).astype(xp.int64)

    n_live = xp.sum(nonempty, axis=-1)
    budget = xp.maximum(xp.asarray(max_cc, dtype=xp.int64), n_live)
    K = alloc.shape[-1]

    # trim: repeatedly decrement the lexicographic-max (alloc, -share)
    # holder while over budget; stop when it is already down to 1 channel
    for _ in range(trim_iters):
        over = xp.sum(alloc, axis=-1) > budget
        a_max = xp.max(xp.where(nonempty, alloc, -1), axis=-1)
        m1 = nonempty & (alloc == a_max[..., None])
        s_min = xp.min(xp.where(m1, shares, xp.inf), axis=-1)
        m2 = m1 & (shares == s_min[..., None])
        sel = xp.argmax(m2, axis=-1)
        can = over & (
            xp.take_along_axis(alloc, sel[..., None], axis=-1)[..., 0] > 1
        )
        alloc = alloc - (
            can[..., None] & (xp.arange(K) == sel[..., None])
        ).astype(xp.int64)

    # grant: leftovers round-robin by descending fractional part (stable)
    frac = shares - floors
    ahead = (frac[..., None, :] > frac[..., :, None]) | (
        (frac[..., None, :] == frac[..., :, None])
        & (xp.arange(K)[..., None, :] < xp.arange(K)[..., :, None])
    )
    pos = xp.sum(ahead & nonempty[..., None, :], axis=-1)
    deficit = xp.maximum(budget - xp.sum(alloc, axis=-1), 0)[..., None]
    nl = xp.maximum(n_live, 1)[..., None]
    add = deficit // nl + (pos < deficit % nl)
    return xp.where(nonempty, alloc + add, 0)
