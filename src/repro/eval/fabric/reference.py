"""Scalar reference semantics of the fluid stepping hooks.

These are the pure, per-channel forms the fabric array kernels mirror; the
event-driven ``core.simulator`` consumes them directly (it re-exports them
for backwards compatibility), and the property tests in
``tests/test_fabric_kernels.py`` pin the batched kernels to them on random
inputs. Keep them dependency-free: this module sits below both ``core``
and the fabric drivers in the import graph (only a function-level
``core.types`` import for the resume-file constructor).
"""
from __future__ import annotations

import math
from typing import Sequence

_EPS = 1e-12


def tick_rate_update(
    prev_estimate: float, delta_bytes: float, period: float
) -> float:
    """Measured-rate refresh at a controller tick (EMA after the first one).

    The first measurement seeds the estimate; afterwards old and new are
    blended 50/50, matching the paper's 5-second smoothing.
    """
    inst = delta_bytes / period
    return inst if prev_estimate == 0 else 0.5 * prev_estimate + 0.5 * inst


def next_event_dt(
    time_to_tick: float,
    deads: Sequence[float],
    remainings: Sequence[float],
    rates: Sequence[float],
) -> float:
    """Time until the next state change among busy channels, capped by the
    controller tick. ``deads[i] > 0`` means channel i is in dead time (its
    next event is dead-time expiry); otherwise it finishes its file in
    ``remaining/rate``. Channels with no pending event contribute nothing.
    """
    dt = time_to_tick
    for dead, rem, r in zip(deads, remainings, rates):
        if dead > _EPS:
            dt = min(dt, dead)
        elif r > _EPS:
            dt = min(dt, rem / r)
    return max(dt, 0.0)


def coupled_fair_share(
    demand: Sequence[float],
    member: Sequence[Sequence[bool]],
    link_cap: Sequence[float],
) -> list:
    """Progressive-filling reference for ``kernels.waterfill_coupled``.

    Classic max-min fairness over rows sharing finite links: raise every
    unfrozen row's rate in lockstep until a link saturates (freeze its
    members at the common level) or a row reaches its demand (freeze it
    there), remove the bound capacity, repeat. Returns the per-row rates.
    ``member[l][r]`` is row r's membership of link l. Rows on no link get
    their full demand. O(rows * links) scalar loops — a test oracle, not
    a kernel.
    """
    R = len(demand)
    L = len(link_cap)
    x = [0.0] * R
    frozen = [False] * R
    remaining_cap = [float(c) for c in link_cap]
    for r in range(R):
        if not any(member[l][r] for l in range(L)):
            x[r] = float(demand[r])
            frozen[r] = True
    level = 0.0
    for _ in range(R + L + 1):
        active = [r for r in range(R) if not frozen[r]]
        if not active:
            break
        # headroom to the next freezing event at the common level
        step = math.inf
        for r in active:
            step = min(step, demand[r] - level)
        for l in range(L):
            members = [r for r in active if member[l][r]]
            if members:
                step = min(step, remaining_cap[l] / len(members))
        if not math.isfinite(step):
            break
        step = max(step, 0.0)
        level += step
        for l in range(L):
            members = [r for r in active if member[l][r]]
            remaining_cap[l] -= step * len(members)
        newly = set()
        for l in range(L):
            if remaining_cap[l] <= _EPS * max(link_cap[l], 1.0):
                for r in active:
                    if member[l][r]:
                        newly.add(r)
        for r in active:
            if demand[r] - level <= _EPS * max(demand[r], 1.0):
                newly.add(r)
        for r in newly:
            x[r] = level
            frozen[r] = True
    for r in range(R):
        if not frozen[r]:
            x[r] = level
    return x


def resume_file(remaining: float):
    """Synthetic file re-queued when a busy channel is closed mid-transfer
    (the in-flight remainder restarts; conservative, matches GridFTP)."""
    from repro.core.types import FileSpec  # function-level: breaks the
    # core -> fabric -> core import cycle

    return FileSpec(name="__resume__", size=int(math.ceil(remaining)))
