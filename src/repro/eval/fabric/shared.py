"""Shared-fabric coupling spec: scenario rows tied through link capacities.

A :class:`SharedFabric` attaches a scenario row to named backbone links of
finite capacity inside a named fabric *group*. Rows that share a group are
no longer independent: every event sweep first water-fills each row's
channel caps against its own disk/bandwidth pool (exactly the uncoupled
physics), then runs :func:`repro.eval.fabric.kernels.waterfill_coupled`
across the group's (links x rows) membership table so the per-row pools
shrink to a max-min fair share of each saturated link. Groups are purely
nominal — two groups never interact even if their link names collide
(links are keyed ``(group, link)``).

The spec is deliberately tiny and value-like (frozen, tuple fields) so a
``Scenario`` stays hashable and JSON-friendly with a fabric attached.
:func:`resolve_fabric` lowers a per-row ``Optional[SharedFabric]`` column
into the three arrays every backend consumes: ``group_id`` (S,), the
``member`` (L, S) table, and ``link_cap`` (L,).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

#: characters reserved by the scenario-name suffix grammar (``|fab:...``)
_RESERVED = ("|", ":")


@dataclasses.dataclass(frozen=True)
class SharedFabric:
    """One row's attachment to a coupled fabric group.

    ``links``/``capacity`` are parallel tuples naming the backbone links
    this row rides and their capacities in bytes/s. Capacity is a
    property of the *link*: every row of a group declaring the same link
    must declare the same capacity (``resolve_fabric`` rejects
    mismatches). ``tenant`` is a free-form label folded into the
    scenario name so tenants that are otherwise identical points of the
    matrix (same network/dataset/algorithm/seed) keep unique names.
    """

    group: str
    links: Tuple[str, ...]
    capacity: Tuple[float, ...]
    tenant: str = ""

    def __post_init__(self):
        if not self.group:
            raise ValueError("SharedFabric.group must be non-empty")
        for label, value in (("group", self.group), ("tenant", self.tenant)):
            for ch in _RESERVED:
                if ch in value:
                    raise ValueError(
                        f"SharedFabric.{label} {value!r} contains reserved "
                        f"character {ch!r} (scenario-name suffix grammar)"
                    )
        if len(self.links) != len(self.capacity):
            raise ValueError(
                f"links/capacity length mismatch: {len(self.links)} links, "
                f"{len(self.capacity)} capacities"
            )
        if not self.links:
            raise ValueError(
                "SharedFabric needs at least one link (use "
                "shared_fabric=None for an uncoupled row)"
            )
        if len(set(self.links)) != len(self.links):
            raise ValueError(f"duplicate link names in {self.links!r}")
        for name, cap in zip(self.links, self.capacity):
            if not name:
                raise ValueError("link names must be non-empty")
            if not (cap > 0.0):
                raise ValueError(
                    f"link {name!r} capacity must be positive, got {cap!r}"
                )

    @property
    def name_suffix(self) -> str:
        """The scenario-name tag: ``fab:<group>`` (+ ``:<tenant>``)."""
        t = f":{self.tenant}" if self.tenant else ""
        return f"fab:{self.group}{t}"


@dataclasses.dataclass
class ResolvedFabric:
    """The array form of a batch's fabric column.

    ``group_id[r]`` is -1 for uncoupled rows, else a dense group index;
    ``member[l, r]`` marks row r's membership of global link l;
    ``link_cap[l]`` its capacity. Links of different groups occupy
    disjoint global indices, so one membership table covers a batch
    holding many independent groups (the cross-link exclusion-min inside
    ``waterfill_coupled`` only ever reads a row's own links).
    """

    group_id: np.ndarray  # (S,) int64, -1 == uncoupled
    member: np.ndarray  # (L, S) bool
    link_cap: np.ndarray  # (L,) float64
    n_groups: int

    @property
    def coupled(self) -> bool:
        return self.member.shape[0] > 0


def resolve_fabric(
    fabrics: Sequence[Optional[SharedFabric]],
) -> ResolvedFabric:
    """Lower a per-row fabric column into dense coupling arrays."""
    S = len(fabrics)
    group_id = np.full(S, -1, dtype=np.int64)
    group_of: dict = {}
    link_of: dict = {}
    caps: list = []
    hits: list = []
    for r, fab in enumerate(fabrics):
        if fab is None:
            continue
        gid = group_of.setdefault(fab.group, len(group_of))
        group_id[r] = gid
        for name, cap in zip(fab.links, fab.capacity):
            key = (fab.group, name)
            li = link_of.get(key)
            if li is None:
                li = link_of[key] = len(caps)
                caps.append(float(cap))
            elif caps[li] != float(cap):
                raise ValueError(
                    f"link {name!r} of group {fab.group!r} declared with "
                    f"conflicting capacities {caps[li]!r} and {cap!r}"
                )
            hits.append((li, r))
    member = np.zeros((len(caps), S), dtype=bool)
    for li, r in hits:
        member[li, r] = True
    return ResolvedFabric(
        group_id=group_id,
        member=member,
        link_cap=np.asarray(caps, dtype=np.float64),
        n_groups=len(group_of),
    )
