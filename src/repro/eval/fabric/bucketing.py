"""Canonical shape bucketing: make every sweep hit a small, fixed set of
compiled programs.

The JAX driver's fused loop (:func:`repro.eval.fabric.jax_backend.
_device_rounds`) is one ``jax.jit`` whose compile cache is keyed on the
shape of *every* array in the carried state: scenario rows S, channel
axis C, chunk axis K, resume-stack depth P, bandwidth-profile width B,
timeline width T, and the flat file-size buffer Q. Left raw, each of
those takes whatever value a batch happens to produce — Q in particular
is the total file count of a chunk's scenarios, different for every
chunk — so a full-matrix run pays a fresh ~5-10 s XLA compile per chunk
and the tuner's candidate planes paid hundreds of them (the ~14 min
"jax oracle" of PR 5, vs 34 s on NumPy).

Every shape that reaches the jit signature is therefore *bucketed* to a
canonical pad ladder — the next power of two at or above a per-axis
floor — shared by the matrix runner's chunking, the tuner's candidate
planes, and the fuzz harness:

  * S: padded rows, floor :data:`MIN_ROW_PAD` (the jax driver pads rows
    itself; runner chunk spans are cut power-of-two-aligned so live rows
    fill the padded shape — see :func:`chunk_spans`);
  * C / P: pre-sized by doubling from 4, already on the ladder;
  * K: chunk axis, bucketed in the driver (padding chunks are born done);
  * B: bandwidth-profile width (1 for all-static batches, else the
    ladder from :data:`PROFILE_PAD_FLOOR`);
  * T: timeline width (1 when no row records, else the budget — keep
    budgets powers of two);
  * Q: the flat file-size buffer, zero-padded at device upload to the
    quarter-step ladder of :func:`qsizes_pad`.

With the persistent XLA cache (``REPRO_XLA_CACHE``) the surviving
handful of signatures compiles once per machine, not once per process.
"""
from __future__ import annotations

from typing import Tuple

#: floor on the padded device row count (the jax driver's straggler-tail
#: economics set it; see ``jax_backend._MIN_PAD`` which aliases this)
MIN_ROW_PAD = 8

#: floor on the bucketed flat file-size buffer: a 1024-slot f64 pad costs
#: 8 KB of upload, while every distinct raw length below it would be one
#: more compiled program
QSIZES_FLOOR = 1024

#: the smallest padded device shape compaction will descend to (the jax
#: driver's deterministic quarter-step rung policy bottoms out here);
#: shared with :func:`signature_ladder` so the executor's AOT warm-start
#: pre-builds exactly the rungs a running batch can reach. Straggler
#: tails run thousands of narrow sweeps whose cost is linear in the pad
#: width, so the heterogeneous full grid needs the 64 rung; each extra
#: rung is one more program per (C, B) family, which the jax.export
#: trace cache keeps to ~0.3 s/program in warm processes
COMPACT_FLOOR = 64

#: chunk remainders below this are not split further into power-of-two
#: spans but padded as one chunk — a 32-row padded tail beats three
#: extra device batches with their own fixed dispatch cost
MIN_SPAN = 64

#: floor on the bucketed bandwidth-profile width of any batch that has a
#: profiled row at all (all-static batches keep the width-1 fast path).
#: Testbed profiles run 2-16 steps; letting a chunk's max width pick the
#: bucket minted separate B=4 programs for chunks that happened to hold
#: only short-profile networks — one more trace per (C, Q) family for a
#: few columns of (inf, last-multiplier) pad the gather never selects
PROFILE_PAD_FLOOR = 16


def bucket(n: int, floor: int = 1) -> int:
    """Next power of two at or above ``max(n, floor)`` (``floor`` for 0)."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def qsizes_pad(n: int) -> int:
    """Bucketed length of the flat file-size buffer: the *quarter-step*
    ladder ``1024, 4096, 16384, 65536, ...``.

    Q is the noisiest signature axis — a chunk's raw length is the total
    file count of whatever 1024 rows the cost sort dealt it, so the pow2
    ladder still minted five Q rungs across the tuner's candidate plane.
    4x steps cut that to three for the price of at most 3x dead f64
    slots (8 B each, upload-only)."""
    q = QSIZES_FLOOR
    n = int(n)
    while q < n:
        q *= 4
    return q


def chunk_spans(
    n: int, size: int, pad_aligned: bool = False
) -> Tuple[Tuple[int, int], ...]:
    """Split ``n`` rows into execution-chunk ``(lo, hi)`` spans.

    ``pad_aligned=False`` is the plain uniform split (the NumPy driver
    has no padded shapes to fill). ``pad_aligned=True`` cuts spans whose
    sizes are powers of two wherever that matters: full ``size``-row
    chunks first, then the remainder decomposed into descending
    power-of-two spans down to :data:`MIN_SPAN`, with the final scraps
    as one padded chunk. Live rows then fill the padded device shape —
    a 276-row grid becomes 256 + 20(pad 32) instead of one 276(pad 512)
    batch sweeping 46% dead rows — and every span lands on the
    canonical ladder.
    """
    spans = []
    lo = 0
    if pad_aligned:
        # keep `size` itself on the ladder so full chunks are exact
        size = bucket(size)
    while n - lo >= size:
        spans.append((lo, lo + size))
        lo += size
    rest = n - lo
    while pad_aligned and rest >= MIN_SPAN:
        take = 1 << (rest.bit_length() - 1)  # largest pow2 <= rest
        if take < MIN_SPAN:
            break
        spans.append((lo, lo + take))
        lo += take
        rest = n - lo
    if rest > 0:
        spans.append((lo, n))
    return tuple(spans)


def canonical_signature(sim) -> Tuple[int, ...]:
    """The bucketed jit-cache signature a :class:`FabricSimulation`'s
    batch will occupy on the jax driver: ``(rows, C, K, P, B, T, Q)``.

    ``rows`` is the initial padded row count; compaction walks it down
    the same ladder (each rung at most once per ``(C, K, P, B, T, Q)``
    combination). C / P reflect the closed-form capacity pre-sizing the
    jax driver applies before its first sweep, so the signature can be
    computed without running — the pad-ladder canary test plans the full
    grid's shapes this way.
    """
    need_c, need_p = sim.capacity_need()
    return (
        bucket(max(sim.S, getattr(sim, "_pad_floor", 0)), MIN_ROW_PAD),
        bucket(need_c, sim.C),
        sim.K,
        bucket(need_p, sim.P),
        sim.prof_t.shape[1],
        sim.tl_t.shape[1],
        qsizes_pad(sim.qsizes.shape[0]),
    )


def signature_ladder(
    sig: Tuple[int, ...], floor: int = COMPACT_FLOOR
) -> Tuple[Tuple[int, ...], ...]:
    """Every signature a batch starting at ``sig`` can occupy over its
    lifetime: the initial shape plus the deterministic quarter-step
    compaction rungs of the rows axis (``R, R//4, ..., floor`` — only
    the rows axis moves; compaction never reshapes C/K/P/B/T/Q).
    ``floor`` is the batch's compaction floor (:data:`COMPACT_FLOOR`
    for the heterogeneous grid, ``plan.PLAN_COMPACT_FLOOR`` for
    all-static candidate planes). The executor AOT-warms exactly this
    set per chunk, so mid-run compaction re-entry hits a pre-built
    executable too."""
    rows = int(sig[0])
    floor = int(floor)
    rest = tuple(sig[1:])
    out = [(rows,) + rest]
    while rows > floor:
        rows = max(rows // 4, floor)
        out.append((rows,) + rest)
    return tuple(out)
