"""JAX instantiation of the fabric kernels: jit + vmap at matrix scale.

The advance loop *and the controller decision layer* run on-device: a
per-scenario sweep function — the same :mod:`repro.eval.fabric.kernels`
fluid kernels plus the :mod:`repro.eval.fabric.controllers` decision
kernels (ProMC streak machine, laggard-ETA grants, SC cursor walk,
masked channel Open/Close/Move transitions, LIFO resume stack) — is
``vmap``-mapped over the scenario axis and iterated inside a
``jit``-compiled ``lax.while_loop``. Steady-state SC / MC / ProMC and
baseline scenarios therefore never leave the device: the per-scenario
host-sync count is O(1) instead of O(ticks).

For every *built-in* scheduler the loop is zero-host-round: timeline
recording streams into an on-device ring buffer (the
``kernels.timeline_push`` uniform-stride decimator, bit-identical to the
NumPy driver's), simultaneous multi-chunk completions drain through an
unrolled on-device handler loop, and the channel / resume-stack axes are
pre-sized from the driver's closed-form worst-case bound
(:meth:`FabricSimulation.capacity_need`), so the old capacity-guard park
classes cannot fire. A scenario *parks* (``stall``) only when its next
transition genuinely needs Python:

  * custom Scheduler subclasses (anything that is not exactly one of the
    three paper controllers or a no-op baseline) park at their callback
    events, exactly like the pre-fusion design;
  * the capacity guards (an SC open wave exceeding the pre-sized channel
    axis, a resume push into a full prepend stack) remain compiled in as
    an assertion-guarded fallback — unreachable for built-in schedulers,
    but a custom subclass that defeats the closed-form bound degrades to
    a one-sweep host replay instead of corrupting device state.

The host then replays exactly the NumPy driver's transition half
(:meth:`FabricSimulation._post`) for the parked rows and re-enters the
device loop. Scenarios are independent — their clocks may drift
arbitrarily — so this interleaving produces the same per-scenario event
sequence as the synchronized NumPy sweeps; ``eval.difftest`` holds all
backends to the event simulator within the 2% bar, and ``SYNC_STATS``
proves the zero-replay property on every run.

Numerics run in float64 via the scoped ``jax.experimental.enable_x64``
context (never the global flag: the rest of the repo traces in f32).

Execution-loop structure (the overlap-pipelined executor rides on it):

  * the jit boundary takes *(mutable, const, qsizes)* instead of one
    merged state dict, and only the mutable half is carried through the
    ``while_loop`` — the read-only tables are closed over as loop
    invariants, so the carry's double buffer covers state that actually
    changes, not the decision tables;
  * the mutable half is **donated** (``donate_argnums=0``) whenever
    :func:`donation_enabled` says so (default: on under the async
    executor, forced via ``REPRO_FABRIC_DONATE``), so steady-state sweeps
    update device buffers in place instead of allocating a second copy.
    Donated buffers are dead after the call — the driver re-uploads from
    host NumPy each round and never touches a donated array again;
  * each batch can be pinned to a device (``device=``) — the executor
    round-robins chunks across ``jax.devices()``;
  * :func:`warm_signature` AOT-compiles (``jit(...).lower().compile()``)
    the loop for a canonical :func:`bucketing.canonical_signature` before
    the first chunk needs it, taking the ~1 s/signature Python retrace
    off the critical path. ``SYNC_STATS`` merges are per-run atomic so
    interleaved chunks report the same totals as serial execution.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.simulator import SimResult, Simulation

from . import controllers, kernels
from .bucketing import COMPACT_FLOOR, MIN_ROW_PAD, bucket, qsizes_pad
from .driver import (
    _EPS,
    _NO_CHUNK,
    KIND_MC,
    KIND_PROMC,
    KIND_SC,
    FabricSimulation,
)
from .shim import jax_ops

_ERR_NONE, _ERR_MAXTIME, _ERR_STRANDED = 0, 1, 2
_STALL_NONE, _STALL_POST = 0, 1

#: cap on device sweeps per while_loop entry: parked scenarios wait for
#: the loop to exit before their Python decision runs, so unbounded entries
#: let one long trivial stretch starve every parked controller. With the
#: controller layer fused, parking is a rare edge — the quarter-cohort
#: early exit (compactable shapes only) still bounds any parked row's
#: wait there, and the cap bounds it everywhere else.
_ROUND_CAP = 2048

#: floor on the padded device row count. Straggler tails run thousands of
#: narrow sweeps whose cost is linear in the pad width, so a low floor is
#: what makes the endgame cheap; each extra power-of-two bucket costs one
#: more XLA trace at compile time. Once a round starts at the floor,
#: draining below half the cohort cannot shrink the device shape, so the
#: half-cohort early exit is skipped there (see ``_device_rounds``).
#: Aliased from :mod:`repro.eval.fabric.bucketing` — the canonical pad
#: ladder shared by the runner's chunk spans and the tuner's planes.
_MIN_PAD = MIN_ROW_PAD

#: host-sync + wall-clock telemetry. The accumulator lives in the
#: jax-free :mod:`repro.eval.fabric.stats` (the executor records build/
#: compute walls from NumPy runs too); these are the *same* objects, so
#: ``jax_backend.SYNC_STATS`` / ``reset_sync_stats`` keep their
#: historical spelling and reset both views in place.
from .stats import (  # noqa: E402,F401  (re-exported API)
    SYNC_STATS,
    _SYNC_LOCK,
    _merge_sync_stats,
    reset_sync_stats,
)


def _persistent_cache_active() -> bool:
    try:
        return bool(jax.config.jax_compilation_cache_dir)
    except Exception:
        return False


def donation_enabled(default: Optional[bool] = None) -> bool:
    """Resolve the buffer-donation toggle: ``REPRO_FABRIC_DONATE`` wins,
    else ``default`` (a driver kwarg), else on exactly when the async
    executor is active — ``REPRO_FABRIC_EXECUTOR=serial`` preserves the
    undonated pre-executor execution path byte for byte.

    A configured persistent compilation cache (``REPRO_XLA_CACHE`` /
    ``jax_compilation_cache_dir``) no longer blanket-disables donation.
    The underlying hazard — on jax 0.4.x CPU, donated executables of
    this program do not survive the cache's serialize/deserialize round
    trip, and a program read back from disk aliases stale buffers and
    produces nondeterministic garbage — only bites programs that
    *round-trip the cache*. Freshly-compiled donated programs in the
    same process are correct, so every donated compile now runs inside
    :func:`_suppress_persistent_cache` (donated executables are never
    serialized, hence never read back); only a signature whose donated
    compile failed falls back to the undonated cache-served program
    (see :meth:`JaxFabricSimulation._device_call`)."""
    env = os.environ.get("REPRO_FABRIC_DONATE")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no", "")
    if default is not None:
        return bool(default)
    from .executor import executor_mode

    return executor_mode() == "async"


_SUPPRESS_LOCK = threading.Lock()
_suppress_depth = 0
_suppressed_cache_dir: Optional[str] = None


@contextlib.contextmanager
def _suppress_persistent_cache():
    """Scoped (refcounted, process-wide) removal of the persistent
    compilation cache directory.

    Donated executables of the device loop must never be serialized to —
    or deserialized from — the persistent cache (the jax 0.4.x CPU
    aliasing bug above), so every donated compile runs inside this
    window. The config flag is process-global, hence the refcount: nested
    or concurrent donated compiles share one save/restore, and the worst
    case for an unrelated concurrent compile is one missed cache write,
    never a wrong program."""
    global _suppress_depth, _suppressed_cache_dir
    with _SUPPRESS_LOCK:
        if _suppress_depth == 0:
            saved = None
            try:
                saved = jax.config.jax_compilation_cache_dir
            except Exception:
                saved = None
            _suppressed_cache_dir = saved
            if saved:
                jax.config.update("jax_compilation_cache_dir", None)
        _suppress_depth += 1
    try:
        yield
    finally:
        with _SUPPRESS_LOCK:
            _suppress_depth -= 1
            if _suppress_depth == 0:
                if _suppressed_cache_dir:
                    jax.config.update(
                        "jax_compilation_cache_dir", _suppressed_cache_dir
                    )
                _suppressed_cache_dir = None


#: state arrays the device sweep may mutate (host <-> device sync set)
_MUTABLE = (
    "t", "done", "next_tick", "n_events", "dead", "rem", "busy",
    "chunk_done", "completed_at", "delivered", "delivered_at_tick",
    "rate_est", "queue_bytes", "qptr", "finish_t", "fin_any", "stall",
    "err", "chunk_of", "cap", "prepend_n", "prepend_sizes", "streak",
    "pair_fast", "pair_slow", "sc_cursor", "n_moves",
    "tl_t", "tl_rate", "tl_len", "tl_stride", "tl_seen", "tl_last_t",
    "tl_last_rate",
)
#: read-only inputs fixed for a batch's lifetime — device-cached, rebuilt
#: only when compaction changes the row set
_CONST_STATIC = (
    "max_time", "tick_period", "bw", "disk_rate", "sat_cc", "contention",
    "trivial_tick", "trivial_complete", "qoff", "qlen", "fsdt", "kind",
    "sc_order", "conc", "par", "cap_k", "avg_fs_k", "nfiles",
    "setup_cost", "promc_ratio", "promc_patience", "prof_t", "prof_mult",
    "n_chunks", "record_timeline", "cap_need",
)


def _views_row(ops, xp, row, chunk_of, busy, rem, queue_bytes, rate_est, K):
    """Per-row ChunkView arrays: (K,) channel counts, ETA inputs."""
    open_mask = chunk_of != _NO_CHUNK
    n_ch = ops.count_by_chunk(chunk_of, open_mask, K)
    n_open = xp.sum(open_mask)
    inflight = ops.chunk_scatter_add(
        xp.zeros_like(queue_bytes), chunk_of, rem, open_mask & busy
    )
    bytes_rem = queue_bytes + inflight
    pred = controllers.predicted_chunk_rate(
        ops, row["avg_fs_k"], row["cap_k"], row["fsdt"], n_ch, n_open,
        row["bw"], row["disk_rate"], row["sat_cc"], row["contention"],
    )
    eta = controllers.chunk_eta(ops, bytes_rem, rate_est, pred, row["chunk_done"])
    return bytes_rem, n_ch, eta


#: per-sweep scratch passed between the phases of one device sweep
#: (zero-initialized on upload so the while_loop carry keeps its shape)
_SCRATCH = ("_completed", "_handler", "_tick", "_moving", "_msrc", "_mdst")

#: the on-device timeline ring-buffer state threaded through phase A
_TIMELINE = (
    "tl_t", "tl_rate", "tl_len", "tl_stride", "tl_seen", "tl_last_t",
    "tl_last_rate",
)


def _phase_advance(
    row: dict, qsizes, with_stack: bool = True, coupled: bool = False
):
    """Phase A of one sweep (always runs): physics advance, park
    detection, queue feed, completion marking, tick EMA bookkeeping, and
    scenario-done detection — everything except the (rarer) controller
    handlers, which the batch-level driver gates behind ``lax.cond``.

    ``with_stack=False`` is the pure-FIFO feed variant the driver picks
    (batch-level ``lax.cond``) on sweeps where no resume file exists
    anywhere — the common case — skipping the resume-stack gathers whose
    cost scales with the pre-sized stack depth P.

    ``coupled=True`` is the shared-fabric variant: the coupled device
    loop pre-computes each row's granted pool (``row["_pool_ovr"]``, the
    cross-row ``waterfill_coupled`` output — the uncoupled pool verbatim
    for rows outside every fabric group) and the group lockstep horizon
    cap (``row["_dt_ovr"]``, +inf for uncoupled rows), and this phase
    substitutes them for its own pool / caps its own dt. Everything
    downstream of the two substitutions is the uncoupled sweep
    unchanged.
    """
    ops = jax_ops()
    xp = ops.xp
    K = row["chunk_done"].shape[-1]
    P = row["prepend_sizes"].shape[-1]

    runnable = (
        ~row["done"]
        & (row["stall"] == _STALL_NONE)
        & (row["err"] == _ERR_NONE)
    )
    err = xp.where(
        row["t"] > row["max_time"], _ERR_MAXTIME, _ERR_NONE
    )

    # ---- advance: rates, horizon, fluid byte movement ----
    transferring = row["busy"] & (row["dead"] <= _EPS)
    oh = row["chunk_of"][..., :, None] == xp.arange(K)
    n_ch_open = xp.sum(oh, axis=-2)
    stranded = (~xp.any(row["busy"])) & xp.any(
        ~row["chunk_done"] & (n_ch_open == 0)
    )
    err = xp.where((err == _ERR_NONE) & stranded, _ERR_STRANDED, err)
    # rows that are parked/done, or errored *this* sweep, freeze at their
    # pre-sweep state: zeroing dt and gating every transition mask below
    # makes the whole sweep a natural no-op for them — no commit masking
    alive = runnable & (err == _ERR_NONE)
    if row["prof_t"].shape[-1] == 1:  # static path: the common case
        eff_bw, next_prof = row["bw"], xp.inf
    else:
        prof_at = xp.sum(row["prof_t"] <= row["t"]) - 1
        mult = row["prof_mult"][xp.maximum(prof_at, 0)]
        eff_bw = row["bw"] * xp.where(prof_at >= 0, mult, 1.0)
        next_prof = xp.min(
            xp.where(row["prof_t"] > row["t"], row["prof_t"], xp.inf)
        )
    if coupled:
        pool = row["_pool_ovr"]
    else:
        pool = kernels.disk_pool(
            ops, xp.sum(transferring), eff_bw, row["disk_rate"],
            row["sat_cc"], row["contention"],
        )
    rates = kernels.waterfill(
        ops, xp.where(transferring, row["cap"], 0.0), pool
    )
    # ---- timeline ring buffer (pre-advance t, this sweep's rates) ----
    tl = {k: row[k] for k in _TIMELINE}
    if row["tl_t"].shape[-1] > 1:  # width-1 buffers mean "no row records"
        (
            tl["tl_t"], tl["tl_rate"], tl["tl_len"], tl["tl_stride"],
            tl["tl_seen"], tl["tl_last_t"], tl["tl_last_rate"],
        ) = kernels.timeline_push(
            ops, alive & row["record_timeline"], row["t"], xp.sum(rates),
            row["tl_t"], row["tl_rate"], row["tl_len"], row["tl_stride"],
            row["tl_seen"], row["tl_last_t"], row["tl_last_rate"],
        )
    dt = kernels.event_horizon(
        ops,
        xp.minimum(row["next_tick"] - row["t"], next_prof - row["t"]),
        row["busy"], row["dead"], transferring, row["rem"], rates,
    )
    if coupled:
        # lockstep: a fabric group shares one clock; members take the
        # group-minimum horizon (a partial advance crosses no threshold,
        # so the sweep is a natural no-op beyond the moved bytes)
        dt = xp.minimum(dt, row["_dt_ovr"])
    dt = xp.where(alive, dt, 0.0)
    t2 = row["t"] + dt
    busy2, dead2, rem2, moved, finished = kernels.advance_channels(
        ops, alive, dt, row["busy"], row["dead"], transferring,
        row["rem"], rates,
    )
    delivered2 = row["delivered"] + xp.sum(
        xp.where(oh & (moved != 0.0)[..., :, None], moved[..., :, None], 0.0),
        axis=-2,
    )
    fin_any = xp.where(alive, xp.any(finished), row["fin_any"])

    # ---- decision-point detection (pre-feed completion == post-feed:
    # feeding swaps queue files for busy channels, never zeroes both) ----
    files_left = row["qlen"] - row["qptr"] + row["prepend_n"]
    busy_pc = xp.sum(oh & busy2[..., :, None], axis=-2)
    comp_pre = ~row["chunk_done"] & (files_left == 0) & (busy_pc == 0)
    comp_any_pre = xp.any(comp_pre)
    tick_hit = t2 >= row["next_tick"] - _EPS
    kind = row["kind"]
    # SC / MC / ProMC route through the fused controller phases below;
    # KIND_STATIC (the autotuner's fixed-parameter candidate rows) sits
    # deliberately below KIND_SC — like the trivial baselines it acts
    # only at t=0, so it needs neither the handlers nor a host replay
    known = kind >= KIND_SC

    # Only *custom* scheduler subclasses still need Python: their
    # callbacks run through the scalar protocol on the host. Built-in
    # rows never park — multi-chunk same-sweep completions drain through
    # the on-device phase-B loop, and the channel / resume-stack axes
    # are pre-sized from the closed-form worst-case bound, so the
    # capacity guards below can never fire for them (``SYNC_STATS``/CI
    # gate on exactly that). The guards stay as defense in depth should
    # the bound ever be wrong: ``sc_short`` checks the *actual* free
    # columns against the next SC wave (single-wave conservative — the
    # static ``cap_need`` term covers the multi-wave drain) and
    # ``pp_full`` the *actual* stack depth, each degrading to a
    # one-sweep host replay (with growth) instead of corrupting device
    # state.
    C = row["chunk_of"].shape[-1]
    n_free = xp.sum(row["chunk_of"] == _NO_CHUNK)
    freed_cols = xp.sum(xp.where(comp_pre, n_ch_open, 0))
    sc_short = (
        (kind == KIND_SC)
        & comp_any_pre
        & (
            (row["cap_need"] > C)
            | (n_free + freed_cols < xp.max(row["conc"]))
        )
    )
    pp_full = (
        (kind == KIND_PROMC) & tick_hit & xp.any(row["prepend_n"] >= P)
    )
    needs_py = alive & (
        (comp_any_pre & ~row["trivial_complete"] & ~known)
        | (tick_hit & ~row["trivial_tick"] & (kind != KIND_PROMC))
        | sc_short
        | pp_full
    )
    ok = alive & ~needs_py

    # ---- feed (LIFO resume stack first, then FIFO queue) ----
    busy3, dead3, rem3, qptr3, qb3, pn3 = kernels.feed_queues(
        ops, ok, row["chunk_of"], busy2, dead2, rem2, qsizes,
        row["qoff"], row["qlen"], row["qptr"], row["queue_bytes"],
        row["fsdt"], row["prepend_sizes"] if with_stack else None,
        row["prepend_n"],
    )

    # ---- chunk completions: mark (handlers run in phase B) ----
    # post-feed busy count derives from the feed deltas (a fed channel is
    # exactly a queue/stack pop): no second per-chunk count needed
    busy_pc3 = busy_pc + (qptr3 - row["qptr"]) + (row["prepend_n"] - pn3)
    completed = (
        ~row["chunk_done"]
        & ((row["qlen"] - qptr3 + pn3) == 0)
        & (busy_pc3 == 0)
        & ok
    )
    chunk_done2 = row["chunk_done"] | completed
    qb4 = xp.where(completed, 0.0, qb3)
    completed_at2 = xp.where(completed, t2, row["completed_at"])
    comp_any = xp.any(completed)

    is_promc = kind == KIND_PROMC
    streak2 = xp.where(comp_any & is_promc, 0, row["streak"])
    pf2 = xp.where(comp_any & is_promc, -1, row["pair_fast"])
    ps2 = xp.where(comp_any & is_promc, -1, row["pair_slow"])

    # ---- tick EMA bookkeeping (the ProMC decision is phase C) ----
    do_tick = tick_hit & ok
    ema = kernels.tick_ema(
        ops, row["rate_est"], delivered2, row["delivered_at_tick"],
        row["tick_period"],
    )
    rate_est2 = xp.where(do_tick, ema, row["rate_est"])
    dat2 = xp.where(do_tick, delivered2, row["delivered_at_tick"])
    next_tick2 = row["next_tick"] + xp.where(
        do_tick, row["tick_period"], 0.0
    )

    # ---- scenario completion ----
    done2 = ok & xp.all(chunk_done2) & (fin_any | comp_any)
    finish_t2 = xp.where(done2, t2, row["finish_t"])

    # ---- commit ----
    # frozen rows (parked/done/errored) took dt=0 with every transition
    # mask gated on ``alive``/``ok``, so their arrays pass through
    # unchanged by construction — no per-array commit masking needed
    out = dict(row)
    out["err"] = xp.where(runnable, err, row["err"])
    out["t"] = t2
    out["n_events"] = row["n_events"] + xp.where(alive, 1, 0)
    out["busy"] = busy3
    out["dead"] = dead3
    out["rem"] = rem3
    out["delivered"] = delivered2
    out["fin_any"] = fin_any
    out["qptr"] = qptr3
    out["queue_bytes"] = qb4
    out["prepend_n"] = pn3
    out["chunk_done"] = chunk_done2
    out["completed_at"] = completed_at2
    out["rate_est"] = rate_est2
    out["delivered_at_tick"] = dat2
    out["next_tick"] = next_tick2
    out["streak"] = streak2
    out["pair_fast"] = pf2
    out["pair_slow"] = ps2
    out["finish_t"] = finish_t2
    out["done"] = row["done"] | done2
    out["stall"] = xp.where(needs_py, _STALL_POST, row["stall"])
    out.update(tl)
    # scratch for phases B-D (zeroed wherever this sweep didn't act)
    out["_completed"] = completed
    out["_handler"] = comp_any & known
    out["_tick"] = do_tick & is_promc
    out["_moving"] = xp.zeros_like(alive)
    return out


def _phase_complete(row: dict, qsizes):
    """Phase B, one drain step (runs only on sweeps where some row
    completed a chunk on a fused controller): the completion handler —
    SC close/cursor/open or MC/ProMC laggard grants — plus the
    post-action feed, for the *lowest-index* unhandled completed chunk
    of each row (``argmax`` of the remaining mask), which the handler
    then clears. The batch driver iterates this step in a ``lax.
    while_loop`` until every row's completions drain, mirroring the host
    ``_complete_ctrl``'s ascending ``for k in range(K)`` per row — so
    simultaneous multi-chunk completions (empty size classes at t=0,
    exact ties) no longer need a host replay, the common
    single-completion sweep pays one drain iteration, and only one
    handler body is ever compiled."""
    ops = jax_ops()
    xp = ops.xp
    K = row["chunk_done"].shape[-1]
    C = row["chunk_of"].shape[-1]
    kind = row["kind"]
    remaining = row["_completed"] & xp.expand_dims(row["_handler"], -1)
    trig = xp.any(remaining, axis=-1)
    k = xp.argmax(remaining, axis=-1)

    chunk_of_c, busy_c, dead_c, rem_c, cap_c = (
        row["chunk_of"], row["busy"], row["dead"], row["rem"], row["cap"],
    )
    qb_c, qptr_c, pn_c = (
        row["queue_bytes"], row["qptr"], row["prepend_n"],
    )
    nmoves_c = row["n_moves"]

    # SC: close the finished chunk, cursor past empties, open the next
    sc_t = trig & (kind == KIND_SC)
    chunk_of_c, busy_c, dead_c, rem_c, cap_c = controllers.close_chunk(
        ops, sc_t, k, chunk_of_c, busy_c, dead_c, rem_c, cap_c
    )
    cursor_c = controllers.sc_advance_cursor(
        ops, sc_t, row["sc_cursor"], row["sc_order"], row["nfiles"],
        row["n_chunks"],
    )
    open_ok = sc_t & (cursor_c < row["n_chunks"])
    nxt = row["sc_order"][xp.clip(cursor_c, 0, K - 1)]
    n_open = xp.where(open_ok, row["conc"][nxt], 0)
    chunk_of_c, dead_c, cap_c = controllers.open_ranked(
        ops, n_open, nxt, chunk_of_c, dead_c, cap_c,
        row["setup_cost"], row["cap_k"],
    )
    # MC / ProMC: freed channels to the largest-ETA laggards
    mc_t = trig & ((kind == KIND_MC) | (kind == KIND_PROMC))
    bytes_rem, n_ch, eta = _views_row(
        ops, xp, row, chunk_of_c, busy_c, rem_c, qb_c,
        row["rate_est"], K,
    )
    live = ~row["chunk_done"] & (xp.arange(K) != k) & (bytes_rem > 0)
    freed = xp.where(mc_t, n_ch[..., k], 0)
    grants, first = controllers.laggard_grants(
        ops, eta, n_ch, live, freed, C
    )
    acted = mc_t & (xp.sum(grants) > 0)
    (
        chunk_of_c, busy_c, dead_c, rem_c, cap_c, nmoves_c,
    ) = controllers.apply_grants(
        ops, acted, k, grants, first, chunk_of_c, busy_c, dead_c,
        rem_c, cap_c, nmoves_c, row["par"], row["cap_k"],
        row["setup_cost"],
    )
    busy_c, dead_c, rem_c, qptr_c, qb_c, pn_c = kernels.feed_queues(
        ops, sc_t | acted, chunk_of_c, busy_c, dead_c, rem_c, qsizes,
        row["qoff"], row["qlen"], qptr_c, qb_c, row["fsdt"],
        row["prepend_sizes"], pn_c,
    )
    # the handled chunk leaves the remaining-completions mask, so the
    # batch drain loop terminates after the deepest row's count
    cleared = row["_completed"] & ~(
        (xp.arange(K) == xp.expand_dims(k, -1)) & xp.expand_dims(trig, -1)
    )
    return dict(
        row, chunk_of=chunk_of_c, busy=busy_c, dead=dead_c, rem=rem_c,
        cap=cap_c, queue_bytes=qb_c, qptr=qptr_c, prepend_n=pn_c,
        sc_cursor=cursor_c, n_moves=nmoves_c, _completed=cleared,
    )


def _phase_tick(row: dict):
    """Phase C (runs only on sweeps where some ProMC row ticked): the
    streak state machine over the post-handler views; a firing row sets
    ``_moving`` for phase D."""
    ops = jax_ops()
    xp = ops.xp
    K = row["chunk_done"].shape[-1]
    pt = row["_tick"]
    bytes_rem, n_ch, eta = _views_row(
        ops, xp, row, row["chunk_of"], row["busy"], row["rem"],
        row["queue_bytes"], row["rate_est"], K,
    )
    live = ~row["chunk_done"] & (bytes_rem > 0)
    streak3, pf3, ps3, move, msrc, mdst = controllers.promc_tick(
        ops, eta, row["rate_est"], n_ch, live, row["streak"],
        row["pair_fast"], row["pair_slow"], row["promc_ratio"],
        row["promc_patience"],
    )
    return dict(
        row,
        streak=xp.where(pt, streak3, row["streak"]),
        pair_fast=xp.where(pt, pf3, row["pair_fast"]),
        pair_slow=xp.where(pt, ps3, row["pair_slow"]),
        _moving=pt & move,
        _msrc=xp.where(pt, msrc, 0),
        _mdst=xp.where(pt, mdst, 0),
    )


def _phase_move(row: dict, qsizes):
    """Phase D (runs only on sweeps where some ProMC row fired a move):
    one fast->slow channel move with the LIFO resume push, then feed."""
    ops = jax_ops()
    xp = ops.xp
    moving = row["_moving"]
    (
        chunk_of_c, busy_c, dead_c, rem_c, cap_c, qb_c, ps_sizes_c, pn_c,
        nmoves_c,
    ) = controllers.move_channel(
        ops, moving, row["_msrc"], row["_mdst"], row["chunk_of"],
        row["busy"], row["dead"], row["rem"], row["cap"],
        row["queue_bytes"], row["prepend_sizes"], row["prepend_n"],
        row["n_moves"], row["par"], row["cap_k"], row["setup_cost"],
    )
    busy_c, dead_c, rem_c, qptr_c, qb_c, pn_c = kernels.feed_queues(
        ops, moving, chunk_of_c, busy_c, dead_c, rem_c, qsizes,
        row["qoff"], row["qlen"], row["qptr"], qb_c, row["fsdt"],
        ps_sizes_c, pn_c,
    )
    return dict(
        row, chunk_of=chunk_of_c, busy=busy_c, dead=dead_c, rem=rem_c,
        cap=cap_c, queue_bytes=qb_c, qptr=qptr_c, prepend_n=pn_c,
        prepend_sizes=ps_sizes_c, n_moves=nmoves_c,
    )


#: the while_loop carry: everything the device may write. The read-only
#: tables (``_CONST_STATIC``) are *not* carried — they're closed over as
#: loop invariants — so the carry's double buffer, and the donation
#: aliasing below, cover exactly the state that changes.
_CARRY = _MUTABLE + _SCRATCH


def _device_rounds_fn(mut: dict, const: dict, qsizes, compact_floor: int):
    """Advance every runnable scenario to its own next Python decision
    point (or completion): vmapped sweeps inside lax.while_loop. Each
    sweep is phase A (always) plus controller phases B/C/D gated by
    batch-level ``lax.cond`` — completions, ProMC ticks, and fired moves
    are sparse across sweeps, so most iterations pay phase A alone.

    ``mut`` is the carried (and donatable) half; ``const`` the per-batch
    read-only tables, merged into the phase row-dicts each iteration and
    stripped before the carry closes. ``compact_floor`` is the *static*
    per-batch compaction floor (part of the program identity): it decides
    at trace time whether the early exit can ever lead anywhere.
    """
    import functools

    phase_a = jax.vmap(_phase_advance, in_axes=(0, None))
    phase_a_fifo = jax.vmap(
        functools.partial(_phase_advance, with_stack=False),
        in_axes=(0, None),
    )
    phase_b = jax.vmap(_phase_complete, in_axes=(0, None))
    phase_c = jax.vmap(_phase_tick)
    phase_d = jax.vmap(_phase_move, in_axes=(0, None))

    def runnable(st):
        return (
            ~st["done"]
            & (st["stall"] == _STALL_NONE)
            & (st["err"] == _ERR_NONE)
        )

    start_count = jnp.sum(runnable(mut))
    # the row axis is a static jit shape: whether an early exit can ever
    # lead anywhere is decided at trace time. Rows at (or below) this
    # batch's compaction floor can't shrink their device shape, so
    # exiting early would buy a full state download/re-upload for
    # nothing — those programs run to completion (or the sweep cap).
    # Above the floor the exit fraction follows the floor itself:
    # heterogeneous grid batches (deep ladder, floor 64) exit once half
    # the starting cohort has drained — straggler tails get compacted
    # down the rungs promptly — while all-static plane batches (shallow
    # ladder, floor 256) ride to a quarter cohort before syncing, since
    # their rows drain nearly together and each exit is a full state
    # download/re-upload.
    can_shrink = mut["done"].shape[0] > compact_floor
    exit_div = 2 if compact_floor < 256 else 4

    def cond(carry):
        st, it = carry
        n = jnp.sum(runnable(st))
        keep = (n > 0) & (it < _ROUND_CAP)
        if can_shrink:
            keep &= (exit_div * n > start_count) | (start_count <= _MIN_PAD)
        return keep

    def body(carry):
        st, it = carry
        st = {**st, **const}
        # resume files are rare: feed through the pure-FIFO phase-A
        # variant unless some row's stack holds one
        st = lax.cond(
            jnp.any(st["prepend_n"] > 0),
            lambda s: phase_a(s, qsizes),
            lambda s: phase_a_fifo(s, qsizes),
            st,
        )
        # drain completed chunks: each iteration handles every row's
        # lowest-index remaining completion (ascending k per row, the
        # host _complete_ctrl order) and clears it, so the loop runs
        # exactly as deep as the worst row's completion count — zero
        # iterations on the common no-completion sweep
        st = lax.while_loop(
            lambda s: jnp.any(s["_completed"] & s["_handler"][:, None]),
            lambda s: phase_b(s, qsizes),
            st,
        )
        st = lax.cond(
            jnp.any(st["_tick"]), phase_c, lambda s: s, st
        )
        st = lax.cond(
            jnp.any(st["_moving"]), lambda s: phase_d(s, qsizes),
            lambda s: s, st,
        )
        return {k: st[k] for k in _CARRY}, it + 1

    state, iters = lax.while_loop(cond, body, (dict(mut), 0))
    return state, iters


#: the undonated loop (exact pre-executor semantics: inputs stay live).
#: ``compact_floor`` is static: two batches with identical shapes but
#: different floors are different programs (the early-exit clause folds
#: at trace time)
_device_rounds = jax.jit(_device_rounds_fn, static_argnums=3)
#: the donated twin: the mutable carry updates in place, halving the
#: loop's peak device footprint. The driver re-uploads from host NumPy
#: every round, so donated inputs are never read again.
_device_rounds_donated = jax.jit(
    _device_rounds_fn, donate_argnums=0, static_argnums=3
)


def _row_demand(row: dict):
    """Per-row inputs to the cross-row coupling step: the uncoupled
    disk/bandwidth pool and the coupled *demand* — that pool clipped to
    the row's transferring channel caps, totalled with ``caps_total``
    (waterfill's own cumsum-of-sorted reduction, so an unsaturated grant
    reproduces the uncoupled water-fill bit for bit). Mirrors the phase-A
    prologue's physics read-only; the sweep itself recomputes nothing
    from these."""
    ops = jax_ops()
    xp = ops.xp
    runnable = (
        ~row["done"]
        & (row["stall"] == _STALL_NONE)
        & (row["err"] == _ERR_NONE)
    )
    transferring = row["busy"] & (row["dead"] <= _EPS)
    if row["prof_t"].shape[-1] == 1:
        eff_bw = row["bw"]
    else:
        prof_at = xp.sum(row["prof_t"] <= row["t"]) - 1
        mult = row["prof_mult"][xp.maximum(prof_at, 0)]
        eff_bw = row["bw"] * xp.where(prof_at >= 0, mult, 1.0)
    pool = kernels.disk_pool(
        ops, xp.sum(transferring), eff_bw, row["disk_rate"],
        row["sat_cc"], row["contention"],
    )
    caps_eff = xp.where(transferring, row["cap"], 0.0)
    demand = xp.minimum(pool, kernels.caps_total(ops, caps_eff))
    return runnable, pool, demand


def _row_horizon(row: dict, pool):
    """One row's own event horizon under an externally granted ``pool``
    — the per-member input to the group's lockstep minimum. Phase A then
    recomputes the identical dt and caps it with the group minimum."""
    ops = jax_ops()
    xp = ops.xp
    transferring = row["busy"] & (row["dead"] <= _EPS)
    if row["prof_t"].shape[-1] == 1:
        next_prof = xp.inf
    else:
        next_prof = xp.min(
            xp.where(row["prof_t"] > row["t"], row["prof_t"], xp.inf)
        )
    rates = kernels.waterfill(
        ops, xp.where(transferring, row["cap"], 0.0), pool
    )
    return kernels.event_horizon(
        ops,
        xp.minimum(row["next_tick"] - row["t"], next_prof - row["t"]),
        row["busy"], row["dead"], transferring, row["rem"], rates,
    )


def _device_rounds_coupled_fn(
    mut: dict, const: dict, qsizes, fab: dict, compact_floor: int
):
    """The shared-fabric twin of :func:`_device_rounds_fn`: identical
    vmapped phases, but every sweep starts with one cross-row coupling
    step — per-row demands (vmapped), one batch ``waterfill_coupled``
    over the (links x rows) membership table, per-row horizons under the
    grants (vmapped), and a segment-min over group ids for the lockstep
    dt — all inside the fused ``while_loop``, so coupled sweeps stay
    zero-host-round.

    ``fab`` carries ``gid`` (rows,) int64 (-1 == uncoupled, pad rows
    included), ``member`` (L, rows) bool, ``link_cap`` (L,) f64 (pad
    links hold cap 0 and no members — their water level is +inf, which
    the member-min ignores), and ``gslot`` (G,) f64 zeros whose only job
    is giving the group axis a static shape for the segment-min.

    No early-exit clause: coupled batches never compact (a done tenant
    already releases its link share via zero demand, and a frozen row
    set keeps the membership table and one compiled program for the
    whole run), so exiting early buys a host sync for nothing.
    """
    import functools

    ops = jax_ops()
    phase_a = jax.vmap(
        functools.partial(_phase_advance, coupled=True), in_axes=(0, None)
    )
    phase_a_fifo = jax.vmap(
        functools.partial(_phase_advance, with_stack=False, coupled=True),
        in_axes=(0, None),
    )
    phase_b = jax.vmap(_phase_complete, in_axes=(0, None))
    phase_c = jax.vmap(_phase_tick)
    phase_d = jax.vmap(_phase_move, in_axes=(0, None))
    demand_v = jax.vmap(_row_demand)
    horizon_v = jax.vmap(_row_horizon)

    gid = fab["gid"]
    member = fab["member"]
    link_cap = fab["link_cap"]
    G = fab["gslot"].shape[0]
    gclip = jnp.clip(gid, 0, G - 1)
    in_group = gid >= 0

    def runnable(st):
        return (
            ~st["done"]
            & (st["stall"] == _STALL_NONE)
            & (st["err"] == _ERR_NONE)
        )

    def cond(carry):
        st, it = carry
        return (jnp.sum(runnable(st)) > 0) & (it < _ROUND_CAP)

    def body(carry):
        st, it = carry
        st = {**st, **const}
        live, pool, demand = demand_v(st)
        grant, _ = kernels.waterfill_coupled(
            ops, jnp.where(live & in_group, demand, 0.0), member, link_cap
        )
        pool_ovr = jnp.where(in_group, grant, pool)
        dt_own = horizon_v(st, pool_ovr)
        g_dt = (
            jnp.full((G,), jnp.inf)
            .at[gclip]
            .min(jnp.where(live & in_group, dt_own, jnp.inf))
        )
        st["_pool_ovr"] = pool_ovr
        st["_dt_ovr"] = jnp.where(in_group, g_dt[gclip], jnp.inf)
        st = lax.cond(
            jnp.any(st["prepend_n"] > 0),
            lambda s: phase_a(s, qsizes),
            lambda s: phase_a_fifo(s, qsizes),
            st,
        )
        st = lax.while_loop(
            lambda s: jnp.any(s["_completed"] & s["_handler"][:, None]),
            lambda s: phase_b(s, qsizes),
            st,
        )
        st = lax.cond(jnp.any(st["_tick"]), phase_c, lambda s: s, st)
        st = lax.cond(
            jnp.any(st["_moving"]), lambda s: phase_d(s, qsizes),
            lambda s: s, st,
        )
        return {k: st[k] for k in _CARRY}, it + 1

    state, iters = lax.while_loop(cond, body, (dict(mut), 0))
    return state, iters


#: the coupled loop and its donated twin. ``fab`` rides the jit
#: signature through its array shapes (L, G, rows) — bucketed to the
#: pow2 ladder by ``_upload_fabric`` so the program count stays bounded.
_device_rounds_coupled = jax.jit(
    _device_rounds_coupled_fn, static_argnums=4
)
_device_rounds_coupled_donated = jax.jit(
    _device_rounds_coupled_fn, donate_argnums=0, static_argnums=4
)


# ------------------------------------------------------------------ #
# AOT warm-start: pre-compile the canonical-signature ladder
# ------------------------------------------------------------------ #

#: per-key shape templates over the canonical signature axes
#: (rows, C, K, P, B, T, Q); ``signature_shapes`` instantiates them.
#: Kept explicit — and honest via the test that diffs it against a real
#: ``_upload`` — because AOT avals must match runtime uploads exactly.
_F64, _I64, _BOOL = np.float64, np.int64, np.bool_
_SHAPE_TABLE = {
    # mutable scalars (rows,)
    "t": ("S", _F64), "next_tick": ("S", _F64), "finish_t": ("S", _F64),
    "tl_last_t": ("S", _F64), "tl_last_rate": ("S", _F64),
    "done": ("S", _BOOL), "fin_any": ("S", _BOOL),
    "n_events": ("S", _I64), "stall": ("S", _I64), "err": ("S", _I64),
    "streak": ("S", _I64), "pair_fast": ("S", _I64),
    "pair_slow": ("S", _I64), "sc_cursor": ("S", _I64),
    "n_moves": ("S", _I64), "tl_len": ("S", _I64),
    "tl_stride": ("S", _I64), "tl_seen": ("S", _I64),
    # channel axis (rows, C)
    "dead": ("SC", _F64), "rem": ("SC", _F64), "cap": ("SC", _F64),
    "busy": ("SC", _BOOL), "chunk_of": ("SC", _I64),
    # chunk axis (rows, K)
    "chunk_done": ("SK", _BOOL), "completed_at": ("SK", _F64),
    "delivered": ("SK", _F64), "delivered_at_tick": ("SK", _F64),
    "rate_est": ("SK", _F64), "queue_bytes": ("SK", _F64),
    "qptr": ("SK", _I64), "prepend_n": ("SK", _I64),
    # resume stack + timeline ring
    "prepend_sizes": ("SKP", _F64),
    "tl_t": ("ST", _F64), "tl_rate": ("ST", _F64),
    # per-sweep scratch
    "_completed": ("SK", _BOOL), "_handler": ("S", _BOOL),
    "_tick": ("S", _BOOL), "_moving": ("S", _BOOL),
    "_msrc": ("S", _I64), "_mdst": ("S", _I64),
    # read-only tables
    "max_time": ("S", _F64), "tick_period": ("S", _F64),
    "bw": ("S", _F64), "disk_rate": ("S", _F64),
    "contention": ("S", _F64), "setup_cost": ("S", _F64),
    "promc_ratio": ("S", _F64),
    "trivial_tick": ("S", _BOOL), "trivial_complete": ("S", _BOOL),
    "record_timeline": ("S", _BOOL),
    "sat_cc": ("S", _I64), "kind": ("S", _I64),
    "promc_patience": ("S", _I64), "n_chunks": ("S", _I64),
    "cap_need": ("S", _I64),
    "qoff": ("SK", _I64), "qlen": ("SK", _I64), "sc_order": ("SK", _I64),
    "conc": ("SK", _I64), "par": ("SK", _I64), "nfiles": ("SK", _I64),
    "fsdt": ("SK", _F64), "cap_k": ("SK", _F64), "avg_fs_k": ("SK", _F64),
    "prof_t": ("SB", _F64), "prof_mult": ("SB", _F64),
}


def signature_shapes(
    sig: Tuple[int, ...], device=None
) -> Tuple[dict, dict, jax.ShapeDtypeStruct]:
    """``(mut, const, qsizes)`` aval pytrees for one canonical signature
    ``(rows, C, K, P, B, T, Q)`` — exactly what :meth:`JaxFabricSimulation.
    _upload` produces for a batch occupying that signature, so
    ``jit(...).lower(*signature_shapes(sig)).compile()`` pre-builds the
    very executable the runtime call will look up."""
    rows, C, K, P, B, T, Q = sig
    dims = {
        "S": (rows,), "SC": (rows, C), "SK": (rows, K),
        "SKP": (rows, K, P), "ST": (rows, T), "SB": (rows, B),
    }
    sharding = None
    if device is not None:
        from jax.sharding import SingleDeviceSharding

        sharding = SingleDeviceSharding(device)

    def aval(shape, dt):
        if sharding is not None:
            return jax.ShapeDtypeStruct(shape, dt, sharding=sharding)
        return jax.ShapeDtypeStruct(shape, dt)

    mut = {k: aval(dims[_SHAPE_TABLE[k][0]], _SHAPE_TABLE[k][1])
           for k in _CARRY}
    const = {k: aval(dims[_SHAPE_TABLE[k][0]], _SHAPE_TABLE[k][1])
             for k in _CONST_STATIC}
    return mut, const, aval((Q,), _F64)


_AOT_LOCK = threading.Lock()
#: ``(sig, device, donate) -> jax.stages.Compiled`` (``None`` records a
#: failed warm so the jit fallback handles that signature quietly)
_AOT_CACHE: dict = {}
#: in-flight warms: waiters block on the event instead of re-compiling
_AOT_PENDING: dict = {}


# ------------------------------------------------------------------ #
# Trace cache: serialized jax.export blobs alongside the XLA cache
# ------------------------------------------------------------------ #
#
# The persistent XLA cache only skips the *backend compile*; every fresh
# process still pays ~1 s of Python trace + StableHLO lowering per
# program before the cache can even be consulted. ``jax.export``
# captures exactly that lowered artifact, so warm processes deserialize
# the StableHLO from disk (~15 ms) and hand it straight to XLA — whose
# persistent cache then returns the executable — instead of re-tracing.
# Cold and warm paths both compile the *exported* call so they share one
# HLO identity (and one XLA cache entry) per program.
#
# Blobs are keyed on the signature plus a digest of the jax version and
# every source file in this package — any edit to the traced code (or
# the constants it closes over) invalidates the whole trace cache.
# Donated programs are excluded (donation metadata does not survive the
# export round trip; donated compiles run inside the cache-suppression
# window instead, so they never reach this cache either).

_EXPORT_DIGEST: Optional[str] = None


def _export_digest() -> str:
    """Digest of everything the device-loop trace can depend on: the jax
    version plus the bytes of every ``.py`` file in this package."""
    global _EXPORT_DIGEST
    if _EXPORT_DIGEST is None:
        import hashlib

        h = hashlib.sha256(jax.__version__.encode())
        pkg = os.path.dirname(os.path.abspath(__file__))
        for name in sorted(os.listdir(pkg)):
            if name.endswith(".py"):
                with open(os.path.join(pkg, name), "rb") as f:
                    h.update(name.encode())
                    h.update(f.read())
        _EXPORT_DIGEST = h.hexdigest()[:32]
    return _EXPORT_DIGEST


def _export_path(sig, floor: int) -> Optional[str]:
    """Blob path for one signature, or None when no persistent cache
    directory is configured (no point caching traces the process can't
    amortize across runs)."""
    base = None
    try:
        base = jax.config.jax_compilation_cache_dir
    except Exception:
        return None
    if not base:
        return None
    name = "rounds-{}-f{}-{}.stablehlo".format(
        "x".join(str(int(x)) for x in sig), int(floor), _export_digest()
    )
    return os.path.join(base, "exports", name)


def _exported_rounds(sig, shapes, floor: int):
    """The exported device loop for ``sig``: deserialized from the blob
    cache when present, else traced now and written back (best effort).
    ``shapes`` must be the device-free avals — sharding is applied later
    at compile time, keeping one blob valid for every device."""
    from jax import export as jax_export

    path = _export_path(sig, floor)
    if path is not None and os.path.exists(path):
        try:
            with open(path, "rb") as f:
                return jax_export.deserialize(f.read())
        except Exception:
            pass  # stale/corrupt blob: fall through to a fresh trace
    exp = jax_export.export(_device_rounds)(*shapes, int(floor))
    if path is not None:
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = "{}.tmp.{}".format(path, os.getpid())
            with open(tmp, "wb") as f:
                f.write(exp.serialize())
            os.replace(tmp, path)
        except Exception:
            pass
    return exp


def _aot_key(sig, device, donate, floor):
    return (tuple(int(x) for x in sig), device, bool(donate), int(floor))


def warm_signature(
    sig, device=None, donate: Optional[bool] = None,
    floor: int = COMPACT_FLOOR,
) -> bool:
    """AOT-compile the device loop for one canonical signature (exactly
    once per ``(sig, device, donate)`` process-wide; concurrent callers
    wait). Returns True if this call did the compile. The executor warms
    each chunk's signature — and its compaction rungs — from a background
    thread while earlier chunks compute, so by the time a chunk reaches
    the device its executable already exists and the ~1 s/signature
    Python retrace never lands on the critical path."""
    donate = donation_enabled(donate)
    key = _aot_key(sig, device, donate, floor)
    with _AOT_LOCK:
        if key in _AOT_CACHE:
            return False
        ev = _AOT_PENDING.get(key)
        if ev is not None:
            owner = False
        else:
            ev = threading.Event()
            _AOT_PENDING[key] = ev
            owner = True
    if not owner:
        ev.wait()
        return False
    compiled = None
    try:
        from jax.experimental import enable_x64

        # x64 is thread-local: the warm thread needs its own context so
        # the traced avals match the runtime's f64 uploads
        with enable_x64():
            if not donate and _persistent_cache_active():
                exp = _exported_rounds(
                    sig, signature_shapes(sig, None), floor
                )
                compiled = (
                    jax.jit(exp.call)
                    .lower(*signature_shapes(sig, device))
                    .compile()
                )
            elif donate:
                # donated executables must never enter the persistent
                # cache (they don't survive its serialize/deserialize
                # round trip on jax 0.4.x CPU): compile them inside the
                # cache-suppression window so only fresh programs exist
                with _suppress_persistent_cache():
                    compiled = _device_rounds_donated.lower(
                        *signature_shapes(sig, device), int(floor)
                    ).compile()
            else:
                compiled = _device_rounds.lower(
                    *signature_shapes(sig, device), int(floor)
                ).compile()
    except Exception:
        compiled = None  # fall back to plain jit for this signature
    finally:
        with _AOT_LOCK:
            _AOT_CACHE[key] = compiled
            _AOT_PENDING.pop(key, None)
        ev.set()
    return compiled is not None


def _aot_lookup(sig, device, donate, floor):
    """The compiled executable for a signature, waiting out an in-flight
    warm (the warm thread is already doing the same compile the jit
    fallback would pay); None if never warmed or the warm failed."""
    key = _aot_key(sig, device, donate, floor)
    with _AOT_LOCK:
        exe = _AOT_CACHE.get(key)
        ev = _AOT_PENDING.get(key)
    if exe is not None:
        return exe
    if ev is not None:
        ev.wait()
        with _AOT_LOCK:
            return _AOT_CACHE.get(key)
    return None


def reset_aot_cache() -> None:
    with _AOT_LOCK:
        _AOT_CACHE.clear()


def compiled_program_count() -> int:
    """Compiled executables for the device loop across all entry points:
    the undonated jit, the donated twin, and the AOT warm cache — the
    bench's compile-tax telemetry and the bucketing tests count this."""
    with _AOT_LOCK:
        aot = sum(1 for v in _AOT_CACHE.values() if v is not None)
    return (
        aot
        + _device_rounds._cache_size()
        + _device_rounds_donated._cache_size()
        + _device_rounds_coupled._cache_size()
        + _device_rounds_coupled_donated._cache_size()
    )


class JaxFabricSimulation(FabricSimulation):
    """FabricSimulation driven by the jit/vmap device loop.

    Host state (the parent's NumPy arrays) stays canonical; each round
    uploads it, lets the device run every scenario to its next decision
    point (usually: completion), downloads, and replays the parent's
    Python half for parked rows. Custom-scheduler bookkeeping (callback
    objects, views) is inherited unchanged.

    ``device`` pins every upload (and the AOT executable) to one
    ``jax.Device`` — the executor round-robins chunks across
    ``jax.devices()`` this way; None uses the default placement.
    ``donate`` overrides :func:`donation_enabled` for this batch.
    """

    #: the executor passes ``device=`` only to drivers that advertise it
    supports_device_placement = True

    def __init__(
        self,
        sims: Sequence[Simulation],
        names: Optional[Sequence[str]] = None,
        *,
        device=None,
        donate: Optional[bool] = None,
        **kwargs,
    ):
        super().__init__(sims, names=names, **kwargs)
        self.device = device
        self.donate = donation_enabled(donate)

    # -------------------------------------------------------------- #

    def _row_arrays(self) -> tuple:
        return super()._row_arrays() + ("_stall",)

    def _pad_rows(self) -> int:
        """Row count uploaded to the device: next power of two >= live rows
        (min ``_MIN_PAD``). Padded rows are born ``done`` and never sweep;
        ``_pad_floor`` (set by the one-rung compaction policy below) keeps
        the post-compaction shape on a deterministic ladder rung instead
        of wherever the live count happened to land."""
        return bucket(max(self.S, getattr(self, "_pad_floor", 0)), _MIN_PAD)

    def _padded(self, key: str, arr: np.ndarray, pad: int):
        if pad:
            fill = np.ones if key == "done" else np.zeros
            arr = np.concatenate(
                [arr, fill((pad,) + arr.shape[1:], dtype=arr.dtype)]
            )
        if self.device is not None:
            return jax.device_put(arr, self.device)
        return jnp.asarray(arr)

    def _to_device(self, arr):
        if self.device is not None:
            return jax.device_put(arr, self.device)
        return jnp.asarray(arr)

    def _upload(self) -> Tuple[dict, dict]:
        """Fresh device buffers for one round: ``(mut, const)``. ``mut``
        is rebuilt from host NumPy every round — which is what makes
        donating it safe — while the read-only ``const`` tables are
        device-cached until compaction/growth reshapes the rows."""
        pad = self._pad_rows() - self.S
        rows = self.S + pad
        mut = {}
        for key in _MUTABLE:
            if key == "stall":
                arr = self._stall
            elif key == "err":
                arr = np.zeros(self.S, dtype=np.int64)
            else:
                arr = getattr(self, key)
            mut[key] = self._padded(key, arr, pad)
        # per-sweep scratch threaded between the device phases
        mut["_completed"] = self._to_device(
            np.zeros((rows, self.K), dtype=bool)
        )
        for key in ("_handler", "_tick", "_moving"):
            mut[key] = self._to_device(np.zeros(rows, dtype=bool))
        for key in ("_msrc", "_mdst"):
            mut[key] = self._to_device(np.zeros(rows, dtype=np.int64))
        # statics are immutable for a given row set: cache on device and
        # rebuild only when compaction (or channel growth) reshapes rows
        cache_key = (self.S, self.C, self.P, pad)
        if getattr(self, "_static_cache_key", None) != cache_key:
            self._static_cache = {
                key: self._padded(key, getattr(self, key), pad)
                for key in _CONST_STATIC
            }
            self._static_cache_key = cache_key
        return mut, self._static_cache

    def _upload_fabric(self) -> dict:
        """Device form of the batch's coupling arrays, padded row-wise to
        the device row bucket (pad rows: gid -1, no memberships) and with
        the link / group axes bucketed to the pow2 ladder (pad links hold
        cap 0 and no members — water level +inf, invisible to the
        member-min; pad group slots only ever hold the +inf identity).
        Built once per run: coupled batches never compact or grow, so the
        shapes — and the one compiled coupled program — stay fixed."""
        rows = self._pad_rows()
        S = self.S
        L = int(self.link_cap.shape[0])
        Lp = bucket(max(L, 1), 2)
        Gp = bucket(max(self._n_groups, 1), 2)
        gid = np.full(rows, -1, dtype=np.int64)
        gid[:S] = self.group_id
        member = np.zeros((Lp, rows), dtype=bool)
        member[:L, :S] = self.link_member
        caps = np.zeros(Lp, dtype=np.float64)
        caps[:L] = self.link_cap
        return {
            "gid": self._to_device(gid),
            "member": self._to_device(member),
            "link_cap": self._to_device(caps),
            "gslot": self._to_device(np.zeros(Gp, dtype=np.float64)),
        }

    def _rounds_signature(self) -> Tuple[int, ...]:
        """The canonical signature of the *current* device shape (it
        walks down the rows ladder as compaction fires) — the AOT-cache
        key the next ``_device_call`` will look up."""
        return (
            self._pad_rows(), self.C, self.K, self.P,
            self.prof_t.shape[1], self.tl_t.shape[1], self._q_pad,
        )

    def _device_call(self, mut: dict, const: dict, qsizes):
        """One device round through the best available executable: the
        AOT-warmed one when the executor pre-built it, else the jit twin
        matching this batch's donation mode.

        Coupled batches never consult the AOT cache — the executor warms
        *uncoupled* signatures, and a shape-compatible uncoupled
        executable would silently run the wrong physics — they go
        straight to the coupled jit twins (donated ones inside the
        cache-suppression window, like every donated compile).

        Donated batches under a persistent compilation cache resolve via
        a synchronous AOT warm (whose compile runs cache-suppressed), so
        a donated executable never round-trips the cache; if that warm
        fails, the batch drops to the undonated cache-served program for
        the rest of its run instead of risking stale-buffer aliasing.
        """
        floor = self.compact_floor()
        if self.coupled:
            if self.donate:
                if _persistent_cache_active():
                    with _suppress_persistent_cache():
                        return _device_rounds_coupled_donated(
                            mut, const, qsizes, self._fab_dev, floor
                        )
                return _device_rounds_coupled_donated(
                    mut, const, qsizes, self._fab_dev, floor
                )
            return _device_rounds_coupled(
                mut, const, qsizes, self._fab_dev, floor
            )
        sig = self._rounds_signature()
        exe = _aot_lookup(sig, self.device, self.donate, floor)
        if exe is not None:
            return exe(mut, const, qsizes)
        if self.donate and _persistent_cache_active():
            warm_signature(sig, self.device, True, floor)
            exe = _aot_lookup(sig, self.device, True, floor)
            if exe is not None:
                return exe(mut, const, qsizes)
            self.donate = False
            exe = _aot_lookup(sig, self.device, False, floor)
            if exe is not None:
                return exe(mut, const, qsizes)
            return _device_rounds(mut, const, qsizes, floor)
        fn = _device_rounds_donated if self.donate else _device_rounds
        return fn(mut, const, qsizes, floor)

    def _download(self, state: dict) -> None:
        for key in _MUTABLE:
            if key == "err":
                continue
            # np.array (not asarray): device buffers are zero-copy
            # read-only views, and the host half mutates these in place
            arr = np.array(state[key][: self.S])
            setattr(self, "_stall" if key == "stall" else key, arr)
        err = np.asarray(state["err"][: self.S])
        if err.any():
            s = int(np.flatnonzero(err)[0])
            if err[s] == _ERR_MAXTIME:
                raise RuntimeError(
                    f"batch scenario {self.rt[s].name!r} exceeded max_time="
                    f"{self.max_time[s]}s (t={self.t[s]:.1f})"
                )
            r = self.rt[s]
            live = np.flatnonzero(~self.chunk_done[s])
            raise RuntimeError(
                f"scheduler {r.scheduler.name} stranded chunks "
                f"{[r.chunks[int(k)].name for k in live]} in {r.name!r}"
            )

    # -------------------------------------------------------------- #

    def run(self) -> List[SimResult]:
        from jax.experimental import enable_x64

        all_rt = list(self.rt)
        self.start()
        # pre-size the channel and resume-stack axes from the closed-form
        # worst-case bound: every built-in scheduler then fits the device
        # shape for its whole run, so the capacity-guard park classes
        # (SC open waves, resume-stack overflow) can never fire
        need_c, need_p = self.capacity_need()
        while self.C < need_c:
            self._grow()
        while self.P < need_p:
            self._grow_prepend()
        with enable_x64():
            self._drive()
        return [self._result(r) for r in all_rt]

    def _maybe_compact(self) -> None:
        """Compaction policy for the device loop: one deterministic
        quarter-step rung, then stop.

        The parent compacts whenever half the batch is done — right for
        NumPy, where a rebuild is free and sweep cost tracks live rows.
        Here every rebuild that shrinks the padded row bucket is a fresh
        jit signature, and each signature costs seconds of *retrace* per
        process even when the persistent cache supplies the compiled
        executable (tracing is Python, the cache only skips XLA).
        Walking every pow2 rung (1024 -> 512 -> ... -> 16) spent more
        wall time tracing than the narrower sweeps saved. So: when the
        live rows fit a 4x smaller pad, compact to exactly ``pad // 4``
        (pinned via ``_pad_floor`` even if far fewer rows survive) and
        stop at this batch's :meth:`compact_floor` device shape — a
        1024-row grid chunk occupies exactly {1024, 256, 64}, never a
        stray 512/128 rung from wherever the live count happened to
        land, and an all-static candidate-plane chunk stops at 256
        (its rows drain together; the narrow tail rungs only buy extra
        host syncs there).
        """
        if self.coupled:
            # frozen row set: membership table, group ids, and the one
            # compiled coupled program stay valid for the whole run
            return
        floor = self.compact_floor()
        live = self.S - int(self.done.sum())
        pad = self._pad_rows()
        if pad > floor and bucket(live, _MIN_PAD) * 4 <= pad:
            self._pad_floor = max(pad // 4, floor)
            self._compact()

    def _drive(self) -> None:
        self._stall = np.zeros(self.S, dtype=np.int64)
        # accumulate host-sync telemetry privately and merge once at the
        # end: under the pipelined executor several batches drive
        # concurrently, and per-increment writes to the module-global
        # counters would interleave (same totals, but torn reads for any
        # observer); one locked merge per run keeps SYNC_STATS exactly
        # serial-equivalent
        stats = {k: 0 for k in SYNC_STATS}
        stats["runs"] = 1
        stats["scenarios"] = self.S
        # the flat file-size buffer is a jit-signature axis too — its raw
        # length is the batch's total file count, different for every
        # chunk, which made every chunk a fresh XLA compile. Zero-pad to
        # the quarter-step ladder; the feed kernel only reads qoff+qptr <
        # qoff+qlen, so the pad slots are dead weight (8 B each), not
        # semantics
        self._q_pad = qsizes_pad(self.qsizes.shape[0])
        qsizes_dev = self._to_device(
            np.concatenate(
                [self.qsizes, np.zeros(self._q_pad - self.qsizes.shape[0])]
            )
        )
        if self.coupled:
            self._fab_dev = self._upload_fabric()
        try:
            while not self.done.all():
                progressed = False
                runnable = ~self.done & (self._stall == _STALL_NONE)
                if runnable.any():
                    mut, const = self._upload()
                    state, iters = self._device_call(mut, const, qsizes_dev)
                    # donated inputs are dead past this point; the next
                    # round re-uploads from the host arrays _download
                    # refreshes, so nothing reads them again
                    del mut
                    t0 = time.perf_counter()
                    self._download(state)
                    stats["download_wall_s"] += time.perf_counter() - t0
                    stats["rounds"] += 1
                    progressed = int(iters) > 0
                post_rows = ~self.done & (self._stall == _STALL_POST)
                if post_rows.any():
                    # custom-scheduler callbacks (or a capacity guard a
                    # custom subclass defeated): replay the NumPy
                    # transition half
                    stats["replay_rounds"] += 1
                    stats["post_row_replays"] += int(post_rows.sum())
                    self._post(post_rows)
                    self._stall[post_rows] = _STALL_NONE
                    progressed = True
                if not progressed:
                    raise RuntimeError(
                        "jax fabric backend made no progress; device loop "
                        f"exited with {int(runnable.sum())} runnable rows"
                    )
                self._maybe_compact()
        finally:
            _merge_sync_stats(stats)
