"""JAX instantiation of the fabric kernels: jit + vmap at matrix scale.

The inter-decision advance loop runs entirely on-device: a per-scenario
sweep function (the same :mod:`repro.eval.fabric.kernels` the NumPy driver
uses, on ``(C,)``/``(K,)`` rows) is ``vmap``-mapped over the scenario axis
and iterated inside a ``jit``-compiled ``lax.while_loop``. Scenarios whose
next transition needs Python — a non-trivial controller tick or chunk
completion, or queued resume files whose LIFO order lives in host lists —
*park* (``stall``) at that decision point while the rest keep sweeping;
the loop exits when every live scenario is parked. The host then replays
exactly the NumPy driver's Python half (:meth:`FabricSimulation._post` /
``step``) for the parked rows and re-enters the device loop, so each
host round-trip amortizes over every scenario's full run-up to its next
decision instead of costing one sync per event.

Scenarios are independent — their clocks may drift arbitrarily — so this
interleaving produces the same per-scenario event sequence as the
synchronized NumPy sweeps; ``eval.difftest`` holds all backends to the
event simulator within the 2% bar.

Numerics run in float64 via the scoped ``jax.experimental.enable_x64``
context (never the global flag: the rest of the repo traces in f32).
Timeline-recording scenarios are permanently parked and advance through
the host path, which appends their (t, rate) samples.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.simulator import SimResult, Simulation

from . import kernels
from .driver import _EPS, _NO_CHUNK, FabricSimulation
from .shim import jax_ops

_ERR_NONE, _ERR_MAXTIME, _ERR_STRANDED = 0, 1, 2
_STALL_NONE, _STALL_POST, _STALL_FULL = 0, 1, 2

#: cap on device sweeps per while_loop entry: parked scenarios wait for
#: the loop to exit before their Python decision runs, so unbounded entries
#: let one long trivial stretch starve every parked controller. Bounded
#: entries + the half-parked early exit keep rows rejoining promptly while
#: still amortizing hundreds of events per host round-trip.
_ROUND_CAP = 512

#: state arrays the device sweep may mutate (host <-> device sync set)
_MUTABLE = (
    "t", "done", "next_tick", "n_events", "dead", "rem", "busy",
    "chunk_done", "completed_at", "delivered", "delivered_at_tick",
    "rate_est", "queue_bytes", "qptr", "finish_t", "fin_any", "stall",
    "err",
)
#: read-only inputs the Python half may rewrite between rounds
#: (scheduler actions retarget channels; feeds consume resume files)
_CONST_PY = ("has_prepend", "chunk_of", "cap", "prepend_n")
#: read-only inputs fixed for a batch's lifetime — device-cached, rebuilt
#: only when compaction changes the row set
_CONST_STATIC = (
    "max_time", "tick_period", "bw", "disk_rate", "sat_cc", "contention",
    "trivial_tick", "trivial_complete", "qoff", "qlen", "fsdt",
)
_CONST = _CONST_PY + _CONST_STATIC


def _sweep_row(row: dict, qsizes):
    """One event sweep of a single scenario (vmapped over the batch).

    Mirrors ``FabricSimulation._advance`` + the vector branches of
    ``_post``; rows whose transition needs Python set ``stall`` and keep
    their post-advance state for the host to finish.
    """
    ops = jax_ops()
    xp = ops.xp
    K = row["chunk_done"].shape[-1]

    runnable = (
        ~row["done"]
        & (row["stall"] == _STALL_NONE)
        & (row["err"] == _ERR_NONE)
    )
    err = xp.where(
        row["t"] > row["max_time"], _ERR_MAXTIME, _ERR_NONE
    )

    # ---- advance (P1): rates, horizon, fluid byte movement ----
    transferring = row["busy"] & (row["dead"] <= _EPS)
    pool = kernels.disk_pool(
        ops, xp.sum(transferring), row["bw"], row["disk_rate"],
        row["sat_cc"], row["contention"],
    )
    rates = kernels.waterfill(
        ops, xp.where(transferring, row["cap"], 0.0), pool
    )
    held = ops.count_by_chunk(
        row["chunk_of"], row["chunk_of"] != _NO_CHUNK, K
    ) > 0
    stranded = (~xp.any(row["busy"])) & xp.any(~row["chunk_done"] & ~held)
    err = xp.where((err == _ERR_NONE) & stranded, _ERR_STRANDED, err)

    dt = kernels.event_horizon(
        ops, row["next_tick"] - row["t"], row["busy"], row["dead"],
        transferring, row["rem"], rates,
    )
    t2 = row["t"] + dt
    busy2, dead2, rem2, moved, finished = kernels.advance_channels(
        ops, xp.asarray(True), dt, row["busy"], row["dead"], transferring,
        row["rem"], rates,
    )
    delivered2 = ops.chunk_scatter_add(
        row["delivered"], row["chunk_of"], moved, moved != 0.0
    )
    fin_any = xp.any(finished)

    # ---- decision-point detection (pre-feed completion == post-feed:
    # feeding swaps queue files for busy channels, never zeroes both) ----
    files_left = row["qlen"] - row["qptr"] + row["prepend_n"]
    busy_pc = ops.count_by_chunk(row["chunk_of"], busy2, K)
    comp_pre = ~row["chunk_done"] & (files_left == 0) & (busy_pc == 0)
    tick_hit = t2 >= row["next_tick"] - _EPS
    needs_py = (
        row["has_prepend"]
        | (xp.any(comp_pre) & ~row["trivial_complete"])
        | (tick_hit & ~row["trivial_tick"])
    )

    # ---- post (P2-P5), fully vectorizable rows only ----
    busy3, dead3, rem3, qptr3, qb3 = kernels.feed_queues(
        ops, ~needs_py, row["chunk_of"], busy2, dead2, rem2, qsizes,
        row["qoff"], row["qlen"], row["qptr"], row["queue_bytes"],
        row["fsdt"],
    )
    busy_pc3 = ops.count_by_chunk(row["chunk_of"], busy3, K)
    completed = (
        ~row["chunk_done"]
        & ((row["qlen"] - qptr3 + row["prepend_n"]) == 0)
        & (busy_pc3 == 0)
        & ~needs_py
    )
    chunk_done2 = row["chunk_done"] | completed
    qb4 = xp.where(completed, 0.0, qb3)
    completed_at2 = xp.where(completed, t2, row["completed_at"])
    comp_any = xp.any(completed)

    do_tick = tick_hit & ~needs_py
    ema = kernels.tick_ema(
        ops, row["rate_est"], delivered2, row["delivered_at_tick"],
        row["tick_period"],
    )
    rate_est2 = xp.where(do_tick, ema, row["rate_est"])
    dat2 = xp.where(do_tick, delivered2, row["delivered_at_tick"])
    next_tick2 = row["next_tick"] + xp.where(
        do_tick, row["tick_period"], 0.0
    )

    done2 = ~needs_py & xp.all(chunk_done2) & (fin_any | comp_any)
    finish_t2 = xp.where(done2, t2, row["finish_t"])

    # ---- commit: skip parked/done rows, freeze errored rows pre-sweep ----
    upd = runnable & (err == _ERR_NONE)

    def sel(new, old):
        return xp.where(upd, new, old)

    out = dict(row)
    out["err"] = xp.where(runnable, err, row["err"])
    out["t"] = sel(t2, row["t"])
    out["n_events"] = row["n_events"] + xp.where(upd, 1, 0)
    out["busy"] = sel(busy3, row["busy"])
    out["dead"] = sel(dead3, row["dead"])
    out["rem"] = sel(rem3, row["rem"])
    out["delivered"] = sel(delivered2, row["delivered"])
    out["fin_any"] = sel(fin_any, row["fin_any"])
    out["qptr"] = sel(qptr3, row["qptr"])
    out["queue_bytes"] = sel(qb4, row["queue_bytes"])
    out["chunk_done"] = sel(chunk_done2, row["chunk_done"])
    out["completed_at"] = sel(completed_at2, row["completed_at"])
    out["rate_est"] = sel(rate_est2, row["rate_est"])
    out["delivered_at_tick"] = sel(dat2, row["delivered_at_tick"])
    out["next_tick"] = sel(next_tick2, row["next_tick"])
    out["finish_t"] = sel(finish_t2, row["finish_t"])
    out["done"] = row["done"] | (upd & done2)
    out["stall"] = xp.where(
        upd & needs_py, _STALL_POST, row["stall"]
    )
    return out


@jax.jit
def _device_rounds(state: dict, qsizes):
    """Advance every runnable scenario to its own next Python decision
    point (or completion): vmapped sweeps inside lax.while_loop."""
    sweep = jax.vmap(_sweep_row, in_axes=(0, None))

    def runnable(st):
        return (
            ~st["done"]
            & (st["stall"] == _STALL_NONE)
            & (st["err"] == _ERR_NONE)
        )

    start_count = jnp.sum(runnable(state))

    def cond(carry):
        st, it = carry
        n = jnp.sum(runnable(st))
        # run while anything is runnable, under the sweep cap, until half
        # the round's starting cohort has parked at a Python decision
        return (n > 0) & (it < _ROUND_CAP) & (2 * n > start_count)

    def body(carry):
        st, it = carry
        return sweep(st, qsizes), it + 1

    state, iters = lax.while_loop(cond, body, (state, 0))
    return state, iters


class JaxFabricSimulation(FabricSimulation):
    """FabricSimulation driven by the jit/vmap device loop.

    Host state (the parent's NumPy arrays) stays canonical; each round
    uploads it, lets the device run every scenario to its next decision
    point, downloads, and replays the parent's Python half for parked
    rows. Python-side bookkeeping (schedulers, resume queues, views) is
    inherited unchanged.
    """

    def __init__(
        self,
        sims: Sequence[Simulation],
        names: Optional[Sequence[str]] = None,
        **kwargs,
    ):
        super().__init__(sims, names=names, **kwargs)

    # -------------------------------------------------------------- #

    def _row_arrays(self) -> tuple:
        return super()._row_arrays() + ("_stall",)

    def _pad_rows(self) -> int:
        """Row count uploaded to the device: next power of two >= live rows
        (min 32). Padded rows are born ``done`` and never sweep; bucketing
        bounds the number of XLA shapes traced as compaction shrinks S."""
        n = max(32, self.S)
        return 1 << (n - 1).bit_length()

    def _padded(self, key: str, arr: np.ndarray, pad: int):
        if pad:
            fill = np.ones if key == "done" else np.zeros
            arr = np.concatenate(
                [arr, fill((pad,) + arr.shape[1:], dtype=arr.dtype)]
            )
        return jnp.asarray(arr)

    def _upload(self) -> dict:
        pad = self._pad_rows() - self.S
        state = {}
        for key in _MUTABLE + _CONST_PY:
            if key == "stall":
                arr = self._stall
            elif key == "err":
                arr = np.zeros(self.S, dtype=np.int64)
            else:
                arr = getattr(self, key)
            state[key] = self._padded(key, arr, pad)
        # statics are immutable for a given row set: cache on device and
        # rebuild only when compaction (or channel growth) reshapes rows
        cache_key = (self.S, self.C, pad)
        if getattr(self, "_static_cache_key", None) != cache_key:
            self._static_cache = {
                key: self._padded(key, getattr(self, key), pad)
                for key in _CONST_STATIC
            }
            self._static_cache_key = cache_key
        state.update(self._static_cache)
        return state

    def _download(self, state: dict) -> None:
        for key in _MUTABLE:
            if key == "err":
                continue
            # np.array (not asarray): device buffers are zero-copy
            # read-only views, and the host half mutates these in place
            arr = np.array(state[key][: self.S])
            setattr(self, "_stall" if key == "stall" else key, arr)
        err = np.asarray(state["err"][: self.S])
        if err.any():
            s = int(np.flatnonzero(err)[0])
            if err[s] == _ERR_MAXTIME:
                raise RuntimeError(
                    f"batch scenario {self.rt[s].name!r} exceeded max_time="
                    f"{self.max_time[s]}s (t={self.t[s]:.1f})"
                )
            r = self.rt[s]
            live = np.flatnonzero(~self.chunk_done[s])
            raise RuntimeError(
                f"scheduler {r.scheduler.name} stranded chunks "
                f"{[r.chunks[int(k)].name for k in live]} in {r.name!r}"
            )

    # -------------------------------------------------------------- #

    def run(self) -> List[SimResult]:
        from jax.experimental import enable_x64

        all_rt = list(self.rt)
        self.start()
        with enable_x64():
            self._drive()
        return [self._result(r) for r in all_rt]

    def _drive(self) -> None:
        # timeline-recording rows park permanently: their (t, rate) samples
        # are host-side appends, so they advance through the NumPy path
        self._stall = np.where(
            self.record_timeline, _STALL_FULL, _STALL_NONE
        ).astype(np.int64)
        qsizes_dev = jnp.asarray(self.qsizes)
        while not self.done.all():
            progressed = False
            runnable = ~self.done & (self._stall == _STALL_NONE)
            if runnable.any():
                state, iters = _device_rounds(self._upload(), qsizes_dev)
                self._download(state)
                progressed = int(iters) > 0
            post_rows = ~self.done & (self._stall == _STALL_POST)
            full_rows = ~self.done & (self._stall == _STALL_FULL)
            if post_rows.any():
                self._post(post_rows)
                self._stall[post_rows] = _STALL_NONE
                progressed = True
            if full_rows.any():
                self.step(full_rows)
                progressed = True
            if not progressed:
                raise RuntimeError(
                    "jax fabric backend made no progress; device loop "
                    f"exited with {int(runnable.sum())} runnable rows"
                )
            self._maybe_compact()
