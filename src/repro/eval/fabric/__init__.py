"""Backend-neutral fluid-kernel layer for the scenario-matrix simulator.

This package owns the *array semantics* of the fluid transfer model. The
kernels in :mod:`repro.eval.fabric.kernels` — batched water-filling,
per-file dead time, tick EMA, next-event horizon reduction, and the
feed/complete/tick state transitions — are written once against a minimal
array-API shim (:mod:`repro.eval.fabric.shim`) and instantiated twice:

  * **NumPy** (:class:`repro.eval.fabric.driver.FabricSimulation` with
    :func:`repro.eval.fabric.shim.numpy_ops`) — the eager batched fast
    path; bit-compatible successor of the old ``eval.batchsim`` module.
  * **JAX** (:class:`repro.eval.fabric.jax_backend.JaxFabricSimulation`)
    — the same kernels traced per-scenario, ``vmap``-mapped over the
    batch, and advanced inside a ``jit``-compiled ``lax.while_loop`` so
    scenarios run on-device between controller decision points.

An optional Pallas water-fill kernel
(:mod:`repro.eval.fabric.kernels.waterfill_pallas`) sits behind the same
``(caps, pool) -> rates`` signature with an interpreter-mode fallback for
CPU-only hosts.

Fidelity contract
-----------------
State transitions mirror ``core.simulator.Simulation.step`` exactly — the
same rate model (``netmodel.channel_rate_cap`` / disk aggregate pool /
max-min water-filling), the same serial dead-time accounting
(``netmodel.file_start_dead_time``, ``netmodel.channel_open_cost``), the
same controller-tick EMA (``fabric.reference.tick_rate_update``), and the
same feed -> completions -> tick ordering within an event sweep. Scenarios
are mutually independent, so backends may advance their clocks in any
interleaving (the JAX backend runs each scenario ahead to its own next
Python decision point), but every *per-scenario* event sequence must be
identical. ``eval.difftest`` enforces per-scenario throughput agreement
across all backends on every matrix scenario; if you change one side,
change the other — and the scalar references in
:mod:`repro.eval.fabric.reference` / ``core.netmodel`` — together.
"""
from __future__ import annotations

import importlib

#: public name -> defining submodule, resolved lazily (PEP 562) so that
#: ``core.netmodel``/``core.simulator`` can re-export fabric pieces without
#: dragging the driver (and its core imports) into their import cycle.
_EXPORTS = {
    "ArrayOps": ".shim",
    "numpy_ops": ".shim",
    "jax_ops": ".shim",
    "FabricSimulation": ".driver",
    "JaxFabricSimulation": ".jax_backend",
    "waterfill_batch": ".kernels",
    "get_backend": ".registry",
    "BACKENDS": ".registry",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(modname, __name__), name)
    globals()[name] = value
    return value
