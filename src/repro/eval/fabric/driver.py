"""Batched fluid fast-path driver over the backend-neutral fabric kernels.

The event-driven :class:`repro.core.simulator.Simulation` spends its time in
per-event Python: water-filling over channels, horizon search, per-channel
advancement, queue feeding. :class:`FabricSimulation` runs the *same* event
semantics for S scenarios at once — channel state lives in (S, C) arrays,
per-chunk queue state in (S, K) arrays over one flat file-size buffer, and
all array math goes through :mod:`repro.eval.fabric.kernels` against an
:class:`repro.eval.fabric.shim.ArrayOps` namespace. Each outer sweep
advances every live scenario to its own next event simultaneously;
scenarios are independent, so their clocks drift apart freely.

The controller decision layer is batched too: SC / MC / ProMC rows run
their tick and chunk-completion logic through the array kernels of
:mod:`repro.eval.fabric.controllers` — the ProMC streak state machine,
laggard-ETA discounting, and channel Open/Close/Move transitions are
masked (S,)-row updates, with resume files held on a device-friendly
LIFO stack ``(S, K, P)`` instead of host lists. Python remains only for
*custom* scheduler subclasses (anything that is not exactly one of the
three paper controllers or a no-op baseline), which still go through the
scalar callback protocol.

A sweep is split into :meth:`FabricSimulation._advance` (rates, horizon,
fluid byte movement) and :meth:`FabricSimulation._post` (feed, completions,
tick, scenario-done detection); the JAX backend fuses both halves into its
on-device loop — timeline recording included, via the shared
``kernels.timeline_push`` ring buffer — and reuses ``_post`` only for
rows it parks (custom controllers; capacity-guard edges survive as an
assertion-guarded fallback that the pre-sized axes from
:meth:`capacity_need` make unreachable for built-in schedulers).

The fidelity contract against ``Simulation.step`` lives in the package
docstring (:mod:`repro.eval.fabric`); ``eval.difftest`` enforces it on
every matrix scenario.
"""
from __future__ import annotations

import math
import os
from typing import List, Optional, Sequence

import numpy as np

from repro.core import netmodel
from repro.core.schedulers import (
    Close,
    ChunkView,
    Move,
    MultiChunkScheduler,
    Open,
    ProActiveMultiChunkScheduler,
    Scheduler,
    SingleChunkScheduler,
)
from repro.core.simulator import SimResult, Simulation
from repro.core.types import TransferParams

from . import controllers, kernels
from .bucketing import COMPACT_FLOOR, PROFILE_PAD_FLOOR, bucket
from .reference import resume_file
from .shared import resolve_fabric
from .shim import NO_CHUNK, ArrayOps, numpy_ops

_EPS = 1e-12
_NO_CHUNK = NO_CHUNK

#: controller kinds the vectorized decision layer understands; anything
#: else (KIND_CUSTOM) drives the scalar callback protocol on the host.
#: KIND_STATIC (the autotuner's fixed-parameter candidate rows) behaves
#: exactly like a trivial baseline at runtime — initial Opens, then no
#: actions — but is kept distinct so capacity pre-sizing and telemetry
#: can see the candidate axis; it deliberately sits *below* KIND_SC so
#: every ``kind >= KIND_SC`` controller-dispatch guard excludes it.
(
    KIND_CUSTOM, KIND_TRIVIAL, KIND_STATIC, KIND_SC, KIND_MC, KIND_PROMC,
) = -1, 0, 1, 2, 3, 4


def _scheduler_kind(scheduler: Scheduler) -> int:
    from repro.core.baselines import StaticParamsScheduler

    cls = type(scheduler)
    if cls is SingleChunkScheduler:
        return KIND_SC
    if cls is MultiChunkScheduler:
        return KIND_MC
    if cls is ProActiveMultiChunkScheduler:
        return KIND_PROMC
    if cls is StaticParamsScheduler:
        return KIND_STATIC
    if (
        cls.on_tick is Scheduler.on_tick
        and cls.on_chunk_complete is Scheduler.on_chunk_complete
    ):
        return KIND_TRIVIAL
    return KIND_CUSTOM


class _ScenarioRuntime:
    """Python-side (non-vectorizable) per-scenario state: the controller
    object (for custom schedulers) and chunk metadata."""

    __slots__ = (
        "index", "name", "network", "scheduler", "chunks", "params",
        "trivial_tick", "trivial_complete", "tick_period",
        "total_bytes", "avg_fs", "predict_cache", "archive",
    )

    def __init__(self, index: int, name: str, sim: Simulation):
        self.index = index
        #: final metrics snapshot taken when the scenario's row is retired
        #: by compaction: (finish_t, n_events, completed_at, delivered,
        #: n_moves)
        self.archive = None
        self.name = name
        self.network = sim.network
        self.scheduler = sim.scheduler
        self.chunks = [st.chunk for st in sim.states]
        self.params: List[TransferParams] = [c.params for c in self.chunks]
        cls = type(sim.scheduler)
        self.trivial_tick = cls.on_tick is Scheduler.on_tick
        self.trivial_complete = (
            cls.on_chunk_complete is Scheduler.on_chunk_complete
        )
        self.tick_period = sim.tick_period
        self.total_bytes = float(sum(st.queue_bytes for st in sim.states))
        self.avg_fs = [max(c.avg_file_size, 1.0) for c in self.chunks]
        #: (chunk, n_channels, total_channels) -> predicted rate; the model
        #: is pure, and allocations revisit the same few tuples constantly
        self.predict_cache: dict = {}


#: default scenario wall-clock guard, mirroring ``Simulation(max_time=)``
_DEFAULT_MAX_TIME = 48 * 3600.0


class _PlanRuntime:
    """Columnar-ingest twin of :class:`_ScenarioRuntime`: plan rows are
    always built-in controllers, so the runtime carries only what result
    assembly, compaction and error paths read (names + byte totals) —
    scheduler/chunks are shared name-only refs, never Python objects."""

    __slots__ = (
        "index", "name", "network", "scheduler", "chunks", "total_bytes",
        "archive",
    )

    def __init__(self, index, name, network, scheduler, chunks, total_bytes):
        self.index = index
        self.name = name
        self.network = network
        self.scheduler = scheduler
        self.chunks = chunks
        self.total_bytes = total_bytes
        self.archive = None


#: every per-scenario row array of the driver state, for compaction and
#: device upload; (S,) scalars and (S, C)/(S, K)/(S, K, P) tables alike
_ROW_ARRAYS = (
    "t", "done", "next_tick", "tick_period", "n_events", "finish_t",
    "fin_any", "max_time", "record_timeline",
    "trivial_tick", "trivial_complete", "bw", "disk_rate", "sat_cc",
    "contention", "n_chunks", "chunk_of", "dead", "rem", "busy", "cap",
    "chunk_done", "completed_at", "delivered", "delivered_at_tick",
    "rate_est", "queue_bytes", "fsdt", "qoff", "qlen", "qptr", "prepend_n",
    "prepend_sizes", "kind", "streak", "pair_fast", "pair_slow",
    "promc_ratio", "promc_patience", "sc_cursor", "sc_order", "conc",
    "par", "cap_k", "avg_fs_k", "nfiles", "setup_cost", "n_moves",
    "prof_t", "prof_mult", "cap_need",
    "tl_t", "tl_rate", "tl_len", "tl_stride", "tl_seen", "tl_last_t",
    "tl_last_rate",
)

#: default on-device timeline sample budget per scenario (override with
#: ``timeline_budget=`` or ``REPRO_FABRIC_TIMELINE_BUDGET``). Recording
#: rows decimate by uniform stride past this, so memory stays fixed no
#: matter how many events a scenario runs.
DEFAULT_TIMELINE_BUDGET = 512


class FabricSimulation:
    """Run many scenarios through the fluid transfer model simultaneously.

    Construction takes ready ``Simulation`` objects (one per scenario, fresh
    schedulers) so scenario assembly stays in one place (eval.scenarios);
    only their initial state is consumed, never their event loop.

    ``ops`` selects the array backend for the batched sweeps (NumPy by
    default; the JAX subclass drives the same state on-device).
    ``waterfill_impl`` may name an alternative water-fill kernel
    (``"closed"`` — the sort-based closed form — or ``"pallas"`` for the
    optional Pallas kernel; also via ``REPRO_FABRIC_WATERFILL``).
    ``fused_step`` (``"none"`` / ``"pallas"``, also via
    ``REPRO_FABRIC_FUSED_STEP``) routes resume-free sweeps through the
    fused Pallas advance+feed kernel
    (:mod:`repro.eval.fabric.kernels.fused_step_pallas`) instead of the
    split ``_advance`` / feed path; the JAX subclass ignores it (its
    device loop is already fused).
    """

    #: whether the driver accepts a ``device=`` kwarg and benefits from
    #: the executor's round-robin device sharding (the JAX subclass flips
    #: this; the eager NumPy driver has no device axis, so the pipelined
    #: executor still overlaps its prep and compute but never pins it)
    supports_device_placement = False

    def __init__(
        self,
        sims: Optional[Sequence[Simulation]],
        names: Optional[Sequence[str]] = None,
        *,
        ops: Optional[ArrayOps] = None,
        waterfill_impl: Optional[str] = None,
        fused_step: Optional[str] = None,
        timeline_budget: Optional[int] = None,
        fabric: Optional[Sequence] = None,
        plan=None,
    ):
        self.ops = ops or numpy_ops()
        self.timeline_budget = int(
            timeline_budget
            if timeline_budget is not None
            else os.environ.get(
                "REPRO_FABRIC_TIMELINE_BUDGET", DEFAULT_TIMELINE_BUDGET
            )
        )
        if self.timeline_budget < 2:
            raise ValueError("timeline_budget must be >= 2")
        impl = waterfill_impl or os.environ.get(
            "REPRO_FABRIC_WATERFILL", "closed"
        )
        if impl not in ("closed", "pallas"):
            raise ValueError(
                f"unknown waterfill_impl {impl!r}; options: closed, pallas"
            )
        self.waterfill_impl = impl
        fused = fused_step or os.environ.get(
            "REPRO_FABRIC_FUSED_STEP", "none"
        )
        if fused not in ("none", "pallas"):
            raise ValueError(
                f"unknown fused_step {fused!r}; options: none, pallas"
            )
        self.fused_step = fused
        #: set by the columnar path only: (open_n, visit_rank) initial
        #: channel layout, consumed once by :meth:`_start_plan`
        self._plan_open = None
        if plan is not None:
            if sims:
                raise ValueError("pass either sims or plan=, not both")
            if fabric is not None:
                raise ValueError(
                    "plan= batches carry their fabric column on the plan "
                    "itself (ScenarioPlan.fabrics); fabric= is sims-only"
                )
            self._init_from_plan(plan)
            return
        if names is None:
            names = [f"scenario{i}" for i in range(len(sims))]
        self.rt = [
            _ScenarioRuntime(i, n, sim)
            for i, (n, sim) in enumerate(zip(names, sims))
        ]
        S = len(self.rt)
        self.S = S
        if fabric is not None and len(fabric) != S:
            raise ValueError(
                f"fabric column length {len(fabric)} != batch size {S}"
            )
        self._set_fabric(fabric)
        self.C = 4  # channel capacity; grows on demand
        self.P = 4  # resume-stack capacity; grows on demand
        # chunk axis bucketed to the canonical pow2 ladder: padding chunks
        # are born done/empty (see chunk_done below), so a 3-chunk batch
        # shares the K=4 compiled program with a 4-chunk one
        K = bucket(max((len(r.chunks) for r in self.rt), default=1))
        self.K = K

        # scenario scalars
        self.t = np.zeros(S)
        self.done = np.zeros(S, dtype=bool)
        self.next_tick = np.array([r.tick_period for r in self.rt])
        self.tick_period = np.array([r.tick_period for r in self.rt])
        self.n_events = np.zeros(S, dtype=np.int64)
        self.finish_t = np.zeros(S)
        #: per-sweep flag: some channel finished a file (consumed by _post)
        self.fin_any = np.zeros(S, dtype=bool)
        # per-scenario settings carried over from the event Simulations
        self.max_time = np.array([sim.max_time for sim in sims])
        self.record_timeline = np.array(
            [sim.record_timeline for sim in sims], dtype=bool
        )
        self.trivial_tick = np.array([r.trivial_tick for r in self.rt])
        self.trivial_complete = np.array(
            [r.trivial_complete for r in self.rt]
        )
        # network constants
        self.bw = np.array([r.network.bandwidth for r in self.rt])
        self.disk_rate = np.array(
            [r.network.disk.streaming_rate for r in self.rt]
        )
        self.sat_cc = np.array(
            [r.network.disk.saturation_cc for r in self.rt], dtype=np.int64
        )
        self.contention = np.array(
            [r.network.disk.contention for r in self.rt]
        )
        # time-varying bandwidth profiles: piecewise-constant multiplier
        # steps, padded to the widest profile in the batch ((0, 1.0) rows
        # for static paths — the common case costs one gather per sweep)
        profiles = [
            getattr(r.network, "bandwidth_profile", None) or ((0.0, 1.0),)
            for r in self.rt
        ]
        # profile width rides the same ladder (all-static batches keep the
        # width-1 fast path; mixed batches bucket so step counts don't leak
        # into the jit signature): pad steps hold t=inf / the last
        # multiplier, which the gather below never selects
        B = max((len(p) for p in profiles), default=1)
        if B > 1:
            B = bucket(B, PROFILE_PAD_FLOOR)
        self.prof_t = np.full((S, B), np.inf)
        self.prof_mult = np.ones((S, B))
        for r, prof in zip(self.rt, profiles):
            for j, (pt, pm) in enumerate(prof):
                self.prof_t[r.index, j] = pt
                self.prof_mult[r.index, j] = pm
            self.prof_mult[r.index, len(prof):] = prof[-1][1]

        # channel state, padded to capacity C
        self.chunk_of = np.full((S, self.C), _NO_CHUNK, dtype=np.int64)
        self.dead = np.zeros((S, self.C))
        self.rem = np.zeros((S, self.C))
        self.busy = np.zeros((S, self.C), dtype=bool)
        self.cap = np.zeros((S, self.C))

        # per-chunk state, padded to K (padding slots are born done/empty)
        self.n_chunks = np.array(
            [len(r.chunks) for r in self.rt], dtype=np.int64
        )
        self.chunk_done = np.zeros((S, K), dtype=bool)
        self.chunk_done[np.arange(K)[None, :] >= self.n_chunks[:, None]] = True
        self.completed_at = np.full((S, K), math.nan)
        self.delivered = np.zeros((S, K))
        self.delivered_at_tick = np.zeros((S, K))
        self.rate_est = np.zeros((S, K))
        self.queue_bytes = np.zeros((S, K))
        #: serial per-file dead time per chunk (params are fixed per chunk)
        self.fsdt = np.zeros((S, K))

        # controller state: kind dispatch, ProMC streak machine, SC cursor,
        # per-chunk decision tables (caps, parallelism, concurrency, sizes)
        self.kind = np.array(
            [_scheduler_kind(r.scheduler) for r in self.rt], dtype=np.int64
        )
        self.streak = np.zeros(S, dtype=np.int64)
        self.pair_fast = np.full(S, -1, dtype=np.int64)
        self.pair_slow = np.full(S, -1, dtype=np.int64)
        self.promc_ratio = np.array(
            [getattr(r.scheduler, "ratio", 2.0) for r in self.rt]
        )
        self.promc_patience = np.array(
            [getattr(r.scheduler, "patience", 3) for r in self.rt],
            dtype=np.int64,
        )
        self.sc_cursor = np.zeros(S, dtype=np.int64)
        self.sc_order = np.zeros((S, K), dtype=np.int64)
        self.conc = np.zeros((S, K), dtype=np.int64)
        self.par = np.ones((S, K), dtype=np.int64)
        self.cap_k = np.zeros((S, K))
        self.avg_fs_k = np.ones((S, K))
        self.nfiles = np.zeros((S, K), dtype=np.int64)
        self.setup_cost = np.array(
            [r.network.channel_setup_cost for r in self.rt]
        )
        self.n_moves = np.zeros(S, dtype=np.int64)

        # FIFO queues: one flat size buffer + (offset, length, cursor) per
        # (scenario, chunk). Resume files go to the (S, K, P) LIFO stack,
        # consumed before the cursor moves — exactly deque.appendleft/
        # popleft order.
        sizes: List[float] = []
        self.qoff = np.zeros((S, K), dtype=np.int64)
        self.qlen = np.zeros((S, K), dtype=np.int64)
        self.qptr = np.zeros((S, K), dtype=np.int64)
        #: count of re-queued resume files per (scenario, chunk)
        self.prepend_n = np.zeros((S, K), dtype=np.int64)
        self.prepend_sizes = np.zeros((S, K, self.P))
        for r in self.rt:
            if isinstance(r.scheduler, SingleChunkScheduler):
                order = list(r.scheduler._order)
                self.sc_order[r.index, : len(order)] = order
            for k, chunk in enumerate(r.chunks):
                self.qoff[r.index, k] = len(sizes)
                self.qlen[r.index, k] = len(chunk.files)
                self.queue_bytes[r.index, k] = chunk.total_bytes
                sizes.extend(float(f.size) for f in chunk.files)
                self.fsdt[r.index, k] = netmodel.file_start_dead_time(
                    r.network, r.params[k]
                )
                self.conc[r.index, k] = r.params[k].concurrency
                self.par[r.index, k] = r.params[k].parallelism
                self.cap_k[r.index, k] = netmodel.channel_rate_cap(
                    r.network, r.params[k].parallelism
                )
                self.avg_fs_k[r.index, k] = r.avg_fs[k]
                self.nfiles[r.index, k] = len(chunk.files)
        self.qsizes = np.asarray(sizes, dtype=np.float64)

        # on-device timeline ring buffer (uniform-stride decimation past
        # the budget); all-static width 1 when no row records, so batches
        # without timelines pay one no-op column at most
        T = self.timeline_budget if self.record_timeline.any() else 1
        self.tl_t = np.zeros((S, T))
        self.tl_rate = np.zeros((S, T))
        self.tl_len = np.zeros(S, dtype=np.int64)
        self.tl_stride = np.ones(S, dtype=np.int64)
        self.tl_seen = np.zeros(S, dtype=np.int64)
        self.tl_last_t = np.zeros(S)
        self.tl_last_rate = np.zeros(S)

        #: closed-form per-scenario worst case of simultaneously open
        #: channels (see :meth:`capacity_need`); the JAX backend pre-sizes
        #: its channel/resume axes from it so capacity-guard parks never
        #: fire for built-in schedulers
        self.cap_need = np.array(
            [
                self._worst_case_channels(r, bool(self.group_id[r.index] >= 0))
                for r in self.rt
            ],
            dtype=np.int64,
        )
        self._need_c_floor = 1
        self._started = False

    def _init_from_plan(self, plan) -> None:
        """Materialize the resident arrays straight from a
        :class:`repro.eval.fabric.plan.ScenarioPlan` — no ``Simulation``
        objects, no per-row packing loop. Column-for-column this mirrors
        the legacy constructor above (same dtypes, same pad values:
        ``tests/test_plan_ingest.py`` pins bit-identity), but every fill
        is a gather over the plan's context/network tables."""
        S = self.S = plan.n_rows
        self.C = 4
        self.P = 4
        nets = plan.networks
        ni = plan.net_idx
        # chunk axis re-buckets to this batch's widest row (a sliced
        # sub-plan of one-chunk rows keeps K=1 even if the parent plan
        # carried four-chunk contexts)
        n_chunks = plan.n_chunks.astype(np.int64)
        K = bucket(int(n_chunks.max(initial=1)))
        self.K = K
        self.rt = [
            _PlanRuntime(
                i,
                plan.names[i],
                nets[ni[i]],
                plan.sched_refs[i],
                plan.chunk_refs[i],
                float(plan.total_bytes[i]),
            )
            for i in range(S)
        ]

        # scenario scalars
        self.t = np.zeros(S)
        self.done = np.zeros(S, dtype=bool)
        self.next_tick = plan.tick_period.copy()
        self.tick_period = plan.tick_period.copy()
        self.n_events = np.zeros(S, dtype=np.int64)
        self.finish_t = np.zeros(S)
        self.fin_any = np.zeros(S, dtype=bool)
        self.max_time = np.full(S, _DEFAULT_MAX_TIME)
        self.record_timeline = plan.record_timeline.copy()
        self.trivial_tick = plan.trivial_tick.copy()
        self.trivial_complete = plan.trivial_complete.copy()
        # network constants: one small per-network table each, gathered
        net_f = lambda f: np.array(  # noqa: E731
            [f(n) for n in nets], dtype=np.float64
        )[ni]
        self.bw = net_f(lambda n: n.bandwidth)
        self.disk_rate = net_f(lambda n: n.disk.streaming_rate)
        self.sat_cc = np.array(
            [n.disk.saturation_cc for n in nets], dtype=np.int64
        )[ni]
        self.contention = net_f(lambda n: n.disk.contention)
        profiles = [
            getattr(n, "bandwidth_profile", None) or ((0.0, 1.0),)
            for n in nets
        ]
        B = max((len(profiles[j]) for j in ni), default=1)
        if B > 1:
            B = bucket(B, PROFILE_PAD_FLOOR)
        pt = np.full((len(nets), B), np.inf)
        pm = np.ones((len(nets), B))
        for j, prof in enumerate(profiles):
            for b, (t0, m0) in enumerate(prof[:B]):
                pt[j, b] = t0
                pm[j, b] = m0
            pm[j, len(prof):] = prof[-1][1]
        self.prof_t = pt[ni]
        self.prof_mult = pm[ni]

        # channel state
        self.chunk_of = np.full((S, self.C), _NO_CHUNK, dtype=np.int64)
        self.dead = np.zeros((S, self.C))
        self.rem = np.zeros((S, self.C))
        self.busy = np.zeros((S, self.C), dtype=bool)
        self.cap = np.zeros((S, self.C))

        # per-chunk state (plan columns are padded to plan.K >= K)
        self.n_chunks = n_chunks
        self.chunk_done = np.zeros((S, K), dtype=bool)
        self.chunk_done[np.arange(K)[None, :] >= n_chunks[:, None]] = True
        self.completed_at = np.full((S, K), math.nan)
        self.delivered = np.zeros((S, K))
        self.delivered_at_tick = np.zeros((S, K))
        self.rate_est = np.zeros((S, K))
        self.queue_bytes = plan.queue_bytes[:, :K].copy()
        self.fsdt = plan.fsdt[:, :K].copy()

        # controller state (plan rows are never KIND_CUSTOM; ProMC rows
        # carry the schedulers' default streak machine)
        self.kind = plan.kind.copy()
        self.streak = np.zeros(S, dtype=np.int64)
        self.pair_fast = np.full(S, -1, dtype=np.int64)
        self.pair_slow = np.full(S, -1, dtype=np.int64)
        self.promc_ratio = np.full(S, 2.0)
        self.promc_patience = np.full(S, 3, dtype=np.int64)
        self.sc_cursor = np.zeros(S, dtype=np.int64)
        self.sc_order = plan.sc_order[:, :K].copy()
        self.conc = plan.conc[:, :K].copy()
        self.par = plan.par[:, :K].copy()
        self.cap_k = plan.cap_k[:, :K].copy()
        self.avg_fs_k = plan.avg_fs_k[:, :K].copy()
        self.nfiles = plan.qlen[:, :K].copy()
        self.setup_cost = net_f(lambda n: n.channel_setup_cost)
        self.n_moves = np.zeros(S, dtype=np.int64)

        # FIFO queues: rows address the plan's shared flat buffer through
        # their offsets — sub-plans of the same parent share one buffer
        # (read-only in every kernel), collapsing the jax queue-pad
        # signature axis to a single rung per plan
        self.qoff = plan.qoff[:, :K].copy()
        self.qlen = plan.qlen[:, :K].copy()
        self.qptr = np.zeros((S, K), dtype=np.int64)
        self.prepend_n = np.zeros((S, K), dtype=np.int64)
        self.prepend_sizes = np.zeros((S, K, self.P))
        self.qsizes = plan.qsizes

        T = self.timeline_budget if self.record_timeline.any() else 1
        self.tl_t = np.zeros((S, T))
        self.tl_rate = np.zeros((S, T))
        self.tl_len = np.zeros(S, dtype=np.int64)
        self.tl_stride = np.ones(S, dtype=np.int64)
        self.tl_seen = np.zeros(S, dtype=np.int64)
        self.tl_last_t = np.zeros(S)
        self.tl_last_rate = np.zeros(S)

        self.cap_need = plan.cap_need.copy()
        # plan chunks pad the channel axis to the shape-hint floor: a few
        # dead columns buy every cc<=PLAN_C_FLOOR chunk the SAME compiled
        # C, and batches holding profiled rows share one (C, B=16)
        # program family (see ScenarioPlan.shape_hints)
        from .plan import (
            PLAN_C_FLOOR,
            PLAN_COMPACT_FLOOR,
            PLAN_PROFILED_C_FLOOR,
        )

        self._need_c_floor = (
            PLAN_C_FLOOR if B == 1 else PLAN_PROFILED_C_FLOOR
        )
        # all-static batches (baseline + candidate rows, no timelines)
        # drain chunk-at-a-time, so compaction stops at the plane floor:
        # the grid's narrow straggler rungs would only add device
        # re-entries and download syncs here (see plan.PLAN_COMPACT_FLOOR)
        if not self.record_timeline.any() and bool(
            (self.kind <= KIND_STATIC).all()
        ):
            self._compact_floor = PLAN_COMPACT_FLOOR
        self._plan_open = (
            plan.open_n[:, :K].copy(),
            plan.visit_rank[:, :K].copy(),
        )
        # plan.cap_need already carries the coupled SC widening (see
        # plan.build_plan); only the membership arrays resolve here
        self._set_fabric(getattr(plan, "fabrics", None))
        self._started = False

    def _set_fabric(self, fabrics) -> None:
        """Lower the per-row fabric column into the coupling arrays the
        sweep reads (``group_id`` (S,), ``link_member`` (L, S),
        ``link_cap`` (L,)); all-``None`` columns collapse to the
        uncoupled fast path (``self.coupled`` False, L == 0)."""
        if fabrics is None or all(f is None for f in fabrics):
            self.group_id = np.full(self.S, -1, dtype=np.int64)
            self.link_member = np.zeros((0, self.S), dtype=bool)
            self.link_cap = np.zeros(0, dtype=np.float64)
            self._n_groups = 0
            self.coupled = False
            return
        fab = resolve_fabric(fabrics)
        self.group_id = fab.group_id
        self.link_member = fab.member
        self.link_cap = fab.link_cap
        self._n_groups = fab.n_groups
        self.coupled = fab.coupled

    @staticmethod
    def _worst_case_channels(
        r: _ScenarioRuntime, coupled: bool = False
    ) -> int:
        """Closed-form bound on channels a scenario can hold at once.

        * SC holds one chunk's wave at a time, except when empty-chunk (or
          exactly tied) completions advance the cursor while earlier waves
          still run — each such completion co-schedules at most one more
          chunk, so the bound is the sum of the ``1 + n_empty`` largest
          per-chunk concurrencies. Coupled rows advance on the *group*
          horizon, so completion ties the uncoupled physics could never
          produce become ordinary (two chunks starved to identical rates
          finish on the same sweep); the only safe static bound is every
          wave live at once — the full concurrency sum.
        * MC / ProMC open ``max(maxCC, n_nonempty)`` channels up front
          (every non-empty chunk gets at least one) and every later
          transition (laggard grants, ProMC moves) conserves the count —
          coupling changes rates, never that invariant.
        * Trivial baselines and static-params candidate rows only act at
          t=0 (bounded by the per-chunk concurrency sum — exactly the
          candidate's ``cc`` for a one-chunk static row); custom
          schedulers keep the host-growth path.
        """
        kind = _scheduler_kind(r.scheduler)
        conc = sorted(
            (int(c.params.concurrency) for c in r.chunks if len(c.files)),
            reverse=True,
        )
        n_empty = len(r.chunks) - len(conc)
        max_cc = int(getattr(r.scheduler, "max_cc", 1))
        if kind == KIND_SC and not coupled:
            return max(1, sum(conc[: 1 + n_empty]))
        if kind in (KIND_MC, KIND_PROMC):
            return max(1, max_cc, len(conc))
        return max(1, sum(conc))

    def capacity_need(self) -> tuple:
        """Batch-wide worst-case ``(channels, resume-stack)`` capacities.

        Valid once :meth:`start` ran (initial actions may already hold
        the per-row bound's worth of channels; custom schedulers can
        exceed the closed form, so the observed open count joins the
        max). A chunk's resume-stack depth never exceeds its channel
        count — a push closes a busy channel and a regained channel pops
        the stack before the queue — so the stack bound is the channel
        bound plus one slot of headroom for the device loop's
        prospective-overflow guard.
        """
        open_now = (self.chunk_of != _NO_CHUNK).sum(axis=1)
        need_c = int(np.maximum(self.cap_need, open_now).max(initial=1))
        need_c = max(need_c, self._need_c_floor)
        return need_c, need_c + 1

    def compact_floor(self) -> int:
        """The smallest padded device shape compaction will descend to
        for this batch: :data:`bucketing.COMPACT_FLOOR` for the
        heterogeneous grid, ``plan.PLAN_COMPACT_FLOOR`` for all-static
        candidate-plane batches (set by :meth:`_init_from_plan`). The
        jax backend passes it as a *static* jit argument, so the two
        policies occupy disjoint compiled programs."""
        return int(getattr(self, "_compact_floor", COMPACT_FLOOR))

    # ------------------------------------------------------------------ #
    # water-fill dispatch
    # ------------------------------------------------------------------ #

    def _waterfill(self, caps, pool):
        if self.waterfill_impl == "pallas":
            from .kernels.waterfill_pallas import waterfill_pallas_f64

            return waterfill_pallas_f64(caps, pool)
        return kernels.waterfill(self.ops, caps, pool)

    # ------------------------------------------------------------------ #
    # channel bookkeeping (mirrors Simulation._open_channel/_close_channels)
    # ------------------------------------------------------------------ #

    def _grow(self) -> None:
        pad = self.C
        self.C *= 2

        def z(a, fill):
            return np.concatenate(
                [a, np.full((self.S, pad), fill, dtype=a.dtype)], axis=1
            )

        self.chunk_of = z(self.chunk_of, _NO_CHUNK)
        self.dead = z(self.dead, 0.0)
        self.rem = z(self.rem, 0.0)
        self.busy = z(self.busy, False)
        self.cap = z(self.cap, 0.0)

    def _grow_prepend(self) -> None:
        pad = self.P
        self.P *= 2
        self.prepend_sizes = np.concatenate(
            [self.prepend_sizes, np.zeros((self.S, self.K, pad))], axis=2
        )

    def _push_resume(self, s: int, chunk: int, size: float) -> None:
        if self.prepend_n[s, chunk] >= self.P:
            self._grow_prepend()
        self.prepend_sizes[s, chunk, self.prepend_n[s, chunk]] = size
        self.prepend_n[s, chunk] += 1
        self.queue_bytes[s, chunk] += size

    def _open_channel(
        self, r: _ScenarioRuntime, chunk: int, prev: Optional[TransferParams]
    ) -> None:
        s = r.index
        free = np.flatnonzero(self.chunk_of[s] == _NO_CHUNK)
        if free.size == 0:
            self._grow()
            free = np.flatnonzero(self.chunk_of[s] == _NO_CHUNK)
        c = free[0]
        params = r.params[chunk]
        self.chunk_of[s, c] = chunk
        self.dead[s, c] = netmodel.channel_open_cost(r.network, params, prev)
        self.rem[s, c] = 0.0
        self.busy[s, c] = False
        self.cap[s, c] = netmodel.channel_rate_cap(r.network, params.parallelism)

    def _close_channels(
        self, r: _ScenarioRuntime, chunk: int, n: int
    ) -> List[TransferParams]:
        s = r.index
        cols = np.flatnonzero(self.chunk_of[s] == chunk)
        # idle first, matching the event simulator's preference
        cols = sorted(cols, key=lambda c: bool(self.busy[s, c]))
        closed: List[TransferParams] = []
        for c in cols[:n]:
            if self.busy[s, c] and self.rem[s, c] > 0:
                f = resume_file(self.rem[s, c])
                self._push_resume(s, chunk, float(f.size))
            self.chunk_of[s, c] = _NO_CHUNK
            self.busy[s, c] = False
            self.dead[s, c] = 0.0
            self.rem[s, c] = 0.0
            self.cap[s, c] = 0.0
            closed.append(r.params[chunk])
        if closed:
            self._pack_row(s)
        return closed

    def _pack_row(self, s: int) -> None:
        """Left-pack row ``s``'s channel axis after a close, keeping column
        order equal to the event simulator's channel-list order (closes
        remove, opens append) — see ``kernels.compact_channels``."""
        order = np.argsort(self.chunk_of[s] == _NO_CHUNK, kind="stable")
        for arr in (self.chunk_of, self.busy, self.dead, self.rem, self.cap):
            arr[s] = arr[s][order]

    def _apply(self, r: _ScenarioRuntime, actions) -> None:
        for act in actions:
            if isinstance(act, Open):
                for _ in range(act.n):
                    self._open_channel(r, act.chunk, prev=None)
            elif isinstance(act, Close):
                self._close_channels(r, act.chunk, act.n)
            elif isinstance(act, Move):
                moved = self._close_channels(r, act.src, act.n)
                for prev in moved:
                    self._open_channel(r, act.dst, prev=prev)
                self.n_moves[r.index] += len(moved)

    # ------------------------------------------------------------------ #
    # queue feeding
    # ------------------------------------------------------------------ #

    def _files_left(self, s: int, k: int) -> int:
        return int(
            self.qlen[s, k] - self.qptr[s, k] + self.prepend_n[s, k]
        )

    def _feed_py(self, r: _ScenarioRuntime) -> None:
        """Scalar feed for one scenario (after custom-scheduler actions).
        Mirrors Simulation._feed_channels."""
        s = r.index
        idle = np.flatnonzero((self.chunk_of[s] != _NO_CHUNK) & ~self.busy[s])
        for c in idle:
            k = int(self.chunk_of[s, c])
            if self.prepend_n[s, k] > 0:
                self.prepend_n[s, k] -= 1
                size = self.prepend_sizes[s, k, self.prepend_n[s, k]]
            elif self.qptr[s, k] < self.qlen[s, k]:
                size = self.qsizes[self.qoff[s, k] + self.qptr[s, k]]
                self.qptr[s, k] += 1
            else:
                continue
            self.queue_bytes[s, k] -= size
            self.busy[s, c] = True
            self.rem[s, c] = size
            self.dead[s, c] += self.fsdt[s, k]

    def _feed_vec(self, rows: np.ndarray) -> None:
        """Batched feed (the ``kernels.feed_queues`` fabric kernel, LIFO
        resume stack included — skipped while no resume files exist)."""
        ps = self.prepend_sizes if self.prepend_n.any() else None
        (
            self.busy, self.dead, self.rem, self.qptr, self.queue_bytes,
            self.prepend_n,
        ) = kernels.feed_queues(
            self.ops, rows, self.chunk_of, self.busy, self.dead,
            self.rem, self.qsizes, self.qoff, self.qlen, self.qptr,
            self.queue_bytes, self.fsdt, ps, self.prepend_n,
        )

    # ------------------------------------------------------------------ #
    # controller plumbing (mirrors Simulation._view)
    # ------------------------------------------------------------------ #

    def _bytes_remaining(self, r: _ScenarioRuntime, k: int) -> float:
        s = r.index
        mask = (self.chunk_of[s] == k) & self.busy[s]
        return float(self.queue_bytes[s, k]) + float(self.rem[s][mask].sum())

    def _view(self, r: _ScenarioRuntime) -> List[ChunkView]:
        s = r.index
        ko = self.chunk_of[s]
        open_mask = ko != _NO_CHUNK
        n_open_total = int(open_mask.sum())
        nK = len(r.chunks)
        n_ch = np.bincount(ko[open_mask], minlength=nK)
        busy_ch = np.bincount(ko[open_mask & self.busy[s]], minlength=nK)
        inflight = np.zeros(nK)
        np.add.at(
            inflight, ko[open_mask & self.busy[s]],
            self.rem[s][open_mask & self.busy[s]],
        )
        views = []
        for k, chunk in enumerate(r.chunks):
            key = (k, int(n_ch[k]), n_open_total)
            predicted = r.predict_cache.get(key)
            if predicted is None:
                predicted = netmodel.predict_chunk_rate(
                    r.network,
                    r.avg_fs[k],
                    chunk.params,
                    max(int(n_ch[k]), 1),
                    total_active_channels=max(1, n_open_total),
                )
                r.predict_cache[key] = predicted
            views.append(
                ChunkView(
                    index=k,
                    ctype=chunk.ctype,
                    bytes_remaining=float(self.queue_bytes[s, k])
                    + float(inflight[k]),
                    files_remaining=self._files_left(s, k) + int(busy_ch[k]),
                    throughput=float(self.rate_est[s, k]),
                    n_channels=int(n_ch[k]),
                    done=bool(self.chunk_done[s, k]),
                    predicted_rate=predicted,
                )
            )
        return views

    def _view_arrays(self):
        """Batched ChunkViews: the (S, K) decision inputs (ETA, measured
        and predicted rates, channel counts) for the controller kernels."""
        open_mask = self.chunk_of != _NO_CHUNK
        n_ch = self.ops.count_by_chunk(self.chunk_of, open_mask, self.K)
        n_open = open_mask.sum(axis=-1)
        inflight = self.ops.chunk_scatter_add(
            np.zeros_like(self.queue_bytes), self.chunk_of, self.rem,
            open_mask & self.busy,
        )
        bytes_rem = self.queue_bytes + inflight
        pred = controllers.predicted_chunk_rate(
            self.ops, self.avg_fs_k, self.cap_k, self.fsdt,
            n_ch, n_open, self.bw, self.disk_rate, self.sat_cc,
            self.contention,
        )
        eta = controllers.chunk_eta(
            self.ops, bytes_rem, self.rate_est, pred, self.chunk_done
        )
        return bytes_rem, n_ch, eta

    def _check_completions_py(self, r: _ScenarioRuntime) -> List[int]:
        s = r.index
        completed = []
        for k in range(len(r.chunks)):
            if self.chunk_done[s, k]:
                continue
            busy = bool(((self.chunk_of[s] == k) & self.busy[s]).any())
            if self._files_left(s, k) == 0 and not busy:
                self._mark_complete(s, k)
                completed.append(k)
        return completed

    def _mark_complete(self, s: int, k: int) -> None:
        self.chunk_done[s, k] = True
        self.queue_bytes[s, k] = 0.0
        self.completed_at[s, k] = self.t[s]

    # ------------------------------------------------------------------ #
    # the vectorized event loop
    # ------------------------------------------------------------------ #

    def start(self) -> None:
        # idempotent: run() calls start() unconditionally, and re-applying
        # initial actions (stateful schedulers, advanced queue cursors)
        # would corrupt a batch a caller already started explicitly
        if self._started:
            return
        self._started = True
        if self._plan_open is not None:
            self._start_plan()
            return
        for r in self.rt:
            self._apply(r, r.scheduler.initial_actions(self._view(r)))
            self._feed_py(r)
            # mirror post-initial controller state into the row arrays
            if isinstance(r.scheduler, SingleChunkScheduler):
                self.sc_cursor[r.index] = r.scheduler._cursor
            if isinstance(r.scheduler, ProActiveMultiChunkScheduler):
                self.streak[r.index] = r.scheduler._streak
                pair = r.scheduler._streak_pair or (-1, -1)
                self.pair_fast[r.index], self.pair_slow[r.index] = pair

    def _start_plan(self) -> None:
        """Vectorized t=0 initial actions for plan-ingested batches.

        The plan pre-computed, per row, how many channels each chunk
        opens (``open_n``) and in which service order the controller
        would have issued the Opens (``visit_rank``). Applying them in
        that order against the legacy ``_open_channel`` (lowest free
        column) lays chunk ``k``'s channels out contiguously starting at
        the sum of the counts of chunks served before it — so the column
        layout, setup dead time and per-channel caps are reproduced here
        as pure array work, followed by one batched feed. SC cursors and
        the ProMC streak machine keep their start-of-run defaults, which
        is exactly what the scalar facades hold after ``start``."""
        open_n, vrank = self._plan_open
        total_open = open_n.sum(axis=1)
        max_open = int(total_open.max(initial=0))
        while self.C < max_open:
            self._grow()
        S, K, C = self.S, self.K, self.C
        # column offset of each chunk's block: channels opened by chunks
        # earlier in the service order
        ahead = vrank[:, :, None] > vrank[:, None, :]
        off = np.sum(np.where(ahead, open_n[:, None, :], 0), axis=2)
        cols = np.arange(C)[None, None, :]
        lo = off[:, :, None]
        hi = (off + open_n)[:, :, None]
        occupies = (cols >= lo) & (cols < hi)  # (S, K, C), disjoint in K
        chunk_idx = occupies.argmax(axis=1)
        is_open = occupies.any(axis=1)
        self.chunk_of = np.where(is_open, chunk_idx, _NO_CHUNK).astype(
            np.int64
        )
        # every t=0 Open pays the full setup cost (no prior channel on
        # the row: _open_channel's warm-reopen discount can't apply)
        self.dead = np.where(is_open, self.setup_cost[:, None], 0.0)
        self.cap = np.where(
            is_open, np.take_along_axis(self.cap_k, chunk_idx, axis=1), 0.0
        )
        self.rem = np.zeros((S, C))
        self.busy = np.zeros((S, C), dtype=bool)
        self._feed_vec(np.ones(S, dtype=bool))

    def step(self, rows: Optional[np.ndarray] = None) -> None:
        """One synchronized sweep over ``rows`` (default: all scenarios):
        every live selected scenario advances to its own next event.
        Mirrors Simulation.step; keep the orders in lockstep."""
        act = ~self.done if rows is None else (~self.done & rows)
        if not act.any():
            return
        if (
            self.fused_step == "pallas"
            and not self.coupled
            and not self.prepend_n.any()
        ):
            # resume-free sweeps (the overwhelmingly common case) run
            # water-fill + horizon + advance + FIFO feed as one fused
            # Pallas launch; _post then skips its own feed
            self._advance_fused(act)
            self._post(act, skip_feed=True)
        else:
            self._advance(act)
            self._post(act)

    def _bandwidth_now(self):
        """Effective per-row bandwidth under the profile at time ``t`` and
        the time of each row's next profile step (inf when static)."""
        if self.prof_t.shape[1] == 1:  # all-static batch: one (0, 1.0) step
            return self.bw, np.full(self.S, np.inf)
        at = np.sum(self.prof_t <= self.t[:, None], axis=1) - 1
        mult = np.take_along_axis(
            self.prof_mult, np.maximum(at, 0)[:, None], axis=1
        )[:, 0]
        eff_bw = self.bw * np.where(at >= 0, mult, 1.0)
        nxt = np.min(
            np.where(self.prof_t > self.t[:, None], self.prof_t, np.inf),
            axis=1,
        )
        return eff_bw, nxt

    def _advance(self, act: np.ndarray) -> None:
        """Physics half of a sweep: rates, horizon, fluid byte movement.

        Leaves ``self.fin_any[act]`` holding whether a channel finished a
        file, which :meth:`_post` consumes for scenario-done detection.
        """
        over = act & (self.t > self.max_time)
        if over.any():
            s = int(np.flatnonzero(over)[0])
            raise RuntimeError(
                f"batch scenario {self.rt[s].name!r} exceeded max_time="
                f"{self.max_time[s]}s (t={self.t[s]:.1f})"
            )
        self.n_events[act] += 1

        transferring = self.busy & (self.dead <= _EPS)
        n_t = transferring.sum(axis=1)
        eff_bw, next_prof = self._bandwidth_now()
        pool = kernels.disk_pool(
            self.ops, n_t, eff_bw, self.disk_rate, self.sat_cc,
            self.contention,
        )
        if self.coupled:
            # shared-fabric override: each coupled row's demand is what it
            # could actually move uncoupled — pool clipped to its channel
            # caps, totalled with waterfill's own cumsum-of-sorted
            # reduction so an unsaturated grant reproduces the uncoupled
            # water-fill bit for bit — and the cross-row kernel shrinks
            # the pools of rows on saturated links to the max-min share
            caps_eff = np.where(transferring, self.cap, 0.0)
            demand = np.where(
                act & (self.group_id >= 0),
                np.minimum(pool, kernels.caps_total(self.ops, caps_eff)),
                0.0,
            )
            grant, _ = kernels.waterfill_coupled(
                self.ops, demand, self.link_member, self.link_cap
            )
            pool = np.where(self.group_id >= 0, grant, pool)
        # water-fill only live rows: the sort inside is the costliest
        # per-iteration op and finished scenarios would pay it for nothing
        rates = np.zeros_like(self.rem)
        act_rows = np.flatnonzero(act)
        rates[act_rows] = self._waterfill(
            np.where(transferring[act_rows], self.cap[act_rows], 0.0),
            pool[act_rows],
        )
        rec = act & self.record_timeline
        if rec.any():
            # on-device-shaped ring buffer: the same kernel the JAX loop
            # runs, so numpy and jax record bit-identically
            (
                self.tl_t, self.tl_rate, self.tl_len, self.tl_stride,
                self.tl_seen, self.tl_last_t, self.tl_last_rate,
            ) = kernels.timeline_push(
                self.ops, rec, self.t, rates.sum(axis=1), self.tl_t,
                self.tl_rate, self.tl_len, self.tl_stride, self.tl_seen,
                self.tl_last_t, self.tl_last_rate,
            )

        dt = kernels.event_horizon(
            self.ops,
            np.minimum(self.next_tick - self.t, next_prof - self.t),
            self.busy, self.dead, transferring, self.rem, rates,
        )
        dt = np.where(act, dt, 0.0)
        if self.coupled:
            # lockstep dt: a fabric group shares one clock, so every live
            # member advances by the group's minimum horizon. A member
            # whose own next event lies further out takes a partial
            # advance — no completion/feed/tick threshold is crossed, so
            # _post is a natural no-op for it beyond the moved bytes.
            live = act & (self.group_id >= 0)
            if live.any():
                g_dt = np.full(self._n_groups, np.inf)
                np.minimum.at(g_dt, self.group_id[live], dt[live])
                dt = np.where(
                    live, g_dt[np.maximum(self.group_id, 0)], dt
                )

        # stranded-chunk detection (scheduler bug), as in the event sim
        no_busy = act & ~self.busy.any(axis=1)
        for s in np.flatnonzero(no_busy):
            r = self.rt[s]
            live = np.flatnonzero(~self.chunk_done[s])
            held = set(self.chunk_of[s][self.chunk_of[s] != _NO_CHUNK].tolist())
            if any(int(k) not in held for k in live):
                raise RuntimeError(
                    f"scheduler {r.scheduler.name} stranded chunks "
                    f"{[r.chunks[int(k)].name for k in live]} in {r.name!r}"
                )

        # advance every live scenario by its own dt
        self.t += dt
        self.busy, self.dead, self.rem, moved, finished = (
            kernels.advance_channels(
                self.ops, act, dt, self.busy, self.dead, transferring,
                self.rem, rates,
            )
        )
        self.delivered = self.ops.chunk_scatter_add(
            self.delivered, self.chunk_of, moved, moved != 0.0
        )
        self.fin_any = np.where(act, finished.any(axis=1), self.fin_any)

    def _advance_fused(self, act: np.ndarray) -> None:
        """Physics half + FIFO feed as one fused Pallas launch.

        Semantics match :meth:`_advance` followed by :meth:`_post`'s feed
        on resume-free sweeps (the caller guarantees ``prepend_n`` is all
        zero); host-side error checks, timeline recording, and the
        delivered scatter stay here, fed by the kernel's returns. The
        bisected water level agrees with the closed form to ~1e-12, so
        results sit far inside the difftest's 2% bar but are not
        bit-identical to the default path.
        """
        from .kernels.fused_step_pallas import fused_advance_feed_f64

        over = act & (self.t > self.max_time)
        if over.any():
            s = int(np.flatnonzero(over)[0])
            raise RuntimeError(
                f"batch scenario {self.rt[s].name!r} exceeded max_time="
                f"{self.max_time[s]}s (t={self.t[s]:.1f})"
            )
        self.n_events[act] += 1
        # stranded-chunk detection on pre-advance state, as in _advance
        no_busy = act & ~self.busy.any(axis=1)
        for s in np.flatnonzero(no_busy):
            r = self.rt[s]
            live = np.flatnonzero(~self.chunk_done[s])
            held = set(self.chunk_of[s][self.chunk_of[s] != _NO_CHUNK].tolist())
            if any(int(k) not in held for k in live):
                raise RuntimeError(
                    f"scheduler {r.scheduler.name} stranded chunks "
                    f"{[r.chunks[int(k)].name for k in live]} in {r.name!r}"
                )
        eff_bw, next_prof = self._bandwidth_now()
        (
            dt, rate_sum, fin, busy, dead, rem, moved, qptr, qb,
        ) = fused_advance_feed_f64(
            act, self.busy, self.dead, self.rem, self.cap, self.chunk_of,
            np.minimum(self.next_tick - self.t, next_prof - self.t),
            eff_bw, self.disk_rate, self.sat_cc, self.contention,
            self.qoff, self.qlen, self.qptr, self.queue_bytes, self.fsdt,
            self.qsizes,
        )
        rec = act & self.record_timeline
        if rec.any():
            (
                self.tl_t, self.tl_rate, self.tl_len, self.tl_stride,
                self.tl_seen, self.tl_last_t, self.tl_last_rate,
            ) = kernels.timeline_push(
                self.ops, rec, self.t, rate_sum, self.tl_t, self.tl_rate,
                self.tl_len, self.tl_stride, self.tl_seen, self.tl_last_t,
                self.tl_last_rate,
            )
        self.t += dt  # kernel zeroes dt on inactive rows
        self.busy, self.dead, self.rem = busy, dead, rem
        self.qptr, self.queue_bytes = qptr, qb
        self.delivered = self.ops.chunk_scatter_add(
            self.delivered, self.chunk_of, moved, moved != 0.0
        )
        self.fin_any = np.where(act, fin, self.fin_any)

    def _post(self, act: np.ndarray, skip_feed: bool = False) -> None:
        """Transition half of a sweep: feed -> completions -> tick -> done.

        The order is the fidelity contract's feed/complete/tick ordering;
        the JAX backend fuses the same sequence on-device and calls this
        only for rows it parked (timeline / custom-controller / guard
        edges — their ``_advance`` ran on-device). ``skip_feed`` is the
        fused-step path, whose kernel already fed the queues.
        """
        # ---- feed (batched, resume-stack aware) ----
        if not skip_feed:
            self._feed_vec(act)

        # ---- chunk completions ----
        # a chunk can only complete in an iteration where one of its
        # channels finished a file (or lost its channels to an action,
        # which the controller branches below handle)
        busy_per_chunk = self.ops.count_by_chunk(
            self.chunk_of, self.busy, self.K
        )
        files_left = self.qlen - self.qptr + self.prepend_n
        completed = (
            act[:, None]
            & ~self.chunk_done
            & (files_left == 0)
            & (busy_per_chunk == 0)
        )
        comp_rows = completed.any(axis=1)
        # trivial controllers (baselines): pure vector bookkeeping
        vec_rows = comp_rows & self.trivial_complete
        if vec_rows.any():
            m = completed & vec_rows[:, None]
            self.chunk_done |= m
            self.queue_bytes[m] = 0.0
            rs, ks = np.nonzero(m)
            self.completed_at[rs, ks] = self.t[rs]
        # SC / MC / ProMC: batched controller kernels
        ctrl_rows = comp_rows & (self.kind >= KIND_SC)
        if ctrl_rows.any():
            self._complete_ctrl(completed & ctrl_rows[:, None])
        # custom controllers: event-ordered python
        py_rows = comp_rows & ~self.trivial_complete & (self.kind == KIND_CUSTOM)
        for s in np.flatnonzero(py_rows):
            r = self.rt[s]
            for k in self._check_completions_py(r):
                actions = r.scheduler.on_chunk_complete(self._view(r), k)
                if actions:
                    self._apply(r, actions)
                    self._feed_py(r)

        # ---- controller tick ----
        tick_hit = act & (self.t >= self.next_tick - _EPS)
        if tick_hit.any():
            ema = kernels.tick_ema(
                self.ops, self.rate_est, self.delivered,
                self.delivered_at_tick, self.tick_period[:, None],
            )
            rows = tick_hit[:, None]
            np.copyto(self.rate_est, ema, where=rows)
            np.copyto(self.delivered_at_tick, self.delivered, where=rows)
            promc_rows = tick_hit & (self.kind == KIND_PROMC)
            if promc_rows.any():
                self._tick_ctrl(promc_rows)
            for s in np.flatnonzero(
                tick_hit & ~self.trivial_tick & (self.kind == KIND_CUSTOM)
            ):
                r = self.rt[s]
                actions = r.scheduler.on_tick(self._view(r))
                if actions:
                    self._apply(r, actions)
                    self._feed_py(r)
            self.next_tick += np.where(tick_hit, self.tick_period, 0.0)

        # ---- scenario completion ----
        newly = act & self.chunk_done.all(axis=1) & (self.fin_any | comp_rows)
        self.finish_t = np.where(newly, self.t, self.finish_t)
        self.done |= newly

    # ------------------------------------------------------------------ #
    # batched controller dispatch (SC / MC / ProMC rows)
    # ------------------------------------------------------------------ #

    def _complete_ctrl(self, m: np.ndarray) -> None:
        """Chunk completions on controller rows: mark all completed chunks
        first (as the scalar ``_check_completions`` does), then run each
        chunk's completion handler in index order with a re-feed after
        every one — the exact event order of the scalar callback loop."""
        rows = m.any(axis=1)
        self.chunk_done |= m
        self.queue_bytes[m] = 0.0
        rs, ks = np.nonzero(m)
        self.completed_at[rs, ks] = self.t[rs]
        # ProMC drops accumulated streak evidence on any completion
        pr = rows & (self.kind == KIND_PROMC)
        self.streak[pr] = 0
        self.pair_fast[pr] = -1
        self.pair_slow[pr] = -1
        for k in range(self.K):
            trig = m[:, k]
            if not trig.any():
                continue
            fed = np.zeros(self.S, dtype=bool)
            sc_t = trig & (self.kind == KIND_SC)
            if sc_t.any():
                self._sc_complete(sc_t, k)
                fed |= sc_t  # SC always emits a Close => always re-feeds
            mc_t = trig & (
                (self.kind == KIND_MC) | (self.kind == KIND_PROMC)
            )
            if mc_t.any():
                fed |= self._laggard_complete(mc_t, k)
            if fed.any():
                self._feed_vec(fed)

    def _sc_complete(self, trig: np.ndarray, k: int) -> None:
        """SC's on_chunk_complete: close the finished chunk's channels,
        advance the cursor past empty size classes, open the next chunk at
        its own concurrency."""
        (
            self.chunk_of, self.busy, self.dead, self.rem, self.cap,
        ) = controllers.close_chunk(
            self.ops, trig, k, self.chunk_of, self.busy, self.dead,
            self.rem, self.cap,
        )
        self.sc_cursor = controllers.sc_advance_cursor(
            self.ops, trig, self.sc_cursor, self.sc_order, self.nfiles,
            self.n_chunks,
        )
        open_ok = trig & (self.sc_cursor < self.n_chunks)
        nxt = np.take_along_axis(
            self.sc_order, np.clip(self.sc_cursor, 0, self.K - 1)[:, None],
            axis=1,
        )[:, 0]
        n_open = np.where(
            open_ok,
            np.take_along_axis(self.conc, nxt[:, None], axis=1)[:, 0],
            0,
        )
        # host-side capacity: grow the channel axis until every row fits
        while True:
            free = (self.chunk_of == _NO_CHUNK).sum(axis=1)
            if (free >= n_open).all():
                break
            self._grow()
        self.chunk_of, self.dead, self.cap = controllers.open_ranked(
            self.ops, n_open, nxt, self.chunk_of, self.dead, self.cap,
            self.setup_cost, self.cap_k,
        )

    def _laggard_complete(self, trig: np.ndarray, k: int) -> np.ndarray:
        """MC/ProMC's on_chunk_complete: re-target the freed channels to
        the largest-ETA chunks (with per-grant discounting). Returns the
        rows that received actions (and therefore re-feed)."""
        bytes_rem, n_ch, eta = self._view_arrays()
        idx = np.arange(self.K)[None, :]
        live = (
            ~self.chunk_done & (idx != k) & (bytes_rem > 0)
        )
        freed = np.where(trig, n_ch[:, k], 0)
        max_iters = int(freed.max())
        if max_iters == 0:
            return np.zeros(self.S, dtype=bool)
        grants, first = controllers.laggard_grants(
            self.ops, eta, n_ch, live, freed, max_iters
        )
        # no grants (no live receivers) => the scalar reference emits no
        # actions at all, leaving the source's idle channels open
        acted = trig & (grants.sum(axis=1) > 0)
        (
            self.chunk_of, self.busy, self.dead, self.rem, self.cap,
            self.n_moves,
        ) = controllers.apply_grants(
            self.ops, acted, k, grants, first, self.chunk_of, self.busy,
            self.dead, self.rem, self.cap, self.n_moves, self.par,
            self.cap_k, self.setup_cost,
        )
        return acted

    def _tick_ctrl(self, rows: np.ndarray) -> None:
        """ProMC periodic re-allocation check on ``rows``: streak update
        plus (on patience expiry) one fast->slow channel move, with the
        LIFO resume push when the move victims a busy channel."""
        bytes_rem, n_ch, eta = self._view_arrays()
        live = ~self.chunk_done & (bytes_rem > 0)
        streak, pf, ps, move, src, dst = controllers.promc_tick(
            self.ops, eta, self.rate_est, n_ch, live, self.streak,
            self.pair_fast, self.pair_slow, self.promc_ratio,
            self.promc_patience,
        )
        self.streak = np.where(rows, streak, self.streak)
        self.pair_fast = np.where(rows, pf, self.pair_fast)
        self.pair_slow = np.where(rows, ps, self.pair_slow)
        # grow the resume stack whenever it is full, even on no-move ticks:
        # the JAX backend parks a row on prospective overflow, and replaying
        # its tick must leave headroom or the row would re-park every tick
        while (self.prepend_n >= self.P).any():
            self._grow_prepend()
        moving = rows & move
        if not moving.any():
            return
        (
            self.chunk_of, self.busy, self.dead, self.rem, self.cap,
            self.queue_bytes, self.prepend_sizes, self.prepend_n,
            self.n_moves,
        ) = controllers.move_channel(
            self.ops, moving, src, dst, self.chunk_of, self.busy,
            self.dead, self.rem, self.cap, self.queue_bytes,
            self.prepend_sizes, self.prepend_n, self.n_moves, self.par,
            self.cap_k, self.setup_cost,
        )
        self._feed_vec(moving)

    # ------------------------------------------------------------------ #
    # live-row compaction
    # ------------------------------------------------------------------ #

    def _compact(self) -> bool:
        """Retire finished scenarios from the batch arrays.

        Synchronized sweeps pay O(live rows) per iteration; without
        compaction a heterogeneous matrix pays full width until its very
        last straggler finishes. Final metrics of retired rows are archived
        on their runtime objects; surviving rows are re-indexed in place.
        Scenarios are independent, so dropping finished rows cannot change
        any survivor's event sequence.
        """
        alive = np.flatnonzero(~self.done)
        if alive.size == self.S:
            return False
        for r in self.rt:
            if r.archive is None and self.done[r.index]:
                s = r.index
                r.archive = (
                    float(self.finish_t[s]),
                    int(self.n_events[s]),
                    self.completed_at[s].copy(),
                    self.delivered[s].copy(),
                    int(self.n_moves[s]),
                    self._timeline(s),
                )
        for name in self._row_arrays():
            setattr(self, name, getattr(self, name)[alive])
        self.group_id = self.group_id[alive]
        if self.link_member.shape[0]:
            self.link_member = self.link_member[:, alive]
        survivors = []
        for new_row, s in enumerate(alive):
            r = self.rt[int(s)]
            r.index = new_row
            survivors.append(r)
        self.rt = survivors
        self.S = alive.size
        return True

    def _row_arrays(self) -> tuple:
        return _ROW_ARRAYS

    def _maybe_compact(self) -> None:
        # amortized: only rebuild once half the batch has finished.
        # Coupled batches never compact: a done tenant already releases
        # its link share (zero demand), and keeping row indices stable
        # keeps the (L, S) membership table and group ids frozen for the
        # whole run (the jax backend additionally keeps one compiled
        # program that way).
        if self.coupled:
            return
        if self.S > 16 and int(self.done.sum()) * 2 >= self.S:
            self._compact()

    # ------------------------------------------------------------------ #

    def run(self) -> List[SimResult]:
        all_rt = list(self.rt)
        self.start()
        while not self.done.all():
            self.step()
            self._maybe_compact()
        return [self._result(r) for r in all_rt]

    def _timeline(self, s: int) -> List[tuple]:
        """Finalized (t, rate) samples of row ``s`` (empty when the
        scenario does not record)."""
        return kernels.timeline_samples(
            self.tl_t[s], self.tl_rate[s], self.tl_len[s],
            self.tl_stride[s], self.tl_seen[s], self.tl_last_t[s],
            self.tl_last_rate[s],
        )

    def _result(self, r: _ScenarioRuntime) -> SimResult:
        if r.archive is not None:
            (
                finish_t, n_events, completed_at, delivered, n_moves,
                timeline,
            ) = r.archive
        else:
            s = r.index
            finish_t = float(self.finish_t[s])
            n_events = int(self.n_events[s])
            completed_at = self.completed_at[s]
            delivered = self.delivered[s]
            n_moves = int(self.n_moves[s])
            timeline = self._timeline(s)
        total_time = max(finish_t, _EPS)
        return SimResult(
            network=r.network.name,
            scheduler=r.scheduler.name,
            total_bytes=r.total_bytes,
            total_time=total_time,
            throughput=r.total_bytes / total_time,
            per_chunk_time={
                c.name: float(completed_at[k])
                for k, c in enumerate(r.chunks)
            },
            per_chunk_bytes={
                c.name: float(delivered[k])
                for k, c in enumerate(r.chunks)
            },
            timeline=timeline,
            n_events=n_events,
            n_moves=n_moves,
        )
