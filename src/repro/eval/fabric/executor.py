"""Overlap-pipelined, multi-device chunk executor for the batched sweeps.

``runner.run_built`` used to execute chunks strictly serially: build one
chunk's Simulations on the host (scenario assembly, ``build_files``,
padding/bucketing), run the driver, block on the download, repeat — the
host sat idle while the device ran and vice versa. This module replaces
that loop with a small pipeline:

  * one **prep thread** walks the chunks in order, builds each chunk's
    Simulations and driver, submits its canonical-signature ladder to a
    background AOT warm thread (jax), and hands the ready driver to a
    bounded per-device queue — explicit double-buffered staging: while
    device ``d`` computes chunk ``j``, chunk ``j + n_devices`` is already
    built and waiting in ``d``'s queue, and the chunk after that is being
    built;
  * one **compute worker per device** drains its queue, runs the driver
    (pinned to that device via ``device=`` for drivers that advertise
    ``supports_device_placement``), and writes results straight into the
    shared results list at the chunk's original indices — results are
    always in input order, independent of interleaving;
  * chunks round-robin across ``jax.devices()``, so the oracle/tuner
    planes scale with device count (validated on CPU hosts via
    ``--xla_force_host_platform_device_count=N``, as ``launch/dryrun.py``
    does).

The queue bound is the backpressure: at most ``queue_depth`` staged
chunks per device plus the one being built, so peak host memory stays a
small constant multiple of one chunk — not the whole sweep.

``REPRO_FABRIC_EXECUTOR=serial`` is the escape hatch: it restores the
exact pre-pipeline execution path (same thread, same loop, no device
pinning, no AOT warm, donation off unless forced) for debugging — a
traceback then points at a plain call stack, and buffer donation cannot
be a variable. ``REPRO_FABRIC_EXECUTOR_DEPTH`` overrides the staging
depth.

Any worker/prep exception cancels the pipeline (remaining chunks are
discarded) and re-raises in the caller, so failure behaviour matches the
serial loop's fail-fast semantics.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Callable, List, Optional, Sequence

from .stats import wall_timer

#: recognised ``REPRO_FABRIC_EXECUTOR`` values
EXECUTOR_MODES = ("serial", "async")

#: staged (built-but-not-running) chunks per device: 1 is classic double
#: buffering — one chunk in flight, one staged, one being built
DEFAULT_QUEUE_DEPTH = 1


def executor_mode(override: Optional[str] = None) -> str:
    """Resolve the executor mode: explicit ``override`` (a run_built /
    run_matrix kwarg or CLI flag) wins, then ``REPRO_FABRIC_EXECUTOR``,
    then the async default."""
    mode = override or os.environ.get("REPRO_FABRIC_EXECUTOR") or "async"
    if mode not in EXECUTOR_MODES:
        raise ValueError(
            f"unknown executor mode {mode!r}; options: {EXECUTOR_MODES}"
        )
    return mode


def backend_devices(cls) -> list:
    """The device list the executor shards over: ``jax.devices()`` for
    drivers that support placement, else a single anonymous slot (the
    NumPy driver still gets prep/compute overlap from the pipeline).

    XLA *host* devices are virtual — N forced CPU devices timeslice the
    same physical cores — so on the cpu platform the list is capped at
    ``os.cpu_count()``: round-robining four device loops onto one core
    benchmarked at 0.3x the single-device rate (threadpool contention
    plus N copies of every compiled program), while real accelerator
    platforms keep the full device list."""
    if getattr(cls, "supports_device_placement", False):
        import jax

        devices = list(jax.devices())
        if devices and devices[0].platform == "cpu":
            devices = devices[: max(1, os.cpu_count() or 1)]
        return devices
    return [None]


def _queue_depth(depth: Optional[int]) -> int:
    if depth is None:
        depth = int(
            os.environ.get("REPRO_FABRIC_EXECUTOR_DEPTH", DEFAULT_QUEUE_DEPTH)
        )
    return max(1, depth)


def _warm_chunk(driver) -> None:
    """AOT-compile the chunk's signature ladder (initial shape + every
    compaction rung) so the compute worker finds ready executables."""
    from . import jax_backend
    from .bucketing import canonical_signature, signature_ladder

    try:
        sig = canonical_signature(driver)
    except Exception:
        return  # custom schedulers may defeat the closed-form bound
    floor = driver.compact_floor()
    for rung in signature_ladder(sig, floor):
        jax_backend.warm_signature(
            rung, device=driver.device, donate=driver.donate, floor=floor
        )


def _warm_loop(warm_q: "queue.Queue", stop: Optional[threading.Event],
               warm: Optional[Callable] = None) -> None:
    """Drain ``warm_q`` (driver -> AOT warm; ``None`` sentinel exits).

    Warm work is pure prefetch, so on pipeline failure (``stop`` set)
    pending drivers are discarded instead of compiled — errors surface
    as soon as the workers join, not after a stray multi-second XLA
    compile of a chunk nobody will run."""
    warm = warm or _warm_chunk
    while True:
        driver = warm_q.get()
        if driver is None:
            return
        if stop is not None and stop.is_set():
            continue  # fail-fast: drop pending warms, keep draining
        try:
            warm(driver)
        except Exception:
            pass  # a failed warm only means the jit fallback compiles


def execute_chunks(
    cls,
    parts: Sequence[Sequence[int]],
    builders: Optional[Sequence[Callable]],
    names: Optional[Sequence[str]],
    results: List,
    mode: Optional[str] = None,
    queue_depth: Optional[int] = None,
    *,
    make_chunk: Optional[Callable] = None,
    prep_workers: Optional[int] = None,
) -> List:
    """Execute ``parts`` (lists of row indices) through driver class
    ``cls``, writing each row's result to ``results[i]``.

    Chunk construction is pluggable: ``make_chunk(part, device)`` must
    return a ready driver for the rows in ``part`` (the columnar plan
    path slices a ``ScenarioPlan``); the default builds ``Simulation``
    objects through ``builders``/``names`` — the legacy object path.

    ``mode="serial"`` runs the historical strictly-serial loop; the
    default async pipeline overlaps host prep, device compute, and AOT
    warming, sharding chunks across devices round-robin. ``prep_workers``
    (default 1) parallelizes chunk prep — only raise it when
    ``make_chunk`` is thread-safe, as plan slicing is and the legacy
    builder chain (shared file-cache hits aside) generally is not
    guaranteed to be.

    Chunk build and driver-run wall time accumulate into the shared
    ``stats.SYNC_STATS`` wall keys in every mode, so the prep-vs-compute
    breakdown (``runner --verbose``) measures the host build tax.
    """
    mode = executor_mode(mode)
    parts = [list(p) for p in parts]
    placed = getattr(cls, "supports_device_placement", False)

    if make_chunk is None:
        if builders is None or names is None:
            raise ValueError("make_chunk or builders+names required")

        def make_chunk(part, dev):
            sims = [builders[i]() for i in part]
            kwargs = {"device": dev} if placed else {}
            return cls(sims, names=[names[i] for i in part], **kwargs)

    if mode == "serial" or len(parts) <= 0:
        for part in parts:
            with wall_timer("build_wall_s"):
                driver = make_chunk(part, None)
            with wall_timer("compute_wall_s"):
                out = driver.run()
            for i, res in zip(part, out):
                results[i] = res
        return results

    devices = backend_devices(cls)
    # with one device there is no sharding win from pinning, and leaving
    # device=None keeps the AOT/jit cache key shared with direct
    # (non-executor) runs of the same shapes
    if len(devices) == 1:
        devices = [None]
    depth = _queue_depth(queue_depth)
    queues: List[queue.Queue] = [
        queue.Queue(maxsize=depth) for _ in devices
    ]
    stop = threading.Event()
    errors: List[BaseException] = []
    err_lock = threading.Lock()

    def fail(exc: BaseException) -> None:
        with err_lock:
            errors.append(exc)
        stop.set()

    def put(q: queue.Queue, item) -> None:
        # bounded-queue put that aborts on pipeline failure; sentinels
        # (None) always go through — workers drain until they see one
        while True:
            if stop.is_set() and item is not None:
                return
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    # chunk prep fans out over a small worker pool: workers claim chunk
    # indices from a shared cursor, so chunk j still lands on device
    # j % n_devices (the round-robin sharding contract) regardless of
    # which worker built it; per-device queue order may interleave, but
    # results are written by original row index so output order is fixed
    next_j = [0]
    j_lock = threading.Lock()

    def prep() -> None:
        try:
            while not stop.is_set():
                with j_lock:
                    j = next_j[0]
                    if j >= len(parts):
                        return
                    next_j[0] = j + 1
                dev = devices[j % len(devices)]
                with wall_timer("build_wall_s"):
                    driver = make_chunk(parts[j], dev)
                if placed:
                    warm_pool_submit(driver)
                put(queues[j % len(devices)], (parts[j], driver))
        except BaseException as exc:  # chunk builds can raise anything
            fail(exc)

    def compute(d: int) -> None:
        q = queues[d]
        while True:
            item = q.get()
            if item is None:
                return
            if stop.is_set():
                continue  # keep draining so prep's puts can't wedge
            part, driver = item
            try:
                with wall_timer("compute_wall_s"):
                    out = driver.run()
                # distinct indices per chunk: concurrent writes are safe
                for i, res in zip(part, out):
                    results[i] = res
            except BaseException as exc:
                fail(exc)

    # a single warm thread: AOT compiles happen off the critical path but
    # still one at a time (XLA compiles are already multi-threaded
    # internally; stacking them thrashes)
    warm_q: "queue.Queue" = queue.Queue()

    def warm_pool_submit(driver) -> None:
        warm_q.put(driver)

    n_prep = max(1, min(prep_workers or 1, max(1, len(parts))))
    prep_threads = [
        threading.Thread(target=prep, name=f"fabric-prep{p}")
        for p in range(n_prep)
    ]
    compute_threads = [
        threading.Thread(target=compute, args=(d,), name=f"fabric-dev{d}")
        for d in range(len(devices))
    ]
    warm_thread = None
    if placed:
        warm_thread = threading.Thread(
            target=_warm_loop, args=(warm_q, stop), name="fabric-warm",
            daemon=True,
        )
        warm_thread.start()
    for t in prep_threads + compute_threads:
        t.start()
    # sentinels flow only after every prep worker is finished (with one
    # ordered prep thread they used to ride its ``finally``)
    for t in prep_threads:
        t.join()
    for q in queues:
        put(q, None)
    for t in compute_threads:
        t.join()
    if warm_thread is not None:
        # leftover warm work is pure prefetch — drop it, then join: an
        # abandoned thread still inside an XLA compile when the
        # interpreter exits aborts the whole process (std::terminate),
        # and the join waits out at most the one in-flight compile
        try:
            while True:
                warm_q.get_nowait()
        except queue.Empty:
            pass
        warm_q.put(None)
        warm_thread.join()
    if errors:
        raise errors[0]
    return results
