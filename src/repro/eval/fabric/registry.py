"""Backend registry: name -> batched fabric driver class.

``numpy`` (alias ``batch``, the historical name) is the eager NumPy
driver; ``jax`` the jit/vmap device loop. The event-driven reference is
not a fabric backend — ``eval.runner`` special-cases it — but the names
here are the ``--backend`` axis surfaced by ``eval.runner`` and
``eval.difftest``.
"""
from __future__ import annotations

from typing import Type

from .driver import FabricSimulation

#: public backend names (excluding the event-driven reference)
BACKENDS = ("numpy", "jax")


def get_backend(name: str) -> Type[FabricSimulation]:
    """Resolve a fabric backend name to its driver class.

    Resolving ``jax`` also arms the opt-in persistent XLA compilation
    cache (``REPRO_XLA_CACHE``) — this is the one chokepoint every jax
    execution path (runner, difftest, tuner, benchmarks) passes through
    before compiling anything.
    """
    if name in ("numpy", "batch"):
        return FabricSimulation
    if name == "jax":
        from .jax_backend import JaxFabricSimulation
        from .xla_cache import enable_persistent_cache

        enable_persistent_cache()
        return JaxFabricSimulation
    raise ValueError(
        f"unknown fabric backend {name!r}; options: {BACKENDS}"
    )
