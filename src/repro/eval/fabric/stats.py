"""Shared pipeline telemetry: host-sync counters + wall-clock splits.

Historically :data:`SYNC_STATS` lived in :mod:`repro.eval.fabric.
jax_backend`; the executor's prep/compute wall instrumentation needs the
same accumulator from NumPy-only runs, which must not import jax. The
dict (and its lock) moved here; ``jax_backend`` re-imports the *same*
objects, so ``jax_backend.SYNC_STATS`` keeps working and
:func:`reset_sync_stats` (in-place) resets both views at once.

Counter keys (``rounds`` .. ``runs``) keep their zero-host-round
contract (see the jax backend docstring). The ``*_wall_s`` keys are the
pipeline's build-tax instrumentation: host-side chunk construction
(``build_wall_s``), driver execution (``compute_wall_s``), and — on the
jax backend — the device->host result downloads inside the drive loop
(``download_wall_s``). Wall keys are float seconds and overlap freely
(several prep/compute threads accumulate concurrently), so they measure
aggregate thread-time per phase, not elapsed wall clock; their ratio is
what the prep-vs-compute breakdown under ``runner --verbose`` reports.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager

#: wall-clock accumulator keys: float seconds, thread-time semantics.
#: Everything else in SYNC_STATS is an exact integer counter — tests that
#: pin counter equality across execution modes must exclude these.
WALL_KEYS = frozenset(
    {"build_wall_s", "compute_wall_s", "download_wall_s"}
)

#: host-sync telemetry, accumulated across runs (reset with
#: :func:`reset_sync_stats`); the eval-matrix bench derives its
#: device-syncs-per-scenario figure from this. ``rounds`` counts device
#: while_loop entries (compaction/straggler re-entries included);
#: ``replay_rounds`` counts only rounds that ended with the host
#: replaying ``_post`` for parked rows, and ``post_row_replays`` the
#: parked rows themselves — both exactly 0 for built-in schedulers, the
#: zero-host-round invariant CI gates on.
SYNC_STATS = {
    "rounds": 0,
    "replay_rounds": 0,
    "post_row_replays": 0,
    "scenarios": 0,
    "runs": 0,
    "build_wall_s": 0.0,
    "compute_wall_s": 0.0,
    "download_wall_s": 0.0,
}

#: guards SYNC_STATS: under the pipelined executor several driver
#: instances finish concurrently, and each merges its private per-run
#: counters in one locked step — interleaved chunks therefore report
#: exactly the totals serial execution would
_SYNC_LOCK = threading.Lock()


def reset_sync_stats() -> None:
    with _SYNC_LOCK:
        for k in SYNC_STATS:
            SYNC_STATS[k] = 0.0 if k in WALL_KEYS else 0


def _merge_sync_stats(local: dict) -> None:
    with _SYNC_LOCK:
        for k, v in local.items():
            SYNC_STATS[k] += v


def record_wall(key: str, seconds: float) -> None:
    """Accumulate ``seconds`` into wall key ``key`` (thread-safe)."""
    with _SYNC_LOCK:
        SYNC_STATS[key] += seconds


@contextmanager
def wall_timer(key: str):
    """Context manager accumulating the enclosed block's wall time."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        record_wall(key, time.perf_counter() - t0)
