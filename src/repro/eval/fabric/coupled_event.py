"""Lockstep event-simulator reference for shared-fabric coupled groups.

The scalar :class:`repro.core.simulator.Simulation` event loop is the
semantics ground truth of the whole fabric layer, but it runs one
transfer at a time — coupling through shared links needs every tenant of
a fabric group advanced against the *same* clock. This module is the
coupled ground truth: it drives N ordinary Simulations in lockstep,
recomputing the cross-tenant link allocation every event:

  1. every live tenant reports ``(pool, demand)``
     (:meth:`Simulation.transfer_demand`: its uncoupled disk/bandwidth
     pool, clipped to what its transferring channels can carry);
  2. one :func:`repro.eval.fabric.kernels.waterfill_coupled` call — the
     very kernel the batched backends run — turns the demands and the
     group's (links x tenants) membership table into per-tenant grants;
  3. each tenant peeks its event horizon under its grant
     (:meth:`Simulation.next_dt`), the group takes the minimum ``D``,
     and every live tenant steps with ``step(max_dt=D, bandwidth=grant)``
     — so all clocks advance together and no tenant crosses an event
     threshold another tenant's allocation change should have preceded.

A tenant whose own horizon exceeds ``D`` takes a partial advance: no
completion, feed, or tick threshold is crossed (``D`` <= its own next
event), so the sweep is a natural no-op for it beyond moving bytes —
exactly the batched drivers' lockstep-dt semantics. Done tenants stop
stepping and contribute zero demand, releasing their link share.

``eval.difftest`` holds both batched backends to this reference within
the standard 2% bar on the multi-tenant ``tenant_matrix``.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.simulator import SimResult, Simulation

from . import kernels
from .shared import SharedFabric, resolve_fabric
from .shim import numpy_ops


def run_coupled_group(
    sims: Sequence[Simulation],
    fabrics: Sequence[Optional[SharedFabric]],
) -> List[SimResult]:
    """Run one fabric group of Simulations to completion in lockstep."""
    fab = resolve_fabric(fabrics)
    ops = numpy_ops()
    n = len(sims)
    for s in sims:
        s.start()
    demand = np.zeros(n, dtype=np.float64)
    while not all(s.done for s in sims):
        demand[:] = 0.0
        for i, s in enumerate(sims):
            if not s.done:
                demand[i] = s.transfer_demand()[1]
        x, _ = kernels.waterfill_coupled(
            ops, demand, fab.member, fab.link_cap
        )
        horizon = math.inf
        for i, s in enumerate(sims):
            if not s.done:
                horizon = min(horizon, s.next_dt(bandwidth=float(x[i])))
        for i, s in enumerate(sims):
            if not s.done:
                s.step(max_dt=horizon, bandwidth=float(x[i]))
    return [s.result() for s in sims]


def run_event_coupled(scenarios: Sequence) -> List:
    """Event-backend results for a matrix holding coupled rows.

    Uncoupled rows run through the ordinary one-Simulation event loop
    (bit-identical to the pre-fabric path); rows sharing a fabric group
    run through :func:`run_coupled_group`. Results come back in input
    order.
    """
    from ..scenarios import build_simulation

    results: List = [None] * len(scenarios)
    groups: dict = {}
    for i, sc in enumerate(scenarios):
        if sc.shared_fabric is None:
            results[i] = build_simulation(sc).run()
        else:
            groups.setdefault(sc.shared_fabric.group, []).append(i)
    for idxs in groups.values():
        sims = [build_simulation(scenarios[i]) for i in idxs]
        out = run_coupled_group(
            sims, [scenarios[i].shared_fabric for i in idxs]
        )
        for i, res in zip(idxs, out):
            results[i] = res
    return results
