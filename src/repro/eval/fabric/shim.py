"""Minimal array-API shim the fabric kernels are written against.

The kernels need plain element-wise math, last-axis reductions, sorting,
cumulative sums, and gathers — all spelled identically in ``numpy`` and
``jax.numpy`` and reached through ``ops.xp``. The two operations whose
efficient form genuinely differs between the backends (scatter-accumulate
into per-chunk slots) are methods on :class:`ArrayOps`:

  * NumPy uses ``np.add.at`` over the non-zero index set, which preserves
    the exact per-element accumulation order of the original ``batchsim``
    loop (bit-compatible golden snapshots);
  * JAX uses a dense one-hot contraction, which traces to a single fused
    XLA reduction and vectorizes under ``vmap``.

Kernels treat chunk/channel structure as the *last* axis (or two), so the
same kernel runs batched over ``(S, C)`` arrays under NumPy and per-scenario
over ``(C,)`` rows under ``jax.vmap``.
"""
from __future__ import annotations

import numpy as np

#: sentinel for a channel slot not assigned to any chunk
NO_CHUNK = -1


class ArrayOps:
    """Array namespace + the few backend-divergent primitives.

    ``xp`` is ``numpy`` or ``jax.numpy``; everything else the kernels use
    is reached as ``ops.xp.<fn>``.
    """

    name: str = "abstract"

    def __init__(self, xp):
        self.xp = xp

    # ------------------------------------------------------------------ #

    def count_by_chunk(self, chunk_idx, mask, n_chunks: int):
        """Integer counts per chunk: ``out[..., k] = sum_c mask & (idx==k)``.

        ``chunk_idx`` (..., C) may contain ``NO_CHUNK`` entries; they match
        no chunk and are dropped. Exact (integer) on both backends.
        """
        xp = self.xp
        onehot = (chunk_idx[..., :, None] == xp.arange(n_chunks)) & mask[
            ..., :, None
        ]
        return xp.sum(onehot, axis=-2)

    def chunk_scatter_add(self, target, chunk_idx, values, mask):
        """``target[..., idx[..., c]] += values[..., c]`` where ``mask``.

        ``target`` (..., K), ``chunk_idx``/``values``/``mask`` (..., C).
        Returns the updated array (never mutates the input).
        """
        raise NotImplementedError

    def table_lookup(self, table, idx):
        """``out[..., c] = table[..., idx[..., c]]`` for small trailing
        tables; ``idx`` entries must already be clipped to range.

        NumPy uses ``take_along_axis`` (fast native gathers); JAX
        contracts against a one-hot — XLA's CPU gather lowers to scalar
        loads and dominates hot-loop profiles, while the one-hot fuses
        into a vectorized select/reduce.
        """
        raise NotImplementedError


class NumpyOps(ArrayOps):
    name = "numpy"

    def __init__(self):
        super().__init__(np)

    def chunk_scatter_add(self, target, chunk_idx, values, mask):
        out = target.copy()
        idx = np.nonzero(mask)
        if idx[0].size:
            # np.nonzero is row-major, so duplicate slots accumulate in the
            # same (scenario, channel) order as the scalar event loop
            np.add.at(out, idx[:-1] + (chunk_idx[idx],), values[idx])
        return out

    def table_lookup(self, table, idx):
        return np.take_along_axis(table, idx, axis=-1)


class JaxOps(ArrayOps):
    name = "jax"

    def __init__(self):
        import jax.numpy as jnp

        super().__init__(jnp)

    def chunk_scatter_add(self, target, chunk_idx, values, mask):
        xp = self.xp
        n_chunks = target.shape[-1]
        onehot = (chunk_idx[..., :, None] == xp.arange(n_chunks)) & mask[
            ..., :, None
        ]
        delta = xp.sum(
            xp.where(onehot, values[..., :, None], 0.0), axis=-2
        )
        return target + delta

    def table_lookup(self, table, idx):
        xp = self.xp
        n = table.shape[-1]
        onehot = idx[..., :, None] == xp.arange(n)
        return xp.sum(
            xp.where(onehot, table[..., None, :], table.dtype.type(0)),
            axis=-1,
        )


def numpy_ops() -> NumpyOps:
    return NumpyOps()


def jax_ops() -> JaxOps:
    return JaxOps()
