"""Opt-in persistent XLA compilation cache (``REPRO_XLA_CACHE``).

Shape bucketing (:mod:`repro.eval.fabric.bucketing`) shrinks the jax
backend's compile footprint to a handful of canonical signatures, but
each of those still costs seconds of XLA time on the first run of every
*process*. JAX ships a persistent on-disk compilation cache that keys
entries on the serialized HLO + compile options + backend version — with
bucketing keeping the HLO set small and stable, pointing the cache at a
durable directory makes cold starts a cache read (< 1s per program)
instead of a compile (~5-10s per program).

Enable by exporting ``REPRO_XLA_CACHE=/path/to/dir``, or just
``REPRO_XLA_CACHE=1`` for the default directory
(``~/.cache/repro_xla``, created on demand); the fabric backend
registry, ``benchmarks/run.py``, and the CI workflow (actions/cache
keyed on the jax version + kernel sources) all route through
:func:`enable_persistent_cache`. Off by default: writing cache entries
into undeclared paths is the wrong default for a library, and tests
that *count* compiles must see real ones.
"""
from __future__ import annotations

import os
from typing import Optional

#: environment variable naming the cache directory (opt-in); truthy
#: one-word values select :data:`DEFAULT_DIR`
ENV_VAR = "REPRO_XLA_CACHE"

#: where ``REPRO_XLA_CACHE=1`` puts the cache
DEFAULT_DIR = os.path.join(
    os.path.expanduser("~"), ".cache", "repro_xla"
)

_TRUTHY = {"1", "true", "yes", "on"}

_configured: Optional[str] = None


def cache_dir() -> Optional[str]:
    """The configured cache directory, or None when disabled."""
    return _configured


def enabled() -> bool:
    """True once the persistent cache has been pointed at a directory
    (or would be on the next backend resolution: the env var counts)."""
    return _configured is not None or bool(os.environ.get(ENV_VAR))


def enable_persistent_cache(path: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at ``path`` (default:
    ``$REPRO_XLA_CACHE``). Returns the directory in effect, or None when
    neither is set. Idempotent; safe to call before any jax import cost
    has been paid — it only touches jax.config.
    """
    global _configured
    if path is None:
        val = os.environ.get(ENV_VAR, "").strip()
        if not val:
            return _configured
        path = DEFAULT_DIR if val.lower() in _TRUTHY else val
    if _configured is not None:
        return _configured
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # the fused device loop is one big program per shape signature: cache
    # every entry (no size floor) but skip sub-second trivia like the
    # difftest's scalar helpers
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    _configured = path
    return _configured
