"""Scenario-matrix batch evaluation subsystem.

The paper's claim is breadth: heuristic tuning wins across widely varying
(network x dataset x scheduler x maxCC) conditions. This package turns that
breadth into an executable object:

  - :mod:`scenarios`  declarative Scenario grid + deterministic builders
  - :mod:`batchsim`   vectorized fluid fast-path advancing ALL scenarios'
                      channel states in batched NumPy arrays between
                      controller decision points — multi-fold faster sweeps
                      than looping the event loop per scenario (measured
                      2.5-3x on the default matrix, growing with matrix
                      size and channel counts) at bit-exact agreement
  - :mod:`runner`     matrix runner over either backend + golden JSON
                      metric snapshots shared by tests and benchmarks
  - :mod:`difftest`   differential harness asserting fast-path/event-sim
                      agreement within tolerance on every scenario

Every future tuning PR is validated against this matrix; see TESTING.md.
"""
from .batchsim import BatchSimulation
from .difftest import DiffReport, assert_agreement, diff_matrix
from .runner import (
    load_golden,
    metrics_snapshot,
    run_matrix,
    run_scenario,
    run_simulations,
    save_golden,
)
from .scenarios import Scenario, build_simulation, default_matrix, smoke_matrix

__all__ = [
    "BatchSimulation",
    "DiffReport",
    "assert_agreement",
    "diff_matrix",
    "Scenario",
    "build_simulation",
    "default_matrix",
    "smoke_matrix",
    "run_matrix",
    "run_scenario",
    "run_simulations",
    "metrics_snapshot",
    "save_golden",
    "load_golden",
]
