"""Scenario-matrix batch evaluation subsystem.

The paper's claim is breadth: heuristic tuning wins across widely varying
(network x dataset x scheduler x maxCC) conditions. This package turns that
breadth into an executable object:

  - :mod:`scenarios`  declarative Scenario grid + deterministic builders
                      (``default_matrix`` 276 scenarios, ``full_matrix``
                      1000+ with impaired-path testbeds and heavy-tail
                      datasets)
  - :mod:`fabric`     backend-neutral fluid kernels + the batched drivers
                      (NumPy fast path; JAX jit/vmap device loop; optional
                      Pallas water-fill)
  - :mod:`runner`     matrix runner over any backend (event/numpy/jax)
                      with chunked execution + golden JSON snapshots
  - :mod:`difftest`   differential harness asserting backend agreement
                      within tolerance on every scenario
  - :mod:`tune`       autotuner: batched (pp, p, cc) parameter-space
                      search over the fused sweep (exhaustive oracle,
                      successive halving, hill climbing), static-oracle
                      regret reports, JSON warm-start history

Every future tuning PR is validated against this matrix; see TESTING.md.

Submodules resolve lazily (PEP 562) so that ``core.netmodel`` /
``core.simulator`` can re-export fabric kernels without an import cycle.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "FabricSimulation": ".fabric.driver",
    "JaxFabricSimulation": ".fabric.jax_backend",
    "DiffReport": ".difftest",
    "assert_agreement": ".difftest",
    "diff_matrix": ".difftest",
    "Scenario": ".scenarios",
    "build_simulation": ".scenarios",
    "default_matrix": ".scenarios",
    "expand_candidates": ".scenarios",
    "full_matrix": ".scenarios",
    "smoke_matrix": ".scenarios",
    "timeline_matrix": ".scenarios",
    "run_built": ".runner",
    "run_matrix": ".runner",
    "run_scenario": ".runner",
    "run_simulations": ".runner",
    "HistoryStore": ".tune",
    "TuneResult": ".tune",
    "hill_climb": ".tune",
    "oracle_search": ".tune",
    "regret_report": ".tune",
    "successive_halving": ".tune",
    "metrics_snapshot": ".runner",
    "save_golden": ".runner",
    "load_golden": ".runner",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(modname, __name__), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
