"""Optimizers and LR schedules."""
from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_at,
)
