"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Optax-free (offline container), functional, optimizer state mirrors the
parameter tree's sharding (m/v inherit param PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - frac)
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init_opt_state(params: PyTree) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    state: Dict[str, PyTree],
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
