"""Network presets from the paper's test environments (Tables 1-2) plus the
TPU-fabric DCN preset used by the grad-sync adaptation.

Calibration targets (paper observations the simulator should land near):
  - Fig 1a/2a: pipelining gives up to ~2x on small files, negligible on large
    -> per-file ``unhidden_overhead`` comparable to the control RTT on the
       Fig-1 XSEDE pair (RTT 60 ms).
  - Fig 1c: one channel moves ~2 Gbps of huge files on XSEDE; eight channels
    reach ~9 Gbps  -> window_efficiency*buf/RTT ~ 2.3 Gbps, disk lanes ~4 Gbps.
  - Fig 9a (BlueWaters-Stampede, 3x10G, DES): MC/ProMC peak ~22 Gbps at CC 8,
    declining beyond (disk contention); Globus Online <= 8.5 Gbps.
  - Fig 9b (Stampede-Comet): MC/ProMC up to ~8.6 Gbps.
  - Fig 9c (SuperMIC-Bridges): 4 MB TCP buffer + server-side stream clamp
    => concurrency keeps helping; ~4 Gbps at high maxCC.
  - Fig 6 (LAN, GlusterFS on 5 servers): >2 Gbps; dips when maxCC > 4.
  - Fig 13: Globus Connect Personal on LAN ~500 Mbps (relay path).
"""
from __future__ import annotations

import dataclasses
import math

from .types import MB, DiskSpec, NetworkSpec, gbps

#: Ethernet-ish segment size used by the Mathis loss-window model below.
_MSS = 1460.0


def impaired_variant(
    base: NetworkSpec,
    name: str,
    *,
    loss_rate: float = 0.0,
    jitter: float = 0.0,
    control_rtt: float | None = None,
    bandwidth_steps: tuple | None = None,
    bandwidth_ramp: tuple | None = None,
) -> NetworkSpec:
    """Derive a pathologically impaired path from a clean testbed preset.

    loss_rate     random segment loss. Per-stream TCP throughput follows the
                  Mathis bound ``MSS/(RTT*sqrt(loss))`` — modeled by capping
                  the effective window at ``MSS * sqrt(1.5/loss)`` bytes, so
                  parallelism (many small windows) becomes the decisive
                  knob, exactly the regime the paper's Sec. 3 argues for.
    jitter        RTT variance. Ack clocking keys on the worst-case RTT, so
                  the window-limited rate sees ``rtt + 2*jitter`` and the
                  per-file command gap inherits the same inflation.
    control_rtt   asymmetric routes: the control channel's round trip when
                  it differs from the data path (satellite uplink, congested
                  reverse path). Inflates per-file dead time only; omit to
                  keep the base path's (a)symmetry.
    bandwidth_steps
                  time-varying capacity ("network conditions vary over
                  time"): ``((t, mult), ...)`` piecewise-constant capacity
                  multipliers; a leading ``(0.0, 1.0)`` step is prepended
                  if missing. Tuning (Algorithm 1) and rate predictions
                  keep using the nominal bandwidth — the realized rates
                  deviating from the plan is precisely what the adaptive
                  controllers must react to.
    bandwidth_ramp
                  ``(t0, t1, end_scale, n_steps)``: a linear capacity drift
                  from 1.0 at ``t0`` to ``end_scale`` at ``t1``, rendered
                  as a dense step ladder (fluid integration stays exact on
                  piecewise-constant rates on every backend).
    """
    rtt = base.rtt + 2.0 * jitter
    buffer_size = base.buffer_size
    window_efficiency = base.window_efficiency
    if loss_rate > 0.0:
        mathis_window = _MSS * math.sqrt(1.5 / loss_rate)
        buffer_size = int(min(buffer_size, mathis_window))
        # loss recovery also wastes a slice of whatever window remains
        window_efficiency *= 0.9
    fields = dict(
        name=name,
        rtt=rtt,
        buffer_size=buffer_size,
        window_efficiency=window_efficiency,
        unhidden_overhead=base.unhidden_overhead + jitter,
    )
    if control_rtt is not None:  # else inherit the base's control path
        fields["control_rtt"] = control_rtt
    if bandwidth_steps is not None and bandwidth_ramp is not None:
        raise ValueError("pass bandwidth_steps or bandwidth_ramp, not both")
    if bandwidth_ramp is not None:
        t0, t1, end_scale, n_steps = bandwidth_ramp
        bandwidth_steps = tuple(
            (t0 + i * (t1 - t0) / n_steps, 1.0 + (end_scale - 1.0) * i / n_steps)
            for i in range(1, n_steps + 1)
        )
    if bandwidth_steps is not None:
        prof = tuple((float(t), float(m)) for t, m in bandwidth_steps)
        if not prof or prof[0][0] > 0.0:
            prof = ((0.0, 1.0),) + prof
        fields["bandwidth_profile"] = prof
    return dataclasses.replace(base, **fields)

# ---------------------------------------------------------------------------
# Table 1 environments (Sec. 3 parameter-effect experiments, Figs. 1-2)
# ---------------------------------------------------------------------------

XSEDE = NetworkSpec(
    name="xsede-lonestar-gordon",
    bandwidth=gbps(10),
    rtt=60e-3,
    buffer_size=32 * MB,
    # "highly tuned and parallelized disk sub-systems at the XSEDE sites"
    disk=DiskSpec(
        streaming_rate=gbps(9.8),
        per_file_overhead=0.004,
        saturation_cc=8,
        contention=0.02,
        per_channel_rate=gbps(4.0),
    ),
    # per-file server-side cost pipelining cannot hide; RTT-comparable so the
    # small-file pipelining win tops out near 2x (Fig. 1a).
    unhidden_overhead=0.055,
)

LONI = NetworkSpec(
    name="loni-queenbee-painter",
    bandwidth=gbps(10),
    rtt=10e-3,
    buffer_size=16 * MB,
    disk=DiskSpec(
        streaming_rate=gbps(5.5),
        per_file_overhead=0.005,
        saturation_cc=8,
        contention=0.03,
        per_channel_rate=gbps(2.5),
    ),
    unhidden_overhead=0.009,
)

# ---------------------------------------------------------------------------
# Table 2 environments (Sec. 4 performance comparison, Figs. 5-13)
# ---------------------------------------------------------------------------

BLUEWATERS_STAMPEDE = NetworkSpec(
    name="bluewaters-stampede",
    bandwidth=gbps(30),  # 3 x 10 Gbps
    rtt=32e-3,
    buffer_size=32 * MB,
    disk=DiskSpec(
        streaming_rate=gbps(24),
        per_file_overhead=0.004,
        saturation_cc=8,
        contention=0.05,  # visible decline past CC=8 (Fig. 9a)
        per_channel_rate=gbps(2.75),
    ),
    unhidden_overhead=0.012,
)

STAMPEDE_COMET = NetworkSpec(
    name="stampede-comet",
    bandwidth=gbps(10),
    rtt=40e-3,
    buffer_size=32 * MB,
    disk=DiskSpec(
        streaming_rate=gbps(9.2),
        per_file_overhead=0.004,
        saturation_cc=8,
        contention=0.02,
        per_channel_rate=gbps(2.3),
    ),
    unhidden_overhead=0.012,
)

SUPERMIC_BRIDGES = NetworkSpec(
    name="supermic-bridges",
    bandwidth=gbps(10),
    rtt=45e-3,
    buffer_size=4 * MB,  # sub-optimal; needs >50MB (Sec 4.2) => CC keeps helping
    disk=DiskSpec(
        streaming_rate=gbps(5.0),
        per_file_overhead=0.005,
        saturation_cc=12,
        contention=0.01,
        per_channel_rate=gbps(0.8),
    ),
    unhidden_overhead=0.012,
    max_streams_per_channel=2,  # server-side clamp; cumulative buffer only
    # grows with concurrency (Sec. 4.2 explanation of Fig. 9c)
)

LAN = NetworkSpec(
    name="didclab-lan-glusterfs",
    bandwidth=gbps(10),
    rtt=0.2e-3,
    buffer_size=1 * MB,
    # storage backed by only five servers (Sec. 4.1) -> early saturation and
    # contention past maxCC=4 (Fig. 6)
    disk=DiskSpec(
        streaming_rate=gbps(3.2),
        per_file_overhead=0.003,
        saturation_cc=4,
        contention=0.08,
        per_channel_rate=gbps(0.9),
    ),
    unhidden_overhead=0.004,
)

# ---------------------------------------------------------------------------
# Impaired-path variants (loss / jitter / asymmetric RTT) — the conditions
# HARP-style sweeps (arXiv:1708.03053) and the two-phase model
# (arXiv:1812.11255) tune against. These widen the full evaluation matrix
# beyond the paper's clean research WANs.
# ---------------------------------------------------------------------------

#: transatlantic-grade path with residual random loss: per-stream windows
#: collapse to the Mathis bound, so parallelism decides everything.
LOSSY_TRANSATLANTIC = impaired_variant(
    STAMPEDE_COMET,
    "lossy-transatlantic",
    loss_rate=2e-4,
)
LOSSY_TRANSATLANTIC = dataclasses.replace(LOSSY_TRANSATLANTIC, rtt=90e-3)

#: overlay/VPN path with heavy RTT variance: ack clocking keys on the
#: worst-case RTT and the per-file command gap inflates with it.
JITTERY_OVERLAY = impaired_variant(
    XSEDE,
    "jittery-overlay",
    jitter=12e-3,
)

#: asymmetric route: clean 20 ms data path, but control traffic rides a
#: congested 180 ms reverse path — pipelining (not parallelism) is the
#: decisive knob because only the per-file command gap is inflated.
ASYM_CONTROL_PATH = impaired_variant(
    dataclasses.replace(LONI, rtt=20e-3),
    "asym-control-path",
    control_rtt=180e-3,
)

# ---------------------------------------------------------------------------
# Time-varying capacity variants ("network conditions vary over time"):
# cross traffic steps the shared backbone down and partially back; an
# evening drain ramps the path away under the transfer. Step times sit
# inside the matrix's typical transfer spans (median ~35 s, p75 ~75 s) so
# the capacity actually moves mid-transfer, not before or after it.
# ---------------------------------------------------------------------------

#: backbone sharing a burst of cross traffic: drops to 45% twelve seconds
#: in, partially recovers, then settles degraded.
STEPPY_BACKBONE = impaired_variant(
    STAMPEDE_COMET,
    "steppy-backbone",
    bandwidth_steps=((12.0, 0.45), (45.0, 0.8), (120.0, 0.6)),
)

#: evening-congestion drain: capacity ramps linearly to 40% between
#: t=8 s and t=88 s (an 8-step ladder), then stays there.
RAMPY_EVENING = impaired_variant(
    LONI,
    "rampy-evening",
    bandwidth_ramp=(8.0, 88.0, 0.4, 8),
)

# ---------------------------------------------------------------------------
# TPU-fabric adaptation presets (DESIGN.md Sec. 2)
# ---------------------------------------------------------------------------

#: Cross-pod data-center network, as seen by one pod's gradient-sync engine.
#: "files" are gradient buckets; the channel window plays the TCP-buffer role.
DCN = NetworkSpec(
    name="tpu-dcn-pod-pair",
    bandwidth=25e9,  # 25 GB/s aggregate per pod pair
    rtt=500e-6,
    buffer_size=4 * MB,  # per-channel in-flight window (staging buffer)
    disk=DiskSpec(
        streaming_rate=700e9,  # HBM-side staging, far from binding
        per_file_overhead=20e-6,
        saturation_cc=64,
        contention=0.001,
        per_channel_rate=80e9,
    ),
    unhidden_overhead=50e-6,  # per-bucket collective launch overhead
    channel_setup_cost=5e-3,  # collective-group re-materialization
    window_efficiency=1.0,  # lossless fabric: no TCP dynamics
)

#: Host <-> distributed checkpoint storage path.
CKPT_STORE = NetworkSpec(
    name="ckpt-object-store",
    bandwidth=10e9,  # 10 GB/s per host aggregate
    rtt=2e-3,
    buffer_size=8 * MB,
    disk=DiskSpec(
        streaming_rate=8e9,
        per_file_overhead=0.002,
        saturation_cc=16,
        contention=0.01,
        per_channel_rate=1.2e9,
    ),
    unhidden_overhead=1e-3,
    window_efficiency=0.9,
)

TESTBEDS = {
    t.name: t
    for t in (
        XSEDE,
        LONI,
        BLUEWATERS_STAMPEDE,
        STAMPEDE_COMET,
        SUPERMIC_BRIDGES,
        LAN,
        LOSSY_TRANSATLANTIC,
        JITTERY_OVERLAY,
        ASYM_CONTROL_PATH,
        STEPPY_BACKBONE,
        RAMPY_EVENING,
        DCN,
        CKPT_STORE,
    )
}
