"""Dataset partitioning into size-class chunks (paper Fig. 3).

Thresholds are derived from the network bandwidth BW.  The paper's units
work out as "bytes moved per 1/20, 1/5, 1 second at line rate":

    Small  <= BW/20          (e.g. 62.5 MB on a 10 Gbps link)
    Medium <= BW/5           (250 MB)
    Large  <= BW             (1250 MB)
    Huge   >  BW

matching the worked Eq. 1 analysis (Medium: BW/20 < avgFileSize <= BW/5
==> 5*RTT < BDP/avgFileSize < 20*RTT).

``num_chunks`` selects how many thresholds are applied (Sec. 4.1):
    1 -> []                      (whole dataset as one chunk, "1-chunk")
    2 -> [BW/20]                 (Small | rest)
    3 -> [BW/20, BW/5]           (Small | Medium | rest)
    4 -> [BW/20, BW/5, BW]       (Small | Medium | Large | Huge)

"up to N chunks will be created if there are enough files" -- empty chunks
are dropped.
"""
from __future__ import annotations

from typing import List, Sequence

from .types import Chunk, ChunkType, FileSpec, NetworkSpec


def size_thresholds(bandwidth: float, num_chunks: int) -> List[float]:
    """Cut-off points (bytes) for a given chunk count (Fig. 3)."""
    if not 1 <= num_chunks <= 4:
        raise ValueError(f"num_chunks must be in [1, 4], got {num_chunks}")
    full = [bandwidth / 20.0, bandwidth / 5.0, bandwidth]
    return full[: num_chunks - 1]


def classify(size: float, thresholds: Sequence[float]) -> int:
    """Index of the size class for ``size`` given ``thresholds`` (ascending)."""
    for i, t in enumerate(thresholds):
        if size <= t:
            return i
    return len(thresholds)


# Size-class label per (num_chunks, class index). With fewer thresholds the
# *upper* classes merge (e.g. 2-chunk = Small + everything-else treated as
# LARGE, matching Sec 4.1's "the rest of the dataset ... combined into a
# single chunk").
_CLASS_LABELS = {
    1: [ChunkType.ALL],
    2: [ChunkType.SMALL, ChunkType.LARGE],
    3: [ChunkType.SMALL, ChunkType.MEDIUM, ChunkType.LARGE],
    4: [ChunkType.SMALL, ChunkType.MEDIUM, ChunkType.LARGE, ChunkType.HUGE],
}


def partition_files(
    files: Sequence[FileSpec],
    network: NetworkSpec,
    num_chunks: int = 2,
) -> List[Chunk]:
    """Partition ``files`` into up to ``num_chunks`` size-class chunks.

    Every input file lands in exactly one chunk; empty chunks are dropped.
    The paper defaults to 2-chunk partitioning for large transfers (Sec. 4.1
    conclusion); callers can sweep 1-4 (benchmarks/fig5_fig6_chunk_counts).
    """
    thresholds = size_thresholds(network.bandwidth, num_chunks)
    labels = _CLASS_LABELS[num_chunks]
    buckets: List[List[FileSpec]] = [[] for _ in labels]
    for f in files:
        buckets[classify(f.size, thresholds)].append(f)
    return [
        Chunk(ctype=label, files=bucket)
        for label, bucket in zip(labels, buckets)
        if bucket
    ]
