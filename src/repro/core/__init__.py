"""The paper's primary contribution: heuristic protocol tuning for
high-performance data transfers (Arslan & Kosar, 2017).

Public surface:
  - chunking.partition_files        Fig. 3 size-class partitioning
  - params.find_optimal_parameters  Algorithm 1
  - schedulers.{SC,MC,ProMC}        Algorithms 2-3 + online re-allocation
  - simulator.Simulation            discrete-event evaluation backend
  - engine.TransferEngine           real threaded backend (checkpoint/data)
  - baselines                       Globus-Online + untuned comparisons
  - testbeds                        paper Tables 1-2 presets + DCN preset
  - runner.run_transfer             one-call pipeline
"""
from .chunking import partition_files, size_thresholds
from .params import assign_chunk_params, find_optimal_parameters
from .runner import ALGORITHMS, build_scheduler, prepare_chunks, run_transfer
from .schedulers import (
    MultiChunkScheduler,
    ProActiveMultiChunkScheduler,
    SingleChunkScheduler,
    make_scheduler,
    round_robin_distribution,
    weighted_distribution,
)
from .simulator import SimResult, Simulation
from .types import (
    GB,
    KB,
    MB,
    Chunk,
    ChunkType,
    DiskSpec,
    FileSpec,
    NetworkSpec,
    TransferParams,
    gbps,
    to_gbps,
)

__all__ = [
    "partition_files",
    "size_thresholds",
    "assign_chunk_params",
    "find_optimal_parameters",
    "ALGORITHMS",
    "build_scheduler",
    "prepare_chunks",
    "run_transfer",
    "MultiChunkScheduler",
    "ProActiveMultiChunkScheduler",
    "SingleChunkScheduler",
    "make_scheduler",
    "round_robin_distribution",
    "weighted_distribution",
    "SimResult",
    "Simulation",
    "GB",
    "KB",
    "MB",
    "Chunk",
    "ChunkType",
    "DiskSpec",
    "FileSpec",
    "NetworkSpec",
    "TransferParams",
    "gbps",
    "to_gbps",
]
