"""Core datatypes for the protocol-tuning engine.

Units convention (paper-faithful):
  - sizes/bytes:   bytes (the paper quotes MB; helpers below convert)
  - bandwidth:     bytes/second
  - time:          seconds (the paper's Eq. 1 analysis requires RTT in seconds:
                   ``20*RTT < 2  <=>  RTT < 100ms``)
  - BDP:           bytes  (= bandwidth * RTT, e.g. 10 Gbps * 60 ms = 75 MB)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

MB = 1024 * 1024
GB = 1024 * MB
KB = 1024


def gbps(x: float) -> float:
    """Gigabits/second -> bytes/second."""
    return x * 1e9 / 8.0


def mbps(x: float) -> float:
    """Megabits/second -> bytes/second."""
    return x * 1e6 / 8.0


def to_gbps(bytes_per_s: float) -> float:
    """bytes/second -> Gigabits/second (for reporting against paper figures)."""
    return bytes_per_s * 8.0 / 1e9


class ChunkType(enum.IntEnum):
    """File-size classes (Fig. 3). Values order by increasing file size."""

    SMALL = 0
    MEDIUM = 1
    LARGE = 2
    HUGE = 3
    # A dataset transferred as one undivided chunk ("1-chunk" in the paper).
    ALL = 4


#: Round-robin ordering used by MC channel distribution (Alg. 2 line 9):
#: {Huge, Small, Large, Medium}.  Ordering matters when maxCC < #chunks.
MC_ROUND_ROBIN_ORDER: tuple = (
    ChunkType.HUGE,
    ChunkType.SMALL,
    ChunkType.LARGE,
    ChunkType.MEDIUM,
    ChunkType.ALL,  # 1-chunk datasets participate last (single chunk anyway)
)

#: ProMC delta coefficients (Sec. 3.4): higher priority to smaller chunks,
#: {Small, Medium, Large, Huge} -> {6, 3, 2, 1}.
PROMC_DELTA = {
    ChunkType.SMALL: 6.0,
    ChunkType.MEDIUM: 3.0,
    ChunkType.LARGE: 2.0,
    ChunkType.HUGE: 1.0,
    ChunkType.ALL: 2.0,  # neutral weight for undivided datasets
}


@dataclasses.dataclass(frozen=True)
class FileSpec:
    """One transferable unit (a file, a checkpoint shard, a gradient tensor)."""

    name: str
    size: int  # bytes
    path: Optional[str] = None  # set for real-engine transfers

    def __post_init__(self):
        if self.size < 0:
            raise ValueError(f"negative file size: {self.name}: {self.size}")


@dataclasses.dataclass(frozen=True)
class TransferParams:
    """The three protocol parameters tuned by the paper (Algorithm 1)."""

    pipelining: int  # queued commands per channel (0 = none)
    parallelism: int  # data streams per file (>= 1)
    concurrency: int  # simultaneous file transfers (channels)

    def __post_init__(self):
        if self.parallelism < 1:
            raise ValueError("parallelism must be >= 1")
        if self.pipelining < 0:
            raise ValueError("pipelining must be >= 0")
        if self.concurrency < 1:
            raise ValueError("concurrency must be >= 1")


def param_triple(params) -> tuple:
    """Normalize a parameter setting to a ``(pp, p, cc)`` int triple.

    Accepts :class:`TransferParams` (or anything exposing its fields) and
    plain 3-sequences — the one conversion every autotuner entry point
    (candidate expansion, search tables, the history store) shares.
    """
    if hasattr(params, "pipelining"):
        return (
            int(params.pipelining),
            int(params.parallelism),
            int(params.concurrency),
        )
    trip = tuple(int(v) for v in params)
    if len(trip) != 3:
        raise ValueError(
            f"expected (pipelining, parallelism, concurrency), got {params!r}"
        )
    return trip


@dataclasses.dataclass(frozen=True)
class DiskSpec:
    """End-system storage model (parallel FS / GlusterFS / local).

    The paper repeatedly attributes throughput ceilings and the concurrency
    sweet-spot to disk sub-systems (Sec. 1, Fig. 9a); we model:
      - ``streaming_rate``: aggregate sequential bandwidth at saturation,
      - ``per_file_overhead``: seek + open/close + metadata cost per file,
      - ``saturation_cc``: concurrency at which aggregate bandwidth saturates
        (number of effective storage servers / OSTs),
      - ``contention``: fractional aggregate-rate loss per channel beyond
        saturation (reproduces the Fig. 9a decline past concurrency 8).
    """

    streaming_rate: float  # bytes/s aggregate at saturation
    per_file_overhead: float = 0.005  # seconds
    saturation_cc: int = 8
    contention: float = 0.02
    #: single-channel ceiling (one storage server / OST lane); defaults to
    #: streaming_rate / saturation_cc when unset.
    per_channel_rate: Optional[float] = None

    @property
    def channel_lane(self) -> float:
        if self.per_channel_rate is not None:
            return self.per_channel_rate
        return self.streaming_rate / max(1, self.saturation_cc)

    def aggregate_rate(self, active_channels: int) -> float:
        """Aggregate disk bandwidth available to ``active_channels`` channels.

        Below saturation the per-channel ``channel_lane`` cap (applied by the
        rate allocator) is what limits throughput; beyond saturation the
        aggregate degrades with contention (Fig. 9a decline past CC=8).
        """
        if active_channels <= 0:
            return 0.0
        over = max(0, active_channels - self.saturation_cc)
        penalty = 1.0 / (1.0 + self.contention * over)
        return self.streaming_rate * penalty


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """A network path between two end systems (paper Tables 1-2)."""

    name: str
    bandwidth: float  # bytes/s
    rtt: float  # seconds
    buffer_size: int  # bytes (max TCP buffer per stream)
    disk: DiskSpec
    #: per-file server-side processing that pipelining cannot hide
    #: (data-channel open/close serialization, FS metadata). This is the term
    #: that bounds the small-file pipelining win at ~2x (Fig 1a/2a).
    unhidden_overhead: float = 0.0
    #: one-time cost of (re-)establishing a data channel; reallocation between
    #: chunks with different parallelism pays this (Sec. 3.2 / 3.4).
    channel_setup_cost: float = 0.1
    #: per-extra-stream end-system efficiency loss (CPU overhead of parallel
    #: streams / channels, Sec. 3 "concurrency incurs the most overhead").
    stream_cpu_overhead: float = 0.002
    #: max useful total streams across all channels (end-system core limit)
    max_total_streams: int = 256
    #: fraction of the nominal window buffer/RTT a TCP stream sustains
    #: (slow-start, loss recovery, ack clocking); 1.0 for lossless fabrics.
    window_efficiency: float = 0.55
    #: server-enforced cap on data streams per transfer (GridFTP server
    #: configuration; SuperMIC-like endpoints clamp this low).
    max_streams_per_channel: int = 64
    #: round-trip time of the *control* channel when it differs from the
    #: data path (asymmetric routes: satellite uplinks, congested reverse
    #: paths). None means symmetric — the data RTT governs the per-file
    #: command/ack gap too.
    control_rtt: Optional[float] = None
    #: time-varying capacity: piecewise-constant multiplier steps
    #: ``((t0, m0), (t1, m1), ...)`` sorted by time with ``t0 == 0`` —
    #: the link carries ``bandwidth * m_i`` from ``t_i`` until the next
    #: step ("network conditions vary over time", the regime the paper's
    #: adaptive controllers exist for). None means a static path. Ramps
    #: are expressed as dense step ladders (``testbeds.impaired_variant``
    #: builds them); Algorithm-1 tuning and rate *predictions* use the
    #: nominal ``bandwidth`` — only realized transfer rates follow the
    #: profile, exactly the mismatch the controllers must absorb.
    bandwidth_profile: Optional[tuple] = None

    def __post_init__(self):
        if self.bandwidth_profile is not None:
            prof = tuple(self.bandwidth_profile)
            if not prof or prof[0][0] != 0.0:
                raise ValueError(
                    "bandwidth_profile must start with a (0.0, mult) step"
                )
            if list(prof) != sorted(prof, key=lambda p: p[0]):
                raise ValueError("bandwidth_profile steps must be sorted")

    def bandwidth_at(self, t: float) -> float:
        """Link capacity at simulation time ``t`` (nominal when static)."""
        if self.bandwidth_profile is None:
            return self.bandwidth
        mult = self.bandwidth_profile[0][1]
        for step_t, step_m in self.bandwidth_profile:
            if step_t <= t:
                mult = step_m
            else:
                break
        return self.bandwidth * mult

    def next_profile_change(self, t: float) -> float:
        """Time of the first profile step strictly after ``t`` (inf when
        none remain / static) — an event horizon for the simulators."""
        if self.bandwidth_profile is None:
            return float("inf")
        for step_t, _ in self.bandwidth_profile:
            if step_t > t:
                return step_t
        return float("inf")

    @property
    def bdp(self) -> float:
        """Bandwidth-delay product in bytes."""
        return self.bandwidth * self.rtt

    def stream_rate_cap(self, parallelism: int) -> float:
        """Max rate of one channel with ``parallelism`` TCP streams.

        Each stream is window-limited to ``window_efficiency * buffer/RTT``;
        aggregation is the whole point of the parallelism parameter (Sec. 3).
        A small CPU tax per additional stream reproduces the mild small-file
        degradation, and servers may clamp the usable stream count.
        """
        p = max(1, min(parallelism, self.max_streams_per_channel))
        per_stream = self.window_efficiency * self.buffer_size / max(self.rtt, 1e-9)
        eff = 1.0 / (1.0 + self.stream_cpu_overhead * (p - 1))
        return min(p * per_stream * eff, self.bandwidth)


@dataclasses.dataclass
class Chunk:
    """A set of files of the same size class plus its tuned parameters."""

    ctype: ChunkType
    files: list  # list[FileSpec]
    params: Optional[TransferParams] = None

    @property
    def total_bytes(self) -> int:
        return sum(f.size for f in self.files)

    @property
    def avg_file_size(self) -> float:
        return self.total_bytes / len(self.files) if self.files else 0.0

    @property
    def name(self) -> str:
        return self.ctype.name

    def __len__(self) -> int:
        return len(self.files)


def dataset_total(files: Sequence[FileSpec]) -> int:
    return sum(f.size for f in files)
