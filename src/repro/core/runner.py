"""High-level entry points: partition -> tune -> schedule -> simulate."""
from __future__ import annotations

from typing import List, Optional, Sequence

from .baselines import (
    GlobusOnlineScheduler,
    StaticParamsScheduler,
    UntunedScheduler,
)
from .chunking import partition_files
from .params import assign_chunk_params
from .schedulers import SCHEDULERS, Scheduler, make_scheduler
from .simulator import SimResult, Simulation
from .types import Chunk, ChunkType, FileSpec, NetworkSpec, TransferParams

ALGORITHMS = tuple(SCHEDULERS) + ("globus", "untuned", "static")


def prepare_chunks(
    files: Sequence[FileSpec],
    network: NetworkSpec,
    num_chunks: int,
    max_cc: int,
) -> List[Chunk]:
    """Fig.-3 partitioning + Algorithm-1 parameter assignment."""
    chunks = partition_files(files, network, num_chunks)
    for c in chunks:
        assign_chunk_params(c, network, max_cc)
    return chunks


def build_scheduler(
    algorithm: str,
    files: Sequence[FileSpec],
    network: NetworkSpec,
    *,
    max_cc: int = 8,
    num_chunks: int = 2,
    **kw,
) -> Scheduler:
    algorithm = algorithm.lower()
    if algorithm == "static":
        params = kw.pop("static_params", None)
        if params is None:
            raise ValueError(
                "algorithm 'static' requires static_params="
                "TransferParams(...) (or a (pp, p, cc) tuple)"
            )
        if not isinstance(params, TransferParams):
            params = TransferParams(*params)
        # no partitioning / Algorithm 1: the whole point is one undivided
        # chunk at the caller's parameters, and candidate sweeps build
        # thousands of these per search round
        chunks = [Chunk(ctype=ChunkType.ALL, files=list(files))]
        return StaticParamsScheduler(chunks, network, max_cc, params, **kw)
    if algorithm == "globus":
        chunks = prepare_chunks(files, network, 1, max_cc)
        return GlobusOnlineScheduler(chunks, network, max_cc, **kw)
    if algorithm == "untuned":
        chunks = prepare_chunks(files, network, 1, max_cc)
        return UntunedScheduler(chunks, network, max_cc)
    chunks = prepare_chunks(files, network, num_chunks, max_cc)
    return make_scheduler(algorithm, chunks, network, max_cc, **kw)


def run_transfer(
    files: Sequence[FileSpec],
    network: NetworkSpec,
    algorithm: str = "promc",
    *,
    max_cc: int = 8,
    num_chunks: int = 2,
    tick_period: float = 5.0,
    record_timeline: bool = False,
    max_time: Optional[float] = None,
    **scheduler_kw,
) -> SimResult:
    """Simulate one transfer task end to end and return its SimResult."""
    sched = build_scheduler(
        algorithm,
        files,
        network,
        max_cc=max_cc,
        num_chunks=num_chunks,
        **scheduler_kw,
    )
    sim = Simulation(
        sched.chunks,
        sched.network,  # baselines may have degraded the path (GCP mode)
        sched,
        tick_period=tick_period,
        record_timeline=record_timeline,
        **({"max_time": max_time} if max_time else {}),
    )
    return sim.run()
