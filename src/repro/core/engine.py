"""Real threaded TransferEngine — the paper's algorithms over actual I/O.

The simulator proves schedule *quality*; this engine proves the algorithms
are a real, runnable system. It drives the same Scheduler controllers with
OS threads:

  channel      = worker thread bound to a slot; a slot is (re)assigned to a
                 chunk by the controller (Move/Open/Close)
  pipelining   = per-channel command prefetch queue: the command latency
                 (control RTT) is paid by a background prefetcher instead of
                 blocking the data path (optionally injected for demos/tests)
  parallelism  = striped pread/pwrite of one file by p sub-threads
  concurrency  = number of live channel slots

Used by `repro.checkpoint` (shard save/restore) and `repro.data` (file
ingestion). On a laptop-class CI box the latency injection is what makes the
paper's effects visible; with it disabled the engine is simply a correct,
concurrent, striped file mover.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from . import netmodel
from .schedulers import Close, ChunkView, Move, Open, Scheduler
from .types import Chunk, FileSpec, NetworkSpec, TransferParams

Reader = Callable[[int, int], bytes]  # (offset, length) -> data
Writer = Callable[[int, bytes], None]  # (offset, data) -> None


@dataclasses.dataclass
class TransferTask:
    """Concrete I/O endpoints for one FileSpec."""

    spec: FileSpec
    read: Reader
    write: Writer
    finalize: Optional[Callable[[], None]] = None


class _DstFd:
    """One destination fd for a TransferTask's lifetime.

    The old implementation reopened (and closed) the destination on every
    ``pwrite`` — per-block syscall churn that dominated small-block striped
    writes and defeated kernel write-behind. ``pwrite`` is positional and
    thread-safe on a shared fd, so the stripe sub-threads need no lock on
    the data path; the lock only guards lazy open and close.
    """

    __slots__ = ("path", "_fd", "_lock")

    def __init__(self, path: str):
        self.path = path
        self._fd = None
        self._lock = threading.Lock()

    def get(self) -> int:
        fd = self._fd
        if fd is None:
            with self._lock:
                if self._fd is None:
                    self._fd = os.open(
                        self.path, os.O_RDWR | os.O_CREAT, 0o644
                    )
                fd = self._fd
        return fd

    def close(self) -> None:
        with self._lock:
            if self._fd is not None:
                os.close(self._fd)
                self._fd = None


def file_task(spec: FileSpec, src: str, dst: str) -> TransferTask:
    """Copy a real file src -> dst (dst created at first write; one fd held
    for the task's lifetime, released in ``finalize``)."""

    def read(offset: int, length: int) -> bytes:
        with open(src, "rb") as f:
            f.seek(offset)
            return f.read(length)

    out = _DstFd(dst)

    def write(offset: int, data: bytes) -> None:
        os.pwrite(out.get(), data, offset)

    return TransferTask(spec=spec, read=read, write=write, finalize=out.close)


def bytes_task(
    spec: FileSpec, data: bytes, dst: str
) -> TransferTask:
    """Write an in-memory payload (e.g. a checkpoint shard) to dst."""

    def read(offset: int, length: int) -> bytes:
        return data[offset : offset + length]

    out = _DstFd(dst)

    def write(offset: int, chunk: bytes) -> None:
        os.pwrite(out.get(), chunk, offset)

    return TransferTask(spec=spec, read=read, write=write, finalize=out.close)


@dataclasses.dataclass
class EngineReport:
    scheduler: str
    total_bytes: int
    total_time: float
    throughput: float
    per_chunk_bytes: Dict[str, int]
    n_moves: int
    files_done: int


class _Slot:
    """One channel slot: assignment is mutated by the controller thread."""

    def __init__(self, slot_id: int, chunk: int, params: TransferParams):
        self.id = slot_id
        self.chunk = chunk
        self.params = params
        self.alive = True
        self.lock = threading.Lock()

    def assignment(self):
        with self.lock:
            return self.chunk, self.params, self.alive

    def reassign(self, chunk: int, params: TransferParams):
        with self.lock:
            self.chunk, self.params = chunk, params

    def kill(self):
        with self.lock:
            self.alive = False


class TransferEngine:
    """Execute chunks' TransferTasks under a Scheduler controller."""

    STRIPE_MIN = 4 * 1024 * 1024  # don't stripe files below 4 MB
    IO_BLOCK = 1024 * 1024

    def __init__(
        self,
        network: NetworkSpec,
        tick_period: float = 0.25,
        inject_latency: bool = False,
        latency_scale: float = 1.0,
    ):
        self.network = network
        self.tick_period = tick_period
        self.inject_latency = inject_latency
        self.latency_scale = latency_scale

    # ------------------------------------------------------------------ #

    def run(
        self,
        chunks: Sequence[Chunk],
        scheduler: Scheduler,
        tasks: Dict[str, TransferTask],
    ) -> EngineReport:
        queues: List[collections.deque] = [
            collections.deque(c.files) for c in chunks
        ]
        qlocks = [threading.Lock() for _ in chunks]
        delivered = [0 for _ in chunks]  # bytes, guarded by stats_lock
        inflight = [0 for _ in chunks]
        done_files = [0]
        stats_lock = threading.Lock()
        completed = [False for _ in chunks]
        rate_window: List[collections.deque] = [
            collections.deque(maxlen=8) for _ in chunks
        ]
        slots: List[_Slot] = []
        slots_lock = threading.Lock()
        threads: List[threading.Thread] = []
        stop = threading.Event()
        errors: List[BaseException] = []
        n_moves = [0]

        def pull(chunk_idx: int) -> Optional[FileSpec]:
            with qlocks[chunk_idx]:
                if queues[chunk_idx]:
                    f = queues[chunk_idx].popleft()
                    with stats_lock:
                        inflight[chunk_idx] += 1
                    return f
            return None

        def transfer_one(f: FileSpec, params: TransferParams, chunk_idx: int):
            task = tasks[f.name]
            if self.inject_latency:
                # control-channel gap amortized by pipelining depth (uses
                # the control RTT on asymmetric paths, like the simulator)
                gap = netmodel.control_gap(self.network, params)
                time.sleep((gap + self.network.unhidden_overhead) * self.latency_scale)
            size = f.size
            p = params.parallelism if size >= self.STRIPE_MIN else 1
            if p <= 1:
                off = 0
                while off < size:
                    blk = min(self.IO_BLOCK, size - off)
                    task.write(off, task.read(off, blk))
                    off += blk
                    with stats_lock:
                        delivered[chunk_idx] += blk
            else:
                stripe = (size + p - 1) // p

                def stripe_worker(start: int, end: int):
                    off = start
                    while off < end:
                        blk = min(self.IO_BLOCK, end - off)
                        task.write(off, task.read(off, blk))
                        off += blk
                        with stats_lock:
                            delivered[chunk_idx] += blk

                subs = []
                for s in range(p):
                    a, b = s * stripe, min(size, (s + 1) * stripe)
                    if a >= b:
                        continue
                    th = threading.Thread(target=stripe_worker, args=(a, b))
                    th.start()
                    subs.append(th)
                for th in subs:
                    th.join()
            if task.finalize:
                task.finalize()
            with stats_lock:
                inflight[chunk_idx] -= 1
                done_files[0] += 1

        def worker(slot: _Slot):
            try:
                while not stop.is_set():
                    chunk_idx, params, alive = slot.assignment()
                    if not alive:
                        return
                    f = pull(chunk_idx)
                    if f is None:
                        time.sleep(0.005)
                        continue
                    transfer_one(f, params, chunk_idx)
            except BaseException as e:  # surface worker failures to caller
                errors.append(e)
                stop.set()

        next_slot_id = [0]

        def apply(actions):
            for act in actions:
                if isinstance(act, Open):
                    for _ in range(act.n):
                        s = _Slot(
                            next_slot_id[0],
                            act.chunk,
                            chunks[act.chunk].params,
                        )
                        next_slot_id[0] += 1
                        with slots_lock:
                            slots.append(s)
                        th = threading.Thread(target=worker, args=(s,), daemon=True)
                        threads.append(th)
                        th.start()
                elif isinstance(act, Close):
                    with slots_lock:
                        victims = [s for s in slots if s.chunk == act.chunk][: act.n]
                        for s in victims:
                            s.kill()
                            slots.remove(s)
                elif isinstance(act, Move):
                    with slots_lock:
                        movable = [s for s in slots if s.chunk == act.src][: act.n]
                        for s in movable:
                            s.reassign(act.dst, chunks[act.dst].params)
                            n_moves[0] += 1

        def view() -> List[ChunkView]:
            with stats_lock, slots_lock:
                views = []
                for i, c in enumerate(chunks):
                    remaining = c.total_bytes - delivered[i]
                    rate = (
                        sum(rate_window[i]) / (len(rate_window[i]) * self.tick_period)
                        if rate_window[i]
                        else 0.0
                    )
                    views.append(
                        ChunkView(
                            index=i,
                            ctype=c.ctype,
                            bytes_remaining=max(0, remaining),
                            files_remaining=len(queues[i]) + inflight[i],
                            throughput=rate,
                            n_channels=sum(1 for s in slots if s.chunk == i),
                            done=completed[i],
                            predicted_rate=1.0,  # engine always has measurements fast
                        )
                    )
                return views

        t0 = time.monotonic()
        apply(scheduler.initial_actions(view()))
        last_delivered = [0 for _ in chunks]

        try:
            while not stop.is_set():
                time.sleep(self.tick_period)
                if errors:
                    break
                with stats_lock:
                    for i in range(len(chunks)):
                        rate_window[i].append(delivered[i] - last_delivered[i])
                        last_delivered[i] = delivered[i]
                # chunk completions
                for i, c in enumerate(chunks):
                    if completed[i]:
                        continue
                    with qlocks[i], stats_lock:
                        empty = not queues[i] and inflight[i] == 0
                    if empty:
                        completed[i] = True
                        apply(scheduler.on_chunk_complete(view(), i))
                if all(completed):
                    break
                apply(scheduler.on_tick(view()))
        finally:
            stop.set()
            for s in list(slots):
                s.kill()
            for th in threads:
                th.join(timeout=5.0)
        if errors:
            raise errors[0]

        total_time = max(time.monotonic() - t0, 1e-9)
        total_bytes = sum(delivered)
        return EngineReport(
            scheduler=scheduler.name,
            total_bytes=total_bytes,
            total_time=total_time,
            throughput=total_bytes / total_time,
            per_chunk_bytes={
                chunks[i].name: delivered[i] for i in range(len(chunks))
            },
            n_moves=n_moves[0],
            files_done=done_files[0],
        )
