"""Discrete-event (fluid) transfer simulator.

Executes a scheduler (SC/MC/ProMC/baseline) against a NetworkSpec without real
I/O: channels progress through per-file dead time (control gap, server
processing, disk seek) and fluid data transfer at water-filled rates
(netmodel.allocate_rates). Rates are recomputed at every event: a channel
state transition, a chunk completion, or a controller tick (default every 5 s
of *virtual* time, the paper's period).

This is the substrate for reproducing the paper's figures (the testbeds are
physical WANs we don't have) and for evaluating DCN grad-sync schedules. The
real threaded engine (`engine.py`) shares the scheduler protocol, so every
algorithm runs unmodified on both.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Sequence

from . import netmodel
from .schedulers import Close, ChunkView, Move, Open, Scheduler
from .types import Chunk, FileSpec, NetworkSpec, TransferParams

_EPS = 1e-12


# The pure stepping hooks (tick EMA, next-event horizon, resume-file
# construction) moved to the backend-neutral fabric layer — they are the
# scalar references the batched fabric kernels mirror. Re-exported here
# because this event loop consumes them directly and they are part of this
# module's historical API.
from repro.eval.fabric.reference import (  # noqa: E402
    next_event_dt,
    resume_file,
    tick_rate_update,
)


@dataclasses.dataclass
class _SimChannel:
    chunk: int
    params: TransferParams
    dead: float = 0.0  # remaining serial overhead (setup / file start)
    file_remaining: float = 0.0  # bytes of current file still to move
    busy: bool = False  # owns a file (in dead time or transferring)
    closed: bool = False

    @property
    def transferring(self) -> bool:
        return self.busy and self.dead <= _EPS and not self.closed


@dataclasses.dataclass
class _ChunkState:
    chunk: Chunk
    queue: Deque[FileSpec]
    queue_bytes: int  # exact bytes still in the queue (not yet pulled)
    delivered: float = 0.0
    delivered_at_last_tick: float = 0.0
    rate_estimate: float = 0.0
    done: bool = False
    completed_at: float = math.nan


@dataclasses.dataclass
class SimResult:
    network: str
    scheduler: str
    total_bytes: float
    total_time: float
    #: aggregate achieved throughput, bytes/s
    throughput: float
    per_chunk_time: Dict[str, float]
    per_chunk_bytes: Dict[str, float]
    timeline: List[tuple]  # (t, instantaneous aggregate rate)
    n_events: int
    n_moves: int

    @property
    def throughput_gbps(self) -> float:
        return self.throughput * 8.0 / 1e9


class Simulation:
    """One transfer task: a set of chunks driven by a Scheduler controller."""

    def __init__(
        self,
        chunks: Sequence[Chunk],
        network: NetworkSpec,
        scheduler: Scheduler,
        tick_period: float = 5.0,
        max_time: float = 48 * 3600.0,
        record_timeline: bool = False,
    ):
        self.network = network
        self.scheduler = scheduler
        self.tick_period = tick_period
        self.max_time = max_time
        self.record_timeline = record_timeline
        self.t = 0.0
        self.channels: List[_SimChannel] = []
        self.states = [
            _ChunkState(
                chunk=c,
                queue=collections.deque(c.files),
                queue_bytes=c.total_bytes,
            )
            for c in chunks
        ]
        self.timeline: List[tuple] = []
        self.n_events = 0
        self.n_moves = 0
        self._started = False
        #: building a ChunkViews snapshot per tick is the costliest part of
        #: a tick; skip it entirely for schedulers that inherit the no-op
        #: ``on_tick`` (their empty action list makes the post-tick apply
        #: and re-feed no-ops too, so the event sequence is unchanged)
        self._nontrivial_tick = (
            type(scheduler).on_tick is not Scheduler.on_tick
        )

    # ------------------------------------------------------------------ #
    # controller plumbing
    # ------------------------------------------------------------------ #

    def _bytes_remaining(self, i: int) -> float:
        """queue bytes + remainders of files currently held by channels."""
        inflight = sum(
            ch.file_remaining
            for ch in self.channels
            if ch.chunk == i and ch.busy and not ch.closed
        )
        return self.states[i].queue_bytes + inflight

    def _view(self) -> List[ChunkView]:
        views = []
        for i, st in enumerate(self.states):
            n_ch = sum(1 for ch in self.channels if ch.chunk == i and not ch.closed)
            predicted = netmodel.predict_chunk_rate(
                self.network,
                max(st.chunk.avg_file_size, 1.0),
                st.chunk.params,
                max(n_ch, 1),
                total_active_channels=max(1, self._n_open()),
            )
            views.append(
                ChunkView(
                    index=i,
                    ctype=st.chunk.ctype,
                    bytes_remaining=self._bytes_remaining(i),
                    files_remaining=len(st.queue)
                    + sum(
                        1
                        for ch in self.channels
                        if ch.chunk == i and ch.busy and not ch.closed
                    ),
                    throughput=st.rate_estimate,
                    n_channels=n_ch,
                    done=st.done,
                    predicted_rate=predicted,
                )
            )
        return views

    def _n_open(self) -> int:
        return sum(1 for ch in self.channels if not ch.closed)

    def _apply(self, actions) -> None:
        for act in actions:
            if isinstance(act, Open):
                for _ in range(act.n):
                    self._open_channel(act.chunk, prev=None)
            elif isinstance(act, Close):
                self._close_channels(act.chunk, act.n)
            elif isinstance(act, Move):
                moved = self._close_channels(act.src, act.n)
                for prev in moved:
                    self._open_channel(act.dst, prev=prev)
                self.n_moves += len(moved)

    def _open_channel(self, chunk: int, prev: Optional[TransferParams]) -> None:
        params = self.states[chunk].chunk.params
        setup = netmodel.channel_open_cost(self.network, params, prev)
        ch = _SimChannel(chunk=chunk, params=params, dead=setup)
        self.channels.append(ch)

    def _close_channels(self, chunk: int, n: int) -> List[TransferParams]:
        """Close up to n channels of a chunk; idle ones first. In-flight files
        are returned to the chunk queue (transfer restarts; conservative)."""
        closed: List[TransferParams] = []
        candidates = sorted(
            (ch for ch in self.channels if ch.chunk == chunk and not ch.closed),
            key=lambda ch: ch.busy,  # idle first
        )
        for ch in candidates[:n]:
            if ch.busy and ch.file_remaining > 0:
                # return unfinished remainder as a synthetic file
                st = self.states[ch.chunk]
                f = resume_file(ch.file_remaining)
                st.queue.appendleft(f)
                st.queue_bytes += f.size
            ch.closed = True
            ch.busy = False
            closed.append(ch.params)
        self.channels = [c for c in self.channels if not c.closed]
        return closed

    # ------------------------------------------------------------------ #
    # event loop
    # ------------------------------------------------------------------ #

    def _feed_channels(self) -> None:
        """Idle channels pull the next file of their chunk (paying dead time)."""
        for ch in self.channels:
            if ch.closed or ch.busy:
                continue
            st = self.states[ch.chunk]
            if st.queue:
                f = st.queue.popleft()
                st.queue_bytes -= f.size
                ch.busy = True
                ch.file_remaining = float(f.size)
                ch.dead += netmodel.file_start_dead_time(self.network, ch.params)

    def _check_completions(self) -> List[int]:
        completed = []
        for i, st in enumerate(self.states):
            if st.done:
                continue
            busy = any(
                ch.busy for ch in self.channels if ch.chunk == i and not ch.closed
            )
            if not st.queue and not busy:
                st.done = True
                st.queue_bytes = 0
                st.completed_at = self.t
                completed.append(i)
        return completed

    @property
    def done(self) -> bool:
        return all(st.done for st in self.states)

    def start(self) -> None:
        """Record totals, apply the controller's initial allocation, feed."""
        self._total_bytes = float(sum(st.queue_bytes for st in self.states))
        self._next_tick = self.tick_period
        self._started = True
        self._apply(self.scheduler.initial_actions(self._view()))
        self._feed_channels()

    def transfer_demand(self) -> tuple:
        """``(pool, demand)`` for the shared-fabric coupled driver.

        ``pool`` is this instant's uncoupled rate pool (link bandwidth vs
        disk aggregate under contention, exactly ``allocate_rates``'s);
        ``demand`` is the rate the transfer could actually use —
        ``min(pool, sum of transferring channels' caps)``. A coupled
        lockstep driver feeds the demands of every tenant in a fabric
        group to ``fabric.kernels.waterfill_coupled`` and passes each
        grant back through :meth:`step`'s ``bandwidth`` override.
        """
        transferring = [
            ch for ch in self.channels if not ch.closed and ch.transferring
        ]
        if not transferring:
            return 0.0, 0.0
        pool = min(
            self.network.bandwidth_at(self.t),
            self.network.disk.aggregate_rate(len(transferring)),
        )
        caps = sum(
            netmodel.channel_rate_cap(self.network, ch.params.parallelism)
            for ch in transferring
        )
        return pool, min(pool, caps)

    def next_dt(self, bandwidth: Optional[float] = None) -> float:
        """The horizon :meth:`step` would advance by, without advancing.

        ``bandwidth`` overrides the rate pool exactly as in :meth:`step`
        — the coupled lockstep driver peeks every group member's horizon
        under its fabric grant, takes the group minimum, and passes it
        back as ``step(max_dt=...)`` so coupled tenants share event
        times.
        """
        open_chs = [ch for ch in self.channels if not ch.closed]
        rates = netmodel.allocate_rates(
            self.network,
            [ch.params.parallelism for ch in open_chs],
            [ch.transferring for ch in open_chs],
            bandwidth=(
                self.network.bandwidth_at(self.t)
                if bandwidth is None
                else bandwidth
            ),
        )
        busy = [ch for ch in open_chs if ch.busy]
        return next_event_dt(
            min(
                self._next_tick - self.t,
                self.network.next_profile_change(self.t) - self.t,
            ),
            [ch.dead for ch in busy],
            [ch.file_remaining for ch in busy],
            [r for ch, r in zip(open_chs, rates) if ch.busy],
        )

    def step(
        self,
        max_dt: Optional[float] = None,
        bandwidth: Optional[float] = None,
    ) -> None:
        """Advance to the next event (state transition, completion, or tick).

        This is the unit the batch fast-path mirrors: rates are recomputed
        from scratch (pure ``netmodel.allocate_rates``), the event horizon
        comes from ``next_event_dt``, and every post-advance transition
        (feed / completion callbacks / tick bookkeeping) happens in a fixed
        order. Keep the order in sync with
        ``eval.fabric.driver.FabricSimulation``.

        The coupled lockstep driver (``eval.fabric.coupled_event``) passes
        ``bandwidth`` (this tenant's fabric grant, which replaces the rate
        pool — always <= the uncoupled pool, so ``min`` with the disk
        aggregate is a no-op) and ``max_dt`` (the fabric group's shared
        horizon, always <= this transfer's own, so partially-advanced
        sweeps cross no event threshold). Defaults preserve the uncoupled
        behaviour bit for bit.
        """
        if not self._started:
            raise RuntimeError("Simulation.step() before start()")
        if self.t > self.max_time:
            raise RuntimeError(
                f"simulation exceeded max_time={self.max_time}s "
                f"(t={self.t:.1f}); remaining="
                f"{[self._bytes_remaining(i) for i in range(len(self.states))]}"
            )
        self.n_events += 1
        open_chs = [ch for ch in self.channels if not ch.closed]
        rates = netmodel.allocate_rates(
            self.network,
            [ch.params.parallelism for ch in open_chs],
            [ch.transferring for ch in open_chs],
            bandwidth=(
                self.network.bandwidth_at(self.t)
                if bandwidth is None
                else bandwidth
            ),
        )
        if self.record_timeline:
            self.timeline.append((self.t, sum(rates)))

        busy = [ch for ch in open_chs if ch.busy]
        # a bandwidth-profile step is an event: rates must be recomputed
        # there, so it caps the horizon exactly like the controller tick
        dt = next_event_dt(
            min(
                self._next_tick - self.t,
                self.network.next_profile_change(self.t) - self.t,
            ),
            [ch.dead for ch in busy],
            [ch.file_remaining for ch in busy],
            [r for ch, r in zip(open_chs, rates) if ch.busy],
        )
        if max_dt is not None:
            dt = min(dt, max_dt)
        if not busy:
            # no channel holds work: either all done (loop exits) or the
            # scheduler stranded a live chunk — treat as a scheduling bug.
            live = [i for i, st in enumerate(self.states) if not st.done]
            held = {ch.chunk for ch in open_chs}
            if any(i not in held for i in live):
                raise RuntimeError(
                    f"scheduler {self.scheduler.name} stranded chunks "
                    f"{[self.states[i].chunk.name for i in live]}"
                )

        # advance
        self.t += dt
        for ch, r in zip(open_chs, rates):
            if ch.closed or not ch.busy:
                continue
            if ch.dead > _EPS:
                ch.dead = max(0.0, ch.dead - dt)
                continue
            if r > _EPS and dt > 0:
                moved = min(ch.file_remaining, r * dt)
                ch.file_remaining -= moved
                self.states[ch.chunk].delivered += moved
            if ch.file_remaining <= _EPS:
                ch.busy = False
                ch.file_remaining = 0.0

        self._feed_channels()
        for cid in self._check_completions():
            self._apply(self.scheduler.on_chunk_complete(self._view(), cid))
            self._feed_channels()

        if self.t >= self._next_tick - _EPS:
            # refresh measured per-chunk rates over the last period
            for st in self.states:
                delta = st.delivered - st.delivered_at_last_tick
                st.delivered_at_last_tick = st.delivered
                st.rate_estimate = tick_rate_update(
                    st.rate_estimate, delta, self.tick_period
                )
            if self._nontrivial_tick:
                self._apply(self.scheduler.on_tick(self._view()))
                self._feed_channels()
            self._next_tick += self.tick_period

    def result(self) -> SimResult:
        if not self._started:
            raise RuntimeError("Simulation.result() before start()")
        per_chunk_time = {
            st.chunk.name: st.completed_at for st in self.states
        }
        per_chunk_bytes = {st.chunk.name: st.delivered for st in self.states}
        total_time = max(self.t, _EPS)
        return SimResult(
            network=self.network.name,
            scheduler=self.scheduler.name,
            total_bytes=self._total_bytes,
            total_time=total_time,
            throughput=self._total_bytes / total_time,
            per_chunk_time=per_chunk_time,
            per_chunk_bytes=per_chunk_bytes,
            timeline=self.timeline,
            n_events=self.n_events,
            n_moves=self.n_moves,
        )

    def run(self) -> SimResult:
        self.start()
        while not self.done:
            self.step()
        return self.result()
