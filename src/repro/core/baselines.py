"""Comparison systems (Sec. 2 / Sec. 4.2).

* ``GlobusOnlineScheduler`` — the state-of-the-art baseline: whole dataset as
  one chunk, *static* parameters chosen from the dataset's average file size
  (< 50 MB small / 50-250 MB medium / > 250 MB large). Non-adaptive. The
  paper observes it selects concurrency <= 4 and parallelism <= 6.
* ``UntunedScheduler`` — globus-url-copy defaults (no pipelining, one stream,
  one channel): the "baseline" of the paper's 10x claim.
* ``connect_personal`` mode degrades the path like Globus Connect Personal on
  a LAN (control relayed through a central internet service; Sec. 4.2 /
  Fig. 13 measured ~500 Mbps vs our 2+ Gbps): per-channel window is clamped
  to an internet-grade relay and per-file overhead grows by the relay RTT.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from .schedulers import Action, ChunkViews, Open, Scheduler
from .types import MB, Chunk, ChunkType, FileSpec, NetworkSpec, TransferParams

#: Static parameter presets per Globus Online size class.
GLOBUS_PRESETS = {
    "small": TransferParams(pipelining=20, parallelism=2, concurrency=2),
    "medium": TransferParams(pipelining=5, parallelism=4, concurrency=4),
    "large": TransferParams(pipelining=2, parallelism=6, concurrency=3),
}


def globus_class(avg_file_size: float) -> str:
    if avg_file_size < 50 * MB:
        return "small"
    if avg_file_size <= 250 * MB:
        return "medium"
    return "large"


def degrade_for_connect_personal(network: NetworkSpec) -> NetworkSpec:
    """Model the Globus-Connect-Personal relay path on a LAN endpoint."""
    relay_rtt = 0.040  # control/relay round trips traverse the internet
    # per-stream window behaves like an internet TCP session: clamp the
    # effective buffer so buffer/RTT lands at relay-grade rate (~40 MB/s),
    # and the relay process handles a single data stream per channel.
    relay_buffer = int(40e6 * network.rtt) if network.rtt > 0 else 8 * 1024
    return dataclasses.replace(
        network,
        name=network.name + "+gcp",
        buffer_size=max(8 * 1024, min(network.buffer_size, relay_buffer)),
        unhidden_overhead=network.unhidden_overhead + relay_rtt,
        max_streams_per_channel=1,
    )


class _StaticOneChunkScheduler(Scheduler):
    """Transfer everything as a single chunk with fixed parameters."""

    params: TransferParams

    def __init__(self, chunks, network, max_cc, params: TransferParams):
        merged = Chunk(
            ctype=ChunkType.ALL,
            files=[f for c in chunks for f in c.files],
            params=params,
        )
        super().__init__([merged], network, max_cc)
        self.params = params

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        return [Open(chunk=0, n=self.params.concurrency)]


class StaticParamsScheduler(_StaticOneChunkScheduler):
    """One undivided chunk driven by *caller-chosen* fixed parameters.

    This is the candidate-evaluation row of the autotuner
    (:mod:`repro.eval.tune`): grid / successive-halving / hill-climbing
    searches sweep the (pipelining, parallelism, concurrency) knob space
    by running one of these per candidate, and the static-oracle regret
    of the adaptive heuristics — "how close does SC/MC/ProMC get to the
    best static setting it never saw" — is computed against their argmax.
    Unlike :class:`GlobusOnlineScheduler` (class-preset parameters) the
    setting is explicit; like every baseline it emits its initial Opens
    and then never acts, so the batched fabric drivers run it through
    the trivial-controller fast path (zero host rounds on JAX).
    """

    name = "Static"

    def __init__(self, chunks, network, max_cc, params: TransferParams):
        super().__init__(chunks, network, max_cc, params)
        p = params
        self.name = (
            f"Static(pp={p.pipelining},p={p.parallelism},cc={p.concurrency})"
        )


class GlobusOnlineScheduler(_StaticOneChunkScheduler):
    name = "GlobusOnline"

    def __init__(self, chunks, network, max_cc, *, connect_personal: bool = False):
        files: List[FileSpec] = [f for c in chunks for f in c.files]
        total = sum(f.size for f in files)
        avg = total / len(files) if files else 1.0
        params = GLOBUS_PRESETS[globus_class(avg)]
        if connect_personal:
            network = degrade_for_connect_personal(network)
        super().__init__(chunks, network, max_cc, params)


class UntunedScheduler(_StaticOneChunkScheduler):
    """globus-url-copy defaults: pp=0, p=1, cc=1 (the 10x-claim baseline)."""

    name = "Untuned"

    def __init__(self, chunks, network, max_cc):
        super().__init__(
            chunks,
            network,
            max_cc,
            TransferParams(pipelining=0, parallelism=1, concurrency=1),
        )
