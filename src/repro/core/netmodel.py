"""Throughput model for channels sharing a network path and a disk system.

The model captures the mechanisms the paper manipulates:

  * per-stream TCP window limit  buffer/RTT, aggregated by ``parallelism``
    (``NetworkSpec.stream_rate_cap``),
  * link capacity shared across concurrent channels (max-min / water-filling),
  * disk sub-system: aggregate bandwidth ramping with concurrency up to
    ``saturation_cc`` then degrading with contention (``DiskSpec``), plus a
    per-channel "lane" cap (one storage server / OST per active channel),
  * per-file dead time: control-channel gap RTT/(1+pipelining) + server-side
    processing (unhidden by pipelining) + per-file disk overhead.

All functions are pure; the simulator and the real engine share them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .types import NetworkSpec, TransferParams


def waterfill(caps: Sequence[float], pool: float) -> List[float]:
    """Max-min fair allocation of ``pool`` across entities with rate ``caps``.

    Classic progressive filling: entities below the fair share keep their cap,
    the remainder is split evenly among the rest.
    """
    n = len(caps)
    if n == 0 or pool <= 0:
        return [0.0] * n
    alloc = [0.0] * n
    remaining = pool
    unfilled = list(range(n))
    # iterate at most n times
    while unfilled and remaining > 1e-12:
        share = remaining / len(unfilled)
        capped = [i for i in unfilled if caps[i] <= share + 1e-12]
        if not capped:
            for i in unfilled:
                alloc[i] += share
            remaining = 0.0
            break
        for i in capped:
            alloc[i] = caps[i]
            remaining -= caps[i]
            unfilled.remove(i)
    return alloc


# The batched (vectorized) form of :func:`waterfill` lives in the
# backend-neutral fabric kernel layer; re-exported here because this module
# is the scalar reference it mirrors (the hypothesis suite pins the two
# together on random inputs).
from repro.eval.fabric.kernels import waterfill_batch  # noqa: E402,F401


def per_channel_disk_lane(network: NetworkSpec) -> float:
    """Single-channel disk ceiling: one storage lane (server/OST) per channel."""
    return network.disk.channel_lane


def channel_rate_cap(network: NetworkSpec, parallelism: int) -> float:
    """Ceiling of one channel: TCP window aggregate x disk lane."""
    return min(
        network.stream_rate_cap(parallelism),
        per_channel_disk_lane(network),
    )


def allocate_rates(
    network: NetworkSpec,
    parallelisms: Sequence[int],
    active: Optional[Sequence[bool]] = None,
    bandwidth: Optional[float] = None,
) -> List[float]:
    """Instantaneous per-channel rates for channels currently moving data.

    ``parallelisms[i]`` is channel i's stream count; ``active[i]`` False means
    the channel is in dead time / idle and consumes no bandwidth.
    ``bandwidth`` overrides the link capacity for this instant (time-varying
    paths pass ``network.bandwidth_at(t)``; default: the nominal capacity).
    """
    n = len(parallelisms)
    if active is None:
        active = [True] * n
    idx = [i for i in range(n) if active[i]]
    if not idx:
        return [0.0] * n
    caps = [channel_rate_cap(network, parallelisms[i]) for i in idx]
    bw = network.bandwidth if bandwidth is None else bandwidth
    pool = min(bw, network.disk.aggregate_rate(len(idx)))
    alloc = waterfill(caps, pool)
    rates = [0.0] * n
    for j, i in enumerate(idx):
        rates[i] = alloc[j]
    return rates


def control_gap(network: NetworkSpec, params: TransferParams) -> float:
    """Control-channel ack gap per file, amortized by pipelining.

    Asymmetric paths (satellite uplinks, congested reverse routes) pay the
    *control* RTT here, which may differ from the data-path RTT that sizes
    the TCP window (``NetworkSpec.control_rtt``).
    """
    rtt = network.control_rtt if network.control_rtt is not None else network.rtt
    return rtt / (1.0 + params.pipelining)


def file_start_dead_time(network: NetworkSpec, params: TransferParams) -> float:
    """Serial per-file overhead paid before bytes flow on a channel.

    control gap   control-RTT/(1+pipelining): with q commands queued at the
                  server the round-trip ack gap amortizes over q+1 files
                  (Sec. 3, "multiple transfer commands can be queued up").
    unhidden      server-side per-file processing pipelining cannot hide;
                  bounds the small-file pipelining win near 2x (Fig 1a/2a).
    disk          per-file seek/open/close/metadata cost.
    """
    gap = control_gap(network, params)
    return gap + network.unhidden_overhead + network.disk.per_file_overhead


def channel_open_cost(
    network: NetworkSpec,
    new_params: TransferParams,
    prev_params: Optional[TransferParams] = None,
) -> float:
    """Cost of opening a channel / re-targeting one to another chunk.

    Parallelism can only be set at connection establishment (Sec. 3.2): moving
    a channel between chunks with different parallelism requires teardown +
    re-setup; identical parallelism reuses the cached data channel cheaply.
    """
    if prev_params is not None and prev_params.parallelism == new_params.parallelism:
        return 0.25 * network.channel_setup_cost
    return network.channel_setup_cost


def predict_chunk_rate(
    network: NetworkSpec,
    avg_file_size: float,
    params: TransferParams,
    n_channels: int,
    total_active_channels: Optional[int] = None,
) -> float:
    """Closed-form steady-state throughput estimate for one chunk.

    Used for a-priori ETAs (before measurements exist) and for unit tests of
    qualitative parameter effects; the simulator computes the real dynamics.
    """
    if n_channels <= 0 or avg_file_size <= 0:
        return 0.0
    total = total_active_channels or n_channels
    cap = channel_rate_cap(network, params.parallelism)
    pool = min(network.bandwidth, network.disk.aggregate_rate(total))
    rate = min(cap, pool / max(1, total))
    dead = file_start_dead_time(network, params)
    t_file = dead + avg_file_size / max(rate, 1e-9)
    return n_channels * avg_file_size / t_file


@dataclasses.dataclass
class RoundEstimate:
    """Napkin-math record used by grad-sync scheduling benchmarks."""

    chunk_name: str
    n_channels: int
    rate: float
    eta: float
