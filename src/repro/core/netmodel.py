"""Throughput model for channels sharing a network path and a disk system.

The model captures the mechanisms the paper manipulates:

  * per-stream TCP window limit  buffer/RTT, aggregated by ``parallelism``
    (``NetworkSpec.stream_rate_cap``),
  * link capacity shared across concurrent channels (max-min / water-filling),
  * disk sub-system: aggregate bandwidth ramping with concurrency up to
    ``saturation_cc`` then degrading with contention (``DiskSpec``), plus a
    per-channel "lane" cap (one storage server / OST per active channel),
  * per-file dead time: control-channel gap RTT/(1+pipelining) + server-side
    processing (unhidden by pipelining) + per-file disk overhead.

All functions are pure; the simulator and the real engine share them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .types import NetworkSpec, TransferParams


def waterfill(caps: Sequence[float], pool: float) -> List[float]:
    """Max-min fair allocation of ``pool`` across entities with rate ``caps``.

    Classic progressive filling: entities below the fair share keep their cap,
    the remainder is split evenly among the rest.
    """
    n = len(caps)
    if n == 0 or pool <= 0:
        return [0.0] * n
    alloc = [0.0] * n
    remaining = pool
    unfilled = list(range(n))
    # iterate at most n times
    while unfilled and remaining > 1e-12:
        share = remaining / len(unfilled)
        capped = [i for i in unfilled if caps[i] <= share + 1e-12]
        if not capped:
            for i in unfilled:
                alloc[i] += share
            remaining = 0.0
            break
        for i in capped:
            alloc[i] = caps[i]
            remaining -= caps[i]
            unfilled.remove(i)
    return alloc


def waterfill_batch(caps, pool):
    """Vectorized :func:`waterfill` over a batch of scenarios.

    ``caps``: float array (S, C) of per-entity rate ceilings — entries for
    absent/idle channels must be 0 (a zero cap allocates zero, exactly like
    being excluded). ``pool``: float array (S,). Returns (S, C) allocations.

    Uses the closed form of max-min fairness with ceilings: every entity gets
    ``min(cap, lam)`` for the water level ``lam`` solving
    ``sum_i min(cap_i, lam) = min(pool, sum_i cap_i)`` — the same fixpoint the
    scalar progressive-filling loop converges to, found here by sorting each
    row once instead of iterating.
    """
    import numpy as np

    caps = np.asarray(caps, dtype=np.float64)
    pool = np.asarray(pool, dtype=np.float64)
    S, C = caps.shape
    if C == 0:
        return np.zeros((S, 0))
    caps_sorted = np.sort(caps, axis=1)
    prefix = np.cumsum(caps_sorted, axis=1)
    pool_eff = np.clip(np.minimum(pool, prefix[:, -1]), 0.0, None)
    # candidate level if the k smallest caps are filled outright:
    #   lam_k = (pool_eff - prefix[k-1]) / (C - k); valid when lam_k <= c_(k)
    prev = np.concatenate([np.zeros((S, 1)), prefix[:, :-1]], axis=1)
    denom = (C - np.arange(C)).astype(np.float64)
    lam_k = (pool_eff[:, None] - prev) / denom
    valid = lam_k <= caps_sorted + 1e-9 * np.maximum(caps_sorted, 1.0)
    # rows with pool >= sum(caps) have every candidate invalid except the
    # last; argmax picks the first valid k
    k = np.argmax(valid, axis=1)
    no_valid = ~valid.any(axis=1)
    lam = lam_k[np.arange(S), k]
    lam[no_valid] = caps_sorted[no_valid, -1]
    return np.minimum(caps, lam[:, None])


def per_channel_disk_lane(network: NetworkSpec) -> float:
    """Single-channel disk ceiling: one storage lane (server/OST) per channel."""
    return network.disk.channel_lane


def channel_rate_cap(network: NetworkSpec, parallelism: int) -> float:
    """Ceiling of one channel: TCP window aggregate x disk lane."""
    return min(
        network.stream_rate_cap(parallelism),
        per_channel_disk_lane(network),
    )


def allocate_rates(
    network: NetworkSpec,
    parallelisms: Sequence[int],
    active: Optional[Sequence[bool]] = None,
) -> List[float]:
    """Instantaneous per-channel rates for channels currently moving data.

    ``parallelisms[i]`` is channel i's stream count; ``active[i]`` False means
    the channel is in dead time / idle and consumes no bandwidth.
    """
    n = len(parallelisms)
    if active is None:
        active = [True] * n
    idx = [i for i in range(n) if active[i]]
    if not idx:
        return [0.0] * n
    caps = [channel_rate_cap(network, parallelisms[i]) for i in idx]
    pool = min(network.bandwidth, network.disk.aggregate_rate(len(idx)))
    alloc = waterfill(caps, pool)
    rates = [0.0] * n
    for j, i in enumerate(idx):
        rates[i] = alloc[j]
    return rates


def file_start_dead_time(network: NetworkSpec, params: TransferParams) -> float:
    """Serial per-file overhead paid before bytes flow on a channel.

    control gap   RTT/(1+pipelining): with q commands queued at the server the
                  round-trip ack gap amortizes over q+1 files (Sec. 3,
                  "multiple transfer commands can be queued up").
    unhidden      server-side per-file processing pipelining cannot hide;
                  bounds the small-file pipelining win near 2x (Fig 1a/2a).
    disk          per-file seek/open/close/metadata cost.
    """
    gap = network.rtt / (1.0 + params.pipelining)
    return gap + network.unhidden_overhead + network.disk.per_file_overhead


def channel_open_cost(
    network: NetworkSpec,
    new_params: TransferParams,
    prev_params: Optional[TransferParams] = None,
) -> float:
    """Cost of opening a channel / re-targeting one to another chunk.

    Parallelism can only be set at connection establishment (Sec. 3.2): moving
    a channel between chunks with different parallelism requires teardown +
    re-setup; identical parallelism reuses the cached data channel cheaply.
    """
    if prev_params is not None and prev_params.parallelism == new_params.parallelism:
        return 0.25 * network.channel_setup_cost
    return network.channel_setup_cost


def predict_chunk_rate(
    network: NetworkSpec,
    avg_file_size: float,
    params: TransferParams,
    n_channels: int,
    total_active_channels: Optional[int] = None,
) -> float:
    """Closed-form steady-state throughput estimate for one chunk.

    Used for a-priori ETAs (before measurements exist) and for unit tests of
    qualitative parameter effects; the simulator computes the real dynamics.
    """
    if n_channels <= 0 or avg_file_size <= 0:
        return 0.0
    total = total_active_channels or n_channels
    cap = channel_rate_cap(network, params.parallelism)
    pool = min(network.bandwidth, network.disk.aggregate_rate(total))
    rate = min(cap, pool / max(1, total))
    dead = file_start_dead_time(network, params)
    t_file = dead + avg_file_size / max(rate, 1e-9)
    return n_channels * avg_file_size / t_file


@dataclasses.dataclass
class RoundEstimate:
    """Napkin-math record used by grad-sync scheduling benchmarks."""

    chunk_name: str
    n_channels: int
    rate: float
    eta: float
