"""Heuristic protocol-parameter estimation (paper Algorithm 1).

    pipelining  = BDP / avgFileSize
    parallelism = min( ceil(BDP / bufferSize), ceil(avgFileSize / bufferSize) )
    concurrency = min( max(BDP / avgFileSize, 2), maxCC )

Rationale (Sec. 3.1):
  - pipelining large for small files (amortizes the per-file RTT gap), small
    for large files (avoids channel load imbalance);
  - parallelism only when (a) the TCP buffer is smaller than the BDP *and*
    (b) the file is big enough to fill multiple buffers;
  - concurrency large for small chunks (they need many channels to reach the
    throughput large files get), lower-bounded by 2, upper-bounded by the
    user-supplied maxCC (end-system cost guard).

The arithmetic itself lives in the array-native controller layer
(:func:`repro.eval.fabric.controllers.tuning.optimal_params`); this module
is the scalar facade — validation, types, and the single-chunk entry
points — instantiating the same kernel the batched drivers use.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.eval.fabric.controllers import tuning as _tuning
from repro.eval.fabric.shim import numpy_ops

from .types import Chunk, NetworkSpec, TransferParams

_OPS = numpy_ops()

#: Practical cap on command queue depth; BDP/avgFileSize is unbounded for tiny
#: files and a queue deeper than the chunk is meaningless. GridFTP clients cap
#: similarly. Does not affect any paper-range scenario's *behaviour* (the gap
#: is fully amortized well before this depth).
MAX_PIPELINING = 4096


def find_optimal_parameters(
    avg_file_size: float,
    bdp: float,
    buffer_size: float,
    max_cc: int,
    num_files: Optional[int] = None,
) -> TransferParams:
    """Algorithm 1, verbatim (with integer rounding at the edges).

    ``num_files`` optionally caps pipelining/concurrency at the chunk's file
    count (a queue or channel pool deeper than the chunk is wasted).
    """
    if avg_file_size <= 0:
        raise ValueError("avg_file_size must be positive")
    if max_cc < 1:
        raise ValueError("max_cc must be >= 1")
    pp, par, cc = _tuning.optimal_params(
        _OPS,
        np.float64(avg_file_size),
        np.float64(bdp),
        np.float64(buffer_size),
        np.float64(max_cc),
        np.int64(num_files if num_files is not None else 0),
        MAX_PIPELINING,
    )
    return TransferParams(
        pipelining=int(pp), parallelism=int(par), concurrency=int(cc)
    )


def assign_chunk_params(
    chunk: Chunk, network: NetworkSpec, max_cc: int
) -> Chunk:
    """Fill ``chunk.params`` from Algorithm 1 for this network.

    A non-empty chunk can still carry zero bytes (metadata-only /
    zero-size files, pure dead-time transfers); Algorithm 1 is undefined
    there, so the average is floored at one byte — the same clamp the
    simulators' chunk views apply.
    """
    chunk.params = find_optimal_parameters(
        avg_file_size=max(chunk.avg_file_size, 1.0),
        bdp=network.bdp,
        buffer_size=network.buffer_size,
        max_cc=max_cc,
        num_files=len(chunk),
    )
    return chunk
