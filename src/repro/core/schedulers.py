"""Transfer scheduling algorithms: SC, MC (Alg. 2), ProMC (Alg. 3).

Schedulers are *controllers*: they decide channel allocation up front and
react to periodic ticks / chunk completions with channel actions. They are
backend-agnostic — the discrete-event simulator and the real threaded engine
both drive them through the same protocol:

    controller.initial_actions(view)            -> [Action]
    controller.on_tick(view)                    -> [Action]   (every period)
    controller.on_chunk_complete(view, cid)     -> [Action]

``view`` is a ChunkViews snapshot (bytes remaining, measured throughput,
channel counts, ETAs). Actions are Open/Close/Move of channels.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from .params import assign_chunk_params
from .types import (
    MC_ROUND_ROBIN_ORDER,
    PROMC_DELTA,
    Chunk,
    ChunkType,
    NetworkSpec,
    TransferParams,
)

# --------------------------------------------------------------------------
# Controller protocol
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Open:
    chunk: int
    n: int = 1


@dataclasses.dataclass(frozen=True)
class Close:
    chunk: int
    n: int = 1


@dataclasses.dataclass(frozen=True)
class Move:
    src: int
    dst: int
    n: int = 1


Action = object  # Open | Close | Move

#: placeholder for chunks with no files (see Scheduler.__init__)
_EMPTY_CHUNK_PARAMS = TransferParams(pipelining=0, parallelism=1, concurrency=1)


@dataclasses.dataclass
class ChunkView:
    """Backend-reported state of one chunk at a point in time."""

    index: int
    ctype: ChunkType
    bytes_remaining: float
    files_remaining: int
    throughput: float  # recent measured rate (bytes/s), 0 before data flows
    n_channels: int
    done: bool
    predicted_rate: float = 0.0  # model-based a-priori rate (for cold ETAs)

    @property
    def eta(self) -> float:
        """Estimated completion time = remaining / throughput (Sec. 3.3)."""
        if self.done or self.bytes_remaining <= 0:
            return 0.0
        rate = self.throughput if self.throughput > 0 else self.predicted_rate
        if rate <= 0:
            return math.inf
        return self.bytes_remaining / rate


ChunkViews = Sequence[ChunkView]


class Scheduler:
    """Base controller. Subclasses implement the three paper algorithms."""

    name = "base"

    def __init__(self, chunks: Sequence[Chunk], network: NetworkSpec, max_cc: int):
        if max_cc < 1:
            raise ValueError("max_cc must be >= 1")
        self.chunks = list(chunks)
        self.network = network
        self.max_cc = max_cc
        for c in self.chunks:
            if c.params is None:
                if len(c) == 0:
                    # empty size class (dataset lacks it): Algorithm 1 is
                    # undefined on zero files; minimal params keep views and
                    # rate predictions well-formed, no channel ever opens
                    c.params = _EMPTY_CHUNK_PARAMS
                else:
                    assign_chunk_params(c, network, max_cc)

    # -- protocol ----------------------------------------------------------
    def initial_actions(self, view: ChunkViews) -> List[Action]:
        raise NotImplementedError

    def on_tick(self, view: ChunkViews) -> List[Action]:
        return []

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        return []

    # -- helpers -----------------------------------------------------------
    @staticmethod
    def _live(view: ChunkViews) -> List[ChunkView]:
        return [v for v in view if not v.done and v.bytes_remaining > 0]

    @staticmethod
    def distribute_to_laggards(
        view: ChunkViews, src: int, n_channels: int
    ) -> List[Action]:
        """Hand ``n_channels`` freed channels to the chunks with the largest
        estimated completion times, one at a time, discounting a chunk's ETA
        as it receives channels (Sec. 3.3: "channels of the finished chunk are
        given to a chunk whose estimated completion time is the largest")."""
        live = [v for v in view if not v.done and v.index != src and v.bytes_remaining > 0]
        if not live:
            return []
        etas = {v.index: v.eta for v in live}
        owners = {v.index: v.n_channels for v in live}
        moves: Dict[int, int] = {}
        for _ in range(n_channels):
            dst = max(etas, key=lambda i: etas[i])
            moves[dst] = moves.get(dst, 0) + 1
            # adding a channel scales the chunk's rate ~ (n+1)/n
            n = owners[dst] + moves[dst]
            if math.isfinite(etas[dst]) and n > 0:
                etas[dst] *= (n - 1) / n if n > 1 else 0.5
        return [Move(src=src, dst=d, n=k) for d, k in moves.items()]


# --------------------------------------------------------------------------
# Single-Chunk (SC): sequential, per-chunk tuned parameters (Sec. 3.2)
# --------------------------------------------------------------------------


class SingleChunkScheduler(Scheduler):
    """Transfer chunks one by one, each with its Algorithm-1 parameters.

    Chunk order: largest size class first (Huge -> Small); the paper does not
    fix an order and throughput is order-insensitive for SC since phases are
    sequential.
    """

    name = "SC"

    def __init__(self, chunks, network, max_cc):
        super().__init__(chunks, network, max_cc)
        self._order = sorted(
            range(len(self.chunks)),
            key=lambda i: -int(self.chunks[i].ctype),
        )
        self._cursor = 0

    def _open_current(self) -> List[Action]:
        while self._cursor < len(self._order):
            idx = self._order[self._cursor]
            chunk = self.chunks[idx]
            if len(chunk) > 0:
                # SC uses the chunk's own concurrency (already maxCC-capped)
                return [Open(chunk=idx, n=chunk.params.concurrency)]
            self._cursor += 1
        return []

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        return self._open_current()

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        done_view = view[chunk]
        actions: List[Action] = [Close(chunk=chunk, n=done_view.n_channels)]
        self._cursor += 1
        actions.extend(self._open_current())
        return actions


# --------------------------------------------------------------------------
# Multi-Chunk (MC): co-scheduled chunks, round-robin channels (Alg. 2)
# --------------------------------------------------------------------------


def round_robin_distribution(
    chunks: Sequence[Chunk], max_cc: int
) -> Dict[int, int]:
    """Alg. 2 lines 8-12: distribute maxCC channels round-robin over the
    chunk set ordered {Huge, Small, Large, Medium}."""
    order = [
        i
        for ct in MC_ROUND_ROBIN_ORDER
        for i, c in enumerate(chunks)
        if c.ctype == ct and len(c) > 0
    ]
    alloc = {i: 0 for i in order}
    if not order:
        return alloc
    k = 0
    for _ in range(max_cc):
        alloc[order[k % len(order)]] += 1
        k += 1
    return alloc


class MultiChunkScheduler(Scheduler):
    """MC (Sec. 3.3): all chunks at once; concurrency = maxCC total,
    round-robin distributed; freed channels go to the largest-ETA chunk."""

    name = "MC"

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        alloc = round_robin_distribution(self.chunks, self.max_cc)
        return [Open(chunk=i, n=n) for i, n in alloc.items() if n > 0]

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        freed = view[chunk].n_channels
        return self.distribute_to_laggards(view, src=chunk, n_channels=freed)


# --------------------------------------------------------------------------
# Pro-Active Multi-Chunk (ProMC): weighted channels + online re-allocation
# (Sec. 3.4, Alg. 3)
# --------------------------------------------------------------------------


def weighted_distribution(
    chunks: Sequence[Chunk], max_cc: int, delta: Optional[Dict] = None
) -> Dict[int, int]:
    """Alg. 3 lines 5-12: weight_i = delta_i * size_i, normalized;
    concurrency_i = floor(weight_i * maxCC).

    Deviations from the bare pseudo-code, both required for a working system:
      * every non-empty chunk receives at least one channel (a floor() of 0
        would strand a chunk forever);
      * channels left over from flooring are granted by largest fractional
        part, never exceeding maxCC total.
    """
    delta = delta or PROMC_DELTA
    live = [i for i, c in enumerate(chunks) if len(c) > 0]
    if not live:
        return {}
    weights = {i: delta[chunks[i].ctype] * chunks[i].total_bytes for i in live}
    total = sum(weights.values()) or 1.0
    shares = {i: weights[i] / total * max_cc for i in live}
    alloc = {i: int(math.floor(shares[i])) for i in live}
    # guarantee progress for every chunk
    for i in live:
        if alloc[i] == 0:
            alloc[i] = 1
    # trim/grant to hit exactly min(max_cc, ...) >= len(live) channels
    budget = max(max_cc, len(live))
    while sum(alloc.values()) > budget:
        i = max(alloc, key=lambda j: (alloc[j], -shares[j]))
        if alloc[i] <= 1:
            break
        alloc[i] -= 1
    frac = sorted(live, key=lambda i: shares[i] - math.floor(shares[i]), reverse=True)
    k = 0
    while sum(alloc.values()) < budget and frac:
        alloc[frac[k % len(frac)]] += 1
        k += 1
    return alloc


class ProActiveMultiChunkScheduler(Scheduler):
    """ProMC: delta-weighted initial allocation + online channel re-allocation.

    Re-allocation rule (Sec. 3.4): if a chunk's ETA is at least ``ratio``
    (default 2x) *smaller* than another's for ``patience`` (default 3)
    consecutive periods, move one channel from the fast chunk to the slow one.
    The periodic check (default every 5 s) is driven by the backend tick.
    """

    name = "ProMC"

    def __init__(
        self,
        chunks,
        network,
        max_cc,
        *,
        delta: Optional[Dict] = None,
        ratio: float = 2.0,
        patience: int = 3,
    ):
        super().__init__(chunks, network, max_cc)
        self.delta = delta or PROMC_DELTA
        self.ratio = ratio
        self.patience = patience
        self._streak = 0
        self._streak_pair: Optional[tuple] = None

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        alloc = weighted_distribution(self.chunks, self.max_cc, self.delta)
        return [Open(chunk=i, n=n) for i, n in alloc.items() if n > 0]

    def on_tick(self, view: ChunkViews) -> List[Action]:
        live = [v for v in self._live(view) if v.n_channels > 0]
        if len(live) < 2:
            self._streak, self._streak_pair = 0, None
            return []
        fast = min(live, key=lambda v: v.eta)
        slow = max(live, key=lambda v: v.eta)
        if not math.isfinite(slow.eta) and slow.throughput == 0:
            # slow chunk has produced no data yet; wait for a measurement
            return []
        imbalanced = (
            slow.eta >= self.ratio * fast.eta
            and fast.index != slow.index
            and fast.n_channels > 1  # never strand the fast chunk
        )
        pair = (fast.index, slow.index)
        if imbalanced and pair == self._streak_pair:
            self._streak += 1
        elif imbalanced:
            self._streak, self._streak_pair = 1, pair
        else:
            self._streak, self._streak_pair = 0, None
            return []
        if self._streak >= self.patience:
            self._streak, self._streak_pair = 0, None
            return [Move(src=fast.index, dst=slow.index, n=1)]
        return []

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        freed = view[chunk].n_channels
        self._streak, self._streak_pair = 0, None
        return self.distribute_to_laggards(view, src=chunk, n_channels=freed)


SCHEDULERS = {
    "sc": SingleChunkScheduler,
    "mc": MultiChunkScheduler,
    "promc": ProActiveMultiChunkScheduler,
}


def make_scheduler(name: str, chunks, network, max_cc, **kw) -> Scheduler:
    try:
        cls = SCHEDULERS[name.lower()]
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; options: {list(SCHEDULERS)}")
    return cls(chunks, network, max_cc, **kw)
