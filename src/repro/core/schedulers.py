"""Transfer scheduling algorithms: SC, MC (Alg. 2), ProMC (Alg. 3).

Schedulers are *controllers*: they decide channel allocation up front and
react to periodic ticks / chunk completions with channel actions. They are
backend-agnostic — the discrete-event simulator and the real threaded engine
both drive them through the same protocol:

    controller.initial_actions(view)            -> [Action]
    controller.on_tick(view)                    -> [Action]   (every period)
    controller.on_chunk_complete(view, cid)     -> [Action]

``view`` is a ChunkViews snapshot (bytes remaining, measured throughput,
channel counts, ETAs). Actions are Open/Close/Move of channels.

This module is a thin *scalar facade* over the array-native controller
kernels in :mod:`repro.eval.fabric.controllers`: every decision —
round-robin and delta-weighted channel distribution, the ProMC streak
state machine, laggard-ETA discounting — runs through the same kernels
the batched NumPy driver and the fused JAX device loop execute, here
instantiated on single-scenario NumPy rows. The arithmetic is mirrored
operation-for-operation, so the facade is bit-identical to the historical
pure-Python implementation (golden snapshots unchanged) and the three
consumers cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.eval.fabric import controllers as _ctrl
from repro.eval.fabric.shim import numpy_ops

from .params import assign_chunk_params
from .types import (
    MC_ROUND_ROBIN_ORDER,
    PROMC_DELTA,
    Chunk,
    ChunkType,
    NetworkSpec,
    TransferParams,
)

_OPS = numpy_ops()

#: ctype -> position in the MC round-robin service order
_RR_RANK = {ct: i for i, ct in enumerate(MC_ROUND_ROBIN_ORDER)}

# --------------------------------------------------------------------------
# Controller protocol
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Open:
    chunk: int
    n: int = 1


@dataclasses.dataclass(frozen=True)
class Close:
    chunk: int
    n: int = 1


@dataclasses.dataclass(frozen=True)
class Move:
    src: int
    dst: int
    n: int = 1


Action = object  # Open | Close | Move

#: placeholder for chunks with no files (see Scheduler.__init__)
_EMPTY_CHUNK_PARAMS = TransferParams(pipelining=0, parallelism=1, concurrency=1)


@dataclasses.dataclass
class ChunkView:
    """Backend-reported state of one chunk at a point in time."""

    index: int
    ctype: ChunkType
    bytes_remaining: float
    files_remaining: int
    throughput: float  # recent measured rate (bytes/s), 0 before data flows
    n_channels: int
    done: bool
    predicted_rate: float = 0.0  # model-based a-priori rate (for cold ETAs)

    @property
    def eta(self) -> float:
        """Estimated completion time = remaining / throughput (Sec. 3.3)."""
        if self.done or self.bytes_remaining <= 0:
            return 0.0
        rate = self.throughput if self.throughput > 0 else self.predicted_rate
        if rate <= 0:
            return math.inf
        return self.bytes_remaining / rate


ChunkViews = Sequence[ChunkView]


def _view_arrays(view: ChunkViews):
    """ChunkViews -> the (K,) NumPy rows the decision kernels consume."""
    bytes_rem = np.array([v.bytes_remaining for v in view], dtype=np.float64)
    thr = np.array([v.throughput for v in view], dtype=np.float64)
    pred = np.array([v.predicted_rate for v in view], dtype=np.float64)
    done = np.array([v.done for v in view], dtype=bool)
    n_ch = np.array([v.n_channels for v in view], dtype=np.int64)
    eta = _ctrl.chunk_eta(_OPS, bytes_rem, thr, pred, done)
    return bytes_rem, thr, n_ch, done, eta


class Scheduler:
    """Base controller. Subclasses implement the three paper algorithms."""

    name = "base"

    def __init__(self, chunks: Sequence[Chunk], network: NetworkSpec, max_cc: int):
        if max_cc < 1:
            raise ValueError("max_cc must be >= 1")
        self.chunks = list(chunks)
        self.network = network
        self.max_cc = max_cc
        for c in self.chunks:
            if c.params is None:
                if len(c) == 0:
                    # empty size class (dataset lacks it): Algorithm 1 is
                    # undefined on zero files; minimal params keep views and
                    # rate predictions well-formed, no channel ever opens
                    c.params = _EMPTY_CHUNK_PARAMS
                else:
                    assign_chunk_params(c, network, max_cc)

    # -- protocol ----------------------------------------------------------
    def initial_actions(self, view: ChunkViews) -> List[Action]:
        raise NotImplementedError

    def on_tick(self, view: ChunkViews) -> List[Action]:
        return []

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        return []

    # -- helpers -----------------------------------------------------------
    @staticmethod
    def _live(view: ChunkViews) -> List[ChunkView]:
        return [v for v in view if not v.done and v.bytes_remaining > 0]

    @staticmethod
    def distribute_to_laggards(
        view: ChunkViews, src: int, n_channels: int
    ) -> List[Action]:
        """Hand ``n_channels`` freed channels to the chunks with the largest
        estimated completion times, one at a time, discounting a chunk's ETA
        as it receives channels (Sec. 3.3: "channels of the finished chunk are
        given to a chunk whose estimated completion time is the largest")."""
        bytes_rem, _thr, n_ch, done, eta = _view_arrays(view)
        idx = np.arange(len(view))
        live = ~done & (idx != src) & (bytes_rem > 0)
        if not live.any() or n_channels <= 0:
            return []
        grants, first = _ctrl.laggard_grants(
            _OPS, eta, n_ch, live, np.int64(n_channels), n_channels
        )
        order = sorted(np.flatnonzero(grants > 0), key=lambda d: first[d])
        return [Move(src=src, dst=int(d), n=int(grants[d])) for d in order]


# --------------------------------------------------------------------------
# Single-Chunk (SC): sequential, per-chunk tuned parameters (Sec. 3.2)
# --------------------------------------------------------------------------


class SingleChunkScheduler(Scheduler):
    """Transfer chunks one by one, each with its Algorithm-1 parameters.

    Chunk order: largest size class first (Huge -> Small); the paper does not
    fix an order and throughput is order-insensitive for SC since phases are
    sequential.
    """

    name = "SC"

    def __init__(self, chunks, network, max_cc):
        super().__init__(chunks, network, max_cc)
        ctypes = np.array([int(c.ctype) for c in self.chunks], dtype=np.int64)
        self._order = [
            int(i) for i in _ctrl.sc_chunk_order(_OPS, ctypes)
        ] if len(self.chunks) else []
        self._cursor = 0

    def _open_current(self) -> List[Action]:
        while self._cursor < len(self._order):
            idx = self._order[self._cursor]
            chunk = self.chunks[idx]
            if len(chunk) > 0:
                # SC uses the chunk's own concurrency (already maxCC-capped)
                return [Open(chunk=idx, n=chunk.params.concurrency)]
            self._cursor += 1
        return []

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        return self._open_current()

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        done_view = view[chunk]
        actions: List[Action] = [Close(chunk=chunk, n=done_view.n_channels)]
        self._cursor += 1
        actions.extend(self._open_current())
        return actions


# --------------------------------------------------------------------------
# Multi-Chunk (MC): co-scheduled chunks, round-robin channels (Alg. 2)
# --------------------------------------------------------------------------


def round_robin_distribution(
    chunks: Sequence[Chunk], max_cc: int
) -> Dict[int, int]:
    """Alg. 2 lines 8-12: distribute maxCC channels round-robin over the
    chunk set ordered {Huge, Small, Large, Medium}. Keys iterate in that
    service order (the order channels open)."""
    if not chunks:
        return {}
    rank = np.array([_RR_RANK[c.ctype] for c in chunks], dtype=np.int64)
    nonempty = np.array([len(c) > 0 for c in chunks], dtype=bool)
    alloc = _ctrl.round_robin_alloc(_OPS, rank, nonempty, max_cc)
    order = sorted(np.flatnonzero(nonempty), key=lambda i: (rank[i], i))
    return {int(i): int(alloc[i]) for i in order}


class MultiChunkScheduler(Scheduler):
    """MC (Sec. 3.3): all chunks at once; concurrency = maxCC total,
    round-robin distributed; freed channels go to the largest-ETA chunk."""

    name = "MC"

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        alloc = round_robin_distribution(self.chunks, self.max_cc)
        return [Open(chunk=i, n=n) for i, n in alloc.items() if n > 0]

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        freed = view[chunk].n_channels
        return self.distribute_to_laggards(view, src=chunk, n_channels=freed)


# --------------------------------------------------------------------------
# Pro-Active Multi-Chunk (ProMC): weighted channels + online re-allocation
# (Sec. 3.4, Alg. 3)
# --------------------------------------------------------------------------


def weighted_distribution(
    chunks: Sequence[Chunk], max_cc: int, delta: Optional[Dict] = None
) -> Dict[int, int]:
    """Alg. 3 lines 5-12: weight_i = delta_i * size_i, normalized;
    concurrency_i = floor(weight_i * maxCC).

    Deviations from the bare pseudo-code, both required for a working system:
      * every non-empty chunk receives at least one channel (a floor() of 0
        would strand a chunk forever);
      * channels left over from flooring are granted by largest fractional
        part, never exceeding maxCC total.
    """
    delta = delta or PROMC_DELTA
    nonempty = np.array([len(c) > 0 for c in chunks], dtype=bool)
    if not nonempty.any():
        return {}
    weights = np.array(
        [delta[c.ctype] * c.total_bytes for c in chunks], dtype=np.float64
    )
    alloc = _ctrl.weighted_alloc(
        _OPS, weights, nonempty, max_cc, trim_iters=len(chunks)
    )
    return {int(i): int(alloc[i]) for i in np.flatnonzero(nonempty)}


class ProActiveMultiChunkScheduler(Scheduler):
    """ProMC: delta-weighted initial allocation + online channel re-allocation.

    Re-allocation rule (Sec. 3.4): if a chunk's ETA is at least ``ratio``
    (default 2x) *smaller* than another's for ``patience`` (default 3)
    consecutive periods, move one channel from the fast chunk to the slow one.
    The periodic check (default every 5 s) is driven by the backend tick.
    """

    name = "ProMC"

    def __init__(
        self,
        chunks,
        network,
        max_cc,
        *,
        delta: Optional[Dict] = None,
        ratio: float = 2.0,
        patience: int = 3,
    ):
        super().__init__(chunks, network, max_cc)
        self.delta = delta or PROMC_DELTA
        self.ratio = ratio
        self.patience = patience
        self._streak = 0
        self._streak_pair: Optional[tuple] = None

    def initial_actions(self, view: ChunkViews) -> List[Action]:
        alloc = weighted_distribution(self.chunks, self.max_cc, self.delta)
        return [Open(chunk=i, n=n) for i, n in alloc.items() if n > 0]

    def on_tick(self, view: ChunkViews) -> List[Action]:
        # scalar short-circuit, bit-equivalent to the kernel's "fewer than
        # two contenders" reset branch: skips the array round-trip for
        # single-chunk scenarios and endgame phases (the common tick)
        n_live = sum(
            1
            for v in view
            if not v.done and v.bytes_remaining > 0 and v.n_channels > 0
        )
        if n_live < 2:
            self._streak, self._streak_pair = 0, None
            return []
        bytes_rem, thr, n_ch, done, eta = _view_arrays(view)
        pf, ps = self._streak_pair if self._streak_pair else (-1, -1)
        streak, pf, ps, move, src, dst = _ctrl.promc_tick(
            _OPS,
            eta,
            thr,
            n_ch,
            ~done & (bytes_rem > 0),
            np.int64(self._streak),
            np.int64(pf),
            np.int64(ps),
            self.ratio,
            np.int64(self.patience),
        )
        self._streak = int(streak)
        self._streak_pair = (
            (int(pf), int(ps)) if int(pf) >= 0 else None
        )
        if move:
            return [Move(src=int(src), dst=int(dst), n=1)]
        return []

    def on_chunk_complete(self, view: ChunkViews, chunk: int) -> List[Action]:
        freed = view[chunk].n_channels
        self._streak, self._streak_pair = 0, None
        return self.distribute_to_laggards(view, src=chunk, n_channels=freed)


SCHEDULERS = {
    "sc": SingleChunkScheduler,
    "mc": MultiChunkScheduler,
    "promc": ProActiveMultiChunkScheduler,
}


def make_scheduler(name: str, chunks, network, max_cc, **kw) -> Scheduler:
    try:
        cls = SCHEDULERS[name.lower()]
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; options: {list(SCHEDULERS)}")
    return cls(chunks, network, max_cc, **kw)
