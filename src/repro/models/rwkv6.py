"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent decay, plus the squared-ReLU channel-mix FFN.

Per head (key dim I, value dim J), with state S in R^{I x J}:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(decay_t))

``decay_t`` is data-dependent (the defining Finch feature): a low-rank MLP of
the token-shift mix. The sequential form here is the reference; the Pallas
kernel (`repro.kernels.rwkv6_scan`) computes the same recurrence chunkwise.

State carried for decode: (wkv_state (B,H,I,J), shift_tm (B,D), shift_cm (B,D)).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import cast, dense_init, rms_norm

Array = jax.Array
LORA_DIM = 64


def rwkv_param_init(key, d_model: int, num_heads: int, head_dim: int,
                    d_ff: int) -> dict:
    h = num_heads * head_dim
    ks = jax.random.split(key, 12)
    return {
        # token-shift mixing coefficients for (r, k, v, g, w)
        "mix_base": 0.5 * jnp.ones((5, d_model), jnp.float32),
        "mix_a": dense_init(ks[0], (d_model, LORA_DIM), scale=0.01),
        "mix_b": dense_init(ks[1], (5, LORA_DIM, d_model), scale=0.01),
        # projections
        "w_r": dense_init(ks[2], (d_model, h)),
        "w_k": dense_init(ks[3], (d_model, h)),
        "w_v": dense_init(ks[4], (d_model, h)),
        "w_g": dense_init(ks[5], (d_model, h)),
        "w_o": dense_init(ks[6], (h, d_model)),
        # data-dependent decay (low-rank) + per-channel base + bonus u
        "decay_base": -6.0 * jnp.ones((h,), jnp.float32),
        "decay_a": dense_init(ks[7], (d_model, LORA_DIM), scale=0.01),
        "decay_b": dense_init(ks[8], (LORA_DIM, h), scale=0.01),
        "u": 0.5 * jnp.ones((num_heads, head_dim), jnp.float32),
        "ln_x": jnp.zeros((h,), jnp.float32),  # per-head group norm scale
        # channel mix
        "cm_mix": 0.5 * jnp.ones((d_model,), jnp.float32),
        "cm_k": dense_init(ks[9], (d_model, d_ff)),
        "cm_v": dense_init(ks[10], (d_ff, d_model)),
    }


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x_{t-1} with the sequence-start slot filled from carried state."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def wkv_scan_ref(r: Array, k: Array, v: Array, w: Array, u: Array,
                 state: Array) -> Tuple[Array, Array]:
    """Sequential WKV recurrence (the oracle the Pallas kernel must match).

    r,k,v,w: (B, T, H, D); u: (H, D); state: (B, H, D, D) -> (y, new_state).
    """

    def step(s, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B, H, D)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        y_t = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    new_state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), new_state


def rwkv_time_mix(
    params: dict,
    x: Array,
    num_heads: int,
    head_dim: int,
    state: Optional[dict] = None,
    use_kernel: bool = False,
) -> Tuple[Array, dict]:
    """x: (B, T, D) -> (out, new_state). fp32 recurrence for stability."""
    b, t, d = x.shape
    hd = num_heads * head_dim
    xf = x.astype(jnp.float32)
    prev_tm = None if state is None else state["shift_tm"]
    xs = _token_shift(xf, prev_tm)  # (B, T, D)
    delta = xs - xf

    # data-dependent 5-way mixing (ddlerp)
    base = xf + delta * params["mix_base"][:, None, None, :]  # (5, B, T, D)
    lora = jnp.einsum(
        "btd,dl,nlm->nbtm", jnp.tanh(xf @ params["mix_a"]),
        jnp.eye(LORA_DIM, dtype=jnp.float32), params["mix_b"]
    )
    mixed = base + delta[None] * lora  # (5, B, T, D)
    xr, xk, xv, xg, xw = mixed

    r = (xr @ params["w_r"]).reshape(b, t, num_heads, head_dim)
    k = (xk @ params["w_k"]).reshape(b, t, num_heads, head_dim)
    v = (xv @ params["w_v"]).reshape(b, t, num_heads, head_dim)
    g = jax.nn.silu(xg @ params["w_g"])  # (B, T, HD)

    decay = params["decay_base"] + jnp.tanh(xw @ params["decay_a"]) @ params[
        "decay_b"
    ]  # (B, T, HD)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, num_heads, head_dim)

    if state is None:
        from repro.distributed.sharding import vary_for_manual

        # zeros carry must match the (possibly manual-axis-varying) scan body
        s0 = vary_for_manual(
            jnp.zeros((b, num_heads, head_dim, head_dim), jnp.float32)
        )
    else:
        s0 = state["wkv"]
    if use_kernel:
        from repro.kernels import ops as kops

        y, s1 = kops.rwkv6_scan(r, k, v, w, params["u"], s0)
    else:
        y, s1 = wkv_scan_ref(r, k, v, w, params["u"], s0)

    # per-head group norm + output gate
    y = y.reshape(b, t, hd)
    yh = y.reshape(b, t, num_heads, head_dim)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    y = yh.reshape(b, t, hd) * (1.0 + params["ln_x"])
    out = (y * g) @ params["w_o"]

    new_state = {"wkv": s1, "shift_tm": xf[:, -1, :]}
    return out.astype(x.dtype), new_state


def rwkv_channel_mix(
    params: dict, x: Array, state: Optional[dict] = None
) -> Tuple[Array, Array]:
    """Squared-ReLU channel mix with token shift. x: (B, T, D)."""
    xf = x.astype(jnp.float32)
    prev = None if state is None else state["shift_cm"]
    xs = _token_shift(xf, prev)
    xk = xf + (xs - xf) * params["cm_mix"]
    h = jax.nn.relu(xk @ params["cm_k"])
    out = (h * h) @ params["cm_v"]
    return out.astype(x.dtype), xf[:, -1, :]
