"""Shared neural-net layers (pure JAX, functional; params are dict pytrees).

Conventions:
  - activations default bf16, params fp32 (cast at use), softmax/norms fp32;
  - attention tensors are (batch, seq, heads, head_dim);
  - every layer fn takes (params, inputs, ...) and returns arrays, no state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

Array = jax.Array

# A window value meaning "unwindowed" in per-layer window arrays; any value
# >= max sequence length behaves identically.
GLOBAL_WINDOW = jnp.iinfo(jnp.int32).max // 2


def cast(x: Array, dtype=jnp.bfloat16) -> Array:
    return x.astype(dtype)


# ------------------------------------------------------------------ #
# init helpers
# ------------------------------------------------------------------ #


def dense_init(key, shape, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(
        jnp.float32
    )


# ------------------------------------------------------------------ #
# norms / activations
# ------------------------------------------------------------------ #


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def activation(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# ------------------------------------------------------------------ #
# rotary embeddings
# ------------------------------------------------------------------ #


def rope(x: Array, positions: Array, theta) -> Array:
    """Apply rotary embeddings. x: (B, S, H, Dh); positions: (B, S) or (S,).

    ``theta`` may be a python float or a traced scalar (per-layer theta in a
    scanned stack, e.g. gemma3 local 10k / global 1M).
    """
    b, s, h, dh = x.shape
    half = dh // 2
    freq_exponents = jnp.arange(half, dtype=jnp.float32) / half
    timescale = jnp.asarray(theta, jnp.float32) ** freq_exponents
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[:, :, None] / timescale[None, None, :]  # (B, S, half)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ #
# attention (reference path; the Pallas flash kernel mirrors this math)
# ------------------------------------------------------------------ #


def attention_scores(
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    k_positions: Array,
    *,
    causal: bool = True,
    window: Optional[Array] = None,
    k_valid_len: Optional[Array] = None,
    logit_softcap: float = 0.0,
) -> Array:
    """Grouped-query attention with causal/sliding-window/cross masking.

    q: (B, S, H, Dh); k, v: (B, T, KV, Dh); H % KV == 0.
    q_positions: (S,) or (B, S); k_positions: (T,) or (B, T).
    window: scalar (possibly traced); tokens attend to (pos-window, pos].
    k_valid_len: mask keys at index >= this (decode with partially filled
    cache).
    """
    b, s, h, dh = q.shape
    _, t, kv, _ = k.shape
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst",
        qg.astype(jnp.float32),
        k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    if logit_softcap:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap

    qp = q_positions if q_positions.ndim == 2 else q_positions[None, :]
    kp = k_positions if k_positions.ndim == 2 else k_positions[None, :]
    mask = jnp.ones((b if qp.shape[0] > 1 or kp.shape[0] > 1 else 1, s, t), bool)
    # negative key positions mark unwritten (rolling-cache) slots
    mask &= kp[:, None, :] >= 0
    if causal:
        mask &= kp[:, None, :] <= qp[:, :, None]
    if window is not None:
        mask &= kp[:, None, :] > (qp[:, :, None] - window)
    if k_valid_len is not None:
        valid = jnp.arange(t)[None, None, :] < jnp.asarray(k_valid_len).reshape(-1, 1, 1)
        mask &= valid
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgst,btkd->bskgd", probs, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, h, dh).astype(q.dtype)


def attention_chunked(
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    k_positions: Array,
    *,
    causal: bool = True,
    window: Optional[Array] = None,
    logit_softcap: float = 0.0,
    q_chunk: int = 1024,
) -> Array:
    """Query-chunked attention: identical math to ``attention_scores`` but the
    (S, T) logits are materialized one q-chunk at a time (flash-style memory
    behaviour without online softmax — each chunk sees the full key row).

    Required for the 32k/500k-sequence cells: a dense 32768^2 fp32 logit
    tensor would be ~4 GB/head. q_positions must be (S,) here.
    """
    b, s, h, dh = q.shape
    if s % q_chunk != 0:
        return attention_scores(
            q, k, v, q_positions, k_positions,
            causal=causal, window=window, logit_softcap=logit_softcap,
        )
    n_chunks = s // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, dh)
    qp = q_positions.reshape(n_chunks, q_chunk)

    def one(args):
        q_i, qp_i = args
        return attention_scores(
            q_i, k, v, qp_i, k_positions,
            causal=causal, window=window, logit_softcap=logit_softcap,
        )

    out = jax.lax.map(one, (jnp.moveaxis(qc, 1, 0), qp))  # (n, B, C, H, Dh)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, dh)


def attend(
    q, k, v, q_positions, k_positions, *,
    causal=True, window=None, logit_softcap=0.0, chunk_threshold=2048,
) -> Array:
    """Dispatch between dense and q-chunked attention by sequence length."""
    if q.shape[1] > chunk_threshold and q_positions.ndim == 1:
        return attention_chunked(
            q, k, v, q_positions, k_positions,
            causal=causal, window=window, logit_softcap=logit_softcap,
        )
    return attention_scores(
        q, k, v, q_positions, k_positions,
        causal=causal, window=window, logit_softcap=logit_softcap,
    )


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int


def attn_param_init(key, dims: AttnDims, cross: bool = False) -> dict:
    kq, kk, kv_, ko = jax.random.split(key, 4)
    d, h, kv, dh = dims.d_model, dims.num_heads, dims.num_kv_heads, dims.head_dim
    return {
        "wq": dense_init(kq, (d, h * dh)),
        "wk": dense_init(kk, (d, kv * dh)),
        "wv": dense_init(kv_, (d, kv * dh)),
        "wo": dense_init(ko, (h * dh, d), scale=1.0 / jnp.sqrt(h * dh)),
    }


def attn_qkv(params: dict, x: Array, dims: AttnDims):
    b, s, _ = x.shape
    q = (x @ cast(params["wq"])).reshape(b, s, dims.num_heads, dims.head_dim)
    k = (x @ cast(params["wk"])).reshape(b, s, dims.num_kv_heads, dims.head_dim)
    v = (x @ cast(params["wv"])).reshape(b, s, dims.num_kv_heads, dims.head_dim)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)
    return q, k, v


def attn_out(params: dict, o: Array) -> Array:
    b, s, h, dh = o.shape
    return o.reshape(b, s, h * dh) @ cast(params["wo"])


# ------------------------------------------------------------------ #
# feed-forward
# ------------------------------------------------------------------ #


def ffn_param_init(key, d_model: int, d_ff: int, glu: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff)),
        "w_down": dense_init(ks[1], (d_ff, d_model)),
    }
    if glu:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def ffn_apply(params: dict, x: Array, act: str, glu: bool) -> Array:
    up = x @ cast(params["w_up"])
    up = shard(up, "batch", "seq", "mlp")
    if glu:
        gate = activation(x @ cast(params["w_gate"]), act)
        h = gate * up
    else:
        h = activation(up, act)
    return h @ cast(params["w_down"])
