"""Model assembly: build_model(config) -> {init, loss, forward, prefill, decode}.

Four assembly families share one public surface:

  * ``LM``        uniform decoder-only stacks (dense / moe / vlm): all layers
                  are attention blocks, scanned over stacked params with
                  per-layer (window, rope-theta) scalars for mixed
                  local/global patterns (gemma3).
  * ``RwkvLM``    uniform RWKV-6 stacks (attention-free).
  * ``HybridLM``  Griffin-style periodic patterns (recurrentgemma "RRL"):
                  scan over full periods + unrolled tail layers.
  * ``EncDecLM``  whisper-style encoder-decoder with cross attention; the
                  audio conv frontend is a stub (precomputed frame
                  embeddings arrive as inputs).

All forward paths are functional; decode carries an explicit cache pytree.
Scan-over-layers keeps the lowered HLO compact (essential for 512-way SPMD
compiles) and remat policy is applied to the scan body.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard, vary_for_manual
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


def sharded_embed_lookup(table: Array, tokens: Array) -> Array:
    """Embedding lookup that stays correct AND partitioner-friendly when the
    table's vocab dim is model-sharded inside a manual-pod shard_map region.

    XLA's SPMD partitioner (this version) hits a replica-group CHECK failure
    partitioning a *gather* over a sharded operand dim under manual sub-axes
    (b/433785288-adjacent). Inside the multi-pod manual region we therefore
    express the lookup as a one-hot x table matmul: iota-compare + dot
    partition cleanly (partial contraction over the vocab shards + model-axis
    all-reduce, the same communication the sharded gather implies), and the
    embedding gradient flows through the dot transpose with no scatter.
    Everywhere else (incl. every single-pod roofline cell) the plain take
    lowers fine and stays gather-cheap.
    """
    from repro.distributed.sharding import current_ctx

    ctx = current_ctx()
    use_onehot = ctx is not None and ctx.manual_axes
    if not use_onehot:
        return jnp.take(table, tokens, axis=0)
    flat = tokens.reshape(-1)
    onehot = jax.nn.one_hot(flat, table.shape[0], dtype=jnp.bfloat16)
    onehot = shard(onehot, "batch", "vocab")
    emb = jnp.einsum(
        "tv,vd->td", onehot, table.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    return emb.reshape(tokens.shape + (table.shape[-1],))


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots
        )
    if policy == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def _stack_layer_params(per_layer):
    """list of per-layer param dicts -> dict of stacked arrays."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def cross_entropy(logits: Array, targets: Array, mask: Array) -> Array:
    """Mean token NLL (fp32). logits (B,S,V) targets (B,S) mask (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass
class BaseLM:
    cfg: ModelConfig
    use_kernels: bool = False
    remat: str = "full"

    # ---- embeddings ------------------------------------------------- #
    def _embed_init(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "tok": L.dense_init(k1, (cfg.vocab_size, cfg.d_model), scale=0.02),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["head"] = L.dense_init(
                k2, (cfg.vocab_size, cfg.d_model), scale=0.02
            )
        return p

    def _embed(self, params, tokens: Array) -> Array:
        emb = sharded_embed_lookup(params["tok"], tokens)
        emb = emb * jnp.sqrt(self.cfg.d_model).astype(jnp.float32)
        return L.cast(shard(emb, "batch", "seq", "embed"))

    def _logits(self, params, h: Array) -> Array:
        h = L.rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        table = params.get("head", params["tok"])
        # bf16 logits: fp32 accumulation inside the matmul, bf16 storage —
        # a fp32 (B, S, V) tensor is the single largest buffer at 200k+
        # vocabs (the convert fuses into the matmul epilogue). The loss
        # upcasts per-element inside its reductions.
        logits = jnp.einsum(
            "bsd,vd->bsv", h, L.cast(table),
            preferred_element_type=jnp.float32,
        ).astype(jnp.bfloat16)
        return shard(logits, "batch", "seq", "vocab")

    # ---- public API -------------------------------------------------- #
    def init(self, key) -> PyTree:
        raise NotImplementedError

    def forward(self, params, batch) -> Tuple[Array, Array]:
        """-> (logits, aux_loss)"""
        raise NotImplementedError

    def loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        logits, aux = self.forward(params, batch)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(batch["targets"], jnp.float32)
        xent = cross_entropy(logits, batch["targets"], mask)
        total = xent + self.cfg.router_aux_weight * aux
        return total, {"xent": xent, "aux": aux}

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        raise NotImplementedError

    def prefill(self, params, batch, cache) -> Tuple[Array, PyTree]:
        raise NotImplementedError

    def decode_step(self, params, token, cache, pos) -> Tuple[Array, PyTree]:
        raise NotImplementedError


# ====================================================================== #
# Uniform attention LM (dense / moe / vlm)
# ====================================================================== #


class LM(BaseLM):
    """All layers are (attention + FFN/MoE) blocks; one scan over the stack."""

    @property
    def dims(self) -> L.AttnDims:
        c = self.cfg
        return L.AttnDims(c.d_model, c.num_heads, c.num_kv_heads, c.head_dim)

    def _layer_statics(self):
        """Per-layer (window, theta) arrays from the pattern."""
        cfg = self.cfg
        windows, thetas = [], []
        for t in cfg.layer_types():
            if t == "L":
                windows.append(cfg.window_size)
                thetas.append(cfg.rope_theta)
            else:
                windows.append(L.GLOBAL_WINDOW)
                thetas.append(cfg.rope_theta_global or cfg.rope_theta)
        return (
            jnp.asarray(windows, jnp.int32),
            jnp.asarray(thetas, jnp.float32),
        )

    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        ka, kf = jax.random.split(key)
        p = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            **L.attn_param_init(ka, self.dims),
        }
        if cfg.num_experts:
            p["moe"] = moe_lib.moe_param_init(
                kf, cfg.d_model, cfg.num_experts, cfg.d_ff_expert,
                cfg.num_shared_experts, cfg.glu,
            )
        else:
            p.update(L.ffn_param_init(kf, cfg.d_model, cfg.d_ff, cfg.glu))
        return p

    def init(self, key) -> PyTree:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 1)
        stacked = _stack_layer_params(
            [self._layer_init(k) for k in keys[: cfg.num_layers]]
        )
        return {"embed": self._embed_init(keys[-1]), "layers": stacked}

    # ---- blocks ------------------------------------------------------ #
    def _ffn_or_moe(self, lp, h) -> Tuple[Array, Array]:
        cfg = self.cfg
        if cfg.num_experts:
            return moe_lib.moe_ffn(
                lp["moe"], h,
                num_experts=cfg.num_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                act=cfg.act, glu=cfg.glu,
            )
        return L.ffn_apply(lp, h, cfg.act, cfg.glu), jnp.float32(0.0)

    def _block_train(self, lp, h, window, theta, positions):
        cfg = self.cfg
        x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp, x, self.dims)
        q = L.rope(q, positions, theta)
        k = L.rope(k, positions, theta)
        o = L.attend(
            q, k, v, positions, positions,
            causal=True, window=window, logit_softcap=cfg.logit_softcap,
        )
        h = h + L.attn_out(lp, o)
        x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        y, aux = self._ffn_or_moe(lp, x)
        h = shard(h + y, "batch", "seq", "embed")
        return h, aux

    # ---- train forward ------------------------------------------------ #
    def forward(self, params, batch) -> Tuple[Array, Array]:
        cfg = self.cfg
        h = self._embed(params["embed"], batch["tokens"])
        if "prefix_embed" in batch:  # vlm: precomputed patch embeddings
            h = jnp.concatenate([L.cast(batch["prefix_embed"]), h], axis=1)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        windows, thetas = self._layer_statics()

        def body(carry, xs):
            h, aux = carry
            lp, window, theta = xs
            h, a = self._block_train(lp, h, window, theta, positions)
            return (h, aux + a), None

        body = _remat(body, self.remat)
        # the aux accumulator becomes manual-axis-varying on the first add
        aux0 = vary_for_manual(jnp.float32(0.0))
        (h, aux), _ = jax.lax.scan(
            body, (h, aux0), (params["layers"], windows, thetas)
        )
        if "prefix_embed" in batch:
            h = h[:, batch["prefix_embed"].shape[1]:, :]
        return self._logits(params["embed"], h), aux / cfg.num_layers

    # ---- serving ------------------------------------------------------ #
    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        shape = (cfg.num_layers, batch_size, max_len,
                 cfg.num_kv_heads, cfg.head_dim)
        k = shard(jnp.zeros(shape, jnp.bfloat16),
                  None, "batch", "kv_seq", "kv", None)
        v = shard(jnp.zeros(shape, jnp.bfloat16),
                  None, "batch", "kv_seq", "kv", None)
        return {"k": k, "v": v}

    def prefill(self, params, batch, cache) -> Tuple[Array, PyTree]:
        cfg = self.cfg
        h = self._embed(params["embed"], batch["tokens"])
        if "prefix_embed" in batch:
            h = jnp.concatenate([L.cast(batch["prefix_embed"]), h], axis=1)
        s = h.shape[1]
        max_len = cache["k"].shape[2]
        positions = jnp.arange(s, dtype=jnp.int32)
        windows, thetas = self._layer_statics()

        def body(h, xs):
            lp, window, theta = xs
            x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            q, k, v = L.attn_qkv(lp, x, self.dims)
            q = L.rope(q, positions, theta)
            k = L.rope(k, positions, theta)
            o = L.attend(q, k, v, positions, positions,
                         causal=True, window=window,
                         logit_softcap=cfg.logit_softcap)
            h = h + L.attn_out(lp, o)
            x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
            y, _ = self._ffn_or_moe(lp, x)
            h = shard(h + y, "batch", "seq", "embed")
            pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
            k_full = shard(jnp.pad(k, pad).astype(jnp.bfloat16),
                           "batch", "kv_seq", "kv", None)
            v_full = shard(jnp.pad(v, pad).astype(jnp.bfloat16),
                           "batch", "kv_seq", "kv", None)
            return h, (k_full, v_full)

        body = _remat(body, "none" if self.remat == "none" else "full")
        h, (ks, vs) = jax.lax.scan(
            body, h, (params["layers"], windows, thetas)
        )
        logits = self._logits(params["embed"], h[:, -1:, :])
        return logits, {"k": ks, "v": vs}

    def decode_step(self, params, token, cache, pos) -> Tuple[Array, PyTree]:
        """token: (B,) int32; pos: scalar int32 (next position to fill)."""
        cfg = self.cfg
        h = self._embed(params["embed"], token[:, None])  # (B, 1, D)
        positions = pos[None].astype(jnp.int32)  # (1,)
        kv_pos = jnp.arange(cache["k"].shape[2], dtype=jnp.int32)
        windows, thetas = self._layer_statics()

        def body(h, xs):
            lp, k_cache, v_cache, window, theta = xs
            x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            q, k, v = L.attn_qkv(lp, x, self.dims)
            q = L.rope(q, positions, theta)
            k = L.rope(k, positions, theta)
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(jnp.bfloat16), (0, pos, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(jnp.bfloat16), (0, pos, 0, 0)
            )
            o = L.attend(q, k_cache, v_cache, positions, kv_pos,
                         causal=True, window=window,
                         logit_softcap=cfg.logit_softcap)
            h = h + L.attn_out(lp, o)
            x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
            y, _ = self._ffn_or_moe(lp, x)
            return h + y, (k_cache, v_cache)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"], windows, thetas)
        )
        logits = self._logits(params["embed"], h)
        return logits[:, 0, :], {"k": ks, "v": vs}


# ====================================================================== #
# RWKV-6 LM (attention-free)
# ====================================================================== #


class RwkvLM(BaseLM):
    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        p = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        p.update(
            rwkv_lib.rwkv_param_init(
                key, cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.d_ff
            )
        )
        return p

    def init(self, key) -> PyTree:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 1)
        stacked = _stack_layer_params(
            [self._layer_init(k) for k in keys[: cfg.num_layers]]
        )
        return {"embed": self._embed_init(keys[-1]), "layers": stacked}

    def _block(self, lp, h, state):
        cfg = self.cfg
        x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        tm_state = None if state is None else {
            "wkv": state["wkv"], "shift_tm": state["shift_tm"]
        }
        y, tm_new = rwkv_lib.rwkv_time_mix(
            lp, x, cfg.num_heads, cfg.head_dim, tm_state, self.use_kernels
        )
        h = h + y
        x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        cm_state = None if state is None else {"shift_cm": state["shift_cm"]}
        y, cm_shift = rwkv_lib.rwkv_channel_mix(
            lp, x, cm_state if state is not None else None
        )
        h = shard(h + y, "batch", "seq", "embed")
        new_state = {
            "wkv": tm_new["wkv"],
            "shift_tm": tm_new["shift_tm"],
            "shift_cm": cm_shift,
        }
        return h, new_state

    def forward(self, params, batch) -> Tuple[Array, Array]:
        h = self._embed(params["embed"], batch["tokens"])

        def body(h, lp):
            h, _ = self._block(lp, h, None)
            return h, None

        body = _remat(body, self.remat)
        h, _ = jax.lax.scan(body, h, params["layers"])
        return self._logits(params["embed"], h), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        lshape = (cfg.num_layers, batch_size)
        return {
            "wkv": jnp.zeros(
                lshape + (cfg.num_heads, cfg.head_dim, cfg.head_dim),
                jnp.float32,
            ),
            "shift_tm": jnp.zeros(lshape + (cfg.d_model,), jnp.float32),
            "shift_cm": jnp.zeros(lshape + (cfg.d_model,), jnp.float32),
        }

    def _run_with_state(self, params, h, cache):
        def body(h, xs):
            lp, st = xs
            h, new_st = self._block(lp, h, st)
            return h, new_st

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
        return h, new_cache

    def prefill(self, params, batch, cache) -> Tuple[Array, PyTree]:
        h = self._embed(params["embed"], batch["tokens"])
        h, new_cache = self._run_with_state(params, h, cache)
        return self._logits(params["embed"], h[:, -1:, :]), new_cache

    def decode_step(self, params, token, cache, pos) -> Tuple[Array, PyTree]:
        h = self._embed(params["embed"], token[:, None])
        h, new_cache = self._run_with_state(params, h, cache)
        logits = self._logits(params["embed"], h)
        return logits[:, 0, :], new_cache


# ====================================================================== #
# Hybrid (Griffin / recurrentgemma): periodic pattern "RRL"
# ====================================================================== #


class HybridLM(BaseLM):
    """Scan over full pattern periods + unrolled tail layers."""

    @property
    def dims(self) -> L.AttnDims:
        c = self.cfg
        return L.AttnDims(c.d_model, c.num_heads, c.num_kv_heads, c.head_dim)

    def _one_layer_init(self, key, ltype: str) -> dict:
        cfg = self.cfg
        ka, kf = jax.random.split(key)
        p = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if ltype == "R":
            p.update(
                rglru_lib.rglru_param_init(
                    ka, cfg.d_model, cfg.lru_width or cfg.d_model,
                    cfg.conv_width,
                )
            )
        else:
            p.update(L.attn_param_init(ka, self.dims))
        p.update(L.ffn_param_init(kf, cfg.d_model, cfg.d_ff, cfg.glu))
        return p

    def _split(self):
        cfg = self.cfg
        period = len(cfg.layer_pattern)
        n_full = cfg.num_layers // period
        tail = cfg.layer_types()[n_full * period:]
        return period, n_full, tail

    def init(self, key) -> PyTree:
        cfg = self.cfg
        period, n_full, tail = self._split()
        pat = cfg.layer_pattern
        keys = jax.random.split(key, n_full * period + len(tail) + 1)
        periods = []
        for i in range(n_full):
            periods.append(
                {
                    f"l{j}": self._one_layer_init(keys[i * period + j], pat[j])
                    for j in range(period)
                }
            )
        params = {
            "embed": self._embed_init(keys[-1]),
            "periods": _stack_layer_params(periods),
            "tail": [
                self._one_layer_init(keys[n_full * period + j], t)
                for j, t in enumerate(tail)
            ],
        }
        return params

    def _attn_layer(self, lp, h, positions, k_cache=None, v_cache=None,
                    cache_positions=None, pos=None):
        """Local-attention layer; rolling window cache when serving.

        Prefill (static seq len > 1) attends over the full sequence and then
        writes only the trailing window into the rolling cache; decode
        updates one slot (slot = position % window)."""
        cfg = self.cfg
        x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp, x, self.dims)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        window = jnp.int32(cfg.window_size or L.GLOBAL_WINDOW)
        if k_cache is None:
            o = L.attend(q, k, v, positions, positions,
                         causal=True, window=window)
            new_cache = None
        elif q.shape[1] > 1:  # prefill into a rolling cache
            o = L.attend(q, k, v, positions, positions,
                         causal=True, window=window)
            k_roll, v_roll, p_roll = _roll_window_cache(
                k, v, positions, k_cache.shape[1]
            )
            new_cache = (k_roll, v_roll, p_roll)
        else:
            w = k_cache.shape[1]
            slot = pos % w
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(jnp.bfloat16), (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(jnp.bfloat16), (0, slot, 0, 0))
            cache_positions = jax.lax.dynamic_update_slice(
                cache_positions, positions[None, :].astype(jnp.int32),
                (0, slot))
            o = L.attention_scores(
                q, k_cache, v_cache, positions, cache_positions[0],
                causal=True, window=window,
                k_valid_len=None,
            )
            new_cache = (k_cache, v_cache, cache_positions)
        h = h + L.attn_out(lp, o)
        return h, new_cache

    def _layer(self, lp, ltype, h, positions, state=None, pos=None):
        cfg = self.cfg
        if ltype == "R":
            x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            y, new_state = rglru_lib.rglru_block(
                lp, x, state, self.use_kernels
            )
            h = h + y
        else:
            if state is None:
                h, new_state = self._attn_layer(lp, h, positions)
            else:
                h, new_state = self._attn_layer(
                    lp, h, positions,
                    k_cache=state["k"], v_cache=state["v"],
                    cache_positions=state["pos"], pos=pos,
                )
                new_state = {"k": new_state[0], "v": new_state[1],
                             "pos": new_state[2]}
        x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = shard(h + L.ffn_apply(lp, x, cfg.act, cfg.glu),
                  "batch", "seq", "embed")
        return h, new_state

    def forward(self, params, batch) -> Tuple[Array, Array]:
        cfg = self.cfg
        pat = cfg.layer_pattern
        period, n_full, tail = self._split()
        h = self._embed(params["embed"], batch["tokens"])
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)

        def body(h, plp):
            for j, t in enumerate(pat):
                h, _ = self._layer(plp[f"l{j}"], t, h, positions)
            return h, None

        body = _remat(body, self.remat)
        h, _ = jax.lax.scan(body, h, params["periods"])
        for lp, t in zip(params["tail"], tail):
            h, _ = self._layer(lp, t, h, positions)
        return self._logits(params["embed"], h), jnp.float32(0.0)

    # ---- serving ------------------------------------------------------ #
    def _empty_states(self, batch_size: int):
        """Per-layer-type state prototypes."""
        cfg = self.cfg
        w = cfg.lru_width or cfg.d_model
        win = cfg.window_size
        r_state = lambda: {
            "h": jnp.zeros((batch_size, w), jnp.float32),
            "conv": jnp.zeros((batch_size, cfg.conv_width - 1, w), jnp.float32),
        }
        a_state = lambda: {
            "k": jnp.zeros((batch_size, win, cfg.num_kv_heads, cfg.head_dim),
                           jnp.bfloat16),
            "v": jnp.zeros((batch_size, win, cfg.num_kv_heads, cfg.head_dim),
                           jnp.bfloat16),
            "pos": -jnp.ones((1, win), jnp.int32),
        }
        return r_state, a_state

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        period, n_full, tail = self._split()
        r_state, a_state = self._empty_states(batch_size)
        mk = lambda t: r_state() if t == "R" else a_state()
        periods = [
            {f"l{j}": mk(t) for j, t in enumerate(cfg.layer_pattern)}
            for _ in range(n_full)
        ]
        return {
            "periods": jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *periods
            ) if n_full > 1 else jax.tree.map(lambda x: x[None], periods[0]),
            "tail": [mk(t) for t in tail],
        }

    def _run_serving(self, params, h, cache, positions, pos):
        cfg = self.cfg
        pat = cfg.layer_pattern
        period, n_full, tail = self._split()

        def body(h, xs):
            plp, pst = xs
            new_states = {}
            for j, t in enumerate(pat):
                h, st = self._layer(
                    plp[f"l{j}"], t, h, positions, pst[f"l{j}"], pos
                )
                new_states[f"l{j}"] = st
            return h, new_states

        h, new_periods = jax.lax.scan(
            body, h, (params["periods"], cache["periods"])
        )
        new_tail = []
        for lp, t, st in zip(params["tail"], tail, cache["tail"]):
            h, st_new = self._layer(lp, t, h, positions, st, pos)
            new_tail.append(st_new)
        return h, {"periods": new_periods, "tail": new_tail}

    def prefill(self, params, batch, cache) -> Tuple[Array, PyTree]:
        h = self._embed(params["embed"], batch["tokens"])
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        h, new_cache = self._run_serving(
            params, h, cache, positions, jnp.int32(0)
        )
        return self._logits(params["embed"], h[:, -1:, :]), new_cache

    def decode_step(self, params, token, cache, pos) -> Tuple[Array, PyTree]:
        h = self._embed(params["embed"], token[:, None])
        positions = pos[None].astype(jnp.int32)
        h, new_cache = self._run_serving(params, h, cache, positions, pos)
        logits = self._logits(params["embed"], h)
        return logits[:, 0, :], new_cache


# prefill for the hybrid rolling cache writes only the last `window` keys; we
# realize that by running the full sequence statefully (the recurrence needs
# every token anyway) and rolling attention caches inside _attn_layer via
# dynamic updates per sequence... for whole-sequence prefill we instead write
# the cache from the final window slice:


def _roll_window_cache(k, v, positions, window):
    """Take the last `window` keys of a prefill and place them at their
    rolling slots (slot = position % window)."""
    s = k.shape[1]
    w = window
    take = min(s, w)
    ks = k[:, s - take:, :, :]
    vs = v[:, s - take:, :, :]
    pos_tail = positions[s - take:]
    slots = pos_tail % w
    b = k.shape[0]
    k_out = jnp.zeros((b, w) + k.shape[2:], jnp.bfloat16)
    v_out = jnp.zeros((b, w) + v.shape[2:], jnp.bfloat16)
    p_out = -jnp.ones((1, w), jnp.int32)
    k_out = k_out.at[:, slots].set(ks.astype(jnp.bfloat16))
    v_out = v_out.at[:, slots].set(vs.astype(jnp.bfloat16))
    p_out = p_out.at[0, slots].set(pos_tail.astype(jnp.int32))
    return k_out, v_out, p_out


# ====================================================================== #
# Encoder-decoder (whisper)
# ====================================================================== #


class EncDecLM(BaseLM):
    @property
    def dims(self) -> L.AttnDims:
        c = self.cfg
        return L.AttnDims(c.d_model, c.num_heads, c.num_kv_heads, c.head_dim)

    def _enc_layer_init(self, key):
        ka, kf = jax.random.split(key)
        cfg = self.cfg
        p = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            **L.attn_param_init(ka, self.dims),
        }
        p.update(L.ffn_param_init(kf, cfg.d_model, cfg.d_ff, cfg.glu))
        return p

    def _dec_layer_init(self, key):
        ka, kc, kf = jax.random.split(key, 3)
        cfg = self.cfg
        p = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "cross_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            **L.attn_param_init(ka, self.dims),
        }
        cross = L.attn_param_init(kc, self.dims)
        p.update({f"x_{k}": v for k, v in cross.items()})
        p.update(L.ffn_param_init(kf, cfg.d_model, cfg.d_ff, cfg.glu))
        return p

    def init(self, key) -> PyTree:
        cfg = self.cfg
        ks = jax.random.split(key, cfg.encoder_layers + cfg.num_layers + 2)
        enc = _stack_layer_params(
            [self._enc_layer_init(k) for k in ks[: cfg.encoder_layers]]
        )
        dec = _stack_layer_params(
            [
                self._dec_layer_init(k)
                for k in ks[cfg.encoder_layers: cfg.encoder_layers + cfg.num_layers]
            ]
        )
        return {
            "embed": self._embed_init(ks[-1]),
            "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "encoder": enc,
            "decoder": dec,
        }

    def _encode(self, params, frames: Array) -> Array:
        """frames: (B, F, D) stub embeddings (conv frontend output)."""
        cfg = self.cfg
        h = L.cast(frames)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)

        def body(h, lp):
            x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            q, k, v = L.attn_qkv(lp, x, self.dims)
            o = L.attend(q, k, v, positions, positions, causal=False)
            h = h + L.attn_out(lp, o)
            x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
            h = h + L.ffn_apply(lp, x, cfg.act, cfg.glu)
            return h, None

        body = _remat(body, self.remat)
        h, _ = jax.lax.scan(body, h, params["encoder"])
        return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _dec_block(self, lp, h, enc_out, positions, k_cache=None,
                   v_cache=None, pos=None):
        cfg = self.cfg
        kv_pos = None
        x = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp, x, self.dims)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        if k_cache is not None:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(jnp.bfloat16), (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(jnp.bfloat16), (0, pos, 0, 0))
            kv_pos = jnp.arange(k_cache.shape[1], dtype=jnp.int32)
            o = L.attend(q, k_cache, v_cache, positions, kv_pos, causal=True)
            kv_out = (k_cache, v_cache)
        else:
            o = L.attend(q, k, v, positions, positions, causal=True)
            kv_out = (k, v)  # prefill caches exactly what attention used
        h = h + L.attn_out(lp, o)
        # cross attention
        x = L.rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        xq = (x @ L.cast(lp["x_wq"])).reshape(
            x.shape[0], x.shape[1], cfg.num_heads, cfg.head_dim)
        xk = (enc_out @ L.cast(lp["x_wk"])).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        xv = (enc_out @ L.cast(lp["x_wv"])).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        o = L.attend(xq, xk, xv, positions, epos, causal=False)
        h = h + o.reshape(x.shape[0], x.shape[1], -1) @ L.cast(lp["x_wo"])
        x = L.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + L.ffn_apply(lp, x, cfg.act, cfg.glu)
        return h, kv_out

    def forward(self, params, batch) -> Tuple[Array, Array]:
        enc_out = self._encode(params, batch["frames"])
        h = self._embed(params["embed"], batch["tokens"])
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)

        def body(h, lp):
            h, _ = self._dec_block(lp, h, enc_out, positions)
            return h, None

        body = _remat(body, self.remat)
        h, _ = jax.lax.scan(body, h, params["decoder"])
        return self._logits(params["embed"], h), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        shape = (cfg.num_layers, batch_size, max_len,
                 cfg.num_kv_heads, cfg.head_dim)
        return {
            "k": shard(jnp.zeros(shape, jnp.bfloat16),
                       None, "batch", "kv_seq", "kv", None),
            "v": shard(jnp.zeros(shape, jnp.bfloat16),
                       None, "batch", "kv_seq", "kv", None),
            "enc_out": jnp.zeros(
                (batch_size, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
            ),
        }

    def prefill(self, params, batch, cache) -> Tuple[Array, PyTree]:
        cfg = self.cfg
        enc_out = self._encode(params, batch["frames"])
        h = self._embed(params["embed"], batch["tokens"])
        s = h.shape[1]
        max_len = cache["k"].shape[2]
        positions = jnp.arange(s, dtype=jnp.int32)

        def body(h, lp):
            h, (k, v) = self._dec_block(lp, h, enc_out, positions)
            pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
            return h, (jnp.pad(k, pad).astype(jnp.bfloat16),
                       jnp.pad(v, pad).astype(jnp.bfloat16))

        h, (ks, vs) = jax.lax.scan(body, h, params["decoder"])
        logits = self._logits(params["embed"], h[:, -1:, :])
        return logits, {"k": ks, "v": vs, "enc_out": enc_out}

    def decode_step(self, params, token, cache, pos) -> Tuple[Array, PyTree]:
        h = self._embed(params["embed"], token[:, None])
        positions = pos[None].astype(jnp.int32)
        enc_out = L.cast(cache["enc_out"])

        def body(h, xs):
            lp, k_cache, v_cache = xs
            h, (k_new, v_new) = self._dec_block(
                lp, h, enc_out, positions, k_cache, v_cache, pos
            )
            return h, (k_new, v_new)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["decoder"], cache["k"], cache["v"])
        )
        logits = self._logits(params["embed"], h)
        return logits[:, 0, :], {"k": ks, "v": vs, "enc_out": cache["enc_out"]}


# ====================================================================== #
# factory + parameter accounting
# ====================================================================== #


def build_model(cfg: ModelConfig, use_kernels: bool = False,
                remat: str = "full") -> BaseLM:
    types = set(cfg.layer_types())
    if cfg.is_encdec:
        cls = EncDecLM
    elif types == {"W"}:
        cls = RwkvLM
    elif "R" in types:
        cls = HybridLM
    else:
        cls = LM
    return cls(cfg=cfg, use_kernels=use_kernels, remat=remat)


def param_shapes(model: BaseLM, key=None) -> PyTree:
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(model.init, key)


def count_params(model: BaseLM) -> int:
    tree = param_shapes(model)
    return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(tree))


def count_active_params(model: BaseLM) -> int:
    """Per-token activated params (MoE experts scaled by top_k / E)."""
    cfg = model.cfg
    tree = param_shapes(model)
    total = 0

    def walk(path, leaf):
        nonlocal total
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        n = int(math.prod(leaf.shape))
        if name.startswith("we_") and cfg.num_experts:
            n = n * cfg.top_k // cfg.num_experts
        total += n
        return leaf

    jax.tree_util.tree_map_with_path(walk, tree)
    return total
