"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(z_t @ W_a + b_a)          recurrence gate
    i_t = sigmoid(z_t @ W_x + b_x)          input gate
    a_t = exp(-c * softplus(lam) * r_t)     c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * z_t)

where z is the input branch after a width-``conv_width`` causal temporal
conv. The recurrence is elementwise-diagonal, hence expressible as an
associative scan (parallel depth log T); the Pallas kernel blocks it over
time with a carried state. Decode carries (h (B,W), conv tail (B,cw-1,W)).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import cast, dense_init

Array = jax.Array
C_FACTOR = 8.0


def rglru_param_init(key, d_model: int, width: int, conv_width: int) -> dict:
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d_model, width)),
        "w_gate_br": dense_init(ks[1], (d_model, width)),
        "conv_w": dense_init(ks[2], (conv_width, width), scale=0.1),
        "conv_b": jnp.zeros((width,), jnp.float32),
        "w_a": dense_init(ks[3], (width, width), scale=0.01),
        "w_x": dense_init(ks[4], (width, width), scale=0.01),
        "gate_b": jnp.zeros((2, width), jnp.float32),
        # lam init so a^c in (0.9, 0.999) at r=1 (Griffin Sec. 2.4)
        "lam": jnp.log(jnp.expm1(-jnp.log(0.97) / C_FACTOR))
        * jnp.ones((width,), jnp.float32),
        "w_out": dense_init(ks[5], (width, d_model)),
    }


def causal_conv1d(z: Array, w: Array, b: Array,
                  tail: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal temporal conv. z: (B, T, W); w: (cw, W).

    ``tail``: (B, cw-1, W) carried context from previous tokens (decode).
    Returns (out, new_tail).
    """
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((z.shape[0], cw - 1, z.shape[2]), z.dtype)
    zp = jnp.concatenate([tail, z], axis=1)  # (B, T+cw-1, W)
    out = sum(
        zp[:, i : i + z.shape[1], :] * w[i][None, None, :] for i in range(cw)
    )
    return out + b, zp[:, -(cw - 1):, :] if cw > 1 else tail


def rglru_scan_ref(a: Array, x_in: Array, h0: Array) -> Tuple[Array, Array]:
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + x_t via associative scan.

    a, x_in: (B, T, W); h0: (B, W). Returns (h (B,T,W), h_last)."""

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    # fold h0 into the first step
    x_in = x_in.at[:, 0, :].add(a[:, 0, :] * h0)
    a_s, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    return h, h[:, -1, :]


def rglru_block(
    params: dict,
    x: Array,
    state: Optional[dict] = None,
    use_kernel: bool = False,
) -> Tuple[Array, dict]:
    """Full recurrent block: gate branch x conv+RG-LRU branch. x: (B,T,D)."""
    xf = x.astype(jnp.float32)
    gate = jax.nn.gelu(xf @ params["w_gate_br"], approximate=True)

    z = xf @ params["w_in"]
    tail = None if state is None else state["conv"]
    z, new_tail = causal_conv1d(z, params["conv_w"], params["conv_b"], tail)

    r = jax.nn.sigmoid(z @ params["w_a"] + params["gate_b"][0])
    i = jax.nn.sigmoid(z @ params["w_x"] + params["gate_b"][1])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r  # (B, T, W)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed in log space for numerical stability
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated_in = beta * (i * z)

    h0 = (
        jnp.zeros((x.shape[0], z.shape[-1]), jnp.float32)
        if state is None
        else state["h"]
    )
    if use_kernel:
        from repro.kernels import ops as kops

        h, h_last = kops.rglru_scan(a, gated_in, h0)
    else:
        h, h_last = rglru_scan_ref(a, gated_in, h0)

    out = (h * gate) @ params["w_out"]
    return out.astype(x.dtype), {"h": h_last, "conv": new_tail}
