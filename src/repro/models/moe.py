"""Mixture-of-Experts FFN (deepseek-moe fine-grained, phi3.5-moe top-2).

Dense-dispatch formulation (MaxText-style): tokens are scattered to experts
through a (tokens, experts, capacity) combine tensor built from pure einsums
and one-hots — no data-dependent scatter/gather, so XLA SPMD partitions it
cleanly: tokens shard over (pod, data), experts over model. The expert-
parallel communication (all-to-all equivalent) materializes as the
contraction over the token dim in the dispatch einsum plus the expert-sharded
FFN matmuls.

Capacity dropping: each expert processes at most
``capacity = ceil(tokens_per_shard * top_k / E) * capacity_factor`` tokens;
overflow tokens fall back to (gate-weighted) zero contribution, standard for
TPU MoE. The router runs in fp32.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import activation, cast, dense_init

Array = jax.Array


def moe_param_init(key, d_model: int, num_experts: int, d_ff: int,
                   num_shared: int, glu: bool) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, num_experts), scale=0.02),
        "we_up": dense_init(ks[1], (num_experts, d_model, d_ff)),
        "we_down": dense_init(ks[2], (num_experts, d_ff, d_model)),
    }
    if glu:
        p["we_gate"] = dense_init(ks[3], (num_experts, d_model, d_ff))
    if num_shared:
        f_shared = num_shared * d_ff
        p["w_up"] = dense_init(ks[4], (d_model, f_shared))
        p["w_down"] = dense_init(ks[5], (f_shared, d_model))
        if glu:
            p["w_gate"] = dense_init(
                jax.random.fold_in(ks[5], 1), (d_model, f_shared)
            )
    return p


def capacity_for(tokens: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(math.ceil(tokens * top_k / num_experts * capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly tiling


#: token groups for local-capacity dispatch; matches the production batch
#: sharding (pod 2 x data 16) so per-group counting is per-shard counting.
DISPATCH_GROUPS = 32

#: §Perf iteration knob: when set, group size targets ~this many tokens.
#: The dispatch/combine einsums cost 2*T*E*C*D with C ~ Tg*top_k/E — LINEAR
#: in the group size — so shrinking Tg from 32k to 2k cuts dispatch FLOPs
#: ~16x while keeping groups batch-shard-aligned (multiples of 32).
DISPATCH_TARGET_TG = None


def _num_groups(t: int) -> int:
    if DISPATCH_TARGET_TG:
        g = min(t, max(DISPATCH_GROUPS, t // DISPATCH_TARGET_TG))
    else:
        g = min(DISPATCH_GROUPS, t)
    while t % g:
        g -= 1
    return g


def moe_ffn(
    params: dict,
    x: Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float,
    act: str,
    glu: bool,
) -> Tuple[Array, Array]:
    """Apply the MoE FFN. x: (B, S, D) -> (y, aux_loss).

    Grouped local-capacity dispatch: tokens are split into G groups aligned
    with the batch sharding; expert positions are counted *within a group*
    and each expert's capacity is per-group. The (G, Tg, E, Cg) combine
    tensor shards over (batch-axes, -, model, -), all dispatch/expert einsums
    are shard-local, and the only communication is the model-axis all-reduce
    of the combined output — identical in structure to a TP FFN. (A global-
    capacity formulation materializes a C ~ T_global dimension on every
    device: 16x the memory at 1M-token steps.)
    """
    b, s, d = x.shape
    t = b * s
    g = _num_groups(t)
    tg = t // g
    xf = x.reshape(g, tg, d)
    xf = shard(xf, "batch", None, "embed")

    # --- routing (fp32) ---
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    gates, ids = jax.lax.top_k(probs, top_k)  # (G, Tg, K)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # renormalize

    # --- load-balancing aux loss (Switch-style) ---
    density = jnp.mean(jax.nn.one_hot(ids[..., 0], num_experts), axis=(0, 1))
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = num_experts * jnp.sum(density * router_mean)

    # --- per-group positions within experts ---
    capacity = capacity_for(tg, num_experts, top_k, capacity_factor)
    oh_e = jax.nn.one_hot(ids, num_experts, dtype=jnp.int32)  # (G, Tg, K, E)
    flat = oh_e.reshape(g, tg * top_k, num_experts)
    pos_flat = jnp.cumsum(flat, axis=1) - 1  # (G, Tg*K, E)
    pos = jnp.take_along_axis(
        pos_flat.reshape(g, tg, top_k, num_experts),
        ids[..., None],
        axis=-1,
    )[..., 0]  # (G, Tg, K)
    keep = pos < capacity

    # --- combine tensor (G, Tg, E, Cg), gate-weighted ---
    combine = jnp.zeros((g, tg, num_experts, capacity), jnp.bfloat16)
    for k in range(top_k):  # static unroll avoids a (…, K, E, C) intermediate
        e_k = jax.nn.one_hot(ids[..., k], num_experts, dtype=jnp.bfloat16)
        c_k = jax.nn.one_hot(pos[..., k], capacity, dtype=jnp.bfloat16)
        w_k = (gates[..., k] * keep[..., k]).astype(jnp.bfloat16)
        combine = combine + jnp.einsum(
            "gte,gtc->gtec", e_k * w_k[..., None], c_k
        )
    combine = shard(combine, "batch", None, "expert", "cap")
    dispatch = (combine > 0).astype(jnp.bfloat16)

    # --- expert computation (batched over groups; all shard-local) ---
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, cast(xf))
    xe = shard(xe, "batch", "expert", "cap", "embed")
    up = jnp.einsum("gecd,edf->gecf", xe, cast(params["we_up"]))
    if glu:
        gate = activation(
            jnp.einsum("gecd,edf->gecf", xe, cast(params["we_gate"])), act
        )
        h = gate * up
    else:
        h = activation(up, act)
    h = shard(h, "batch", "expert", "cap", "moe_mlp")
    ye = jnp.einsum("gecf,efd->gecd", h, cast(params["we_down"]))
    # contraction over e (model-sharded) => the one all-reduce, like TP FFN
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)

    # --- shared experts (deepseek): dense FFN over all tokens ---
    if "w_up" in params:
        up_s = cast(xf) @ cast(params["w_up"])
        up_s = shard(up_s, "batch", None, "mlp")
        if glu:
            h_s = activation(cast(xf) @ cast(params["w_gate"]), act) * up_s
        else:
            h_s = activation(up_s, act)
        y = y + h_s @ cast(params["w_down"])

    return y.reshape(b, s, d).astype(x.dtype), aux
