"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all five families (dense / moe / ssm / hybrid /
vlm / audio); family-specific fields are zero/empty when unused. Layer
heterogeneity is expressed with ``layer_pattern`` over single-character block
codes:

    'G' global (full causal) attention        'L' local (sliding-window) attn
    'R' RG-LRU recurrent block (Griffin)      'W' RWKV-6 time-mix block

The pattern tiles across ``num_layers`` (e.g. gemma3's 5:1 local:global is
"LLLLLG"; recurrentgemma's 2:1 recurrent:attention is "RRL").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int  # query heads (attention layers); wkv heads for rwkv
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer pattern / attention ---
    layer_pattern: str = "G"
    window_size: int = 0  # sliding window for 'L' layers
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3 uses 1M for global layers
    logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- recurrent families ---
    lru_width: int = 0  # RG-LRU hidden width
    conv_width: int = 4  # temporal conv in recurrent block

    # --- encoder-decoder / frontends ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder length (whisper: 1500 frames)
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_prefix_tokens: int = 0  # vision patch tokens prepended (paligemma)

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU / plain)
    glu: bool = True

    # long-context eligibility (sub-quadratic attention path exists)
    supports_long_context: bool = False

    # ------------------------------------------------------------------ #

    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer block codes, pattern tiled to num_layers."""
        pat = self.layer_pattern
        reps = math.ceil(self.num_layers / len(pat))
        return tuple((pat * reps)[: self.num_layers])

    @property
    def attention_free(self) -> bool:
        return all(t == "W" for t in self.layer_types())

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # NOTE: exact parameter counts (total / per-token-active) are computed
    # from the real init tree via jax.eval_shape in repro.models.model
    # (count_params / count_active_params) so they can never drift from the
    # implementation.


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd + train step)."""
    pat_period = len(cfg.layer_pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=max(2, pat_period),
        d_model=64,
        num_heads=2,
        num_kv_heads=min(2, max(1, cfg.num_kv_heads)),
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        lru_width=64 if cfg.lru_width else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        num_prefix_tokens=8 if cfg.num_prefix_tokens else 0,
    )
