"""Model zoo: dense / MoE / SSM / hybrid / VLM / audio families, pure JAX."""
