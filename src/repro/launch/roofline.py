"""Roofline-term derivation from dry-run artifacts.

Hardware constants (TPU v5e-class target):
    peak compute  197 TFLOP/s bf16 per chip
    HBM bandwidth 819 GB/s per chip
    ICI           ~50 GB/s per link per chip
    DCN           25 GB/s aggregate per pod pair (multi-pod cells)

Term semantics (the compiled module is the per-device SPMD program, so
cost_analysis FLOPs/bytes and parsed collective operand sizes are all
*per-device* quantities):

    compute    = flops_per_device / PEAK
    memory     = hbm_bytes_per_device / HBM_BW
    collective = ici_bytes_per_device / ICI_BW
               + dcn_bytes_per_device * n_devices / (DCN_BW * n_pod_pairs)

MODEL_FLOPS (global, useful): 6*N_active*tokens for a train step (fwd+bwd),
2*N_active*tokens for prefill, 2*N_active*batch for one decode step. The
ratio MODEL_FLOPS / (flops_per_device * n_devices) exposes remat/redundancy
waste.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9

ART_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts",
    "dryrun",
)

_SHAPE_TOKENS = {
    "train_4k": ("train", 4096 * 256),
    "prefill_32k": ("prefill", 32768 * 32),
    "decode_32k": ("decode", 128),
    "long_500k": ("decode", 1),
}


def _loop_chain(arch: str, shape: str, accum: int = 8):
    """Static while-loop trip counts, outermost first, for this cell.

    train:   [accum=8, layer_scan, attn_q_chunks]
    prefill: [layer_scan, attn_q_chunks]
    decode:  [layer_scan]
    Layer-scan length = scan trips actually emitted (periods for hybrids).
    """
    from repro.configs import get_config

    cfg = get_config(arch)
    period = len(cfg.layer_pattern)
    l_eff = cfg.num_layers // period if period > 1 else cfg.num_layers
    if cfg.is_encdec:
        l_eff = max(cfg.num_layers, cfg.encoder_layers)
    seq = {"train_4k": 4096, "prefill_32k": 32768,
           "decode_32k": 1, "long_500k": 1}[shape]
    q_chunks = max(1, seq // 1024) if seq > 2048 else 1
    if shape == "train_4k":
        return [accum, l_eff, q_chunks]
    if shape == "prefill_32k":
        return [l_eff, q_chunks]
    return [l_eff]


def _cum_factor(chain, depth: int) -> float:
    f = 1.0
    for i in range(min(depth, len(chain))):
        f *= chain[i]
    return f


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    ici_s: float
    dcn_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    step_time_s: float
    roofline_fraction: float  # compute_s / step bound (1.0 = at the roof)
    note: str = ""

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
            f"{self.collective_s*1e3:.2f} | {self.bottleneck} | "
            f"{self.useful_ratio:.2f} | {self.roofline_fraction:.2f} |"
        )


def derive(record: Dict) -> Optional[Roofline]:
    if record.get("status") != "ok":
        return None
    arch, shape, mesh = record["arch"], record["shape"], record["mesh"]
    n_dev = record["n_devices"]
    flops_dev_xla = max(record["flops_per_device"], 0.0)
    bytes_dev = max(record["bytes_per_device"], 0.0)
    colls = record.get("collectives", {})
    ici_b = colls.get("ici_bytes", 0)
    dcn_b = colls.get("dcn_bytes", 0)
    n_pods = 2 if mesh == "multi" else 1

    # Trip-count-aware accounting (XLA cost_analysis counts while bodies
    # once — launch/flops_audit.py):
    #  * FLOPs: exact jaxpr audit.
    #  * HBM bytes: audited dot-operand traffic (trip-aware) + the fused
    #    module's bytes once (non-dot / out-of-loop traffic).
    #  * collectives: per-op bytes multiplied by the static trip count of
    #    the while-nesting depth the op sits at (metadata scope), using the
    #    cell's known loop chain. DCN grad-sync psums sit at depth 0.
    audit_global = record.get("flops_audit_global", 0.0)
    flops_dev = (
        audit_global / n_dev if audit_global > 0 else flops_dev_xla
    )
    dot_bytes_dev = record.get("dot_bytes_audit_global", 0.0) / n_dev
    bytes_dev_c = dot_bytes_dev + bytes_dev

    chain = _loop_chain(arch, shape, accum=record.get("accum_steps", 8))
    by_depth = colls.get("by_depth")
    if by_depth:
        ici_c = dcn_c = 0.0
        for d_str, v in by_depth.items():
            f = _cum_factor(chain, int(d_str))
            ici_c += v["ici"] * f
            dcn_c += v["dcn"] * f
    else:  # legacy artifact: flop-ratio fallback
        corr = max(
            flops_dev / flops_dev_xla if flops_dev_xla > 0 else 1.0, 1.0
        )
        ici_c, dcn_c = ici_b * corr, dcn_b

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev_c / HBM_BW
    ici_s = ici_c / ICI_BW
    dcn_s = dcn_c * n_dev / (DCN_BW * max(n_pods - 1, 1)) if dcn_c else 0.0
    collective_s = ici_s + dcn_s

    kind, tokens = _SHAPE_TOKENS[shape]
    n_active = record.get("active_params", 0)
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_total = flops_dev * n_dev
    useful = model_flops / hlo_total if hlo_total else 0.0

    step = max(compute_s, memory_s, collective_s)
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    bottleneck = max(terms, key=terms.get)
    frac = compute_s / step if step else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        ici_s=ici_s, dcn_s=dcn_s,
        bottleneck=bottleneck,
        model_flops=model_flops, hlo_flops_total=hlo_total,
        useful_ratio=useful, step_time_s=step, roofline_fraction=frac,
    )


def load_all(mesh: str = "single") -> List[Dict]:
    d = os.path.join(ART_DIR, mesh)
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def report(mesh: str = "single") -> str:
    rows = []
    skips = []
    errors = []
    for rec in load_all(mesh):
        r = derive(rec)
        if r is not None:
            rows.append(r)
        elif rec.get("status") == "skip":
            skips.append(rec)
        else:
            errors.append(rec)
    lines = [
        f"### Roofline — {mesh}-pod mesh "
        f"({'2x16x16' if mesh == 'multi' else '16x16'})",
        "",
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms)"
        " | bottleneck | useful FLOP ratio | roofline fraction |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        lines.append(r.row())
    if skips:
        lines.append("")
        lines.append("Documented skips:")
        for s in skips:
            lines.append(f"- {s['arch']} x {s['shape']}: {s['reason']}")
    if errors:
        lines.append("")
        lines.append("ERRORS (bugs to fix):")
        for e in errors:
            lines.append(f"- {e['arch']} x {e['shape']}: {e.get('error','?')}")
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    print(report(args.mesh))


if __name__ == "__main__":
    main()
