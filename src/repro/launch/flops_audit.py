"""Exact FLOP accounting by walking the jaxpr (trip-count aware).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers / microbatch-accumulation model underreports FLOPs by
~L x accum (verified empirically: a scan of 10 matmuls reports 1 matmul).
This auditor traces the step function abstractly (no allocation, no
compile) and counts dot/conv FLOPs recursively, multiplying scan bodies by
their static lengths and shard_map bodies by the manual-axis mesh size.

The audit/XLA flop ratio also serves as the trip-count correction factor for
cost_analysis byte counts and in-loop collective bytes (loop bodies dominate
both, so the first-order correction is shared; EXPERIMENTS.md states this).
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Any

import jax
import numpy as np
from jax import core as jcore


def _eqn_io_bytes(eqn) -> float:
    """Operand + result bytes of one dot/conv (HBM traffic proxy)."""
    total = 0.0
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = v.aval
        if hasattr(aval, "shape"):
            total += math.prod(aval.shape) * aval.dtype.itemsize
    return total


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (v.aval for v in eqn.invars[:2])
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(
        lhs.shape[d]
        for d in range(len(lhs.shape))
        if d not in lc and d not in lb
    )
    n = math.prod(
        rhs.shape[d]
        for d in range(len(rhs.shape))
        if d not in rc and d not in rb
    )
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * output elements * kernel elements / out_channels
    kernel = math.prod(rhs.shape)
    out_elems = math.prod(out.shape)
    oc = rhs.shape[-1] if rhs.shape else 1
    return 2.0 * out_elems * kernel / max(oc, 1)


_CALL_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")


def _walk(jaxpr):
    """-> (flops, dot_io_bytes), recursive, trip-count aware."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    flops = 0.0
    dbytes = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
            dbytes += _eqn_io_bytes(eqn)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
            dbytes += _eqn_io_bytes(eqn)
        elif name == "scan":
            length = eqn.params.get("length", 1)
            f, b = _walk(eqn.params["jaxpr"])
            flops += length * f
            dbytes += length * b
        elif name == "while":
            # we never emit raw unbounded whiles from model code; a scan
            # lowered early would land here — count once and let the caller
            # know via the xla ratio.
            f, b = _walk(eqn.params["body_jaxpr"])
            flops += f
            dbytes += b
        elif name == "shard_map":
            f, b = _walk(eqn.params["jaxpr"])
            mesh = eqn.params.get("mesh")
            manual = eqn.params.get("manual_axes", ())
            mult = 1
            if mesh is not None and manual:
                for a in manual:
                    try:
                        mult *= dict(mesh.shape)[a]
                    except Exception:
                        pass
            flops += mult * f
            dbytes += mult * b
        elif name == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                sub = [_walk(b) for b in branches]
                flops += max(s[0] for s in sub)
                dbytes += max(s[1] for s in sub)
        else:
            for key in _CALL_PARAMS:
                if key in eqn.params:
                    f, b = _walk(eqn.params[key])
                    flops += f
                    dbytes += b
                    break
    return flops, dbytes


def jaxpr_flops(jaxpr) -> float:
    return _walk(jaxpr)[0]


def audit_step_flops(fn, *args) -> float:
    """Global FLOPs of one call of ``fn(*args)`` (args may be
    ShapeDtypeStructs). Abstract trace only — cheap, no device work."""
    return audit_step(fn, *args)[0]


def audit_step(fn, *args):
    """-> (global FLOPs, global dot-operand bytes) of one call of fn."""
    closed = jax.make_jaxpr(fn)(*args)
    return _walk(closed)
