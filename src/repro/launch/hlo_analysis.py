"""Parse collective traffic out of compiled HLO text.

``cost_analysis`` has no collective-byte term, so the roofline's third term
comes from here: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op we sum the operand byte sizes (the SPMD
module is the per-device program, so operand shapes are per-device shard
sizes = bytes leaving each chip, modulo the algorithm factor).

Replica groups are materialized (both the explicit ``{{0,1},{2,3}}`` and the
iota ``[G,S]<=[N]...`` forms) to classify each op as ICI (within a pod) or
DCN (participants span pods).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result_types: str) -> int:
    """Sum the shapes in the op's RESULT type segment. CPU HLO prints no
    operand types inside the call parens, and the result is the right
    traffic proxy anyway: bytes received per device for all-gather, equal to
    the operand for all-reduce / all-to-all / collective-permute."""
    total = 0
    for m in _SHAPE_RE.finditer(result_types):
        if m.group(1) in _DTYPE_BYTES:
            total += _shape_bytes(m.group(1), m.group(2))
    return total


def _groups_from_line(line: str, n_devices: int) -> Optional[List[List[int]]]:
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        base_dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(base_dims))).reshape(base_dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s).tolist()
    m = _EXPLICIT_RE.search(line)
    if m:
        groups = []
        for grp in m.group(1).split("},{"):
            grp = grp.strip("{} ")
            if grp:
                groups.append([int(x) for x in grp.split(",")])
        return groups or None
    return None


def parse_collectives(
    hlo_text: str, n_devices: int, pod_size: int
) -> Dict:
    """-> {kinds, ici_bytes, dcn_bytes, total_bytes, by_depth}.

    ``by_depth`` splits (ici, dcn) bytes by while-loop nesting depth — the
    number of "while/body" scopes in the op's metadata op_name. An op at
    depth d executes prod(trip_counts[:d]) times per step; the roofline
    multiplies accordingly (launch/roofline.py knows each cell's static loop
    structure). Per-op bytes use the RESULT type (the per-device bytes
    received for all-gather; equal to operand size for all-reduce etc.).
    """
    kinds: Dict[str, Dict[str, float]] = {}
    ici = dcn = 0
    by_depth: Dict[int, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(
            r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(",
            stripped,
        )
        if not m:
            continue
        kind = m.group(2)
        nbytes = _result_bytes(m.group(1))
        depth = 0
        om = re.search(r'op_name="([^"]*)"', stripped)
        if om:
            depth = om.group(1).count("while/body")
        k = kinds.setdefault(kind, {"count": 0, "bytes": 0})
        k["count"] += 1
        k["bytes"] += nbytes
        groups = _groups_from_line(stripped, n_devices)
        crosses = False
        if groups:
            for grp in groups:
                pods = {dev // pod_size for dev in grp}
                if len(pods) > 1:
                    crosses = True
                    break
        d = by_depth.setdefault(depth, {"ici": 0, "dcn": 0})
        if crosses:
            dcn += nbytes
            d["dcn"] += nbytes
        else:
            ici += nbytes
            d["ici"] += nbytes
    return {
        "kinds": kinds,
        "ici_bytes": ici,
        "dcn_bytes": dcn,
        "total_bytes": ici + dcn,
        "by_depth": {str(k): v for k, v in sorted(by_depth.items())},
    }
