"""Assigned input-shape presets and per-cell eligibility.

Four shapes per LM architecture (40 cells total):
    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill)
    decode_32k   ctx 32768,  global_batch 128   (serve decode, 1 new token)
    long_500k    ctx 524288, global_batch 1     (long-context decode)

``long_500k`` requires a sub-quadratic path: it runs for the SSM / hybrid /
mostly-local archs (rwkv6-3b, recurrentgemma-9b, gemma3-1b) and is a
documented skip for the pure full-attention archs (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

#: archs eligible for the 500k-context cell
LONG_OK = {"rwkv6-3b", "recurrentgemma-9b", "gemma3-1b"}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    #: logical->physical rule overrides for this workload
    rules: Dict[str, object]


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec(
        "train_4k", "train", 4096, 256, rules={}
    ),
    "prefill_32k": ShapeSpec(
        "prefill_32k", "prefill", 32768, 32, rules={"kv_seq": "model"}
    ),
    "decode_32k": ShapeSpec(
        "decode_32k", "decode", 32768, 128, rules={"kv_seq": "model"}
    ),
    "long_500k": ShapeSpec(
        "long_500k", "decode", 524288, 1,
        rules={"batch": None, "kv_seq": ("pod", "data")},
    ),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    if shape == "long_500k" and cfg.name not in LONG_OK:
        return False, (
            "pure full-attention architecture: 500k-token decode requires a "
            "sub-quadratic/bounded-state path (documented skip, DESIGN.md)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, zero allocation."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one new token; the cache carries the context
        out["token"] = jax.ShapeDtypeStruct((b,), i32)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        out["prefix_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_tokens, cfg.d_model), f32
        )
    if cfg.frontend == "audio_stub" and shape.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), f32)
    return out


def cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """KV-cache length for serving cells (prefix tokens included)."""
    extra = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    return shape.seq_len + extra
