"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host-
platform placeholder devices stand in for 2 pods x 256 chips. Every cell
must compile; memory_analysis shows it fits; cost_analysis + the parsed
collective schedule feed the roofline (launch/roofline.py).

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
Results are cached incrementally under benchmarks/artifacts/dryrun/.
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    ShardingCtx,
    DEFAULT_RULES,
    param_pspecs,
    use_sharding,
)
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.flops_audit import audit_step  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_mesh  # noqa: E402
from repro.launch.shapes import (  # noqa: E402
    SHAPES,
    ShapeSpec,
    cache_len,
    cell_supported,
    input_specs,
)
from repro.models.model import build_model, count_active_params, count_params  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.train_step import (  # noqa: E402
    StepConfig,
    init_train_state,
    make_train_step,
)
from repro.train.serve_step import make_decode_step, make_prefill  # noqa: E402

ART_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun"
)


# ------------------------------------------------------------------ #
# sharding trees for the AOT arguments
# ------------------------------------------------------------------ #

_SERVE_LEAF_RULES = {
    "k": (None, "batch", "kv_seq", "kv", None),
    "v": (None, "batch", "kv_seq", "kv", None),
    "pos": None,
    "h": (None, "batch", "p_lru"),
    "conv": (None, "batch", None, "p_lru"),
    "wkv": (None, "batch", "heads", None, None),
    "shift_tm": (None, "batch", "p_embed"),
    "shift_cm": (None, "batch", "p_embed"),
    "enc_out": ("batch", None, None),
}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(last.key) if hasattr(last, "key") else str(last)


def cache_pspecs(cache_shapes, ctx: ShardingCtx):
    def walk(path, leaf):
        name = _leaf_name(path)
        names = _SERVE_LEAF_RULES.get(name)
        if names is None:
            return P()
        # pad rank (hybrid caches have an extra leading period dim)
        pad = len(leaf.shape) - len(names)
        if pad < 0:
            names = names[-len(leaf.shape):]
            pad = 0
        return ctx.resolve((None,) * pad + tuple(names), leaf.shape)

    return jax.tree_util.tree_map_with_path(walk, cache_shapes)


def _shardify(shape_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        shape_tree,
        spec_tree,
    )


def batch_pspecs(batch_shapes, ctx: ShardingCtx):
    def walk(path, leaf):
        names = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return ctx.resolve(names, leaf.shape)

    return jax.tree_util.tree_map_with_path(walk, batch_shapes)


# ------------------------------------------------------------------ #
# cell construction
# ------------------------------------------------------------------ #


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               step_cfg: Optional[StepConfig] = None,
               rules_override: Optional[dict] = None,
               remat: str = "full",
               mesh_override=None,
               serve_params_dtype=None):
    """-> (lowerable callable, arg shape/sharding trees)"""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if mesh_override is not None:
        shape_tuple, axes = mesh_override
        mesh = jax.make_mesh(tuple(shape_tuple), tuple(axes))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(DEFAULT_RULES)
    rules.update(shape.rules)
    if rules_override:
        rules.update(rules_override)
    ctx = ShardingCtx(mesh=mesh, rules=rules)
    model = build_model(cfg, remat=remat)
    # 8 microbatches/step for training cells: standard grad accumulation,
    # keeps logits + saved-activation temporaries inside HBM (batch 256 / 8
    # microbatches = 32 sequences, exactly one per (pod, data) shard).
    step_cfg = step_cfg or StepConfig(
        optimizer=AdamWConfig(), accum_steps=8
    )

    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0))
        )
        state_specs = param_pspecs(state_shapes, ctx)
        batch_specs = batch_pspecs(ins, ctx)
        fn = make_train_step(
            model, step_cfg, mesh=mesh, rules=rules, multi_pod=multi_pod
        )
        args = (
            _shardify(state_shapes, state_specs, mesh),
            _shardify(ins, batch_specs, mesh),
        )
        donate = (0,)
    else:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if serve_params_dtype is not None:
            # serving stores a reduced-precision weight copy
            params_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, serve_params_dtype),
                params_shapes,
            )
        params_specs = param_pspecs(params_shapes, ctx)
        b = shape.global_batch
        clen = cache_len(cfg, shape)
        with set_mesh(mesh), use_sharding(mesh, rules):
            cache_shapes = jax.eval_shape(lambda: model.init_cache(b, clen))
        cache_specs = cache_pspecs(cache_shapes, ctx)
        params_arg = _shardify(params_shapes, params_specs, mesh)
        cache_arg = _shardify(cache_shapes, cache_specs, mesh)
        if shape.kind == "prefill":
            fn = make_prefill(model, mesh=mesh, rules=rules)
            batch_specs = batch_pspecs(ins, ctx)
            args = (params_arg, _shardify(ins, batch_specs, mesh), cache_arg)
            donate = (2,)
        else:
            fn0 = make_decode_step(model, mesh=mesh, rules=rules)
            # fix the sampling key statically; lower (params, token, cache, pos)
            def fn(params, token, cache, pos):
                return fn0(params, token, cache, pos, jax.random.PRNGKey(0))

            tok = _shardify(
                {"token": ins["token"]},
                {"token": ctx.resolve(("batch",), ins["token"].shape)},
                mesh,
            )["token"]
            pos = jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P())
            )
            args = (params_arg, tok, cache_arg, pos)
            donate = (2,)
    return fn, args, donate, mesh, cfg, model


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, **build_kw) -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(os.path.join(ART_DIR, mesh_name), exist_ok=True)
    out_path = os.path.join(ART_DIR, mesh_name, f"{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, shape_name)
    record: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skip" if not ok else "pending",
        "reason": reason,
    }
    if ok:
        try:
            t0 = time.time()
            fn, args, donate, mesh, cfg_, model = build_cell(
                arch, shape_name, multi_pod, **build_kw
            )
            with set_mesh(mesh):
                # trip-count-aware global FLOPs + dot bytes
                # (cost_analysis counts scan bodies once; flops_audit.py)
                flops_audit, dot_bytes_audit = audit_step(fn, *args)
                jitted = jax.jit(fn, donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                t0 = time.time()
                compiled = lowered.compile()
                t_compile = time.time() - t0
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            pod_size = (
                mesh.devices.size // mesh.shape["pod"]
                if "pod" in mesh.shape
                else mesh.devices.size
            )
            colls = hlo_analysis.parse_collectives(
                hlo, n_devices=mesh.devices.size, pod_size=pod_size
            )
            # keep the raw collective lines for offline re-analysis
            import re as _re

            coll_lines = [
                l.strip()[:600]
                for l in hlo.splitlines()
                if _re.search(
                    r"= \S+ (all-gather|all-reduce|reduce-scatter|"
                    r"all-to-all|collective-permute)", l
                )
            ]
            record.update(
                status="ok",
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                n_devices=int(mesh.devices.size),
                params=count_params(model),
                active_params=count_active_params(model),
                flops_per_device=float(cost.get("flops", -1.0)),
                bytes_per_device=float(cost.get("bytes accessed", -1.0)),
                flops_audit_global=float(flops_audit),
                dot_bytes_audit_global=float(dot_bytes_audit),
                memory={
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                    "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                    "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
                },
                collectives=colls,
                hlo_collective_lines=coll_lines,
            )
        except Exception as e:  # record failures: they are bugs to fix
            record.update(
                status="error",
                error=f"{type(e).__name__}: {e}",
                trace=traceback.format_exc()[-4000:],
            )
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = ""
    if status == "ok":
        gb = record["memory"]["argument_bytes"] / 1e9
        extra = (
            f" lower={record['lower_s']}s compile={record['compile_s']}s "
            f"args={gb:.2f}GB/dev temp={record['memory']['temp_bytes']/1e9:.2f}GB"
        )
    print(f"[dryrun:{mesh_name}] {arch} x {shape_name}: {status}{extra}",
          flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (
        [False, True] if args.mesh == "both"
        else [args.mesh == "multi"]
    )
    failures = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, multi, force=args.force)
                if rec["status"] == "error":
                    failures += 1
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
