"""§Perf hillclimbing: hypothesis -> change -> re-lower -> measure -> record.

Runs the three chosen cells (worst roofline fraction / most collective-bound
/ most representative of the paper's technique) through named optimization
variants, derives roofline terms for each, and emits the iteration log that
EXPERIMENTS.md §Perf embeds.

    python -m repro.launch.perf --cell yi_train --variant all
"""
# device count must be set before any jax import
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.launch import hlo_analysis, roofline  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.mesh import set_mesh  # noqa: E402
from repro.launch.flops_audit import audit_step  # noqa: E402
from repro.models.model import build_model, count_active_params  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.train_step import StepConfig  # noqa: E402

PERF_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts",
    "perf",
)


def measure(arch, shape, multi_pod, *, step_cfg=None, rules_override=None,
            remat="full", moe_tg=None, mesh_override=None,
            serve_params_dtype=None):
    """Lower+compile one variant; return the roofline-ready record."""
    from repro.models import moe as moe_lib

    prev_tg = moe_lib.DISPATCH_TARGET_TG
    moe_lib.DISPATCH_TARGET_TG = moe_tg
    try:
        t0 = time.time()
        fn, args, donate, mesh, cfg, model = build_cell(
            arch, shape, multi_pod,
            step_cfg=step_cfg, rules_override=rules_override, remat=remat,
            mesh_override=mesh_override,
            serve_params_dtype=serve_params_dtype,
        )
        with set_mesh(mesh):
            fl, db = audit_step(fn, *args)
            compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        pod_size = (
            mesh.devices.size // mesh.shape["pod"]
            if "pod" in mesh.shape else mesh.devices.size
        )
        colls = hlo_analysis.parse_collectives(
            compiled.as_text(), n_devices=mesh.devices.size, pod_size=pod_size
        )
        rec = {
            "arch": arch, "shape": shape,
            "mesh": "multi" if multi_pod else "single",
            "status": "ok",
            "n_devices": int(mesh.devices.size),
            "active_params": count_active_params(model),
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
            "flops_audit_global": float(fl),
            "dot_bytes_audit_global": float(db),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
            },
            "collectives": colls,
            "compile_s": round(time.time() - t0, 1),
            "accum_steps": step_cfg.accum_steps if step_cfg else 8,
        }
        return rec
    finally:
        moe_lib.DISPATCH_TARGET_TG = prev_tg


def terms(rec):
    r = roofline.derive(rec)
    return {
        "compute_ms": round(r.compute_s * 1e3, 1),
        "memory_ms": round(r.memory_s * 1e3, 1),
        "collective_ms": round(r.collective_s * 1e3, 1),
        "ici_ms": round(r.ici_s * 1e3, 1),
        "dcn_ms": round(r.dcn_s * 1e3, 1),
        "bottleneck": r.bottleneck,
        "step_ms": round(r.step_time_s * 1e3, 1),
        "roofline_fraction": round(r.roofline_fraction, 3),
        "useful_ratio": round(r.useful_ratio, 3),
        "temp_gb": round(rec["memory"]["temp_bytes"] / 1e9, 2),
    }


OPT = AdamWConfig()

CELLS = {
    # (c) most representative of the paper's technique: multi-pod train with
    # the scheduled DCN sync; also the heaviest dense arch.
    "yi_train": dict(
        arch="yi-9b", shape="train_4k", multi_pod=True,
        variants=[
            ("baseline", "FSDP re-gathers every layer's weights every "
             "microbatch (fwd+bwd)", {}),
            ("it1_gather_once",
             "HYPOTHESIS: one bf16 TP-only weight gather per step + "
             "per-microbatch grad reduce-scatter cuts ICI ~5x "
             "(weights 2x/ubatch -> grads 1x/ubatch)",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=8,
                                      gather_once=True))),
            ("it2_gather_once_dots",
             "HYPOTHESIS: remat policy 'dots' saves matmul outputs -> "
             "bwd recompute drops, compute term ~ -25% (useful ratio "
             "0.69 -> ~0.9); memory term rises",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=8,
                                      gather_once=True),
                  remat="dots")),
            ("it3_gather_once_accum4",
             "HYPOTHESIS: accum 8->4 halves per-step reduce-scatter "
             "traffic; activation temporaries ~2x (still < HBM)",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=4,
                                      gather_once=True))),
            ("it4_tp4_accum2",
             "HYPOTHESIS (from it1 depth analysis: dominant ICI = per-layer "
             "TP activation all-reduces x accum x L): remap the 512 chips to "
             "(pod 2, data 64, model 4) — TP all-reduce operands shrink 4x "
             "(batch sharded 4x wider) and accum 8->2 cuts trips 4x: "
             "ICI ~ -90%; bf16 TP-4 weight copy 4.4GB/dev still fits",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=2,
                                      gather_once=True),
                  mesh_override=((2, 64, 4), ("pod", "data", "model")))),
            ("it5_tp4_int8_dcn",
             "HYPOTHESIS (it4 leaves DCN fp32 grad sync as 64% of the "
             "collective term): int8-wire sync (all-gather + local "
             "dequant-sum, error feedback available) cuts DCN bytes ~4x "
             "-> collective term ~ -50%, bottleneck nears compute",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=2,
                                      gather_once=True,
                                      compress_codec="int8"),
                  mesh_override=((2, 64, 4), ("pod", "data", "model")))),
        ],
    ),
    # (b) most collective-bound baseline cell
    "phi35_train": dict(
        arch="phi3.5-moe-42b", shape="train_4k", multi_pod=False,
        variants=[
            ("baseline", "per-microbatch FSDP gathers of 42B params "
             "dominate; MoE dispatch adds flops", {}),
            ("it1_gather_once",
             "HYPOTHESIS: gather-once cuts the dominant ICI term ~5x "
             "(bf16 TP-only copy = 5.2 GB/device, fits)",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=8,
                                      gather_once=True))),
            ("it2_moe_tg2048",
             "HYPOTHESIS: dispatch einsum cost ~ 2*T*E*C*D with "
             "C ~ Tg*k/E: shrinking groups 32k->2k tokens cuts MoE "
             "dispatch FLOPs ~16x (compute term -30%+)",
             dict(step_cfg=StepConfig(optimizer=OPT, accum_steps=8,
                                      gather_once=True),
                  moe_tg=2048)),
        ],
    ),
    # (a) worst roofline fraction: FSDP-sharded weights make decode gather
    # the full model every token
    "yi_decode": dict(
        arch="yi-9b", shape="decode_32k", multi_pod=False,
        variants=[
            ("baseline", "weights FSDP(data x model)-sharded: serving "
             "all-gathers every layer's weights per token", {}),
            ("it1_tp_only",
             "HYPOTHESIS: serve-mode TP-only sharding (p_embed->None) "
             "keeps weights resident (fp32 2.2 GB/device) -> no per-token "
             "gathers; memory term -> cache+weights read ~ 5x lower",
             dict(rules_override={"p_embed": None})),
            ("it2_tp_bf16",
             "HYPOTHESIS (from it1: memory floor = fp32 weight reads "
             "~18GB/dev/token): serve from a bf16 weight copy -> weight "
             "read bytes halve; memory term ~ -45%",
             dict(rules_override={"p_embed": None},
                  serve_params_dtype=__import__("jax").numpy.bfloat16)),
        ],
    ),
    # bonus: deepseek prefill was compute-bound with useful-ratio 0.08 —
    # nearly all FLOPs were MoE dispatch overhead
    "deepseek_prefill": dict(
        arch="deepseek-moe-16b", shape="prefill_32k", multi_pod=False,
        variants=[
            ("baseline", "grouped dispatch with Tg=32k tokens: C=3840 "
             "slots/expert/group -> dispatch dominates FLOPs 10:1", {}),
            ("it1_moe_tg2048",
             "HYPOTHESIS: Tg 32k->2k cuts dispatch/combine einsum FLOPs "
             "~16x; compute term approaches the expert-FFN floor "
             "(useful ratio 0.08 -> ~0.5)",
             dict(moe_tg=2048)),
            ("it2_moe_tg2048_tponly",
             "HYPOTHESIS: + TP-only weights remove per-layer FSDP "
             "gathers from prefill (collective term -> ~0)",
             dict(moe_tg=2048, rules_override={"p_embed": None})),
            ("it3_local_attention",
             "HYPOTHESIS (it2 left 6.2s of in-loop collectives: partial-"
             "softmax all-reduces from the kv_seq->model sharding, x28 "
             "layers x 32 q-chunks): keep attention KV local (batch-"
             "sharded only) and reshard the cache once on output -> "
             "collective term collapses to the MoE combine + one reshard",
             dict(moe_tg=2048,
                  rules_override={"p_embed": None, "kv_seq": None})),
        ],
    ),
}


def run_cell_variants(name):
    spec = CELLS[name]
    os.makedirs(PERF_DIR, exist_ok=True)
    out_path = os.path.join(PERF_DIR, f"{name}.json")
    results = []
    print(f"== {name}: {spec['arch']} x {spec['shape']} "
          f"({'multi' if spec['multi_pod'] else 'single'}-pod) ==", flush=True)
    for vname, hypothesis, kw in spec["variants"]:
        try:
            rec = measure(spec["arch"], spec["shape"], spec["multi_pod"], **kw)
            t = terms(rec)
            status = "ok"
        except Exception as e:
            t, status = {"error": str(e)[:300]}, "error"
        results.append({"variant": vname, "hypothesis": hypothesis,
                        "status": status, **t})
        print(f"  {vname:24s} {json.dumps(t)}", flush=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=list(CELLS) + ["all"])
    args = ap.parse_args()
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for n in names:
        run_cell_variants(n)


if __name__ == "__main__":
    main()
