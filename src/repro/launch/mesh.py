"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state: the dry-run sets XLA_FLAGS for 512 host devices before any jax import,
smoke tests keep the default single device.
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def set_mesh(mesh):
    """Version-portable ambient-mesh context.

    ``jax.set_mesh`` only exists on newer jax; older releases (0.4.x) resolve
    bare PartitionSpecs inside jit through the legacy ``with mesh:`` context.
    Every launcher/test goes through this helper instead of either API.
    """
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_plan(shape, axes):
    """Build a (possibly degraded / elastic) mesh from a fault-plan spec."""
    return jax.make_mesh(tuple(shape), tuple(axes))
