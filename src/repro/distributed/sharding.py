"""Logical-axis sharding (MaxText-style rules, divisibility-aware).

Model code annotates activations with *logical* axis names via ``shard(x,
"batch", "seq", None)``. A ``ShardingCtx`` (installed by the launcher with
``use_sharding``) maps logical names to physical mesh axes; outside any
context the annotations are no-ops, so the same model code runs on a laptop
CPU and on a 512-chip mesh.

A logical rule only applies when the dimension size is divisible by the
mapped mesh-axis product — e.g. gemma3-1b's 4 query heads cannot shard over a
16-way model axis, so "heads" silently falls back to replicated while "mlp"
(6912 % 16 == 0) still shards. This is what makes one rule set serve ten
architectures.

Parameter PartitionSpecs are derived from leaf names by ``param_pspecs`` —
every weight name in the model zoo is covered explicitly; 1-D scales/biases
shard over the FSDP axis when divisible.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable ``AbstractMesh`` constructor.

    Newer jax takes ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x takes a
    single ``shape_tuple`` of ``(name, size)`` pairs (passing sizes-only there
    fails with ``TypeError: 'int' object is not iterable``). Rule resolution
    only needs axis names/sizes, never devices, so an abstract mesh is the
    right object for tests and planning code on any version.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    names = tuple(axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"{len(sizes)} sizes for {len(names)} axis names")
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))

#: default logical -> physical rules for the production meshes.
DEFAULT_RULES: Dict[str, AxisSpec] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,  # long-context runs remap this to ("pod", "data")
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "moe_mlp": None,
    "vocab": "model",
    "expert": "model",
    "embed": None,
    "cap": None,  # MoE capacity dim
    # params
    "p_embed": "data",  # FSDP axis for weight matrices' d_model dim
    "p_vocab": "model",
    "p_heads": "model",
    "p_mlp": "model",
    "p_expert": "model",
    "p_lru": "model",
    "p_scale": "data",
    "layer": None,
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, AxisSpec]
    #: axes handled manually by an enclosing shard_map (e.g. {"pod"} in the
    #: multi-pod train step) — stripped from every resolved spec because
    #: with_sharding_constraint may only reference auto axes there.
    manual_axes: frozenset = frozenset()

    def axis_size(self, spec: AxisSpec) -> int:
        if spec is None:
            return 1
        axes = (spec,) if isinstance(spec, str) else spec
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, names: Sequence[Optional[str]], shape) -> P:
        """Logical names -> PartitionSpec, dropping non-divisible entries."""
        out = []
        used: set = set()
        for dim, name in zip(shape, names):
            spec = self.rules.get(name) if name else None
            if spec is not None:
                axes = (spec,) if isinstance(spec, str) else tuple(spec)
                # drop axes that are shard_map-manual or absent from the mesh
                # (e.g. no "pod" axis on the single-pod mesh)
                axes = tuple(
                    a for a in axes
                    if a not in self.manual_axes and a in self.mesh.shape
                )
                if (
                    not axes
                    or any(a in used for a in axes)
                    or dim % self.axis_size(axes) != 0
                ):
                    spec = None
                else:
                    used.update(axes)
                    spec = axes if len(axes) > 1 else axes[0]
            out.append(spec)
        return P(*out)


_local = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_sharding(
    mesh: Mesh,
    rules: Optional[Dict[str, AxisSpec]] = None,
    manual_axes: frozenset = frozenset(),
):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    prev = current_ctx()
    _local.ctx = ShardingCtx(mesh=mesh, rules=merged, manual_axes=manual_axes)
    try:
        yield _local.ctx
    finally:
        _local.ctx = prev


def mark_varying(x, axes):
    """Mark ``x`` varying over manual ``axes``, across jax generations.

    Newer jax tracks varying-manual-axes types and exposes ``pcast`` (or the
    earlier ``pvary``); 0.4.x shard_map has no such type system, so there the
    annotation is a no-op and values are already treated as device-varying.
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    if hasattr(jax.lax, "pcast"):
        try:
            return jax.lax.pcast(x, axes, to="varying")
        except TypeError:
            return jax.lax.pcast(x, to="varying", axes=axes)
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    return x


def shard_map_compat(f, mesh, in_specs, out_specs, manual_axes):
    """shard_map manual over ``manual_axes`` only, across jax generations.

    Newer jax spells this ``jax.shard_map(..., axis_names=manual)``. 0.4.x
    has partial-manual (``auto=rest``) but its XLA CHECK-fails on real model
    bodies (hlo_sharding_util IsManualSubgroup), so there we fall back to a
    FULLY manual region: non-manual axes replicate compute instead of
    sharding it. Collectives over ``manual_axes`` lower identically, so
    numerics — and therefore the scheduling/compression semantics under
    test — are unchanged; only legacy-jax step cost differs.
    ``check_rep=False`` because the legacy replication checker predates
    explicitly-scheduled per-bucket collectives.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(manual),
        )
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def vary_for_manual(x):
    """Mark ``x`` varying over any active manual axes (scan-carry inits that
    will accumulate manual-axis-varying values need matching vma types)."""
    ctx = current_ctx()
    if ctx is None or not ctx.manual_axes:
        return x
    axes = tuple(ctx.manual_axes)
    return jax.tree.map(lambda a: mark_varying(a, axes), x)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context).

    Emits bare-PartitionSpec constraints (resolved against the ambient mesh
    set by the launcher via ``jax.set_mesh``) so the same annotation works in
    plain pjit programs and inside partially-manual shard_map regions.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"shard(): {len(names)} names for rank-{x.ndim} array")
    if ctx.manual_axes and not hasattr(jax, "shard_map"):
        # legacy (0.4.x) partial-manual shard_map: XLA CHECK-fails on sharding
        # constraints inside the auto sub-region (hlo_sharding_util
        # IsManualSubgroup). Drop the hint; in_specs still seed propagation.
        return x
    pspec = ctx.resolve(names, x.shape)
    return jax.lax.with_sharding_constraint(x, pspec)


# ------------------------------------------------------------------ #
# parameter PartitionSpecs (by leaf name)
# ------------------------------------------------------------------ #

#: leaf-name -> logical names per trailing dims (leading 'layer' dims are
#: padded with None automatically).
_PARAM_TABLE: Dict[str, Tuple[Optional[str], ...]] = {
    # attention
    "wq": ("p_embed", "p_heads"),
    "wk": ("p_embed", "p_heads"),
    "wv": ("p_embed", "p_heads"),
    "wo": ("p_heads", "p_embed"),
    # dense ffn
    "w_up": ("p_embed", "p_mlp"),
    "w_gate": ("p_embed", "p_mlp"),
    "w_down": ("p_mlp", "p_embed"),
    # moe
    "router": ("p_embed", None),
    "we_up": ("p_expert", "p_embed", None),
    "we_gate": ("p_expert", "p_embed", None),
    "we_down": ("p_expert", None, "p_embed"),
    # embeddings / head
    "tok": ("p_vocab", "p_embed"),
    "head": ("p_vocab", "p_embed"),
    "pos": (None, "p_embed"),
    # rwkv6
    "w_r": ("p_embed", "p_heads"),
    "w_k": ("p_embed", "p_heads"),
    "w_v": ("p_embed", "p_heads"),
    "w_g": ("p_embed", "p_heads"),
    "w_o": ("p_heads", "p_embed"),
    "decay_a": ("p_embed", None),
    "decay_b": (None, "p_heads"),
    "mix_a": ("p_embed", None),
    "mix_b": (None, None, "p_embed"),
    "cm_k": ("p_embed", "p_mlp"),
    "cm_v": ("p_mlp", "p_embed"),
    # rg-lru recurrent block
    "w_in": ("p_embed", "p_lru"),
    "w_gate_br": ("p_embed", "p_lru"),
    "w_a": ("p_lru", None),
    "w_x": ("p_lru", None),
    "w_out": ("p_lru", "p_embed"),
    "conv_w": (None, "p_lru"),
}

_SCALE_NAMES = {
    "attn_norm", "ffn_norm", "final_norm", "q_norm", "k_norm", "ln_x",
    "mix_base", "u", "decay_base", "cm_mix", "lam", "conv_b", "gate_b",
    "enc_norm", "cross_norm", "mix_w",
}


def _leaf_pspec(name: str, shape, ctx: ShardingCtx) -> P:
    if name in _PARAM_TABLE:
        logical = _PARAM_TABLE[name]
        pad = len(shape) - len(logical)
        names = ("layer",) * pad + logical
        return ctx.resolve(names, shape)
    # scales / biases / mixing vectors: shard trailing dim over FSDP axis
    names = (None,) * (len(shape) - 1) + ("p_scale",)
    if len(shape) == 0:
        return P()
    return ctx.resolve(names, shape)


def param_pspecs(shape_tree, ctx: Optional[ShardingCtx] = None):
    """PartitionSpec tree for a parameter (shape) tree, by leaf names."""
    ctx = ctx or current_ctx()
    if ctx is None:
        raise RuntimeError("param_pspecs requires an active sharding context")

    def walk(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        return _leaf_pspec(name, leaf.shape, ctx)

    return jax.tree_util.tree_map_with_path(walk, shape_tree)


def param_shardings(shape_tree, ctx: Optional[ShardingCtx] = None):
    ctx = ctx or current_ctx()
    specs = param_pspecs(shape_tree, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs)
