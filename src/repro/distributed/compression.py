"""Gradient compression for DCN-crossing collectives (beyond-paper).

Two codecs, applied per chunk class by the sync plan:
  * bf16 — cast before psum, cast back after (2x DCN byte reduction, no
    state; safe for bandwidth-bound buckets).
  * int8 + error feedback — per-tensor max-abs scaling with the residual
    carried into the next step (EF keeps SGD/Adam convergence; see Karimireddy
    et al. 2019). 4x byte reduction.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def int8_encode(
    g: Array, ef: Optional[Array] = None
) -> Tuple[Array, Array, Array]:
    """-> (q int8, scale f32 scalar, new error-feedback residual)."""
    gf = g.astype(jnp.float32)
    if ef is not None:
        gf = gf + ef.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    residual = gf - deq
    return q, scale, residual


def int8_decode(q_sum: Array, scale: Array) -> Array:
    """Decode a psum of int8 payloads (accumulated in int32)."""
    return q_sum.astype(jnp.float32) * scale


def bf16_roundtrip(g: Array) -> Array:
    return g.astype(jnp.bfloat16).astype(g.dtype)


def compression_error(g: Array, codec: str) -> Array:
    """Relative L2 error of one-shot compression (diagnostics)."""
    gf = g.astype(jnp.float32)
    if codec == "bf16":
        d = bf16_roundtrip(gf)
    elif codec == "int8":
        q, s, _ = int8_encode(gf)
        d = int8_decode(q.astype(jnp.int32), s)
    else:
        return jnp.float32(0.0)
    return jnp.linalg.norm(gf - d) / jnp.maximum(jnp.linalg.norm(gf), 1e-30)
