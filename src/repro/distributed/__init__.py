"""Distributed runtime: sharding rules, meshes, DCN grad-sync, fault tolerance."""
