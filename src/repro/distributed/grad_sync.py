"""Cross-pod gradient synchronization scheduled by the paper's technique.

The WAN -> DCN mapping (DESIGN.md Sec. 2): gradient tensors are the "files"
(their byte sizes span 5+ orders of magnitude), the inter-pod DCN is the
wide-area link, and the three protocol parameters become:

    pipelining   -> in-flight window of bucket collectives (amortizes the
                    per-collective launch + DCN latency);
    parallelism  -> slicing one large tensor into p independent collective
                    operands ("streams") so a single huge tensor does not
                    serialize behind one channel window;
    concurrency  -> number of simultaneously outstanding chunk transfers
                    (channel groups the XLA latency-hiding scheduler can
                    overlap with compute and each other).

``build_sync_plan`` partitions the gradient tree into Small/../Huge chunks
(Fig.-3 thresholds against the DCN spec), assigns Algorithm-1 parameters per
chunk, allocates channels with MC round-robin or ProMC delta-weighting, and
emits a deterministic interleaved ordering. ``apply_sync`` executes the plan
inside a shard_map region that is *manual over the pod axis only*: each
bucket/slice becomes its own ``psum`` over "pod" in the lowered HLO — the
dry-run roofline reads them back directly. ``simulate_sync`` replays the
same plan through the discrete-event simulator to score schedule quality
(and is where ProMC's online re-allocation runs).

Beyond-paper extension: per-chunk-class gradient compression (bf16 / int8 +
error feedback) — precision becomes a fourth per-class "protocol parameter";
Small (latency-bound) chunks stay fp32, bandwidth-bound chunks compress.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import testbeds
from repro.core.chunking import partition_files
from repro.core.params import assign_chunk_params
from repro.core.runner import build_scheduler
from repro.core.simulator import SimResult, Simulation
from repro.core.schedulers import (
    round_robin_distribution,
    weighted_distribution,
)
from repro.core.types import Chunk, ChunkType, FileSpec, NetworkSpec
from repro.distributed import compression

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    out = []
    jax.tree_util.tree_map_with_path(
        lambda path, leaf: out.append((_path_str(path), leaf)), tree
    )
    return out


@dataclasses.dataclass(frozen=True)
class SyncItem:
    """One collective: a whole gradient leaf or one slice of it."""

    path: str
    slice_idx: int  # -1 = whole tensor
    n_slices: int
    bytes: int
    chunk_type: ChunkType
    compress: str  # "none" | "bf16" | "int8"


@dataclasses.dataclass
class SyncPlan:
    network: NetworkSpec
    algorithm: str
    max_cc: int
    chunks: List[Chunk]  # core chunks (files = leaf tensors)
    channel_alloc: Dict[int, int]  # chunk idx -> channels
    order: List[SyncItem]  # emission order (interleaved by allocation)
    slicing: Dict[str, int]  # leaf path -> n_slices
    compress_by_class: Dict[ChunkType, str]

    def summary(self) -> str:
        lines = [
            f"sync plan [{self.algorithm}] on {self.network.name}: "
            f"{len(self.order)} collectives, maxCC={self.max_cc}"
        ]
        for i, c in enumerate(self.chunks):
            p = c.params
            lines.append(
                f"  {c.name:6s}: {len(c)} tensors, {c.total_bytes/1e6:.1f} MB, "
                f"pp={p.pipelining} par={p.parallelism} cc={p.concurrency} "
                f"channels={self.channel_alloc.get(i, 0)} "
                f"compress={self.compress_by_class[c.ctype]}"
            )
        return "\n".join(lines)


DEFAULT_COMPRESSION = {
    ChunkType.SMALL: "none",  # latency-bound; compression saves nothing
    ChunkType.MEDIUM: "bf16",
    ChunkType.LARGE: "bf16",
    ChunkType.HUGE: "bf16",  # bandwidth-bound; halve DCN bytes
    ChunkType.ALL: "none",
}

NO_COMPRESSION = {t: "none" for t in ChunkType}


def build_sync_plan(
    grad_shapes: PyTree,
    *,
    network: NetworkSpec = testbeds.DCN,
    max_cc: int = 8,
    num_chunks: int = 2,
    algorithm: str = "promc",
    compress_by_class: Optional[Dict[ChunkType, str]] = None,
) -> SyncPlan:
    """grad_shapes: pytree of ShapeDtypeStruct (or arrays)."""
    compress_by_class = dict(
        DEFAULT_COMPRESSION if compress_by_class is None else compress_by_class
    )
    leaves = flatten_with_paths(grad_shapes)
    files = [
        FileSpec(name=path, size=int(np.prod(leaf.shape) or 1) * leaf.dtype.itemsize)
        for path, leaf in leaves
    ]
    chunks = partition_files(files, network, num_chunks)
    for c in chunks:
        assign_chunk_params(c, network, max_cc)

    if algorithm == "mc":
        alloc = round_robin_distribution(chunks, max_cc)
    elif algorithm == "promc":
        alloc = weighted_distribution(chunks, max_cc)
    elif algorithm == "sc":
        # sequential: chunks emitted one after another, per-chunk concurrency
        alloc = {i: c.params.concurrency for i, c in enumerate(chunks)}
    else:
        raise ValueError(f"unknown sync algorithm {algorithm!r}")

    shape_by_path = {path: leaf for path, leaf in leaves}
    slicing: Dict[str, int] = {}
    per_chunk_items: List[List[SyncItem]] = []
    for c in chunks:
        comp = compress_by_class[c.ctype]
        items = []
        par = c.params.parallelism
        for f in c.files:
            leaf = shape_by_path[f.name]
            n_slices = 1
            if par > 1 and leaf.shape and f.size >= 2 * network.buffer_size:
                # largest divisor of the leading dim <= the stream count
                n_slices = max(
                    d for d in range(1, par + 1) if leaf.shape[0] % d == 0
                )
            slicing[f.name] = n_slices
            if n_slices == 1:
                items.append(
                    SyncItem(f.name, -1, 1, f.size, c.ctype, comp)
                )
            else:
                for si in range(n_slices):
                    items.append(
                        SyncItem(
                            f.name, si, n_slices, f.size // n_slices,
                            c.ctype, comp,
                        )
                    )
        per_chunk_items.append(items)

    # emission order: SC = sequential by chunk; MC/ProMC = interleave chunks
    # proportionally to their channel allocation (a weighted round-robin) so
    # the compiler's scheduler can keep `cc` transfers of each class in
    # flight concurrently.
    order: List[SyncItem] = []
    if algorithm == "sc":
        for items in per_chunk_items:
            order.extend(items)
    else:
        cursors = [0] * len(chunks)
        weights = [max(alloc.get(i, 0), 0) for i in range(len(chunks))]
        while any(
            cursors[i] < len(per_chunk_items[i]) for i in range(len(chunks))
        ):
            for i in range(len(chunks)):
                take = max(weights[i], 1) if cursors[i] < len(
                    per_chunk_items[i]
                ) else 0
                for _ in range(take):
                    if cursors[i] < len(per_chunk_items[i]):
                        order.append(per_chunk_items[i][cursors[i]])
                        cursors[i] += 1

    return SyncPlan(
        network=network,
        algorithm=algorithm,
        max_cc=max_cc,
        chunks=chunks,
        channel_alloc=alloc,
        order=order,
        slicing=slicing,
        compress_by_class=compress_by_class,
    )


# ------------------------------------------------------------------ #
# execution (inside a shard_map region manual over `axis_name`)
# ------------------------------------------------------------------ #


def _psum_one(
    g: jax.Array, axis_name: str, n: int, compress: str,
    ef: Optional[jax.Array], spec=None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    if compress == "none":
        return jax.lax.psum(g, axis_name) / n, None
    if compress == "bf16":
        if jax.default_backend() == "tpu":
            # native bf16 all-reduce on the fabric: half the DCN bytes
            g16 = g.astype(jnp.bfloat16)
            if spec is not None:
                g16 = jax.lax.with_sharding_constraint(g16, spec)
            synced = jax.lax.psum(g16, axis_name)
            return synced.astype(g.dtype) / n, None
        # CPU dry-run: the backend's all-reduce-promotion rewrite of a bf16
        # collective under manual sub-axes CHECK-fails in this XLA version,
        # so emulate: identical quantization numerics, f32 on the wire.
        # (EXPERIMENTS.md notes multi-pod HLO collective bytes are f32-wire.)
        gq = g.astype(jnp.bfloat16).astype(g.dtype)
        return jax.lax.psum(gq, axis_name) / n, None
    if compress == "int8":
        # int8 on the wire via all-gather + local dequant-sum: ~4x fewer
        # wire bytes than the fp32 all-reduce, no int8-accumulation overflow
        # (the sum happens in fp32 after dequant), and no reliance on
        # reduced-precision all-reduce support. Error feedback carries the
        # quantization residual when the caller threads ef state.
        q, scale, new_ef = compression.int8_encode(g, ef)
        qg = jax.lax.all_gather(q, axis_name)  # (P, ...) int8
        sg = jax.lax.all_gather(scale, axis_name)  # (P,)
        sg = sg.reshape((-1,) + (1,) * g.ndim)
        synced = jnp.sum(qg.astype(jnp.float32) * sg, axis=0) / n
        # the local sums are bit-identical across the axis; pmax makes that
        # provable to the vma checker (an extra reduced-size collective —
        # still ~2x fewer wire bytes than an fp32 all-reduce, and on real
        # fabrics the int8 gather dominates the cost)
        synced = jax.lax.pmax(synced, axis_name)
        return synced.astype(g.dtype), new_ef
    raise ValueError(f"unknown compression {compress!r}")


def apply_sync(
    plan: SyncPlan,
    grads: PyTree,
    *,
    axis_name: str = "pod",
    n_pods: int,
    ef_state: Optional[PyTree] = None,
    spec_tree: Optional[PyTree] = None,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Execute the plan: ordered, sliced, per-chunk-compressed psums.

    Must run inside shard_map(..., axis_names={axis_name}); grads must be
    pod-varying (see train.train_step: params are pvary'd before grad).
    ``spec_tree``: PartitionSpecs mirroring grads (re-asserted around dtype
    casts). Returns (synced grads tree, new error-feedback tree or None).
    """
    leaves = dict(flatten_with_paths(grads))
    specs = dict(flatten_with_paths(spec_tree)) if spec_tree is not None else {}
    ef_leaves = dict(flatten_with_paths(ef_state)) if ef_state is not None else {}
    out: Dict[str, jax.Array] = {}
    new_ef: Dict[str, jax.Array] = {}

    # group items by path to rebuild sliced tensors in plan order
    for item in plan.order:
        g = leaves[item.path]
        spec = specs.get(item.path)
        if item.n_slices == 1:
            if item.path in out:
                continue
            synced, ef = _psum_one(
                g, axis_name, n_pods, item.compress,
                ef_leaves.get(item.path), spec,
            )
            out[item.path] = synced
            if ef is not None:
                new_ef[item.path] = ef
        else:
            # slice along axis 0: each slice is an independent "stream"
            if item.path not in out:
                out[item.path] = []  # type: ignore[assignment]
            size0 = g.shape[0] // item.n_slices
            piece = jax.lax.slice_in_dim(
                g, item.slice_idx * size0, (item.slice_idx + 1) * size0, axis=0
            )
            synced, ef = _psum_one(
                piece, axis_name, n_pods, item.compress,
                None,  # EF per-slice omitted (int8 on sliced leaves unused)
                spec,
            )
            out[item.path].append((item.slice_idx, synced))  # type: ignore

    for path, val in list(out.items()):
        if isinstance(val, list):
            pieces = [p for _, p in sorted(val, key=lambda t: t[0])]
            out[path] = jnp.concatenate(pieces, axis=0)

    # rebuild tree in original structure
    flat_paths = [p for p, _ in flatten_with_paths(grads)]
    treedef = jax.tree_util.tree_structure(grads)
    synced_tree = jax.tree_util.tree_unflatten(
        treedef, [out[p] for p in flat_paths]
    )
    ef_tree = None
    if ef_state is not None:
        ef_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(ef_state),
            [
                new_ef.get(p, ef_leaves[p])
                for p in [q for q, _ in flatten_with_paths(ef_state)]
            ],
        )
    return synced_tree, ef_tree


def naive_sync(grads: PyTree, *, axis_name: str = "pod", n_pods: int) -> PyTree:
    """Baseline: one monolithic psum per leaf, no schedule, no compression."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n_pods, grads)


# ------------------------------------------------------------------ #
# schedule-quality evaluation (discrete-event simulation on the DCN)
# ------------------------------------------------------------------ #


def simulate_sync(
    grad_shapes: PyTree,
    *,
    network: NetworkSpec = testbeds.DCN,
    algorithm: str = "promc",
    max_cc: int = 8,
    num_chunks: int = 2,
    compress_by_class: Optional[Dict[ChunkType, str]] = None,
    tick_period: float = 0.05,
) -> SimResult:
    """Score a sync schedule: simulated completion time of one gradient sync
    over the DCN (compression scales the transferred byte counts)."""
    comp = dict(
        DEFAULT_COMPRESSION if compress_by_class is None else compress_by_class
    )
    factor = {"none": 1.0, "bf16": 0.5, "int8": 0.25}
    leaves = flatten_with_paths(grad_shapes)
    plan_files = []
    # byte sizes after per-class compression, classified with the raw size
    raw = [
        (path, int(np.prod(l.shape) or 1) * l.dtype.itemsize)
        for path, l in leaves
    ]
    chunks_probe = partition_files(
        [FileSpec(p, s) for p, s in raw], network, num_chunks
    )
    class_of = {}
    for c in chunks_probe:
        for f in c.files:
            class_of[f.name] = c.ctype
    for path, size in raw:
        f = factor[comp[class_of[path]]]
        plan_files.append(FileSpec(path, max(1, int(size * f))))
    sched = build_scheduler(
        algorithm if algorithm in ("sc", "mc", "promc", "untuned", "globus")
        else "mc",
        plan_files, network, max_cc=max_cc, num_chunks=num_chunks,
    )
    sim = Simulation(
        sched.chunks, sched.network, sched, tick_period=tick_period
    )
    return sim.run()
