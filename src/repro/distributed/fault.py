"""Fault tolerance and elasticity for 1000+-node operation.

Three mechanisms, all host-side (no accelerator coupling):

1. **Heartbeats + straggler detection** — per-host step-time EWMAs; hosts
   slower than ``tau`` x the fleet median for ``patience`` consecutive
   windows are flagged. Mitigation reuses the paper's *online channel
   re-allocation* (Sec. 3.4) at pod granularity: DCN channels are moved away
   from a straggling pod's links exactly like ProMC moves channels from fast
   chunks to slow ones (the straggler's ETA is the laggard).

2. **Restart policy** — bounded retries with exponential backoff; the train
   loop resumes from the newest *complete* checkpoint (atomic index commit,
   see repro.checkpoint).

3. **Elastic re-mesh plans** — given surviving chip counts, propose degraded
   meshes (drop a pod; shrink the data axis to the largest feasible divisor)
   plus the parameter re-shard map, so the job continues at reduced width
   instead of dying.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    n: int = 0
    flags: int = 0  # consecutive slow windows


class StragglerDetector:
    """EWMA step-time tracker with median-relative flagging."""

    def __init__(self, tau: float = 1.5, patience: int = 3, alpha: float = 0.3):
        self.tau = tau
        self.patience = patience
        self.alpha = alpha
        self.hosts: Dict[str, HostStats] = {}

    def record(self, host: str, step_time: float) -> None:
        st = self.hosts.setdefault(host, HostStats())
        st.ewma = (
            step_time
            if st.n == 0
            else (1 - self.alpha) * st.ewma + self.alpha * step_time
        )
        st.n += 1

    def median(self) -> float:
        vals = sorted(s.ewma for s in self.hosts.values() if s.n > 0)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def update_flags(self) -> List[str]:
        """Call once per window; returns hosts flagged as stragglers."""
        med = self.median()
        flagged = []
        for host, st in self.hosts.items():
            if med > 0 and st.ewma > self.tau * med:
                st.flags += 1
            else:
                st.flags = 0
            if st.flags >= self.patience:
                flagged.append(host)
        return flagged


def reallocate_channels_for_straggler(
    channel_alloc: Dict[str, int], straggler: str, min_channels: int = 1
) -> Dict[str, int]:
    """Paper Sec.-3.4 re-allocation at pod granularity: move one DCN channel
    from the fastest (non-straggling) pod to each straggler's peers — i.e.
    reduce the straggler's outbound concurrency so its link stops being the
    collective critical path, handing the channel to the fastest pod."""
    alloc = dict(channel_alloc)
    if straggler not in alloc or alloc[straggler] <= min_channels:
        return alloc
    others = [h for h in alloc if h != straggler]
    if not others:
        return alloc
    fastest = max(others, key=lambda h: alloc[h])
    alloc[straggler] -= 1
    alloc[fastest] += 1
    return alloc


@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 10
    backoff_base: float = 5.0
    backoff_cap: float = 300.0
    failures: int = 0

    def next_delay(self) -> Optional[float]:
        """Seconds to wait before restarting, or None when exhausted."""
        if self.failures >= self.max_failures:
            return None
        delay = min(self.backoff_base * (2 ** self.failures), self.backoff_cap)
        self.failures += 1
        return delay

    def reset(self) -> None:
        self.failures = 0


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    chips: int
    note: str


def elastic_mesh_plans(
    n_pods: int,
    chips_per_pod: int,
    lost_pods: int = 0,
    lost_chips_in_pod: int = 0,
    model_axis: int = 16,
) -> List[MeshPlan]:
    """Degraded-mesh proposals after failures.

    The model axis is preserved (TP width changes would re-shard every
    weight); the data axis shrinks to the largest feasible size; whole-pod
    loss drops the pod axis dimension.
    """
    plans: List[MeshPlan] = []
    pods = n_pods - lost_pods
    if pods < 1:
        return plans
    chips = chips_per_pod - lost_chips_in_pod
    data = chips // model_axis
    # shrink data axis to the largest power-of-two-ish divisor that fits
    while data >= 1:
        if data * model_axis <= chips:
            shape = (pods, data, model_axis) if pods > 1 else (data, model_axis)
            axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
            plans.append(
                MeshPlan(
                    shape=shape,
                    axes=axes,
                    chips=pods * data * model_axis,
                    note=(
                        f"{pods} pod(s) x {data} data x {model_axis} model; "
                        f"global batch rescales by {data * pods}"
                    ),
                )
            )
            break
        data -= 1
    # also offer the half-width fallback (for rolling single-host failures)
    if data >= 2:
        half = data // 2
        shape = (pods, half, model_axis) if pods > 1 else (half, model_axis)
        axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
        plans.append(
            MeshPlan(
                shape=shape,
                axes=axes,
                chips=pods * half * model_axis,
                note="half-width data axis (headroom for rolling failures)",
            )
        )
    return plans
