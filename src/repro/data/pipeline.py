"""Host-side data pipeline: prefetching iterator + engine-backed ingestion.

Two layers:

* ``Prefetcher`` — a bounded background-thread prefetch queue around any
  batch iterator (keeps the host busy preparing batch N+1..N+depth while
  step N runs), with clean shutdown and exception propagation.
* ``ingest_files`` — bulk-loads a mixed-size corpus directory through the
  paper's TransferEngine (chunking + Algorithm 1 + MC/ProMC), the third
  integration point of DESIGN.md §2: shard files of wildly different sizes
  are exactly the workload the technique tunes.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import prepare_chunks, testbeds
from repro.core.engine import TransferEngine, TransferTask
from repro.core.schedulers import make_scheduler
from repro.core.types import FileSpec, NetworkSpec


class Prefetcher:
    """Wrap an iterator with a depth-bounded background prefetch thread."""

    _DONE = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def run():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # surfaced on next __next__
                self._err = e
            finally:
                self._q.put(self._DONE)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._DONE:
            if self._err is not None:
                err, self._err = self._err, None
                raise err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        # drain so the producer can observe the stop flag
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def ingest_files(
    paths: List[str],
    *,
    network: NetworkSpec = testbeds.CKPT_STORE,
    algorithm: str = "mc",
    max_cc: int = 4,
    sink: Optional[Callable[[str, bytes], None]] = None,
) -> Dict[str, bytes]:
    """Read a mixed-size file set through the scheduled transfer engine.

    Returns {path: contents} (or streams into ``sink`` when given). The
    engine tunes pipelining / striping / concurrency per size class exactly
    as it does for WAN transfers — on a parallel filesystem this is what
    keeps many-small-file ingestion from serializing on per-file latency.
    """
    specs: List[FileSpec] = []
    tasks: Dict[str, TransferTask] = {}
    out: Dict[str, bytes] = {}
    lock = threading.Lock()

    for path in paths:
        size = os.path.getsize(path)
        spec = FileSpec(name=path, size=size, path=path)
        specs.append(spec)
        buf = bytearray(size)

        def make(path=path, buf=buf):
            def read(offset: int, length: int) -> bytes:
                with open(path, "rb") as f:
                    f.seek(offset)
                    return f.read(length)

            def write(offset: int, data: bytes) -> None:
                buf[offset : offset + len(data)] = data

            def finalize(path=path, buf=buf):
                payload = bytes(buf)
                if sink is not None:
                    sink(path, payload)
                else:
                    with lock:
                        out[path] = payload

            return TransferTask(
                spec=spec, read=read, write=write, finalize=finalize
            )

        tasks[path] = make()

    chunks = prepare_chunks(specs, network, num_chunks=2, max_cc=max_cc)
    sched = make_scheduler(algorithm, chunks, network, max_cc)
    engine = TransferEngine(network, tick_period=0.05)
    report = engine.run(chunks, sched, tasks)
    if report.files_done != len(specs):
        raise IOError(f"ingested {report.files_done}/{len(specs)} files")
    return out
