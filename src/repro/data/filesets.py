"""Synthetic dataset generators matching the paper's evaluation datasets.

Figure 4  — mixed dataset for the chunk-count analysis: 1 MB..9.2 GB,
            total 300.5 GB.
Figure 8a — Dark Energy Survey: 427 files, 250..750 MB, total 212 GB.
Figure 8b — genome sequencing (Falcon on PacBio reads): ~120 K files,
            45% < 100 KB, 93% < 1 MB, a few up to 13 GB, avg ~500 KB.
Figure 8c — mixed: 6,232 files, 1 MB..5 GB, all four size classes.
Figure 12 — the mixed dataset with the small-file portion doubled.

Every generator is deterministic (seeded) and takes ``scale`` in (0, 1] that
shrinks the file COUNT while preserving the size distribution — the paper's
120 K-file genome dataset simulates fine but slowly; benchmarks default to a
reduced scale and report it.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.types import GB, KB, MB, FileSpec


def _spec_list(prefix: str, sizes: np.ndarray) -> List[FileSpec]:
    return [
        FileSpec(name=f"{prefix}/{i:06d}", size=int(max(1, s)))
        for i, s in enumerate(sizes)
    ]


def dark_energy_survey(scale: float = 1.0, seed: int = 0) -> List[FileSpec]:
    """427 files uniform in 250..750 MB, total ~212 GB (Fig. 8a)."""
    rng = np.random.RandomState(seed)
    n = max(2, int(round(427 * scale)))
    sizes = rng.uniform(250 * MB, 750 * MB, size=n)
    # normalize total to ~212 GB * scale (keeps averages paper-faithful)
    sizes *= (212 * GB * scale) / sizes.sum()
    return _spec_list("des", sizes)


def genome_sequencing(scale: float = 1.0, seed: int = 1) -> List[FileSpec]:
    """~120 K files; 45% < 100 KB, 93% < 1 MB, several files up to 13 GB,
    dataset average ~500 KB (Fig. 8b / Sec. 4.2)."""
    rng = np.random.RandomState(seed)
    n = max(20, int(round(120_000 * scale)))
    n_tiny = int(0.45 * n)  # < 100 KB
    n_small = int(0.48 * n)  # 100 KB .. 1 MB  (brings cumulative to 93%)
    n_huge = max(1, int(round(6 * scale)))  # "several large files up to 13 GB"
    n_mid = max(1, n - n_tiny - n_small - n_huge)  # 1 MB .. 8 MB assembly parts
    tiny = rng.uniform(1 * KB, 100 * KB, size=n_tiny)
    small = rng.uniform(100 * KB, 1 * MB, size=n_small)
    mid = np.exp(rng.uniform(np.log(1 * MB), np.log(8 * MB), size=n_mid))
    huge = np.exp(rng.uniform(np.log(1 * GB), np.log(13 * GB), size=n_huge))
    # keep the tail's BYTE share scale-invariant (~40% of the small/mid bytes,
    # matching the full-size dataset) so reduced-scale runs preserve the
    # throughput-relevant distribution; cap at the paper's 13 GB max.
    rest = tiny.sum() + small.sum() + mid.sum()
    huge *= 0.4 * rest / huge.sum()
    huge = np.clip(huge, 1 * MB, 13 * GB)
    sizes = np.concatenate([tiny, small, mid, huge])
    rng.shuffle(sizes)
    return _spec_list("genome", sizes)


def mixed_dataset(scale: float = 1.0, seed: int = 2) -> List[FileSpec]:
    """6,232 files, 1 MB..5 GB, all four size classes (Fig. 8c)."""
    rng = np.random.RandomState(seed)
    n = max(8, int(round(6232 * scale)))
    # four classes wrt a 10 Gbps link (thresholds 62.5 MB / 250 MB / 1250 MB)
    frac = {"small": 0.62, "medium": 0.20, "large": 0.13, "huge": 0.05}
    n_s = int(frac["small"] * n)
    n_m = int(frac["medium"] * n)
    n_l = int(frac["large"] * n)
    n_h = max(1, n - n_s - n_m - n_l)
    sizes = np.concatenate(
        [
            np.exp(rng.uniform(np.log(1 * MB), np.log(62 * MB), size=n_s)),
            rng.uniform(63 * MB, 250 * MB, size=n_m),
            rng.uniform(251 * MB, 1250 * MB, size=n_l),
            rng.uniform(1251 * MB, 5 * GB, size=n_h),
        ]
    )
    rng.shuffle(sizes)
    return _spec_list("mixed", sizes)


def small_dominated_mixed(scale: float = 1.0, seed: int = 3) -> List[FileSpec]:
    """Fig. 12: the mixed dataset with the size of small files doubled."""
    base = mixed_dataset(scale=scale, seed=seed)
    extra = [
        FileSpec(name=f.name + "+dup", size=f.size)
        for f in base
        if f.size <= 62 * MB
    ]
    return base + extra


def chunk_count_mixed(scale: float = 1.0, seed: int = 4) -> List[FileSpec]:
    """Fig. 4: 1 MB..9.2 GB mixed dataset, total 300.5 GB (chunk-count study)."""
    rng = np.random.RandomState(seed)
    n = max(16, int(round(3000 * scale)))
    sizes = np.exp(rng.uniform(np.log(1 * MB), np.log(9.2 * GB), size=n))
    sizes *= (300.5 * GB * scale) / sizes.sum()
    sizes = np.clip(sizes, 1 * MB, 9.2 * GB)
    rng.shuffle(sizes)
    return _spec_list("ccmix", sizes)


def equal_class_dataset(
    total_bytes: float, seed: int = 5, files_per_class: int = 64
) -> List[FileSpec]:
    """Fig. 7 dataset: all four classes with close-to-equal total sizes."""
    rng = np.random.RandomState(seed)
    per_class = total_bytes / 4.0
    out: List[FileSpec] = []
    ranges = {
        "small": (1 * MB, 62 * MB),
        "medium": (63 * MB, 250 * MB),
        "large": (251 * MB, 1250 * MB),
        "huge": (1251 * MB, 9 * GB),
    }
    for cls, (lo, hi) in ranges.items():
        sizes: List[float] = []
        budget = per_class
        while budget > lo:
            s = float(rng.uniform(lo, min(hi, max(lo + 1, budget))))
            sizes.append(s)
            budget -= s
            if len(sizes) >= files_per_class:
                break
        if not sizes:
            sizes = [per_class]
        out.extend(
            FileSpec(name=f"{cls}/{i:05d}", size=int(s))
            for i, s in enumerate(sizes)
        )
    return out


def heavy_tail_dataset(
    scale: float = 1.0, seed: int = 6, alpha: float = 1.1
) -> List[FileSpec]:
    """Pareto(alpha~1.1) file sizes: the classic storage-census shape where
    a handful of files carry most of the bytes while the count is dominated
    by small ones. Stresses chunk partitioning harder than the paper's
    datasets — the HUGE chunk is nearly all bytes, the SMALL chunk nearly
    all files — which is exactly where scheduler channel-allocation
    differences show up.
    """
    rng = np.random.RandomState(seed)
    n = max(12, int(round(4000 * scale)))
    sizes = 256 * KB * (1.0 + rng.pareto(alpha, size=n))
    sizes = np.clip(sizes, 64 * KB, 20 * GB)
    rng.shuffle(sizes)
    return _spec_list("htail", sizes)


def small_file_swarm(scale: float = 1.0, seed: int = 7) -> List[FileSpec]:
    """Mixed-small-file swarm: 95% of files in 32 KB..2 MB plus a thin mid
    band, no huge anchors. Per-file dead time (pipelining) dominates and
    bandwidth is nearly irrelevant — the opposite corner of the parameter
    space from ``uniform_huge``.
    """
    rng = np.random.RandomState(seed)
    n = max(20, int(round(15_000 * scale)))
    n_tiny = int(0.95 * n)
    n_mid = max(1, n - n_tiny)
    tiny = np.exp(rng.uniform(np.log(32 * KB), np.log(2 * MB), size=n_tiny))
    mid = rng.uniform(2 * MB, 48 * MB, size=n_mid)
    sizes = np.concatenate([tiny, mid])
    rng.shuffle(sizes)
    return _spec_list("swarm", sizes)


def uniform_files(n: int, size: int, prefix: str = "u") -> List[FileSpec]:
    """n equal files — used for the Fig. 1/2 single-parameter sweeps."""
    return [FileSpec(name=f"{prefix}/{i:06d}", size=size) for i in range(n)]


DATASETS = {
    "des": dark_energy_survey,
    "genome": genome_sequencing,
    "mixed": mixed_dataset,
    "small_dominated": small_dominated_mixed,
    "chunk_count_mixed": chunk_count_mixed,
    "heavy_tail": heavy_tail_dataset,
    "small_file_swarm": small_file_swarm,
}
