"""Data substrates: paper dataset generators, synthetic token pipeline."""
