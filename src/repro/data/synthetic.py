"""Deterministic synthetic token pipeline (per-host shardable).

The stream is a learnable mixture: a fixed Markov chain over the vocabulary
plus positional repetition, so small models show a clearly decreasing loss
within a few hundred steps (used by the e2e example and integration tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    order: int = 2  # markov order proxy (pattern period)


class SyntheticLM:
    """Markov-ish synthetic corpus with a fixed random transition table."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        rng = np.random.RandomState(data.seed)
        v = cfg.vocab_size
        k = min(v, 64)  # active sub-vocabulary
        self.active = rng.choice(v, size=k, replace=False)
        # each active token deterministically prefers ~3 successors
        self.next_tbl = rng.randint(0, k, size=(k, 3))
        self.k = k

    def _sequence(self, rng: np.random.RandomState, length: int) -> np.ndarray:
        k = self.k
        idx = np.empty(length, np.int64)
        cur = rng.randint(0, k)
        for t in range(length):
            idx[t] = cur
            choices = self.next_tbl[cur]
            cur = int(choices[rng.randint(0, 3)])
        return self.active[idx]

    def batches(self, n_steps: Optional[int] = None) -> Iterator[Dict]:
        d = self.data
        step = 0
        while n_steps is None or step < n_steps:
            rng = np.random.RandomState(d.seed * 100003 + step)
            toks = np.stack(
                [
                    self._sequence(rng, d.seq_len + 1)
                    for _ in range(d.global_batch)
                ]
            )
            batch = {
                "tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32),
            }
            batch.update(frontend_stubs(self.cfg, d.global_batch, seed=step))
            yield batch
            step += 1


def frontend_stubs(cfg: ModelConfig, batch: int, seed: int = 0) -> Dict:
    """Precomputed modality-frontend embeddings (the stubs the assignment
    mandates: SigLIP patches for paligemma, audio frames for whisper)."""
    out: Dict = {}
    rng = np.random.RandomState(seed + 7)
    if cfg.frontend == "vision_stub":
        out["prefix_embed"] = (
            rng.randn(batch, cfg.num_prefix_tokens, cfg.d_model).astype(
                np.float32
            )
            * 0.02
        )
    if cfg.frontend == "audio_stub":
        out["frames"] = (
            rng.randn(batch, cfg.encoder_seq, cfg.d_model).astype(np.float32)
            * 0.02
        )
    return out
