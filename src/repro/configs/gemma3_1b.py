"""gemma3-1b [hf:google/gemma-3-1b-pt]: 5:1 local:global attention, 128k ctx.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, sliding window 512,
RoPE theta 10k local / 1M global.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern="LLLLLG",
    window_size=512,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    act="gelu",
    supports_long_context=True,  # mostly-local; global layers decode linearly
)
