"""llama3.2-3b [hf:meta-llama]: small llama3 dense.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    layer_pattern="G",
    rope_theta=500_000.0,
)
