"""rwkv6-3b "Finch" [arXiv:2404.05892; hf]: attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536; WKV heads of dim 64 (40 heads).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern="W",
    glu=False,            # rwkv channel-mix is a 2-matrix squared-relu FFN
    act="relu2",
    supports_long_context=True,  # O(1) state per token
)
