"""Assigned-architecture registry: --arch <id> resolves here."""
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.phi35_moe_42b import CONFIG as PHI35_MOE_42B
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.gemma3_1b import CONFIG as GEMMA3_1B
from repro.configs.yi_9b import CONFIG as YI_9B
from repro.configs.phi4_mini_38b import CONFIG as PHI4_MINI_38B
from repro.configs.llama32_3b import CONFIG as LLAMA32_3B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE

ARCHS = {
    c.name: c
    for c in (
        DEEPSEEK_MOE_16B,
        PHI35_MOE_42B,
        PALIGEMMA_3B,
        RWKV6_3B,
        GEMMA3_1B,
        YI_9B,
        PHI4_MINI_38B,
        LLAMA32_3B,
        RECURRENTGEMMA_9B,
        WHISPER_BASE,
    )
}

#: convenient aliases used by --arch
ALIASES = {
    "deepseek-moe-16b": "deepseek-moe-16b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b",
    "paligemma-3b": "paligemma-3b",
    "rwkv6-3b": "rwkv6-3b",
    "gemma3-1b": "gemma3-1b",
    "yi-9b": "yi-9b",
    "phi4-mini-3.8b": "phi4-mini-3.8b",
    "llama3.2-3b": "llama3.2-3b",
    "recurrentgemma-9b": "recurrentgemma-9b",
    "whisper-base": "whisper-base",
}


def get_config(arch: str):
    key = ALIASES.get(arch, arch)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]
