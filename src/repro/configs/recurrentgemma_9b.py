"""recurrentgemma-9b [arXiv:2402.19427]: Griffin — RG-LRU + local attn, 1:2.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000; pattern RRL
(two recurrent blocks per local-attention block), window 2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern="RRL",
    window_size=2048,
    lru_width=4096,
    act="gelu",
    supports_long_context=True,  # bounded state + windowed attention
)
