"""deepseek-moe-16b [arXiv:2401.06066; hf]: fine-grained MoE.

28L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=102400,
2 shared + 64 routed experts, top-6 routing.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,          # expert width (fine-grained)
    d_ff_expert=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    layer_pattern="G",
    tie_embeddings=False,
)
