"""whisper-base [arXiv:2212.04356]: encoder-decoder audio transformer.

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865. The conv frontend is
a STUB: input_specs() provides 1500 precomputed frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    layer_pattern="G",
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
    act="gelu",
    glu=False,
)
