"""paligemma-3b [arXiv:2407.07726; hf]: SigLIP + gemma backbone (VLM).

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216. The SigLIP vision
tower is a STUB: input_specs() provides 256 precomputed patch embeddings
prepended to the text sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    layer_pattern="G",
    frontend="vision_stub",
    num_prefix_tokens=256,
    act="gelu",
)
