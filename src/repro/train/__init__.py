"""Training / serving loops and steps."""
