"""Fault-tolerant training loop: checkpoint/restart, heartbeats, metrics.

Single-host reference implementation of the control plane a 1000-node job
needs: periodic (async) checkpoints with atomic commit, resume from the
newest complete checkpoint after a crash, straggler detection fed by step
times, and bounded restarts with backoff. The integration test kills a run
mid-flight and verifies bit-exact resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.distributed.fault import RestartPolicy, StragglerDetector
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import StepConfig, init_train_state, make_train_step

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    async_ckpt: bool = True
    log_every: int = 10
    host: str = "host0"


def _tree_put(template: PyTree, loaded: PyTree) -> PyTree:
    """Cast restored numpy arrays back to the template's dtypes/structure."""
    return jax.tree.map(
        lambda t, l: jnp.asarray(l, dtype=t.dtype), template, loaded
    )


def train(
    model,
    step_cfg: StepConfig,
    batches: Iterator[Dict],
    loop: LoopConfig,
    seed: int = 0,
    mesh=None,
    rules=None,
    multi_pod: bool = False,
    crash_at: Optional[int] = None,  # test hook: raise at this step
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Dict[str, Any]:
    """Run (or resume) training; returns final state + history."""
    step_fn = jax.jit(
        make_train_step(model, step_cfg, mesh=mesh, rules=rules,
                        multi_pod=multi_pod)
    )
    state = init_train_state(model, jax.random.PRNGKey(seed))

    start_step = 0
    if loop.ckpt_dir:
        latest = ckpt.latest_step(loop.ckpt_dir)
        if latest is not None:
            loaded, start_step = ckpt.restore(loop.ckpt_dir)
            state = _tree_put(state, loaded)

    saver = (
        ckpt.AsyncCheckpointer(loop.ckpt_dir)
        if (loop.ckpt_dir and loop.async_ckpt)
        else None
    )
    detector = StragglerDetector()
    history: List[Dict] = []

    it = iter(batches)
    # skip consumed batches deterministically on resume
    for _ in range(start_step):
        next(it)

    for step in range(start_step, loop.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.monotonic() - t0
        detector.record(loop.host, dt)

        if crash_at is not None and step + 1 == crash_at:
            if saver:
                saver.wait()
            raise RuntimeError(f"injected crash at step {step + 1}")

        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            if saver:
                saver.save(state, step + 1)
            else:
                ckpt.save(state, loop.ckpt_dir, step + 1)

        if (step + 1) % loop.log_every == 0 or step + 1 == loop.total_steps:
            entry = {"step": step + 1, "time_s": dt, **metrics}
            history.append(entry)
            if on_metrics:
                on_metrics(step + 1, entry)

    if saver:
        saver.wait()
    if loop.ckpt_dir:
        ckpt.save(state, loop.ckpt_dir, loop.total_steps)
    return {"state": state, "history": history, "stragglers": detector}


def train_with_restarts(
    make_batches: Callable[[], Iterator[Dict]],
    run_once: Callable[[Iterator[Dict]], Dict],
    policy: Optional[RestartPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> Dict:
    """Supervisor: restart `run_once` from checkpoints until success or the
    restart budget is exhausted (backoff between attempts)."""
    policy = policy or RestartPolicy()
    while True:
        try:
            result = run_once(make_batches())
            policy.reset()
            return result
        except RuntimeError:
            delay = policy.next_delay()
            if delay is None:
                raise
            sleep(min(delay, 0.01))  # tests shrink real waiting
