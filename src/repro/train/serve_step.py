"""Serving steps: batched prefill + single-token decode under the mesh.

``decode_*`` / ``long_*`` dry-run shapes lower these (one new token against
a KV cache / recurrent state of the configured context length), not
train_step. Sampling is greedy/temperature on fp32 logits.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import use_sharding
from repro.models.model import BaseLM

PyTree = Any


def make_prefill(model: BaseLM, mesh=None, rules: Optional[dict] = None):
    def prefill(params, batch, cache):
        def run():
            return model.prefill(params, batch, cache)

        if mesh is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return prefill


def make_decode_step(model: BaseLM, mesh=None, rules: Optional[dict] = None,
                     temperature: float = 0.0):
    def decode(params, token, cache, pos, key):
        def run():
            logits, new_cache = model.decode_step(params, token, cache, pos)
            if temperature > 0:
                nxt = jax.random.categorical(key, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt.astype(jnp.int32), new_cache

        if mesh is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return decode


def generate(
    model: BaseLM,
    params: PyTree,
    prompt: jax.Array,
    max_new_tokens: int,
    max_len: Optional[int] = None,
    extra_batch: Optional[dict] = None,
    temperature: float = 0.0,
    seed: int = 0,
) -> jax.Array:
    """Convenience host-loop generation (examples / tests; not the perf path)."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new_tokens)
    prefix = getattr(model.cfg, "num_prefix_tokens", 0) or 0
    if model.cfg.frontend != "vision_stub":
        prefix = 0
    cache = model.init_cache(b, max_len + prefix)
    batch = {"tokens": prompt, **(extra_batch or {})}
    prefill = jax.jit(make_prefill(model))
    decode = jax.jit(make_decode_step(model, temperature=temperature))
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out = [tok]
    key = jax.random.PRNGKey(seed)
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        tok, cache = decode(params, tok, cache, jnp.int32(prefix + s + i), sub)
        out.append(tok)
    return jnp.stack(out, axis=1)
