"""Train step assembly: loss -> grads -> (chunk-scheduled DCN sync) -> AdamW.

Two lowering modes share all model/optimizer code:

  * single-pod mesh ("data", "model"): plain pjit; XLA inserts the in-pod
    gradient reduce-scatters implied by the FSDP parameter sharding.
  * multi-pod mesh ("pod", "data", "model"): the step body runs inside
    ``jax.shard_map`` *manual over the pod axis only*. Params are made
    pod-varying (``pcast``) before differentiation so gradients come back
    pod-local, and the cross-pod synchronization is executed explicitly by
    the paper-scheduled ``grad_sync.apply_sync`` plan — every bucket/slice is
    a separate all-reduce over "pod" in the lowered HLO.

The sharding context (logical-axis rules) opens inside the step so the model
annotations resolve at trace time on any mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import grad_sync
from repro.distributed.sharding import mark_varying, shard_map_compat, use_sharding
from repro.models.model import BaseLM
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

PyTree = Any


def _pod_vary(tree: PyTree) -> PyTree:
    """Mark params as pod-varying so grads are pod-local (we own the sync)."""
    return jax.tree.map(lambda x: mark_varying(x, "pod"), tree)


@dataclasses.dataclass(frozen=True)
class StepConfig:
    optimizer: AdamWConfig = AdamWConfig()
    sync_algorithm: str = "promc"  # sc | mc | promc | naive
    sync_max_cc: int = 8
    sync_num_chunks: int = 2
    compress: bool = True  # per-class DCN compression (beyond-paper)
    #: codec for the bandwidth-bound (Medium+) chunk classes: "bf16" or
    #: "int8" (int8 travels as all-gather + local dequant-sum; pair with
    #: error feedback for long runs)
    compress_codec: str = "bf16"
    #: gradient-accumulation microbatches per step (1 = off). Activation
    #: and logits temporaries scale ~1/accum_steps.
    accum_steps: int = 1
    #: perf mode (§Perf iteration 1): differentiate w.r.t. a bf16 TP-only
    #: compute copy of the weights gathered ONCE per step outside the
    #: microbatch loop (instead of FSDP re-gathering every layer's weights
    #: on every microbatch, fwd AND bwd — the dominant ICI term of every
    #: baseline train cell). Gradients keep FSDP layout via a constrained
    #: accumulator (one grad-sized reduce-scatter per microbatch). Requires
    #: the bf16 TP-only weight copy to fit HBM.
    gather_once: bool = False


def init_train_state(model: BaseLM, key) -> Dict[str, PyTree]:
    params = model.init(key)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    model: BaseLM,
    cfg: StepConfig,
    mesh=None,
    rules: Optional[dict] = None,
    multi_pod: bool = False,
):
    """Returns step(state, batch) -> (state, metrics). Jit/lower it under
    ``jax.set_mesh(mesh)`` (the launcher does)."""

    def _spec_trees(manual_axes):
        """(fsdp specs, TP-only gathered specs) for the param tree."""
        from repro.distributed.sharding import (
            DEFAULT_RULES,
            ShardingCtx,
            param_pspecs,
        )

        merged = dict(DEFAULT_RULES)
        if rules:
            merged.update(rules)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        fsdp = param_pspecs(
            shapes, ShardingCtx(mesh=mesh, rules=merged,
                                manual_axes=manual_axes)
        )
        gathered_rules = dict(merged)
        gathered_rules["p_embed"] = None  # kill the FSDP axis
        gath = param_pspecs(
            shapes, ShardingCtx(mesh=mesh, rules=gathered_rules,
                                manual_axes=manual_axes)
        )
        return fsdp, gath

    def _core(state, batch, manual_axes: frozenset, n_pods: int):
        def run():
            params = state["params"]
            if n_pods > 1:
                params = _pod_vary(params)

            fsdp_specs = None
            if cfg.gather_once:
                if mesh is None:
                    raise ValueError("gather_once requires a mesh")
                fsdp_specs, gath_specs = _spec_trees(manual_axes)
                # ONE all-gather per step: bf16 TP-only compute copy, hoisted
                # outside the microbatch loop (it is loop-invariant); the
                # backward pass re-reads this resident buffer instead of
                # re-gathering per microbatch.
                params = jax.tree.map(
                    lambda p, sp: jax.lax.with_sharding_constraint(
                        p.astype(jnp.bfloat16), sp
                    ),
                    params, gath_specs,
                )

            def loss_fn(p, b):
                loss, metrics = model.loss(p, b)
                return loss, metrics

            k = cfg.accum_steps
            if k <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, batch)
            else:
                # microbatched gradient accumulation: (B, ...) -> (k, B/k, ...)
                def split(x):
                    return x.reshape((k, x.shape[0] // k) + x.shape[1:])

                micro = jax.tree.map(split, batch)

                def acc(carry, mb):
                    c_loss, c_metrics, c_grads = carry
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb
                    )
                    if fsdp_specs is not None:
                        # keep the accumulator in FSDP layout: the add forces
                        # one grad-sized reduce-scatter per microbatch (vs.
                        # per-microbatch weight gathers in the baseline)
                        g_acc = jax.tree.map(
                            lambda a, x, sp: jax.lax.with_sharding_constraint(
                                a + x.astype(jnp.float32), sp
                            ),
                            c_grads, g, fsdp_specs,
                        )
                    else:
                        g_acc = jax.tree.map(jnp.add, c_grads, g)
                    return (
                        c_loss + l,
                        jax.tree.map(jnp.add, c_metrics, m),
                        g_acc,
                    ), None

                zero_metrics = jax.eval_shape(
                    lambda: loss_fn(params, jax.tree.map(lambda x: x[0], micro))
                )[1]
                init = (
                    jnp.float32(0.0),
                    jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 zero_metrics),
                    jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params
                    ),
                )
                if fsdp_specs is not None:
                    init = (
                        init[0], init[1],
                        jax.tree.map(
                            lambda z, sp: jax.lax.with_sharding_constraint(
                                z, sp
                            ),
                            init[2], fsdp_specs,
                        ),
                    )
                if n_pods > 1:
                    # the accumulated grads are pod-varying; the scan carry
                    # must start with matching varying-axes types
                    init = _pod_vary(init)
                (loss, metrics, grads), _ = jax.lax.scan(acc, init, micro)
                loss = loss / k
                metrics = jax.tree.map(lambda m: m / k, metrics)
                grads = jax.tree.map(lambda g: g / k, grads)

            if n_pods > 1:
                if cfg.sync_algorithm == "naive":
                    grads = grad_sync.naive_sync(
                        grads, axis_name="pod", n_pods=n_pods
                    )
                else:
                    shapes = jax.tree.map(
                        lambda g: jax.ShapeDtypeStruct(g.shape, g.dtype), grads
                    )
                    if not cfg.compress:
                        cbc = grad_sync.NO_COMPRESSION
                    elif cfg.compress_codec == "int8":
                        cbc = {
                            t: ("int8" if c != "none" else "none")
                            for t, c in grad_sync.DEFAULT_COMPRESSION.items()
                        }
                    else:
                        cbc = None
                    plan = grad_sync.build_sync_plan(
                        shapes,
                        max_cc=cfg.sync_max_cc,
                        num_chunks=cfg.sync_num_chunks,
                        algorithm=cfg.sync_algorithm,
                        compress_by_class=cbc,
                    )
                    spec_tree = None
                    if mesh is not None:
                        from repro.distributed.sharding import (
                            ShardingCtx,
                            DEFAULT_RULES,
                            param_pspecs,
                        )

                        merged = dict(DEFAULT_RULES)
                        if rules:
                            merged.update(rules)
                        ctx = ShardingCtx(
                            mesh=mesh, rules=merged,
                            manual_axes=frozenset({"pod"}),
                        )
                        spec_tree = param_pspecs(shapes, ctx)
                    grads, _ = grad_sync.apply_sync(
                        plan, grads, axis_name="pod", n_pods=n_pods,
                        spec_tree=spec_tree,
                    )
                loss = jax.lax.psum(loss, "pod") / n_pods
                metrics = jax.tree.map(
                    lambda m: jax.lax.psum(m, "pod") / n_pods, metrics
                )

            new_params, new_opt, opt_metrics = adamw_update(
                cfg.optimizer, state["params"], grads, state["opt"]
            )
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
            }
            out_metrics = {"loss": loss, **metrics, **opt_metrics}
            return new_state, out_metrics

        if mesh is not None:
            with use_sharding(mesh, rules, manual_axes=manual_axes):
                return run()
        return run()

    if multi_pod:
        if mesh is None:
            raise ValueError("multi_pod requires a mesh")
        n_pods = mesh.shape["pod"]
        inner = partial(_core, manual_axes=frozenset({"pod"}), n_pods=n_pods)
        step = shard_map_compat(
            inner,
            mesh=mesh,
            in_specs=(P(), P("pod")),
            out_specs=(P(), P()),
            manual_axes={"pod"},
        )
        return step
    return partial(_core, manual_axes=frozenset(), n_pods=1)


def make_eval_step(model: BaseLM, mesh=None, rules: Optional[dict] = None):
    def step(params, batch):
        def run():
            loss, metrics = model.loss(params, batch)
            return {"loss": loss, **metrics}

        if mesh is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return step
