"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they execute in interpret mode, which runs the kernel body
in Python — bit-for-bit the same program the TPU would trace. Models call
these wrappers; the pure-jnp oracles live in ``ref.py``.

Model-facing signatures use model layouts and adapt to kernel layouts here.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _wk
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Model layout: q (B, S, H, Dh); k, v (B, T, KV, Dh) -> (B, S, H, Dh)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(
        qt, kt, vt,
        causal=causal, window=window, logit_softcap=logit_softcap,
        interpret=_interpret(),
    )
    return jnp.swapaxes(out, 1, 2)


def rwkv6_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
    u: jax.Array, s0: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Model layout: r/k/v/w (B, T, H, D); u (H, D); s0 (B, H, D, D)."""
    args = [jnp.moveaxis(x, 1, 2) for x in (r, k, v, w)]
    y, sfin = _wk.rwkv6_scan(*args, u, s0, interpret=_interpret())
    return jnp.moveaxis(y, 2, 1), sfin


def rglru_scan(
    a: jax.Array, x: jax.Array, h0: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """a, x (B, T, W); h0 (B, W)."""
    return _rg.rglru_scan(a, x, h0, interpret=_interpret())


REF = {
    "flash_attention": ref.flash_attention_ref,
    "rwkv6_scan": ref.rwkv6_scan_ref,
    "rglru_scan": ref.rglru_scan_ref,
}
