"""RG-LRU diagonal linear recurrence kernel for TPU (Pallas).

    h_t = a_t * h_{t-1} + x_t          (elementwise over width W)

Blocked over (batch, width, time): width tiles map to VPU lanes; the carried
state for each (b, width-tile) lives in VMEM scratch across the sequential
innermost time-chunk grid dimension. Within a chunk, a log2(Ct)-depth Blelloch
composition would also work; the fori_loop form keeps VMEM traffic minimal
and is exact.

Layouts: a, x (B, T, W); h0 (B, W). Grid (B, W/Wb, T/Ct), time innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    a_ref, x_ref, h0_ref, y_ref, hfin_ref, h_scratch,
    *, chunk: int, n_chunks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)  # (1, Wb)

    a = a_ref[0].astype(jnp.float32)  # (Ct, Wb)
    x = x_ref[0].astype(jnp.float32)

    def step(t, ys):
        a_t = jax.lax.dynamic_slice_in_dim(a, t, 1, 0)  # (1, Wb)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)
        h = a_t * h_scratch[...] + x_t
        h_scratch[...] = h
        return jax.lax.dynamic_update_slice_in_dim(ys, h, t, 0)

    ys = jax.lax.fori_loop(
        0, chunk, step, jnp.zeros((chunk, a.shape[1]), jnp.float32)
    )
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ti == n_chunks - 1)
    def _fin():
        hfin_ref[...] = h_scratch[...].astype(hfin_ref.dtype)


def _largest_divisor(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(
    a: jax.Array,
    x: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 256,
    block_w: int = 512,
    interpret: bool = False,
):
    """a, x: (B, T, W); h0: (B, W) -> (h (B,T,W) fp32, h_T (B,W) fp32)."""
    b, t, w = a.shape
    ct = _largest_divisor(t, chunk)
    wb = _largest_divisor(w, block_w)
    n_chunks = t // ct
    kernel = functools.partial(_rglru_kernel, chunk=ct, n_chunks=n_chunks)
    h, hfin = pl.pallas_call(
        kernel,
        grid=(b, w // wb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ct, wb), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, ct, wb), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, wb), lambda bi, wi, ti: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, wb), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, wb), lambda bi, wi, ti: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, wb), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
    return h, hfin
