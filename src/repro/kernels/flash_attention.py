"""Blocked FlashAttention forward for TPU (Pallas): GQA + causal + sliding
window + logit softcap.

TPU-native design (vs. the CUDA original): the kernel exploits the
*sequential-minor* TPU grid — the key-block index is the innermost grid
dimension, so the online-softmax accumulators (m, l, acc) live in VMEM
scratch that persists across key blocks for a given query block; no atomics
or inter-core reduction needed. Block shapes keep the MXU busy ((block, 128+)
matmuls) and the working set in VMEM:

    q blk (Bq, D) + k/v blks (Bk, D) + acc (Bq, D) fp32
    ~ (512x128 + 2*512x128 + 512x128*4) * 2B  ~= 0.6 MB << 16 MB VMEM.

Fully-masked (q-block, k-block) pairs (beyond causal diagonal / outside the
sliding window) are skipped via @pl.when — with a 1024-token window at 32k
sequence, ~97% of key blocks are skipped.

Layouts: q (B, H, S, D); k, v (B, KV, T, D). Grid (B*KV, G, S/Bq, T/Bk).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,  # blocks
    m_ref, l_ref, acc_ref,  # VMEM scratch, persistent over k blocks
    *,
    block_q: int,
    block_k: int,
    n_kblocks: int,
    causal: bool,
    window: Optional[int],
    logit_softcap: float,
    scale: float,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    q_start = qi * block_q
    k_start = kj * block_k

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- block-level relevance (static masks use runtime block ids) ---
    q_last = q_start + block_q - 1
    k_first = k_start
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_first <= q_last
    if window is not None:
        # earliest key any query in this block may see: q_start - window + 1
        k_last = k_start + block_k - 1
        relevant &= k_last > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (Bq, Bk)
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m == -inf): exp(NEG_INF - NEG_INF) = 1
        # would pollute l; clamp the shift argument instead.
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == n_kblocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked query rows -> zeros
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def _largest_divisor(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, T, D); H % KV == 0. -> (B, H, S, D)."""
    b, h, s, d = q.shape
    _, kv, t, _ = k.shape
    if h % kv:
        raise ValueError(f"H={h} not divisible by KV={kv}")
    g = h // kv
    bq = _largest_divisor(s, block_q)
    bk = _largest_divisor(t, block_k)
    n_kblocks = t // bk

    qr = q.reshape(b, kv, g, s, d).reshape(b * kv, g, s, d)
    kr = k.reshape(b * kv, 1, t, d)
    vr = v.reshape(b * kv, 1, t, d)

    kernel = functools.partial(
        _flash_kernel,
        block_q=bq, block_k=bk, n_kblocks=n_kblocks,
        causal=causal, window=window, logit_softcap=logit_softcap,
        scale=1.0 / (d ** 0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, g, s // bq, n_kblocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bh, gi, qi, kj: (bh, gi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bh, gi, qi, kj: (bh, 0, kj, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bh, gi, qi, kj: (bh, 0, kj, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d), lambda bh, gi, qi, kj: (bh, gi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, kv, g, s, d).reshape(b, h, s, d)
